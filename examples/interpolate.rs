//! Fig. 6 reproduction: spherical interpolation in x_T decoded by the
//! deterministic DDIM process (dim(τ)=50 like the paper). Writes
//! `out/interpolate.pgm` (one row per latent pair, 11 interpolants) and
//! prints the path-smoothness metric vs a DDPM control.
//!
//! Flags: --artifacts DIR --dataset NAME --steps S --pairs N --seed K

use ddim_serve::cli::Args;
use ddim_serve::eval::path_smoothness;
use ddim_serve::rng::{slerp, GaussianSource};
use ddim_serve::runtime::Runtime;
use ddim_serve::sampler::BatchRunner;
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};
use ddim_serve::tensor::{save_pgm, tile_grid};

const ALPHAS: usize = 11;

fn main() -> ddim_serve::Result<()> {
    let args = Args::from_env()?;
    let dataset = args.get_or("dataset", "blobs").to_string();
    let steps = args.get_usize("steps", 50)?;
    let pairs = args.get_usize("pairs", 4)?;
    let seed = args.get_u64("seed", 3)?;

    let mut rt = Runtime::load(args.get_or("artifacts", "artifacts"))?;
    let dim = rt.manifest().sample_dim();
    let img = rt.manifest().img;
    let plan = SamplePlan::generate(rt.alphas(), TauKind::Linear, steps, NoiseMode::Eta(0.0))?;
    let mut runner = BatchRunner::new(&rt, &dataset, 16)?;

    // latent pairs + slerp paths
    let mut g = GaussianSource::seeded(seed);
    let mut latents: Vec<Vec<f32>> = Vec::new();
    for _ in 0..pairs {
        let a = g.vec(dim);
        let b = g.vec(dim);
        for k in 0..ALPHAS {
            latents.push(slerp(&a, &b, k as f64 / (ALPHAS - 1) as f64));
        }
    }
    println!(
        "decoding {} latents (S={steps}, DDIM) on dataset {dataset}...",
        latents.len()
    );
    let t0 = std::time::Instant::now();
    let images = runner.run_from(&mut rt, &plan, latents, 0)?;
    println!("decoded in {:.1}s ({} executable calls)", t0.elapsed().as_secs_f64(), runner.calls);

    // smoothness per pair
    let mut worst = 0.0f64;
    for p in 0..pairs {
        let path = &images[p * ALPHAS..(p + 1) * ALPHAS];
        let (max_jump, mean_jump) = path_smoothness(path);
        println!(
            "pair {p}: max adjacent feature jump / endpoint = {max_jump:.3}, mean = {mean_jump:.3} (1/{}={:.3} is perfectly even)",
            ALPHAS - 1,
            1.0 / (ALPHAS - 1) as f64
        );
        worst = worst.max(max_jump);
    }

    let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
    let grid = tile_grid(&refs, pairs, ALPHAS, img, img)?;
    save_pgm("out/interpolate.pgm", &grid)?;
    println!("grid written to out/interpolate.pgm (rows = pairs, cols = alpha 0..1)");
    println!("worst max-jump ratio: {worst:.3} (paper's qualitative claim: smooth morphs, no abrupt switches)");
    Ok(())
}
