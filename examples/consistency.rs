//! Fig. 5 reproduction: decode the *same* x_T with trajectories of different
//! lengths. Under DDIM the results share high-level features; under DDPM
//! they don't. Writes `out/consistency_{ddim,ddpm}.pgm` (rows = different
//! x_T, cols = S ∈ {5,10,20,50,100}) and prints the consistency ratio.
//!
//! Flags: --artifacts DIR --dataset NAME --count N --seed K

use ddim_serve::cli::Args;
use ddim_serve::eval::consistency_score;
use ddim_serve::rng::GaussianSource;
use ddim_serve::runtime::Runtime;
use ddim_serve::sampler::BatchRunner;
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};
use ddim_serve::tensor::{save_pgm, tile_grid};

const S_LIST: [usize; 5] = [5, 10, 20, 50, 100];

fn main() -> ddim_serve::Result<()> {
    let args = Args::from_env()?;
    let dataset = args.get_or("dataset", "sprites").to_string();
    let count = args.get_usize("count", 6)?;
    let seed = args.get_u64("seed", 11)?;

    let mut rt = Runtime::load(args.get_or("artifacts", "artifacts"))?;
    let dim = rt.manifest().sample_dim();
    let img = rt.manifest().img;
    let mut runner = BatchRunner::new(&rt, &dataset, 16)?;

    // fixed latents shared across all trajectory lengths
    let mut g = GaussianSource::seeded(seed);
    let latents: Vec<Vec<f32>> = (0..count).map(|_| g.vec(dim)).collect();

    for (label, mode) in [("ddim", NoiseMode::Eta(0.0)), ("ddpm", NoiseMode::Eta(1.0))] {
        let mut per_s: Vec<Vec<Vec<f32>>> = Vec::new();
        for s in S_LIST {
            let plan = SamplePlan::generate(rt.alphas(), TauKind::Linear, s, mode)?;
            per_s.push(runner.run_from(&mut rt, &plan, latents.clone(), 1234)?);
        }
        // consistency of every shorter trajectory vs the longest (S=100)
        let longest = per_s.last().unwrap();
        println!("--- {label} ---");
        for (i, s) in S_LIST.iter().enumerate().take(S_LIST.len() - 1) {
            let (same, cross, ratio) = consistency_score(&per_s[i], longest);
            println!(
                "S={s:<4} vs S=100: same-x_T dist {same:.3}, cross-x_T dist {cross:.3}, ratio {ratio:.3}"
            );
        }
        // grid: rows = latents, cols = S values
        let mut cells: Vec<&[f32]> = Vec::new();
        for r in 0..count {
            for sidx in 0..S_LIST.len() {
                cells.push(&per_s[sidx][r]);
            }
        }
        let grid = tile_grid(&cells, count, S_LIST.len(), img, img)?;
        let path = format!("out/consistency_{label}.pgm");
        save_pgm(&path, &grid)?;
        println!("grid -> {path} (rows: x_T seeds, cols: S = {S_LIST:?})");
    }
    println!("\npaper's claim: DDIM ratios well below 1 (same x_T -> same features irrespective of S); DDPM ratios near 1.");
    Ok(())
}
