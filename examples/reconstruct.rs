//! Sec. 5.4 / Table 2 demo: encode real (procedural) images to x_T with the
//! reverse ODE, decode them back, and print the per-dimension MSE for a few
//! S values — the error should fall as S grows. Writes a side-by-side
//! original/reconstruction strip to `out/reconstruct.pgm`.
//!
//! Flags: --artifacts DIR --dataset NAME --count N

use ddim_serve::cli::Args;
use ddim_serve::eval::per_dim_mse;
use ddim_serve::runtime::Runtime;
use ddim_serve::sampler::BatchRunner;
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};
use ddim_serve::tensor::{save_pgm, tile_grid};

fn main() -> ddim_serve::Result<()> {
    let args = Args::from_env()?;
    let dataset = args.get_or("dataset", "sprites").to_string();
    let count = args.get_usize("count", 8)?;

    let mut rt = Runtime::load(args.get_or("artifacts", "artifacts"))?;
    let img = rt.manifest().img;
    let mut runner = BatchRunner::new(&rt, &dataset, 16)?;

    // "real" inputs: deterministic DDIM samples (clean members of the model's
    // data manifold, like the paper's test-set images are for its model)
    let gen20 = SamplePlan::generate(rt.alphas(), TauKind::Linear, 20, NoiseMode::Eta(0.0))?;
    let originals = runner.generate(&mut rt, &gen20, count, 99)?;

    let mut last_recon: Vec<Vec<f32>> = Vec::new();
    println!("S     per-dim MSE ([0,1] scale)");
    for s in [5usize, 10, 20, 50, 100] {
        let enc = SamplePlan::encode(rt.alphas(), TauKind::Linear, s)?;
        let dec = SamplePlan::generate(rt.alphas(), TauKind::Linear, s, NoiseMode::Eta(0.0))?;
        let latents = runner.run_from(&mut rt, &enc, originals.clone(), 0)?;
        let recons = runner.run_from(&mut rt, &dec, latents, 0)?;
        let mse = per_dim_mse(&originals, &recons)?;
        println!("{s:<5} {mse:.6}");
        last_recon = recons;
    }

    // strip: originals on top, S=100 reconstructions below
    let mut rows: Vec<&[f32]> = originals.iter().map(|v| v.as_slice()).collect();
    rows.extend(last_recon.iter().map(|v| v.as_slice()));
    let grid = tile_grid(&rows, 2, count, img, img)?;
    save_pgm("out/reconstruct.pgm", &grid)?;
    println!("originals (top) vs S=100 reconstructions (bottom) -> out/reconstruct.pgm");
    Ok(())
}
