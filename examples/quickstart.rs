//! Quickstart: load the artifacts, generate a 4×4 grid of DDIM samples in
//! 20 steps, and write it to `out/quickstart.pgm`.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Flags: --artifacts DIR --dataset NAME --steps S --eta E --seed K

use ddim_serve::cli::Args;
use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::request::{Request, RequestBody};
use ddim_serve::coordinator::{Engine, ResponseBody};
use ddim_serve::sampler::SamplerKind;
use ddim_serve::schedule::{NoiseMode, TauKind};
use ddim_serve::tensor::{save_pgm, tile_grid};

fn main() -> ddim_serve::Result<()> {
    let args = Args::from_env()?;
    let dataset = args.get_or("dataset", "sprites").to_string();
    let steps = args.get_usize("steps", 20)?;
    let mode = NoiseMode::parse(args.get_or("eta", "0.0"))?;
    let seed = args.get_u64("seed", 7)?;

    let cfg = ServeConfig {
        artifact_root: args.get_or("artifacts", "artifacts").to_string(),
        dataset: dataset.clone(),
        ..Default::default()
    };
    println!("loading artifacts from {} ...", cfg.artifact_root);
    let mut engine = Engine::new(cfg)?;

    let t0 = std::time::Instant::now();
    let id = engine.submit(Request {
        dataset,
        steps,
        mode,
        tau: TauKind::Quadratic,
        sampler: SamplerKind::parse(args.get_or("sampler", "ddim"))?,
        body: RequestBody::Generate { count: 16, seed },
        return_images: true,
        cache: ddim_serve::coordinator::CacheMode::Use,
        qos: Default::default(),
    })?;
    let responses = engine.run_until_idle()?;
    let resp = responses.iter().find(|r| r.id == id).unwrap();
    let images = match &resp.body {
        ResponseBody::Ok { outputs } => outputs,
        other => {
            return Err(ddim_serve::Error::Coordinator(format!("generation failed: {other:?}")))
        }
    };

    let img = engine.manifest().img;
    let refs: Vec<&[f32]> = images.iter().map(|v| v.as_slice()).collect();
    let grid = tile_grid(&refs, 4, 4, img, img)?;
    save_pgm("out/quickstart.pgm", &grid)?;
    println!(
        "16 samples (S={steps}, {}) in {:.2}s -> out/quickstart.pgm",
        mode.label(),
        t0.elapsed().as_secs_f64()
    );
    println!("engine: {}", engine.metrics().summary());
    Ok(())
}
