//! End-to-end serving driver (the DESIGN.md §4 validation run): start the
//! full stack — TCP server, shard pool, continuous batcher, sample cache —
//! and fire an open-loop Poisson workload of mixed requests at it from
//! concurrent client connections. Reports client-side latency percentiles,
//! server-side metrics, batch occupancy, and cache effectiveness.
//!
//!     cargo run --release --example serve_e2e -- --requests 60 --rate 4
//!     cargo run --release --example serve_e2e -- --requests 120 --rate 20 \
//!         --seed-pool 8 --zipf 1.1          # Zipf-hot: exercises the cache
//!
//! Flags: --artifacts DIR --dataset NAME --requests N --rate HZ --seed K
//!        --seed-pool N (0 = every request unique / cache-cold)
//!        --zipf S (popularity skew of the seed pool; default 1.1)
//!        --cache on|off --coalesce on|off
//!        --access-log PATH (structured access log + 1/8 span sampling;
//!        the run tails the log and prints a Prometheus scrape excerpt
//!        — see docs/observability.md)

use std::sync::mpsc;
use std::time::{Duration, Instant};

use ddim_serve::artifacts::Manifest;
use ddim_serve::cli::Args;
use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::server::Client;
use ddim_serve::coordinator::{Histogram, RequestBody, Server};
use ddim_serve::jobj;
use ddim_serve::json::Value;
use ddim_serve::schedule::NoiseMode;
use ddim_serve::workload::Workload;

/// Wire form of a workload request (all three body kinds).
fn request_json(req: &ddim_serve::coordinator::Request) -> Value {
    let eta = match req.mode {
        NoiseMode::Eta(e) => Value::Num(e),
        NoiseMode::SigmaHat => Value::Str("hat".into()),
    };
    let rows_json = |rows: &[Vec<f32>]| {
        Value::Arr(
            rows.iter()
                .map(|r| Value::Arr(r.iter().map(|&x| Value::Num(x as f64)).collect()))
                .collect(),
        )
    };
    match &req.body {
        RequestBody::Generate { count, seed } => jobj![
            ("op", "generate"),
            ("dataset", req.dataset.as_str()),
            ("steps", req.steps),
            ("eta", eta),
            ("sampler", req.sampler.label()),
            ("count", *count),
            ("seed", *seed),
        ],
        RequestBody::Decode { latents } => jobj![
            ("op", "decode"),
            ("dataset", req.dataset.as_str()),
            ("steps", req.steps),
            ("eta", eta),
            ("sampler", req.sampler.label()),
            ("latents", rows_json(latents)),
        ],
        RequestBody::Encode { images } => jobj![
            ("op", "encode"),
            ("dataset", req.dataset.as_str()),
            ("steps", req.steps),
            ("sampler", req.sampler.label()),
            ("images", rows_json(images)),
        ],
    }
}

fn main() -> ddim_serve::Result<()> {
    let args = Args::from_env()?;
    let dataset = args.get_or("dataset", "sprites").to_string();
    let n_requests = args.get_usize("requests", 60)?;
    let rate = args.get_f64("rate", 4.0)?;
    let seed = args.get_u64("seed", 1)?;
    let pool = args.get_usize("seed-pool", 0)?;
    let zipf_s = args.get_f64("zipf", 1.1)?;

    let artifact_root = args.get_or("artifacts", "artifacts").to_string();
    let mut cfg = ServeConfig {
        artifact_root: artifact_root.clone(),
        dataset: dataset.clone(),
        listen: "127.0.0.1:0".into(),
        max_batch: 16,
        max_lanes: 64,
        queue_capacity: 256,
        ..Default::default()
    };
    if let Some(v) = args.get("cache") {
        cfg.cache_enabled = ddim_serve::cli::parse_on_off("cache", v)?;
    }
    if let Some(v) = args.get("coalesce") {
        cfg.coalesce_enabled = ddim_serve::cli::parse_on_off("coalesce", v)?;
    }
    let access_log = args.get("access-log").map(str::to_string);
    if let Some(path) = &access_log {
        cfg.access_log = path.clone();
        cfg.trace_sample = 8; // every 8th request gets stage spans in the log
    }
    println!("starting server (compiling executables)...");
    let t_start = Instant::now();
    let server = Server::start(cfg)?;
    let addr = server.addr();
    println!("server up on {addr} in {:.1}s", t_start.elapsed().as_secs_f64());

    // Build the open-loop workload: mixed S/eta/count/body classes at
    // `rate` Hz. With a seed pool, identities are Zipf-hot and the
    // decode/encode bodies are materialised from the model's sample_dim.
    let workload = if pool > 0 {
        let dim = Manifest::load(&artifact_root)?.sample_dim();
        Workload::zipf(&dataset, rate, dim, pool, zipf_s)
    } else {
        Workload::standard(&dataset, rate)
    };
    let plan = workload.generate(n_requests, seed);
    println!(
        "workload: {n_requests} requests over {:.1}s ({} classes, {}, open loop)",
        plan.last().map(|(t, _)| *t).unwrap_or(0.0),
        workload.classes.len(),
        if pool > 0 {
            format!("Zipf({zipf_s}) pool of {pool}")
        } else {
            "unique identities".into()
        }
    );

    // Replay: one thread per request (arrival-time-faithful), results back
    // over a channel: (index, latency, ok, requested steps, cached).
    let (tx, rx) = mpsc::channel::<(usize, f64, bool, usize, bool)>();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (i, (arrival, req)) in plan.into_iter().enumerate() {
        let tx = tx.clone();
        let line = request_json(&req);
        let steps_requested = req.steps * req.lane_count();
        handles.push(std::thread::spawn(move || {
            // open loop: wait until this request's arrival time
            let now = t0.elapsed().as_secs_f64();
            if arrival > now {
                std::thread::sleep(Duration::from_secs_f64(arrival - now));
            }
            let sent = Instant::now();
            let (ok, cached) = (|| -> ddim_serve::Result<(bool, bool)> {
                let mut c = Client::connect(addr)?;
                let resp = c.roundtrip(&line)?;
                let ok =
                    resp.get("ok").ok().and_then(|v| v.as_bool().ok()).unwrap_or(false);
                let cached = resp
                    .get_opt("cached")
                    .and_then(|v| v.as_bool().ok())
                    .unwrap_or(false);
                Ok((ok, cached))
            })()
            .unwrap_or((false, false));
            let _ = tx.send((i, sent.elapsed().as_secs_f64(), ok, steps_requested, cached));
        }));
    }
    drop(tx);

    let mut hist = Histogram::new();
    let mut failures = 0usize;
    let mut total_steps = 0usize;
    let mut client_cached = 0usize;
    let mut done = 0usize;
    for (_, latency, ok, steps, cached) in rx {
        if ok {
            hist.record(latency);
            total_steps += steps;
            client_cached += cached as usize;
        } else {
            failures += 1;
        }
        done += 1;
        if done % 20 == 0 {
            println!("  {done}/{n_requests} done");
        }
    }
    for h in handles {
        let _ = h.join();
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== serve_e2e results ===");
    println!("requests     : {n_requests} ({failures} failed, {client_cached} served from cache)");
    println!("wall time    : {wall:.2}s");
    println!(
        "throughput   : {:.2} req/s, {:.1} requested model-steps/s",
        (n_requests - failures) as f64 / wall,
        total_steps as f64 / wall
    );
    println!(
        "client latency: p50 {:.0}ms  p95 {:.0}ms  p99 {:.0}ms  mean {:.0}ms  max {:.0}ms",
        hist.quantile(0.5) * 1e3,
        hist.quantile(0.95) * 1e3,
        hist.quantile(0.99) * 1e3,
        hist.mean() * 1e3,
        hist.max() * 1e3,
    );

    // server-side view
    let mut c = Client::connect(addr)?;
    let m = c.roundtrip(&jobj![("op", "metrics")])?;
    let get = |k: &str| m.get(k).ok().and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
    println!(
        "server metrics: calls={} steps={} occupancy={:.2} p50={:.0}ms p95={:.0}ms rejected={}",
        get("executable_calls"),
        get("steps_executed"),
        get("occupancy"),
        get("latency_p50_s") * 1e3,
        get("latency_p95_s") * 1e3,
        get("requests_rejected"),
    );
    if let Ok(cache) = m.get("cache") {
        let cget = |k: &str| cache.get(k).ok().and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
        println!(
            "cache metrics : hits={} misses={} hit_rate={:.2} coalesced={} evictions={} bytes={}",
            cget("hits"),
            cget("misses"),
            cget("hit_rate"),
            cget("coalesced_waiters"),
            cget("evictions"),
            cget("bytes"),
        );
    }
    if access_log.is_some() {
        // the scrape the same port serves to Prometheus, excerpted
        let p = c.roundtrip(&jobj![("op", "metrics"), ("format", "prometheus")])?;
        if let Ok(text) = p.get("prometheus").and_then(|v| v.as_str()) {
            println!("prometheus scrape ({} bytes), excerpt:", text.len());
            for line in text
                .lines()
                .filter(|l| {
                    l.starts_with("ddim_build_info")
                        || l.starts_with("ddim_requests_completed_total")
                        || l.starts_with("ddim_cache_hits_total")
                        || l.starts_with("ddim_access_log_lines_total")
                })
                .take(4)
            {
                println!("  {line}");
            }
        }
    }
    server.shutdown();
    println!("server shut down cleanly");
    // after shutdown the writer thread has drained: tail the access log
    if let Some(path) = &access_log {
        let text = std::fs::read_to_string(path).unwrap_or_default();
        let lines: Vec<&str> = text.lines().collect();
        println!("access log: {} lines at {path}, last 3:", lines.len());
        for line in lines.iter().rev().take(3).rev() {
            println!("  {line}");
        }
    }
    if failures > 0 {
        return Err(ddim_serve::Error::Coordinator(format!("{failures} requests failed")));
    }
    Ok(())
}
