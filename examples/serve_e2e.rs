//! End-to-end serving driver (the DESIGN.md §4 validation run): start the
//! full stack — TCP server, engine thread, continuous batcher, AOT
//! executables — and fire an open-loop Poisson workload of mixed requests
//! at it from concurrent client connections. Reports client-side latency
//! percentiles, server-side metrics, and batch occupancy.
//!
//!     cargo run --release --example serve_e2e -- --requests 60 --rate 4
//!
//! Flags: --artifacts DIR --dataset NAME --requests N --rate HZ --seed K

use std::sync::mpsc;
use std::time::{Duration, Instant};

use ddim_serve::cli::Args;
use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::server::Client;
use ddim_serve::coordinator::{Histogram, Server};
use ddim_serve::jobj;
use ddim_serve::schedule::NoiseMode;
use ddim_serve::workload::Workload;

fn main() -> ddim_serve::Result<()> {
    let args = Args::from_env()?;
    let dataset = args.get_or("dataset", "sprites").to_string();
    let n_requests = args.get_usize("requests", 60)?;
    let rate = args.get_f64("rate", 4.0)?;
    let seed = args.get_u64("seed", 1)?;

    let cfg = ServeConfig {
        artifact_root: args.get_or("artifacts", "artifacts").to_string(),
        dataset: dataset.clone(),
        listen: "127.0.0.1:0".into(),
        max_batch: 16,
        max_lanes: 64,
        queue_capacity: 256,
        ..Default::default()
    };
    println!("starting server (compiling executables)...");
    let t_start = Instant::now();
    let server = Server::start(cfg)?;
    let addr = server.addr();
    println!("server up on {addr} in {:.1}s", t_start.elapsed().as_secs_f64());

    // Build the open-loop workload: mixed S/eta/count classes at `rate` Hz.
    let workload = Workload::standard(&dataset, rate);
    let plan = workload.generate(n_requests, seed);
    println!(
        "workload: {n_requests} requests over {:.1}s ({} classes, open loop)",
        plan.last().map(|(t, _)| *t).unwrap_or(0.0),
        workload.classes.len()
    );

    // Replay: one thread per request (arrival-time-faithful), results back
    // over a channel.
    let (tx, rx) = mpsc::channel::<(usize, f64, bool, usize)>();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for (i, (arrival, req)) in plan.into_iter().enumerate() {
        let tx = tx.clone();
        let mode_s = match req.mode {
            NoiseMode::Eta(e) => format!("{e}"),
            NoiseMode::SigmaHat => "hat".into(),
        };
        let (count, rseed) = match req.body {
            ddim_serve::coordinator::RequestBody::Generate { count, seed } => (count, seed),
            _ => unreachable!(),
        };
        let steps = req.steps;
        let sampler = req.sampler.label();
        let ds = req.dataset.clone();
        handles.push(std::thread::spawn(move || {
            // open loop: wait until this request's arrival time
            let now = t0.elapsed().as_secs_f64();
            if arrival > now {
                std::thread::sleep(Duration::from_secs_f64(arrival - now));
            }
            let sent = Instant::now();
            let ok = (|| -> ddim_serve::Result<bool> {
                let mut c = Client::connect(addr)?;
                let resp = c.roundtrip(&jobj![
                    ("op", "generate"),
                    ("dataset", ds.as_str()),
                    ("steps", steps),
                    ("eta", mode_s.as_str()),
                    ("sampler", sampler),
                    ("count", count),
                    ("seed", rseed),
                ])?;
                Ok(resp.get("ok").ok().and_then(|v| v.as_bool().ok()).unwrap_or(false))
            })()
            .unwrap_or(false);
            let _ = tx.send((i, sent.elapsed().as_secs_f64(), ok, steps * count));
        }));
    }
    drop(tx);

    let mut hist = Histogram::new();
    let mut failures = 0usize;
    let mut total_steps = 0usize;
    let mut done = 0usize;
    for (_, latency, ok, steps) in rx {
        if ok {
            hist.record(latency);
            total_steps += steps;
        } else {
            failures += 1;
        }
        done += 1;
        if done % 20 == 0 {
            println!("  {done}/{n_requests} done");
        }
    }
    for h in handles {
        let _ = h.join();
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== serve_e2e results ===");
    println!("requests     : {n_requests} ({failures} failed)");
    println!("wall time    : {wall:.2}s");
    println!("throughput   : {:.2} req/s, {:.1} model-steps/s", (n_requests - failures) as f64 / wall, total_steps as f64 / wall);
    println!(
        "client latency: p50 {:.0}ms  p95 {:.0}ms  p99 {:.0}ms  mean {:.0}ms  max {:.0}ms",
        hist.quantile(0.5) * 1e3,
        hist.quantile(0.95) * 1e3,
        hist.quantile(0.99) * 1e3,
        hist.mean() * 1e3,
        hist.max() * 1e3,
    );

    // server-side view
    let mut c = Client::connect(addr)?;
    let m = c.roundtrip(&jobj![("op", "metrics")])?;
    let get = |k: &str| m.get(k).ok().and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
    println!(
        "server metrics: calls={} steps={} occupancy={:.2} p50={:.0}ms p95={:.0}ms rejected={}",
        get("executable_calls"),
        get("steps_executed"),
        get("occupancy"),
        get("latency_p50_s") * 1e3,
        get("latency_p95_s") * 1e3,
        get("requests_rejected"),
    );
    server.shutdown();
    println!("server shut down cleanly");
    if failures > 0 {
        return Err(ddim_serve::Error::Coordinator(format!("{failures} requests failed")));
    }
    Ok(())
}
