//! Appendix A, evaluated: the categorical (multinomial) DDIM that the paper
//! defines but leaves as future work. A tabular Bayes predictor plays f_θ
//! (zero model error), so what's measured is purely the *sampler*: total
//! variation to the true data distribution vs number of steps S, for the
//! DDIM-like (η=0, σ=σ_max) and fully-stochastic (η=1, σ=0) families.
//!
//!     cargo run --release --example discrete_ddim

use ddim_serve::discrete::{DiscreteSampler, DiscreteSchedule, TabularModel};
use ddim_serve::discrete::total_variation;

fn main() -> ddim_serve::Result<()> {
    let t_max = 200usize;
    let k = 8usize;
    // a lumpy data distribution over 8 symbols
    let p0 = vec![0.30, 0.22, 0.16, 0.12, 0.09, 0.06, 0.03, 0.02];
    let sched = DiscreteSchedule::linear(t_max, k)?;
    let sampler = DiscreteSampler::new(sched, TabularModel::new(p0.clone())?)?;

    let n = 40_000usize;
    println!("=== Appendix A: categorical DDIM, K={k}, T={t_max}, {n} samples/cell ===");
    println!("{:>6} | {:>14} | {:>14}", "S", "TV (eta=0 DDIM)", "TV (eta=1 stoch)");
    println!("{}", "-".repeat(42));
    for s in [2usize, 3, 5, 10, 25, 50, 200] {
        let tau: Vec<usize> = (1..=s).map(|i| i * t_max / s).collect();
        let tv0 = total_variation(&sampler.empirical(&tau, 0.0, n, 42)?, &p0);
        let tv1 = total_variation(&sampler.empirical(&tau, 1.0, n, 42)?, &p0);
        println!("{s:>6} | {tv0:>14.4} | {tv1:>14.4}");
    }
    println!("\nwith the exact predictor both families are consistent (the discrete");
    println!("Theorem-1 analogue); the sigma family controls HOW the chain spends");
    println!("its stochasticity — the DDIM-like chain carries x_t across hops:");

    // per-hop carryover weight sigma_t along a 10-step trajectory
    let s = 10usize;
    let tau: Vec<usize> = (1..=s).map(|i| i * t_max / s).collect();
    let sched = sampler.schedule();
    for (label, eta) in [("eta=0 (DDIM-like)", 0.0), ("eta=1 (stochastic)", 1.0)] {
        let mean_sigma: f64 = (0..tau.len())
            .map(|i| {
                let t = tau[i];
                let t_prev = if i == 0 { 0 } else { tau[i - 1] };
                sched.sigma(t, t_prev, eta)
            })
            .sum::<f64>()
            / tau.len() as f64;
        println!("  {label}: mean per-hop x_t-carryover weight sigma = {mean_sigma:.3}");
    }
    Ok(())
}
