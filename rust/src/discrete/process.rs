//! The categorical forward/reverse process of Appendix A: schedule, the
//! Eq.-19 posterior mixture, and the σ_t families (DDPM-like vs DDIM-like).

use crate::error::{Error, Result};

/// α_{0..T} for the categorical process: α decreasing 1 → 0 (the appendix's
/// convention — unlike the Gaussian table, α_T = 0 exactly, making
/// q(x_T | x₀) uniform).
#[derive(Debug, Clone)]
pub struct DiscreteSchedule {
    alpha: Vec<f64>,
    k: usize,
}

impl DiscreteSchedule {
    /// Linear α_t = 1 − t/T (simple, satisfies α₀=1, α_T=0, decreasing).
    pub fn linear(t_max: usize, k: usize) -> Result<Self> {
        if t_max == 0 || k < 2 {
            return Err(Error::Schedule(format!("bad discrete schedule T={t_max}, K={k}")));
        }
        let alpha = (0..=t_max).map(|t| 1.0 - t as f64 / t_max as f64).collect();
        Ok(Self { alpha, k })
    }

    /// Cosine-ish α (slower early destruction) — used by the ablation.
    pub fn cosine(t_max: usize, k: usize) -> Result<Self> {
        if t_max == 0 || k < 2 {
            return Err(Error::Schedule("bad discrete schedule".into()));
        }
        let alpha = (0..=t_max)
            .map(|t| {
                let x = t as f64 / t_max as f64;
                (0.5 * (1.0 + (std::f64::consts::PI * x).cos())).max(0.0)
            })
            .collect();
        Ok(Self { alpha, k })
    }

    pub fn t_max(&self) -> usize {
        self.alpha.len() - 1
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn alpha(&self, t: usize) -> f64 {
        self.alpha[t]
    }

    /// Marginal q(x_t | x₀ = j): Eq. (17) as a probability vector.
    pub fn marginal(&self, t: usize, x0: usize) -> Vec<f64> {
        let a = self.alpha[t];
        let mut p = vec![(1.0 - a) / self.k as f64; self.k];
        p[x0] += a;
        p
    }

    /// Largest admissible σ_t for the (t → t_prev) transition: the Eq.-18
    /// mixture weights must all be ≥ 0, i.e.
    ///   σ_t ≤ α_prev/α_t (x₀-weight)  and  σ_t ≤ (1−α_prev)/(1−α_t).
    /// At this maximum the uniform-noise weight hits 0 where possible — the
    /// "DDIM-like" deterministic-ish extreme the appendix describes.
    pub fn sigma_max(&self, t: usize, t_prev: usize) -> f64 {
        let a_t = self.alpha[t];
        let a_p = self.alpha[t_prev];
        let c1 = if a_t > 0.0 { a_p / a_t } else { f64::INFINITY };
        let c2 = if a_t < 1.0 { (1.0 - a_p) / (1.0 - a_t) } else { f64::INFINITY };
        c1.min(c2).min(1.0)
    }

    /// σ_t(η) = (1−η) · σ_max, matching the Gaussian convention: **η=0 is
    /// the DDIM-like extreme** (σ maximal, x_{t−1} pinned to x_t/x̂₀ with
    /// minimal fresh uniform noise — the appendix's "less stochastic"
    /// limit), η=1 the fully-stochastic independent-resample process.
    pub fn sigma(&self, t: usize, t_prev: usize, eta: f64) -> f64 {
        (1.0 - eta.clamp(0.0, 1.0)) * self.sigma_max(t, t_prev)
    }
}

/// The Eq.-19 posterior mixture weights for q_σ(x_{t_prev} | x_t, x₀):
/// `w_xt·δ(x_t) + w_x0·δ(x₀) + w_u·1_K`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Posterior {
    pub w_xt: f64,
    pub w_x0: f64,
    pub w_uniform: f64,
}

impl Posterior {
    /// Build the mixture for a (t → t_prev) transition at noise scale σ.
    pub fn new(sched: &DiscreteSchedule, t: usize, t_prev: usize, sigma: f64) -> Result<Self> {
        if t_prev >= t || t > sched.t_max() {
            return Err(Error::Schedule(format!("bad transition {t} -> {t_prev}")));
        }
        let a_t = sched.alpha(t);
        let a_p = sched.alpha(t_prev);
        let w_xt = sigma;
        let w_x0 = a_p - sigma * a_t;
        let w_uniform = (1.0 - a_p) - (1.0 - a_t) * sigma;
        if w_x0 < -1e-12 || w_uniform < -1e-12 {
            return Err(Error::Schedule(format!(
                "sigma {sigma} infeasible for {t}->{t_prev}: weights {w_x0}, {w_uniform}"
            )));
        }
        Ok(Self { w_xt, w_x0: w_x0.max(0.0), w_uniform: w_uniform.max(0.0) })
    }

    /// Probability vector over K classes given concrete x_t and x₀.
    pub fn probs(&self, k: usize, xt: usize, x0: usize) -> Vec<f64> {
        let mut p = vec![self.w_uniform / k as f64; k];
        p[xt] += self.w_xt;
        p[x0] += self.w_x0;
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 1e-12
    }

    #[test]
    fn schedule_endpoints() {
        for sched in [
            DiscreteSchedule::linear(100, 5).unwrap(),
            DiscreteSchedule::cosine(100, 5).unwrap(),
        ] {
            assert!(close(sched.alpha(0), 1.0));
            assert!(sched.alpha(sched.t_max()).abs() < 1e-12);
            for t in 1..=sched.t_max() {
                assert!(sched.alpha(t) <= sched.alpha(t - 1) + 1e-15);
            }
        }
        assert!(DiscreteSchedule::linear(0, 5).is_err());
        assert!(DiscreteSchedule::linear(10, 1).is_err());
    }

    #[test]
    fn marginal_is_distribution() {
        let s = DiscreteSchedule::linear(50, 7).unwrap();
        for t in [0, 10, 25, 50] {
            let p = s.marginal(t, 3);
            assert!(close(p.iter().sum::<f64>(), 1.0));
            assert!(p.iter().all(|&x| x >= 0.0));
        }
        // t=0 is a point mass; t=T is uniform
        assert!(close(s.marginal(0, 3)[3], 1.0));
        let u = s.marginal(50, 3);
        assert!(u.iter().all(|&x| close(x, 1.0 / 7.0)));
    }

    #[test]
    fn posterior_weights_sum_to_one() {
        let s = DiscreteSchedule::linear(100, 4).unwrap();
        for (t, tp) in [(100, 50), (60, 59), (10, 0)] {
            for eta in [0.0, 0.5, 1.0] {
                let sig = s.sigma(t, tp, eta);
                let post = Posterior::new(&s, t, tp, sig).unwrap();
                let sum = post.w_xt + post.w_x0 + post.w_uniform;
                assert!(close(sum, 1.0), "weights sum {sum}");
                let p = post.probs(4, 1, 2);
                assert!(close(p.iter().sum::<f64>(), 1.0));
            }
        }
    }

    /// The appendix's consistency requirement: composing q(x_t|x0) with the
    /// posterior must reproduce q(x_{t_prev}|x0) — the discrete Lemma 1.
    #[test]
    fn marginals_preserved_under_posterior() {
        let s = DiscreteSchedule::cosine(80, 6).unwrap();
        let x0 = 2usize;
        for (t, tp) in [(80, 40), (40, 20), (20, 0), (80, 79)] {
            for eta in [0.0, 0.3, 1.0] {
                let sig = s.sigma(t, tp, eta);
                let post = Posterior::new(&s, t, tp, sig).unwrap();
                let pt = s.marginal(t, x0);
                // sum_{x_t} q(x_t|x0) * q(x_prev | x_t, x0)
                let mut composed = vec![0.0f64; 6];
                for (xt, &pxt) in pt.iter().enumerate() {
                    for (j, pj) in post.probs(6, xt, x0).into_iter().enumerate() {
                        composed[j] += pxt * pj;
                    }
                }
                let want = s.marginal(tp, x0);
                for (a, b) in composed.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-10, "eta {eta}: {composed:?} vs {want:?}");
                }
            }
        }
    }

    #[test]
    fn sigma_max_hits_zero_uniform_weight_when_feasible() {
        let s = DiscreteSchedule::linear(100, 4).unwrap();
        let (t, tp) = (60, 30);
        let smax = s.sigma_max(t, tp);
        let post = Posterior::new(&s, t, tp, smax).unwrap();
        // at sigma_max one of the two constraints is tight
        assert!(
            post.w_uniform < 1e-12 || post.w_x0 < 1e-12,
            "no tight constraint at sigma_max: {post:?}"
        );
    }

    #[test]
    fn infeasible_sigma_rejected() {
        let s = DiscreteSchedule::linear(100, 4).unwrap();
        let smax = s.sigma_max(70, 30);
        assert!(Posterior::new(&s, 70, 30, smax + 0.05).is_err());
        assert!(Posterior::new(&s, 30, 70, 0.1).is_err()); // wrong direction
    }
}
