//! Appendix A: the non-Markovian forward process for *discrete* (categorical)
//! data, and its DDIM-style reverse process — the paper defines it (Eqs.
//! 17–21) but "leaves empirical evaluations as future work"; this module
//! does that evaluation on a toy distribution where the optimal denoiser is
//! available in closed form (a tabular Bayes predictor), so the sampler is
//! exercised exactly as the theory intends, with no learned-model error in
//! the way.
//!
//! Summary of the appendix: for one-hot x₀ over K values,
//!   q(x_t | x₀)          = Cat(α_t x₀ + (1−α_t) 1_K)                  (17)
//!   q(x_{t−1}|x_t, x₀)   = Cat(σ_t x_t + (α_{t−1} − σ_t α_t) x₀
//!                            + ((1−α_{t−1}) − (1−α_t)σ_t) 1_K)        (19)
//!   p_θ(x_{t−1}|x_t)     = same with x₀ → f_θ(x_t)                    (20)
//! with 1_K the uniform vector. σ_t interpolates stochasticity exactly like
//! the Gaussian σ: the *DDIM-like* extreme maximises σ_t subject to the
//! mixture weights staying non-negative, which pins x_{t−1} to x_t / x̂₀
//! with as little fresh uniform noise as possible.

mod process;
mod sampler;

pub use process::{DiscreteSchedule, Posterior};
pub use sampler::{total_variation, DiscreteSampler, TabularModel};
