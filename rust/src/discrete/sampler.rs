//! Sampling for the categorical DDIM (Appendix A), with a *tabular Bayes*
//! predictor standing in for f_θ: for a known data distribution p₀ over K
//! values, the optimal x₀-predictor given x_t is exact:
//!
//!   p(x₀ = j | x_t = i) ∝ p₀(j) · q(x_t = i | x₀ = j)
//!
//! which lets us evaluate the *sampler* (accelerated sub-sequences, σ
//! families) with zero model error — the appendix's missing experiment.

use crate::discrete::{DiscreteSchedule, Posterior};
use crate::error::{Error, Result};
use crate::rng::Pcg64;

/// Exact x₀-posterior predictor for a known categorical data distribution.
#[derive(Debug, Clone)]
pub struct TabularModel {
    p0: Vec<f64>,
}

impl TabularModel {
    pub fn new(p0: Vec<f64>) -> Result<Self> {
        let s: f64 = p0.iter().sum();
        if p0.len() < 2 || p0.iter().any(|&x| x < 0.0) || (s - 1.0).abs() > 1e-9 {
            return Err(Error::Schedule(format!("bad p0 (sum {s})")));
        }
        Ok(Self { p0 })
    }

    pub fn k(&self) -> usize {
        self.p0.len()
    }

    pub fn p0(&self) -> &[f64] {
        &self.p0
    }

    /// f_θ(x_t): the exact posterior p(x₀ | x_t) under the forward process.
    pub fn predict_x0(&self, sched: &DiscreteSchedule, t: usize, xt: usize) -> Vec<f64> {
        let k = self.p0.len();
        let a = sched.alpha(t);
        let mut post: Vec<f64> = (0..k)
            .map(|j| {
                let lik = (1.0 - a) / k as f64 + if j == xt { a } else { 0.0 };
                self.p0[j] * lik
            })
            .collect();
        let z: f64 = post.iter().sum();
        for p in &mut post {
            *p /= z;
        }
        post
    }
}

/// Categorical DDIM sampler over a τ sub-sequence.
pub struct DiscreteSampler {
    sched: DiscreteSchedule,
    model: TabularModel,
}

impl DiscreteSampler {
    pub fn new(sched: DiscreteSchedule, model: TabularModel) -> Result<Self> {
        if sched.k() != model.k() {
            return Err(Error::Schedule("K mismatch between schedule and model".into()));
        }
        Ok(Self { sched, model })
    }

    fn draw(probs: &[f64], rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        let mut acc = 0.0;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if u < acc {
                return i;
            }
        }
        probs.len() - 1
    }

    /// Generate one sample by walking reversed(τ) from the uniform prior.
    /// `eta=0` is the DDIM-like extreme (σ = σ_max, matching the Gaussian
    /// convention), `eta=1` the fully stochastic one. Uses the Rao-Blackwellised Eq.-20 reverse kernel:
    /// marginalise over the x̂₀ posterior rather than sampling it.
    pub fn generate(&self, tau: &[usize], eta: f64, rng: &mut Pcg64) -> Result<usize> {
        let k = self.sched.k();
        if tau.is_empty() || *tau.last().unwrap() != self.sched.t_max() {
            return Err(Error::Schedule("tau must end at T for the uniform prior".into()));
        }
        let mut xt = rng.next_below(k as u64) as usize; // q(x_T) = uniform
        for i in (0..tau.len()).rev() {
            let t = tau[i];
            let t_prev = if i == 0 { 0 } else { tau[i - 1] };
            let sigma = self.sched.sigma(t, t_prev, eta);
            let post = Posterior::new(&self.sched, t, t_prev, sigma)?;
            let x0_probs = self.model.predict_x0(&self.sched, t, xt);
            // p(x_prev) = w_xt δ(x_t) + w_x0 * p(x0|x_t) + w_u uniform
            let mut probs = vec![post.w_uniform / k as f64; k];
            probs[xt] += post.w_xt;
            for (j, &pj) in x0_probs.iter().enumerate() {
                probs[j] += post.w_x0 * pj;
            }
            xt = Self::draw(&probs, rng);
        }
        Ok(xt)
    }

    /// Sample `n` values and return the empirical distribution.
    pub fn empirical(&self, tau: &[usize], eta: f64, n: usize, seed: u64) -> Result<Vec<f64>> {
        let mut rng = Pcg64::seeded(seed);
        let mut counts = vec![0usize; self.sched.k()];
        for _ in 0..n {
            counts[self.generate(tau, eta, &mut rng)?] += 1;
        }
        Ok(counts.into_iter().map(|c| c as f64 / n as f64).collect())
    }

    pub fn schedule(&self) -> &DiscreteSchedule {
        &self.sched
    }

    pub fn model(&self) -> &TabularModel {
        &self.model
    }
}

/// Total-variation distance between two distributions (the eval metric for
/// the Appendix-A experiment).
pub fn total_variation(p: &[f64], q: &[f64]) -> f64 {
    0.5 * p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(t_max: usize) -> DiscreteSampler {
        let sched = DiscreteSchedule::linear(t_max, 5).unwrap();
        let model = TabularModel::new(vec![0.4, 0.3, 0.15, 0.1, 0.05]).unwrap();
        DiscreteSampler::new(sched, model).unwrap()
    }

    #[test]
    fn tabular_model_validates() {
        assert!(TabularModel::new(vec![0.5, 0.6]).is_err());
        assert!(TabularModel::new(vec![1.0]).is_err());
        assert!(TabularModel::new(vec![0.7, 0.3]).is_ok());
    }

    #[test]
    fn predictor_is_bayes_consistent() {
        let s = setup(100);
        // at t=0 the observation IS x0
        let p = s.model().predict_x0(s.schedule(), 0, 3);
        assert!((p[3] - 1.0).abs() < 1e-12);
        // at t=T the observation carries nothing: posterior == prior
        let p = s.model().predict_x0(s.schedule(), 100, 3);
        for (a, b) in p.iter().zip(s.model().p0()) {
            assert!((a - b).abs() < 1e-12);
        }
        // in between, observing class i raises its posterior above prior
        let p = s.model().predict_x0(s.schedule(), 50, 4);
        assert!(p[4] > s.model().p0()[4]);
    }

    #[test]
    fn full_chain_recovers_data_distribution() {
        // with the exact predictor and the full trajectory, samples must be
        // ~ p0 for ANY eta (Theorem-1 analogue: same marginals)
        let s = setup(50);
        let tau: Vec<usize> = (1..=50).collect();
        for eta in [0.0, 0.5, 1.0] {
            let emp = s.empirical(&tau, eta, 30_000, 7).unwrap();
            let tv = total_variation(&emp, s.model().p0());
            assert!(tv < 0.02, "eta {eta}: TV {tv}");
        }
    }

    #[test]
    fn accelerated_chain_stays_close_with_high_sigma() {
        // the appendix's point: few-step sampling works, and the
        // DDIM-like (sigma_max) family degrades most gracefully
        let s = setup(200);
        let tau: Vec<usize> = vec![40, 80, 120, 160, 200]; // S=5 of T=200
        let emp_ddim = s.empirical(&tau, 0.0, 30_000, 9).unwrap();
        let tv_ddim = total_variation(&emp_ddim, s.model().p0());
        assert!(tv_ddim < 0.05, "S=5 DDIM-like TV {tv_ddim}");
        let emp_stoch = s.empirical(&tau, 1.0, 30_000, 9).unwrap();
        let tv_stoch = total_variation(&emp_stoch, s.model().p0());
        // both are consistent here (exact model); DDIM-like must not be worse
        assert!(tv_ddim <= tv_stoch + 0.02, "{tv_ddim} vs {tv_stoch}");
    }

    #[test]
    fn generate_rejects_bad_tau() {
        let s = setup(50);
        let mut rng = Pcg64::seeded(0);
        assert!(s.generate(&[], 1.0, &mut rng).is_err());
        assert!(s.generate(&[10, 20], 1.0, &mut rng).is_err()); // doesn't end at T
    }

    #[test]
    fn total_variation_props() {
        assert_eq!(total_variation(&[0.5, 0.5], &[0.5, 0.5]), 0.0);
        assert!((total_variation(&[1.0, 0.0], &[0.0, 1.0]) - 1.0).abs() < 1e-12);
    }
}
