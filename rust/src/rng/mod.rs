//! RNG substrate. All stochasticity on the request path (x_T priors, the
//! per-step DDPM noise, workload arrival processes) flows through a
//! deterministic, seedable PCG64 so that (a) η=0 trajectories are bitwise
//! reproducible and (b) every experiment in EXPERIMENTS.md can be re-run
//! exactly. [`fnv`] holds the FNV-1a seed-derivation / content-digest
//! primitives those seeds are built from.

mod fnv;
mod gaussian;
mod pcg;
mod slerp;

pub use fnv::{fnv1a, state_seed, Fnv128, Fnv64};
pub use gaussian::GaussianSource;
pub use pcg::Pcg64;
pub use slerp::slerp;
