//! RNG substrate. All stochasticity on the request path (x_T priors, the
//! per-step DDPM noise, workload arrival processes) flows through a
//! deterministic, seedable PCG64 so that (a) η=0 trajectories are bitwise
//! reproducible and (b) every experiment in EXPERIMENTS.md can be re-run
//! exactly.

mod gaussian;
mod pcg;
mod slerp;

pub use gaussian::GaussianSource;
pub use pcg::Pcg64;
pub use slerp::slerp;
