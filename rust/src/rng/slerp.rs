//! Spherical linear interpolation in latent space — Eq. (67) of the paper
//! (Shoemake 1985), used for the Fig. 6 interpolation experiment: gaussian
//! latents concentrate near a sphere, so slerp (not lerp) keeps interpolants
//! on-distribution for the deterministic DDIM decoder.

/// slerp(a, b; alpha) with the paper's convention: alpha=0 -> a, alpha=1 -> b.
/// Falls back to lerp when the vectors are (anti)parallel enough that the
/// spherical formula loses precision.
pub fn slerp(a: &[f32], b: &[f32], alpha: f64) -> Vec<f32> {
    assert_eq!(a.len(), b.len(), "slerp length mismatch");
    let dot: f64 = a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum();
    let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    let cos = (dot / (na * nb)).clamp(-1.0, 1.0);
    let theta = cos.acos();
    if theta.sin().abs() < 1e-6 {
        // nearly collinear: lerp is exact to fp precision here
        return a
            .iter()
            .zip(b)
            .map(|(x, y)| ((1.0 - alpha) * *x as f64 + alpha * *y as f64) as f32)
            .collect();
    }
    let wa = ((1.0 - alpha) * theta).sin() / theta.sin();
    let wb = (alpha * theta).sin() / theta.sin();
    a.iter()
        .zip(b)
        .map(|(x, y)| (wa * *x as f64 + wb * *y as f64) as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::GaussianSource;

    #[test]
    fn endpoints() {
        let mut g = GaussianSource::seeded(1);
        let a = g.vec(64);
        let b = g.vec(64);
        assert_eq!(slerp(&a, &b, 0.0), a);
        let s1 = slerp(&a, &b, 1.0);
        for (x, y) in s1.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn preserves_norm_for_equal_norm_inputs() {
        // For |a| == |b|, slerp stays on the sphere of that radius.
        let mut g = GaussianSource::seeded(2);
        let mut a = g.vec(256);
        let mut b = g.vec(256);
        let norm = |v: &[f32]| v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let (na, nb) = (norm(&a), norm(&b));
        a.iter_mut().for_each(|x| *x /= na as f32);
        b.iter_mut().for_each(|x| *x /= nb as f32);
        for alpha in [0.1, 0.3, 0.5, 0.7, 0.9] {
            let s = slerp(&a, &b, alpha);
            assert!((norm(&s) - 1.0).abs() < 1e-4, "alpha={alpha}: {}", norm(&s));
        }
    }

    #[test]
    fn collinear_falls_back_to_lerp() {
        let a = vec![1.0f32, 0.0, 0.0];
        let s = slerp(&a, &a, 0.5);
        assert_eq!(s, a);
        let b = vec![2.0f32, 0.0, 0.0];
        let s = slerp(&a, &b, 0.5);
        assert!((s[0] - 1.5).abs() < 1e-6);
    }

    #[test]
    #[should_panic]
    fn length_mismatch_panics() {
        slerp(&[1.0], &[1.0, 2.0], 0.5);
    }
}
