//! Standard-normal sampling (Box–Muller with caching) on top of [`Pcg64`].
//! This is the source of the prior x_T ~ N(0, I) and the per-step DDPM
//! noise ε_t in Eq. (12)'s third term.

use super::Pcg64;

/// A gaussian stream over a PCG64 generator.
#[derive(Debug, Clone)]
pub struct GaussianSource {
    rng: Pcg64,
    spare: Option<f64>,
}

impl GaussianSource {
    pub fn new(rng: Pcg64) -> Self {
        Self { rng, spare: None }
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(Pcg64::seeded(seed))
    }

    /// One standard-normal draw.
    pub fn next(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        // Box–Muller: u in (0,1] to avoid ln(0)
        let u = 1.0 - self.rng.next_f64();
        let v = self.rng.next_f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fill an f32 buffer with iid standard normals.
    pub fn fill(&mut self, out: &mut [f32]) {
        for v in out {
            *v = self.next() as f32;
        }
    }

    /// Allocate-and-fill convenience.
    pub fn vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let mut g = GaussianSource::seeded(3);
        let n = 50_000;
        let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = g.next();
            s1 += z;
            s2 += z * z;
            s3 += z * z * z;
            s4 += z * z * z * z;
        }
        let m = s1 / n as f64;
        let var = s2 / n as f64 - m * m;
        let skew = s3 / n as f64;
        let kurt = s4 / n as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
        assert!(skew.abs() < 0.05, "skew {skew}");
        assert!((kurt - 3.0).abs() < 0.15, "kurtosis {kurt}");
    }

    #[test]
    fn deterministic() {
        let mut a = GaussianSource::seeded(11);
        let mut b = GaussianSource::seeded(11);
        for _ in 0..100 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn fill_matches_next() {
        let mut a = GaussianSource::seeded(5);
        let mut b = GaussianSource::seeded(5);
        let v = a.vec(9);
        for x in v {
            assert_eq!(x, b.next() as f32);
        }
    }
}
