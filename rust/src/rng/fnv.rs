//! FNV-1a hashing primitives — the substrate's seed-derivation and
//! content-digest tools. Consumers sit at every layer: the reference
//! backend derives synthetic model weights from manifest fields
//! ([`fnv1a`]), the engine seeds caller-supplied-state noise streams
//! from content bits ([`state_seed`]), and the sample cache builds its
//! canonical request keys over [`Fnv128`] ([`crate::cache::key`]). The
//! FNV offset/prime constants live here and nowhere else.

/// FNV-1a, 64-bit, streaming builder.
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

const FNV64_OFFSET: u64 = 0xcbf29ce484222325;
const FNV64_PRIME: u64 = 0x100000001b3;

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv64 {
    pub fn new() -> Self {
        Fnv64(FNV64_OFFSET)
    }

    pub fn byte(&mut self, b: u8) -> &mut Self {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(FNV64_PRIME);
        self
    }

    pub fn bytes(&mut self, bs: &[u8]) -> &mut Self {
        for &b in bs {
            self.byte(b);
        }
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.bytes(&v.to_le_bytes())
    }

    /// Length-prefixed string (prefix-free against adjacent fields).
    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes())
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// FNV-1a, 128-bit, streaming builder (offset basis / prime per the FNV
/// reference spec). Twice the width a hash table would need — used where
/// a digest collision would be served as wrong *data*, not a slow probe.
#[derive(Debug, Clone)]
pub struct Fnv128(u128);

const FNV128_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV128_PRIME: u128 = 0x0000000001000000000000000000013B;

impl Default for Fnv128 {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv128 {
    pub fn new() -> Self {
        Fnv128(FNV128_OFFSET)
    }

    pub fn byte(&mut self, b: u8) -> &mut Self {
        self.0 ^= b as u128;
        self.0 = self.0.wrapping_mul(FNV128_PRIME);
        self
    }

    pub fn bytes(&mut self, bs: &[u8]) -> &mut Self {
        for &b in bs {
            self.byte(b);
        }
        self
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.bytes(&v.to_le_bytes())
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.bytes(&v.to_le_bytes())
    }

    /// Length-prefixed string (prefix-free against adjacent fields).
    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes())
    }

    pub fn finish(&self) -> u128 {
        self.0
    }
}

/// FNV-1a over a string — the seed-derivation primitive shared by the
/// reference model and the fixture generator's per-dataset streams.
pub fn fnv1a(s: &str) -> u64 {
    Fnv64::new().bytes(s.as_bytes()).finish()
}

/// Content-derived noise-seed base for caller-supplied-state requests
/// (decode latents / encode images): FNV-64 over the f32 bits plus a
/// direction tag. Lane `i` seeds its PCG64 stream with `base + i`, so two
/// bitwise-identical requests consume bitwise-identical noise — the
/// engine-assigned request id (which differs across engines, shards, and
/// processes) never leaks into the sample. This is what makes stochastic
/// (η > 0) decode a pure function of the request, and therefore cacheable
/// by [`crate::cache`].
pub fn state_seed(direction_tag: u8, rows: &[Vec<f32>]) -> u64 {
    let mut h = Fnv64::new();
    h.byte(direction_tag);
    h.u64(rows.len() as u64);
    for row in rows {
        h.u64(row.len() as u64);
        for &v in row {
            h.bytes(&v.to_bits().to_le_bytes());
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_vectors() {
        // FNV-1a reference vectors ("" and "a") for both widths.
        assert_eq!(Fnv64::new().finish(), 0xcbf29ce484222325);
        assert_eq!(Fnv64::new().byte(b'a').finish(), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a(""), 0xcbf29ce484222325);
        assert_eq!(fnv1a("a"), 0xaf63dc4c8601ec8c);
        assert_eq!(Fnv128::new().finish(), 0x6c62272e07bb014262b821756295c58d);
        assert_eq!(
            Fnv128::new().byte(b'a').finish(),
            0xd228cb696f1a8caf78912b704e4a8964
        );
    }

    #[test]
    fn state_seed_is_content_determined() {
        let a = vec![vec![0.5f32, -0.25]];
        let b = vec![vec![0.5f32, -0.25]];
        assert_eq!(state_seed(1, &a), state_seed(1, &b));
        assert_ne!(state_seed(1, &a), state_seed(2, &a), "direction tag separates streams");
        let mut c = a.clone();
        c[0][1] = f32::from_bits(c[0][1].to_bits() ^ 1);
        assert_ne!(state_seed(1, &a), state_seed(1, &c));
    }
}
