//! PCG64 (XSL-RR 128/64) — O'Neill's PCG family. Chosen over xorshift for
//! its published reference vectors (tested below) and over ChaCha for speed;
//! statistical quality is far beyond what sampling noise needs.

/// PCG64 XSL-RR generator with a seedable stream.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with (state, stream). Matches the PCG reference `pcg64_srandom`.
    pub fn new(seed: u128, stream: u128) -> Self {
        let mut rng = Self { state: 0, inc: (stream << 1) | 1 };
        rng.step();
        rng.state = rng.state.wrapping_add(seed);
        rng.step();
        rng
    }

    /// Convenience seeding from a u64 (stream fixed); what the coordinator
    /// uses for per-request RNGs: `Pcg64::seeded(request_seed)`.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed as u128, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent generator (different stream) from this one —
    /// used to fan a request seed out into per-lane noise streams.
    pub fn fork(&mut self, lane: u64) -> Pcg64 {
        let s = self.next_u64() as u128 | ((lane as u128) << 64);
        Pcg64::new(s, 0x5851f42d4c957f2d ^ lane as u128)
    }

    fn step(&mut self) {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
    }

    /// Next 64 random bits (XSL-RR output function).
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free is overkill;
    /// modulo bias is < 2^-40 for our n, but reject anyway for correctness).
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seeded(7);
        let mut b = Pcg64::seeded(7);
        let mut c = Pcg64::seeded(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Pcg64::seeded(42);
        let n = 20000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_in_range_and_covers() {
        let mut r = Pcg64::seeded(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Pcg64::seeded(99);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
        // reforking from the same parent state is reproducible
        let mut root2 = Pcg64::seeded(99);
        let mut a2 = root2.fork(0);
        let va2: Vec<u64> = (0..4).map(|_| a2.next_u64()).collect();
        assert_eq!(va, va2);
    }
}
