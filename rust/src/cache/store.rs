//! Byte-budgeted sharded LRU over completed samples.
//!
//! Layout: N shards, each its own mutex — a hit on one shard never
//! contends with a publish on another (the coordinator's connection
//! threads all race through here). The total byte budget is divided
//! evenly across shards, so the global invariant `bytes() <= budget`
//! holds without a cross-shard lock.
//!
//! Entries are either **ready** (a completed [`CachedSample`], accounted
//! against the budget, tracked in strict recency order) or **in-flight**
//! (a pinned placeholder some leader is currently computing — zero bytes,
//! *never* evicted; the single-flight table in [`super::coalesce`] holds
//! the waiters, this marker only protects the slot from pressure).
//! Eviction is strict LRU over ready entries: recency is a
//! `BTreeMap<stamp, key>` (stamp = per-shard monotone counter, refreshed
//! on every hit), so the evictee is always the least-recently-used ready
//! entry — property-tested against a model in `tests/cache_properties.rs`.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::cache::key::CacheKey;
use crate::coordinator::request::{Response, ResponseBody};

/// One completed execution, as stored: the full per-lane outputs
/// (executions behind the cache always run with `return_images` forced
/// on) plus the executable-step cost the original run paid.
#[derive(Debug, Clone, PartialEq)]
pub struct CachedSample {
    pub outputs: Vec<Vec<f32>>,
    pub steps_executed: usize,
}

/// Fixed per-entry / per-row bookkeeping estimate added on top of the raw
/// f32 payload when charging the budget (map entry, recency node, Vec
/// headers). An estimate, not an allocator audit — the invariant that
/// matters is that the charge is monotone in payload size and consistent.
const ENTRY_OVERHEAD: usize = 96;
const ROW_OVERHEAD: usize = 32;

impl CachedSample {
    /// Bytes this sample charges against the store budget.
    pub fn cost_bytes(&self) -> usize {
        ENTRY_OVERHEAD
            + self
                .outputs
                .iter()
                .map(|r| r.len() * std::mem::size_of::<f32>() + ROW_OVERHEAD)
                .sum::<usize>()
    }

    /// Materialise a wire response from the cached sample. `return_images`
    /// is applied per caller — the sample always holds the outputs, each
    /// waiter only gets them if it asked.
    pub fn response_for(
        &self,
        id: u64,
        return_images: bool,
        latency_s: f64,
        cached: bool,
    ) -> Response {
        Response {
            id,
            body: ResponseBody::Ok {
                outputs: if return_images { self.outputs.clone() } else { Vec::new() },
            },
            latency_s,
            steps_executed: self.steps_executed,
            cached,
            degraded: None,
            spans: None,
            coalesced: false,
        }
    }
}

/// What a non-touching probe sees (test / metrics support).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Probe {
    Absent,
    InFlight,
    Ready,
}

enum Slot {
    /// Pinned placeholder: a leader is executing this key right now.
    InFlight,
    Ready(Arc<CachedSample>),
}

struct Entry {
    slot: Slot,
    /// Recency stamp (key into `Shard::recency`); unused for in-flight.
    stamp: u64,
    bytes: usize,
}

#[derive(Default)]
struct Shard {
    map: HashMap<u128, Entry>,
    /// stamp -> key, ready entries only, ascending = least recent first.
    recency: BTreeMap<u64, u128>,
    next_stamp: u64,
    bytes: usize,
    evictions: u64,
}

impl Shard {
    fn touch(&mut self, key: u128) {
        let stamp = self.next_stamp;
        self.next_stamp += 1;
        match self.map.get_mut(&key) {
            Some(e) if matches!(e.slot, Slot::Ready(_)) => {
                self.recency.remove(&e.stamp);
                e.stamp = stamp;
                self.recency.insert(stamp, key);
            }
            _ => {}
        }
    }

    fn evict_to(&mut self, budget: usize) {
        while self.bytes > budget {
            // least-recent ready entry; in-flight entries are not in the
            // recency index, so pressure can never evict them
            let Some((&stamp, &key)) = self.recency.iter().next() else { break };
            self.recency.remove(&stamp);
            let e = self.map.remove(&key).expect("recency entry has a map entry");
            self.bytes -= e.bytes;
            self.evictions += 1;
        }
    }
}

/// The sharded store. All methods take `&self`; shared behind an `Arc`.
pub struct CacheStore {
    shards: Vec<Mutex<Shard>>,
    /// Per-shard byte budget (total budget / shard count).
    shard_budget: usize,
    total_budget: usize,
}

/// Default shard count — enough to keep connection threads off each
/// other's locks without shrinking per-shard budgets into uselessness.
pub const DEFAULT_STORE_SHARDS: usize = 8;

/// Minimum bytes a shard should command before it is worth splitting the
/// budget further: below this, sharding would make ordinary samples
/// "oversize" for their shard and the cache silently inert.
const MIN_SHARD_BUDGET: usize = 64 << 10;

impl CacheStore {
    /// Build with the default shard count, scaled *down* for small
    /// budgets so each shard can still hold real samples — a
    /// `--cache-bytes 4096` cache stores 4 KiB samples in one shard
    /// instead of rejecting everything over 512 bytes across eight.
    pub fn new(budget_bytes: usize) -> CacheStore {
        let shards = (budget_bytes / MIN_SHARD_BUDGET).clamp(1, DEFAULT_STORE_SHARDS);
        Self::with_shards(budget_bytes, shards)
    }

    /// Explicit shard count (tests use 1 to pin strict global LRU order).
    pub fn with_shards(budget_bytes: usize, shards: usize) -> CacheStore {
        let shards = shards.max(1);
        CacheStore {
            shards: (0..shards).map(|_| Mutex::new(Shard::default())).collect(),
            shard_budget: budget_bytes / shards,
            total_budget: budget_bytes,
        }
    }

    fn shard(&self, key: CacheKey) -> std::sync::MutexGuard<'_, Shard> {
        self.shards[key.shard(self.shards.len())].lock().unwrap()
    }

    /// Look up a completed sample; a hit refreshes its recency.
    pub fn get(&self, key: CacheKey) -> Option<Arc<CachedSample>> {
        let mut s = self.shard(key);
        let sample = match s.map.get(&key.0) {
            Some(Entry { slot: Slot::Ready(sample), .. }) => sample.clone(),
            _ => return None,
        };
        s.touch(key.0);
        Some(sample)
    }

    /// Pin `key` as in-flight (a leader is about to execute it). No-op if
    /// the key is already present — an existing ready entry is *not*
    /// clobbered (the racing leader will simply re-publish over it).
    pub fn reserve(&self, key: CacheKey) {
        let mut s = self.shard(key);
        s.map
            .entry(key.0)
            .or_insert_with(|| Entry { slot: Slot::InFlight, stamp: 0, bytes: 0 });
    }

    /// Replace the in-flight marker with the completed sample and evict
    /// LRU entries down to the shard budget. A sample too large for the
    /// budget is not stored at all (the marker is dropped); publish over
    /// an existing ready entry just refreshes it.
    pub fn publish(&self, key: CacheKey, sample: Arc<CachedSample>) {
        let cost = sample.cost_bytes();
        let mut s = self.shard(key);
        if cost > self.shard_budget {
            // un-storable: drop the marker so the slot doesn't pin forever
            if matches!(s.map.get(&key.0), Some(Entry { slot: Slot::InFlight, .. })) {
                s.map.remove(&key.0);
            }
            return;
        }
        let stamp = s.next_stamp;
        s.next_stamp += 1;
        if let Some(old) = s.map.remove(&key.0) {
            if matches!(old.slot, Slot::Ready(_)) {
                s.recency.remove(&old.stamp);
                s.bytes -= old.bytes;
            }
        }
        s.map.insert(key.0, Entry { slot: Slot::Ready(sample), stamp, bytes: cost });
        s.recency.insert(stamp, key.0);
        s.bytes += cost;
        let budget = self.shard_budget;
        s.evict_to(budget);
    }

    /// Drop an in-flight marker whose execution failed (ready entries are
    /// left alone).
    pub fn cancel(&self, key: CacheKey) {
        let mut s = self.shard(key);
        if matches!(s.map.get(&key.0), Some(Entry { slot: Slot::InFlight, .. })) {
            s.map.remove(&key.0);
        }
    }

    /// Flush every ready entry and in-flight marker (manifest-digest
    /// invalidation). Eviction counters are preserved.
    pub fn clear(&self) {
        for shard in &self.shards {
            let mut s = shard.lock().unwrap();
            s.map.clear();
            s.recency.clear();
            s.bytes = 0;
        }
    }

    /// Non-touching probe (tests, metrics) — never perturbs recency.
    pub fn probe(&self, key: CacheKey) -> Probe {
        let s = self.shard(key);
        match s.map.get(&key.0) {
            None => Probe::Absent,
            Some(Entry { slot: Slot::InFlight, .. }) => Probe::InFlight,
            Some(Entry { slot: Slot::Ready(_), .. }) => Probe::Ready,
        }
    }

    /// Bytes currently charged across all shards (ready entries only).
    pub fn bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().bytes).sum()
    }

    /// Ready entries resident right now.
    pub fn entries(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().recency.len()).sum()
    }

    /// In-flight (pinned) markers resident right now.
    pub fn inflight(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                let s = s.lock().unwrap();
                s.map.len() - s.recency.len()
            })
            .sum()
    }

    /// Total evictions since construction.
    pub fn evictions(&self) -> u64 {
        self.shards.iter().map(|s| s.lock().unwrap().evictions).sum()
    }

    /// The configured total byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.total_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(n: u128) -> CacheKey {
        CacheKey(n)
    }

    fn sample(rows: usize, row_len: usize) -> Arc<CachedSample> {
        Arc::new(CachedSample {
            outputs: (0..rows).map(|i| vec![i as f32; row_len]).collect(),
            steps_executed: rows * 10,
        })
    }

    #[test]
    fn get_publish_round_trip_and_budget() {
        let store = CacheStore::with_shards(10_000, 1);
        assert!(store.get(k(1)).is_none());
        store.reserve(k(1));
        assert_eq!(store.probe(k(1)), Probe::InFlight);
        assert_eq!(store.bytes(), 0, "in-flight markers charge nothing");
        let s = sample(2, 64);
        store.publish(k(1), s.clone());
        assert_eq!(store.probe(k(1)), Probe::Ready);
        assert_eq!(store.get(k(1)).unwrap().outputs, s.outputs);
        assert_eq!(store.bytes(), s.cost_bytes());
        assert_eq!(store.entries(), 1);
        assert_eq!(store.inflight(), 0);
    }

    #[test]
    fn strict_lru_eviction_with_touch() {
        // budget fits exactly 3 of these samples
        let s = sample(1, 64);
        let store = CacheStore::with_shards(3 * s.cost_bytes(), 1);
        for i in 1..=3u128 {
            store.publish(k(i), sample(1, 64));
        }
        // touch 1 so 2 becomes the LRU
        assert!(store.get(k(1)).is_some());
        store.publish(k(4), sample(1, 64));
        assert_eq!(store.probe(k(2)), Probe::Absent, "LRU (2) evicted, not touched (1)");
        assert_eq!(store.probe(k(1)), Probe::Ready);
        assert_eq!(store.probe(k(3)), Probe::Ready);
        assert_eq!(store.probe(k(4)), Probe::Ready);
        assert_eq!(store.evictions(), 1);
        assert!(store.bytes() <= store.budget_bytes());
    }

    #[test]
    fn inflight_markers_survive_pressure() {
        let s = sample(1, 64);
        let store = CacheStore::with_shards(2 * s.cost_bytes(), 1);
        store.reserve(k(100));
        for i in 1..=10u128 {
            store.publish(k(i), sample(1, 64));
        }
        assert_eq!(store.probe(k(100)), Probe::InFlight, "pinned marker outlived pressure");
        assert!(store.bytes() <= store.budget_bytes());
        assert_eq!(store.inflight(), 1);
        store.cancel(k(100));
        assert_eq!(store.probe(k(100)), Probe::Absent);
    }

    #[test]
    fn oversize_sample_is_not_stored_and_unpins() {
        let store = CacheStore::with_shards(64, 1);
        store.reserve(k(1));
        store.publish(k(1), sample(4, 4096));
        assert_eq!(store.probe(k(1)), Probe::Absent);
        assert_eq!(store.bytes(), 0);
    }

    #[test]
    fn tiny_budgets_scale_shards_down_instead_of_going_inert() {
        // a 4 KiB cache must still be able to store a ~1.2 KiB sample —
        // with the full 8-way split it could not (512 B per shard)
        let store = CacheStore::new(4096);
        let s = sample(1, 256); // 96 + 256*4 + 32 = 1152 bytes
        assert!(s.cost_bytes() > 4096 / DEFAULT_STORE_SHARDS);
        store.publish(k(1), s);
        assert_eq!(store.probe(k(1)), Probe::Ready);
        // large budgets keep the default shard count semantics: entries
        // land and the global budget holds
        let big = CacheStore::new(64 << 20);
        big.publish(k(2), sample(4, 256));
        assert_eq!(big.probe(k(2)), Probe::Ready);
    }

    #[test]
    fn cancel_leaves_ready_entries_alone() {
        let store = CacheStore::with_shards(10_000, 1);
        store.publish(k(1), sample(1, 8));
        store.cancel(k(1));
        assert_eq!(store.probe(k(1)), Probe::Ready);
    }

    #[test]
    fn clear_flushes_everything() {
        let store = CacheStore::with_shards(10_000, 2);
        store.publish(k(1), sample(1, 8));
        store.reserve(k(2));
        store.clear();
        assert_eq!(store.bytes(), 0);
        assert_eq!(store.entries(), 0);
        assert_eq!(store.inflight(), 0);
        assert_eq!(store.probe(k(1)), Probe::Absent);
    }

    #[test]
    fn response_for_filters_outputs_per_caller() {
        let s = sample(2, 4);
        let with = s.response_for(0, true, 0.5, true);
        let without = s.response_for(0, false, 0.5, true);
        assert!(with.cached && without.cached);
        assert_eq!(with.steps_executed, s.steps_executed);
        match (&with.body, &without.body) {
            (ResponseBody::Ok { outputs: a }, ResponseBody::Ok { outputs: b }) => {
                assert_eq!(a, &s.outputs);
                assert!(b.is_empty());
            }
            _ => panic!("expected Ok bodies"),
        }
    }
}
