//! Deterministic sample cache + in-flight request coalescing.
//!
//! DDIM's consistency property (§4.3) makes serving cacheable: with η = 0
//! the map from (x_T, τ, kernel) to x_0 is a deterministic function, and
//! this stack extends that determinism to η > 0 via seeded PCG64 noise
//! streams — two requests with equal sampling-relevant fields produce
//! bitwise-identical samples. So the coordinator never needs to compute
//! the same sample twice:
//!
//! - [`key`]    — canonical 128-bit FNV-1a digest over the sampling-
//!   relevant request fields (`return_images` excluded) plus the manifest
//!   digest and backend kind;
//! - [`store`]  — byte-budgeted sharded LRU over completed responses,
//!   with in-flight placeholders pinned against eviction;
//! - [`coalesce`] — single-flight table: the first arrival for a key
//!   executes, concurrent identical requests park and share the result.
//!
//! [`CacheFront`] is the admission-path facade the router calls ahead of
//! shard dispatch; results are published back on engine completion via
//! the per-dispatch `on_done` callback. Executions admitted through the
//! front run with `return_images` forced on (the cache must hold the
//! pixels to serve any later caller that wants them); each waiter's
//! response is then filtered by its *own* `return_images`.

pub mod coalesce;
pub mod key;
pub mod store;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::artifacts::Manifest;
use crate::config::ServeConfig;
use crate::coordinator::request::{CacheMode, Request, Response, ResponseBody};
use crate::error::Result;
use crate::jobj;
use crate::json::Value;
use crate::runtime::BackendKind;
use crate::schedule::{OptSchedules, TauKind};

pub use coalesce::{Coalescer, ParkedWaiter, Role};
pub use key::{manifest_digest, CacheKey, KEY_VERSION};
pub use store::{CacheStore, CachedSample, Probe};

/// Completion callback a dispatched execution must be answered through
/// (exactly once — the shard layer guarantees delivery even on shutdown).
pub type DoneFn = Box<dyn FnOnce(Response) + Send>;

/// What the admission path decided for one request.
pub enum Admission {
    /// Answered from the completed-sample cache; nothing to dispatch.
    Served,
    /// Parked behind an identical in-flight execution; the leader's
    /// fan-out will answer it.
    Parked,
    /// Caller must dispatch `request` to a shard and deliver the engine's
    /// response to `on_done`.
    Execute { request: Request, on_done: DoneFn },
}

/// Point-in-time cache counters (the `"cache"` object in
/// `{"op":"metrics"}`).
#[derive(Debug, Clone, Default)]
pub struct CacheMetrics {
    pub enabled: bool,
    pub coalesce_enabled: bool,
    pub hits: u64,
    pub misses: u64,
    pub coalesced_waiters: u64,
    pub bypassed: u64,
    pub evictions: u64,
    pub bytes: u64,
    pub capacity_bytes: u64,
    pub entries: u64,
    pub inflight: u64,
}

impl CacheMetrics {
    /// hits / (hits + misses); 0 when idle.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Value {
        jobj![
            ("enabled", self.enabled),
            ("coalesce", self.coalesce_enabled),
            ("hits", self.hits),
            ("misses", self.misses),
            ("hit_rate", self.hit_rate()),
            ("coalesced_waiters", self.coalesced_waiters),
            ("bypassed", self.bypassed),
            ("evictions", self.evictions),
            ("bytes", self.bytes),
            ("capacity_bytes", self.capacity_bytes),
            ("entries", self.entries),
            ("inflight", self.inflight),
        ]
    }
}

/// The admission-path facade: store + single-flight table + counters.
/// Either half can be disabled independently (`--cache off` keeps
/// coalescing; `--coalesce off` keeps the store, at the cost of duplicate
/// concurrent executions racing to publish the same key).
pub struct CacheFront {
    store: Option<CacheStore>,
    coalesce: Option<Coalescer>,
    backend: BackendKind,
    /// Digest of the manifest the keys are minted against; swapped (and
    /// the store flushed) by [`CacheFront::refresh_manifest`].
    digest: AtomicU64,
    /// Optimized τ schedules under the current artifact root; their
    /// *content* digests feed `"tau":"opt"` keys, so re-optimizing a cell
    /// mints fresh keys even though every request field stays the same.
    opt: RwLock<OptSchedules>,
    hits: AtomicU64,
    misses: AtomicU64,
    coalesced: AtomicU64,
    bypassed: AtomicU64,
}

impl CacheFront {
    /// Build from serving config. Reads `manifest.json` under
    /// `cfg.artifact_root` for the key digest when any half is enabled;
    /// fully disabled fronts touch no disk and add one branch per submit.
    pub fn from_config(cfg: &ServeConfig) -> Result<CacheFront> {
        let active = cfg.cache_enabled || cfg.coalesce_enabled;
        let (digest, opt) = if active {
            let manifest = Manifest::load(&cfg.artifact_root)?;
            let digest = manifest_digest(&manifest);
            (digest, OptSchedules::load(&manifest.root, digest))
        } else {
            (0, OptSchedules::default())
        };
        Ok(CacheFront {
            store: cfg.cache_enabled.then(|| CacheStore::new(cfg.cache_bytes)),
            coalesce: cfg.coalesce_enabled.then(Coalescer::new),
            backend: cfg.backend,
            digest: AtomicU64::new(digest),
            opt: RwLock::new(opt),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            bypassed: AtomicU64::new(0),
        })
    }

    /// Both halves off?
    pub fn is_inert(&self) -> bool {
        self.store.is_none() && self.coalesce.is_none()
    }

    /// Re-read the manifest under `root` and, if its digest changed
    /// (artifact reload), flush the store and mint future keys against
    /// the new digest. Returns whether an invalidation happened. Old-
    /// digest entries could never answer new-digest keys anyway (the
    /// digest is hashed into every key) — the flush just stops dead
    /// entries from squatting on the byte budget.
    pub fn refresh_manifest(&self, root: &str) -> Result<bool> {
        if self.is_inert() {
            return Ok(false);
        }
        let manifest = Manifest::load(root)?;
        let new = manifest_digest(&manifest);
        // always reload the optimized-schedule registry — even when the
        // manifest digest is unchanged: re-optimizing a (dataset, S) cell
        // rewrites only its schedule file, and the new content digest must
        // start feeding `"tau":"opt"` keys immediately (old-content entries
        // age out of the LRU; no future key can name them)
        *self.opt.write().expect("opt registry lock") = OptSchedules::load(&manifest.root, new);
        let old = self.digest.swap(new, Ordering::SeqCst);
        if old != new {
            if let Some(store) = &self.store {
                store.clear();
            }
        }
        Ok(old != new)
    }

    /// Decide one request's path. `deliver` is the caller's completion
    /// callback; on `Served`/`Parked` it is (or will be) invoked without
    /// the caller dispatching anything. Callers that want to block wrap a
    /// channel sender; the event-loop transport hands responses to the
    /// owning reactor instead — nothing in this layer ever blocks on the
    /// consumer.
    pub fn admit(self: &Arc<Self>, req: Request, deliver: DoneFn) -> Admission {
        if req.cache == CacheMode::Bypass || self.is_inert() {
            if req.cache == CacheMode::Bypass {
                self.bypassed.fetch_add(1, Ordering::Relaxed);
            }
            return Admission::Execute { request: req, on_done: deliver };
        }
        let minted = self.digest.load(Ordering::SeqCst);
        // opt requests key on the resolved schedule's content digest; a
        // missing cell keys on 0 — harmless, since the engine will reject
        // the request with a typed schedule error before anything executes
        let opt_digest = if req.tau == TauKind::Opt {
            self.opt
                .read()
                .expect("opt registry lock")
                .digest(&req.dataset, req.steps)
                .unwrap_or(0)
        } else {
            0
        };
        let key = CacheKey::of(&req, minted, self.backend, opt_digest);
        // latency anchor: the transport arrival instant when the request
        // crossed a connection, so cache hits and coalesced waiters report
        // client-observed latency too — not just time inside this layer
        let arrived = req.qos.arrived.unwrap_or_else(Instant::now);
        if let Some(store) = &self.store {
            if let Some(sample) = store.get(key) {
                self.hits.fetch_add(1, Ordering::Relaxed);
                // cached responses carry id 0 (no engine ever assigned one)
                deliver(sample.response_for(
                    0,
                    req.return_images,
                    arrived.elapsed().as_secs_f64(),
                    true,
                ));
                return Admission::Served;
            }
        }
        let waiter = ParkedWaiter { deliver, return_images: req.return_images, arrived };
        // with coalescing the leader's waiter parks in the table beside
        // everyone else; without it the leader carries its waiter in the
        // completion closure and every concurrent miss executes
        let leader_waiter = match &self.coalesce {
            Some(co) => match co.lead_or_park(key, waiter) {
                Role::Parked => {
                    self.coalesced.fetch_add(1, Ordering::Relaxed);
                    return Admission::Parked;
                }
                Role::Leader => {
                    // close the lookup→lead race: if the previous flight
                    // for this key completed in the gap, it published
                    // *before* closing its flight ([`Self::finish`]), so a
                    // second store probe now sees the sample — serve it
                    // and fold the fresh flight instead of re-executing.
                    // Only the leader counts as a hit here: any follower
                    // drained with it was already counted in
                    // `coalesced_waiters` when it parked — every request
                    // lands in exactly one of {hit, miss, coalesced}.
                    if let Some(store) = &self.store {
                        if let Some(sample) = store.get(key) {
                            self.hits.fetch_add(1, Ordering::Relaxed);
                            // complete() yields the leader first: followers
                            // carry the coalesced marker their park already
                            // counted, the leader stays a plain hit
                            for (i, w) in co.complete(key).into_iter().enumerate() {
                                let mut resp = sample.response_for(
                                    0,
                                    w.return_images,
                                    w.arrived.elapsed().as_secs_f64(),
                                    true,
                                );
                                resp.coalesced = i > 0;
                                (w.deliver)(resp);
                            }
                            return Admission::Served;
                        }
                    }
                    None
                }
            },
            None => Some(waiter),
        };
        self.misses.fetch_add(1, Ordering::Relaxed);
        if let Some(store) = &self.store {
            store.reserve(key);
        }
        let mut request = req;
        request.return_images = true; // the cache needs the pixels
        let front = self.clone();
        Admission::Execute {
            request,
            on_done: Box::new(move |resp| front.finish(key, minted, leader_waiter, resp)),
        }
    }

    /// Publish an execution's outcome: store it (success) or drop the
    /// in-flight pin (failure), then answer every waiter of the flight —
    /// each filtered by its own `return_images`, timed from its own
    /// arrival. Runs on the shard worker thread at completion delivery.
    ///
    /// `minted` is the manifest digest the key was minted under: if the
    /// manifest was reloaded while this execution was in flight (the
    /// store was flushed, the pin with it), the sample is *not* published
    /// — no future key can name it, so storing it would only squat on the
    /// byte budget. The waiters still get their result: their requests
    /// were admitted (and executed) under the old manifest.
    fn finish(&self, key: CacheKey, minted: u64, leader: Option<ParkedWaiter>, resp: Response) {
        let id = resp.id;
        // engine-recorded stage spans belong to the execution the leader
        // paid for; followers shared the result without being traced
        let spans = resp.spans;
        let (sample, failure) = match resp.body {
            ResponseBody::Ok { outputs } => (
                Some(Arc::new(CachedSample { outputs, steps_executed: resp.steps_executed })),
                None,
            ),
            // errors AND typed rejections (overload, deadline expiry): the
            // pin is dropped and nothing is published — a deadline-expired
            // execution must never seed the cache — but the failure body is
            // fanned out verbatim so every waiter sees the typed record
            other => (None, Some(other)),
        };
        // publish BEFORE closing the flight: any thread that missed the
        // store but finds the flight already closed is guaranteed to see
        // the sample on its leader re-probe — with the store on, a key
        // can never execute twice concurrently
        if let Some(store) = &self.store {
            match &sample {
                Some(s) if self.digest.load(Ordering::SeqCst) == minted => {
                    store.publish(key, s.clone());
                }
                // error, or manifest reloaded mid-flight (stale sample):
                // don't store, and drop the in-flight pin — including one
                // a reserve() racing the invalidation flush may have
                // re-inserted (cancel never touches Ready entries)
                _ => store.cancel(key),
            }
        }
        let waiters = match (&self.coalesce, leader) {
            (Some(co), None) => co.complete(key),
            (_, Some(w)) => vec![w],
            (None, None) => Vec::new(),
        };
        for (i, w) in waiters.into_iter().enumerate() {
            let latency_s = w.arrived.elapsed().as_secs_f64();
            let mut resp = match (&sample, &failure) {
                (Some(s), _) => s.response_for(id, w.return_images, latency_s, false),
                (None, Some(body)) => Response {
                    id,
                    body: body.clone(),
                    latency_s,
                    steps_executed: 0,
                    cached: false,
                    degraded: None,
                    spans: None,
                    coalesced: false,
                },
                (None, None) => unreachable!("response is Ok or a failure"),
            };
            // coalesce::complete yields the leader's waiter first (arrival
            // order), so everyone after it shared the leader's execution —
            // the access log's "coalesced" disposition
            resp.coalesced = i > 0;
            if i == 0 {
                resp.spans = spans;
            }
            (w.deliver)(resp);
        }
    }

    /// Does the optimized-schedule registry hold a cell for
    /// `(dataset, steps)`? The router's degradation ladder asks before
    /// rewriting a downgraded request to `"tau":"opt"` — a budget with no
    /// pre-optimized cell keeps the request's original τ kind instead.
    pub fn has_opt_cell(&self, dataset: &str, steps: usize) -> bool {
        self.opt.read().expect("opt registry lock").get(dataset, steps).is_some()
    }

    /// Manifest digest keys are currently minted against (0 when both
    /// halves are disabled). Exported in `ddim_build_info` so dashboards
    /// can correlate metric discontinuities with artifact rollouts.
    pub fn current_digest(&self) -> u64 {
        self.digest.load(Ordering::SeqCst)
    }

    pub fn metrics(&self) -> CacheMetrics {
        CacheMetrics {
            enabled: self.store.is_some(),
            coalesce_enabled: self.coalesce.is_some(),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            coalesced_waiters: self.coalesced.load(Ordering::Relaxed),
            bypassed: self.bypassed.load(Ordering::Relaxed),
            evictions: self.store.as_ref().map(CacheStore::evictions).unwrap_or(0),
            bytes: self.store.as_ref().map(|s| s.bytes() as u64).unwrap_or(0),
            capacity_bytes: self.store.as_ref().map(|s| s.budget_bytes() as u64).unwrap_or(0),
            entries: self.store.as_ref().map(|s| s.entries() as u64).unwrap_or(0),
            inflight: self.store.as_ref().map(|s| s.inflight() as u64).unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::SamplerKind;
    use crate::schedule::{NoiseMode, TauKind};
    use std::sync::mpsc;

    /// Channel-backed DoneFn: what a blocking caller wraps around admit.
    fn chan() -> (DoneFn, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Box::new(move |r| {
                let _ = tx.send(r);
            }),
            rx,
        )
    }

    fn front(cache: bool, coalesce: bool) -> Arc<CacheFront> {
        Arc::new(CacheFront {
            store: cache.then(|| CacheStore::new(1 << 20)),
            coalesce: coalesce.then(Coalescer::new),
            backend: BackendKind::Reference,
            digest: AtomicU64::new(0x5eed),
            opt: RwLock::new(OptSchedules::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            bypassed: AtomicU64::new(0),
        })
    }

    fn req(seed: u64, return_images: bool, cache: CacheMode) -> Request {
        Request {
            dataset: "sprites".into(),
            steps: 5,
            mode: NoiseMode::Eta(0.0),
            tau: TauKind::Linear,
            sampler: SamplerKind::Ddim,
            body: crate::coordinator::request::RequestBody::Generate { count: 1, seed },
            return_images,
            cache,
            qos: Default::default(),
        }
    }

    fn ok_resp(id: u64, outputs: Vec<Vec<f32>>) -> Response {
        Response {
            id,
            body: ResponseBody::Ok { outputs },
            latency_s: 0.25,
            steps_executed: 5,
            cached: false,
            degraded: None,
            spans: None,
            coalesced: false,
        }
    }

    #[test]
    fn miss_execute_publish_then_hit() {
        let f = front(true, true);
        let (tx1, rx1) = chan();
        let Admission::Execute { request, on_done } = f.admit(req(7, false, CacheMode::Use), tx1)
        else {
            panic!("first arrival must execute");
        };
        assert!(request.return_images, "executions behind the cache keep pixels");
        on_done(ok_resp(3, vec![vec![1.0, 2.0]]));
        let leader = rx1.recv().unwrap();
        assert!(!leader.cached);
        match &leader.body {
            // the leader asked for no pixels: filtered out despite forcing
            ResponseBody::Ok { outputs } => assert!(outputs.is_empty()),
            other => panic!("{other:?}"),
        }
        // identical request now hits, and DOES get pixels if it asks
        let (tx2, rx2) = chan();
        assert!(matches!(f.admit(req(7, true, CacheMode::Use), tx2), Admission::Served));
        let hit = rx2.recv().unwrap();
        assert!(hit.cached);
        assert_eq!(hit.steps_executed, 5);
        match &hit.body {
            ResponseBody::Ok { outputs } => assert_eq!(outputs, &vec![vec![1.0, 2.0]]),
            other => panic!("{other:?}"),
        }
        let m = f.metrics();
        assert_eq!((m.hits, m.misses, m.coalesced_waiters), (1, 1, 0));
        assert!((m.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn concurrent_identical_requests_coalesce_onto_one_execution() {
        let f = front(true, true);
        let (tx1, rx1) = chan();
        let (tx2, rx2) = chan();
        let (tx3, rx3) = chan();
        let Admission::Execute { on_done, .. } = f.admit(req(9, true, CacheMode::Use), tx1)
        else {
            panic!("leader executes");
        };
        assert!(matches!(f.admit(req(9, false, CacheMode::Use), tx2), Admission::Parked));
        assert!(matches!(f.admit(req(9, true, CacheMode::Use), tx3), Admission::Parked));
        on_done(ok_resp(11, vec![vec![0.5]]));
        let (r1, r2, r3) = (rx1.recv().unwrap(), rx2.recv().unwrap(), rx3.recv().unwrap());
        for r in [&r1, &r2, &r3] {
            assert!(!r.cached);
            assert_eq!(r.steps_executed, 5);
        }
        // disposition marker: the leader paid for the execution, the
        // parked waiters shared it
        assert!(!r1.coalesced);
        assert!(r2.coalesced && r3.coalesced);
        match (&r1.body, &r2.body, &r3.body) {
            (
                ResponseBody::Ok { outputs: a },
                ResponseBody::Ok { outputs: b },
                ResponseBody::Ok { outputs: c },
            ) => {
                assert_eq!(a, &vec![vec![0.5f32]]);
                assert!(b.is_empty(), "parked waiter did not ask for pixels");
                assert_eq!(c, a, "pixel-wanting waiter shares the leader's outputs");
            }
            other => panic!("{other:?}"),
        }
        let m = f.metrics();
        assert_eq!((m.hits, m.misses, m.coalesced_waiters), (0, 1, 2));
    }

    #[test]
    fn bypass_skips_everything() {
        let f = front(true, true);
        // prime the store
        let (tx, rx) = chan();
        let Admission::Execute { on_done, .. } = f.admit(req(1, true, CacheMode::Use), tx)
        else {
            panic!()
        };
        on_done(ok_resp(1, vec![vec![1.0]]));
        rx.recv().unwrap();
        // bypass: same key, but must execute again and not coalesce
        let (tx, rx) = chan();
        let Admission::Execute { request, on_done } = f.admit(req(1, true, CacheMode::Bypass), tx)
        else {
            panic!("bypass must execute");
        };
        assert!(request.return_images);
        on_done(ok_resp(2, vec![vec![9.0]]));
        let r = rx.recv().unwrap();
        assert!(!r.cached);
        let m = f.metrics();
        assert_eq!(m.bypassed, 1);
        assert_eq!(m.hits, 0);
    }

    #[test]
    fn error_responses_are_fanned_out_and_never_cached() {
        let f = front(true, true);
        let (tx1, rx1) = chan();
        let (tx2, rx2) = chan();
        let Admission::Execute { on_done, .. } = f.admit(req(5, false, CacheMode::Use), tx1)
        else {
            panic!()
        };
        assert!(matches!(f.admit(req(5, false, CacheMode::Use), tx2), Admission::Parked));
        on_done(Response {
            id: 0,
            body: ResponseBody::Error { message: "queue full".into() },
            latency_s: 0.0,
            steps_executed: 0,
            cached: false,
            degraded: None,
            spans: None,
            coalesced: false,
        });
        for rx in [rx1, rx2] {
            let r = rx.recv().unwrap();
            assert!(matches!(r.body, ResponseBody::Error { .. }));
            assert!(!r.cached);
        }
        // the failed key is unpinned and free: next arrival executes fresh
        let (tx3, _rx3) = chan();
        assert!(matches!(
            f.admit(req(5, false, CacheMode::Use), tx3),
            Admission::Execute { .. }
        ));
        assert_eq!(f.metrics().entries, 0);
        assert_eq!(f.metrics().inflight, 0);
    }

    #[test]
    fn queue_full_reject_fans_out_to_every_waiter_exactly_once() {
        use crate::coordinator::request::{Reject, RejectReason};
        let f = front(true, true);
        let (tx1, rx1) = chan();
        let (tx2, rx2) = chan();
        let (tx3, rx3) = chan();
        let Admission::Execute { on_done, .. } = f.admit(req(21, false, CacheMode::Use), tx1)
        else {
            panic!("leader executes");
        };
        assert!(matches!(f.admit(req(21, false, CacheMode::Use), tx2), Admission::Parked));
        assert!(matches!(f.admit(req(21, true, CacheMode::Use), tx3), Admission::Parked));
        // the shard's queue rejected the leader: a typed overload response
        on_done(Response {
            id: 0,
            body: ResponseBody::Reject(Reject {
                reason: RejectReason::Overload,
                queued_lanes: 40,
                message: "queue full (capacity 4)".into(),
            }),
            latency_s: 0.0,
            steps_executed: 0,
            cached: false,
            degraded: None,
            spans: None,
            coalesced: false,
        });
        // every waiter is answered exactly once, with the typed body intact
        for rx in [&rx1, &rx2, &rx3] {
            let r = rx.recv().unwrap();
            match &r.body {
                ResponseBody::Reject(rej) => {
                    assert_eq!(rej.reason, RejectReason::Overload);
                    assert_eq!(rej.queued_lanes, 40);
                }
                other => panic!("want typed reject, got {other:?}"),
            }
            assert!(!r.cached);
        }
        for rx in [rx1, rx2, rx3] {
            assert!(rx.try_recv().is_err(), "waiter answered twice");
        }
        // nothing published, nothing pinned: the next arrival executes fresh
        assert_eq!((f.metrics().entries, f.metrics().inflight), (0, 0));
        let (tx4, _rx4) = chan();
        assert!(matches!(
            f.admit(req(21, false, CacheMode::Use), tx4),
            Admission::Execute { .. }
        ));
    }

    #[test]
    fn deadline_expired_execution_is_never_published() {
        use crate::coordinator::request::{Reject, RejectReason};
        let f = front(true, true);
        let (tx1, rx1) = chan();
        let (tx2, rx2) = chan();
        let Admission::Execute { on_done, .. } = f.admit(req(33, true, CacheMode::Use), tx1)
        else {
            panic!()
        };
        assert!(matches!(f.admit(req(33, true, CacheMode::Use), tx2), Admission::Parked));
        // the engine cancelled the work at its pre-publish deadline check
        on_done(Response {
            id: 0,
            body: ResponseBody::Reject(Reject {
                reason: RejectReason::Deadline,
                queued_lanes: 0,
                message: "deadline expired; work cancelled".into(),
            }),
            latency_s: 0.0,
            steps_executed: 0,
            cached: false,
            degraded: None,
            spans: None,
            coalesced: false,
        });
        for rx in [rx1, rx2] {
            let r = rx.recv().unwrap();
            let deadline = matches!(
                &r.body,
                ResponseBody::Reject(rej) if rej.reason == RejectReason::Deadline
            );
            assert!(deadline, "want typed deadline timeout, got {:?}", r.body);
        }
        // the cancelled sample must not seed the cache for future hits
        let m = f.metrics();
        assert_eq!((m.entries, m.inflight, m.bytes), (0, 0, 0));
        let (tx3, _rx3) = chan();
        assert!(matches!(
            f.admit(req(33, true, CacheMode::Use), tx3),
            Admission::Execute { .. }
        ));
    }

    #[test]
    fn coalesce_off_executes_every_concurrent_miss() {
        let f = front(true, false);
        let (tx1, rx1) = chan();
        let (tx2, rx2) = chan();
        let Admission::Execute { on_done: d1, .. } = f.admit(req(2, true, CacheMode::Use), tx1)
        else {
            panic!()
        };
        let Admission::Execute { on_done: d2, .. } = f.admit(req(2, true, CacheMode::Use), tx2)
        else {
            panic!("coalesce off: concurrent identical misses both execute");
        };
        d1(ok_resp(1, vec![vec![3.0]]));
        d2(ok_resp(2, vec![vec![3.0]]));
        assert!(!rx1.recv().unwrap().cached);
        assert!(!rx2.recv().unwrap().cached);
        let m = f.metrics();
        assert_eq!((m.misses, m.coalesced_waiters, m.entries), (2, 0, 1));
        // and the store still serves the published result
        let (tx3, rx3) = chan();
        assert!(matches!(f.admit(req(2, true, CacheMode::Use), tx3), Admission::Served));
        assert!(rx3.recv().unwrap().cached);
    }

    #[test]
    fn cache_off_coalesce_on_single_flights_without_storing() {
        let f = front(false, true);
        let (tx1, rx1) = chan();
        let (tx2, rx2) = chan();
        let Admission::Execute { on_done, .. } = f.admit(req(4, true, CacheMode::Use), tx1)
        else {
            panic!()
        };
        assert!(matches!(f.admit(req(4, true, CacheMode::Use), tx2), Admission::Parked));
        on_done(ok_resp(1, vec![vec![7.0]]));
        assert!(!rx1.recv().unwrap().cached);
        assert!(!rx2.recv().unwrap().cached);
        // no store: the next identical request executes again
        let (tx3, _rx3) = chan();
        assert!(matches!(
            f.admit(req(4, true, CacheMode::Use), tx3),
            Admission::Execute { .. }
        ));
        let m = f.metrics();
        assert!(!m.enabled && m.coalesce_enabled);
        assert_eq!((m.hits, m.coalesced_waiters), (0, 1));
    }

    #[test]
    fn stale_digest_execution_is_not_published() {
        let f = front(true, true);
        let (tx, rx) = chan();
        let Admission::Execute { on_done, .. } = f.admit(req(8, true, CacheMode::Use), tx)
        else {
            panic!()
        };
        // manifest reload lands while the execution is in flight: the
        // store is flushed and future keys mint under the new digest
        f.digest.store(0x9999, Ordering::SeqCst);
        if let Some(store) = &f.store {
            store.clear();
        }
        on_done(ok_resp(1, vec![vec![2.5]]));
        // the waiter still gets its (old-manifest) result...
        let r = rx.recv().unwrap();
        assert!(!r.cached);
        match &r.body {
            ResponseBody::Ok { outputs } => assert_eq!(outputs, &vec![vec![2.5f32]]),
            other => panic!("{other:?}"),
        }
        // ...but nothing unreachable squats on the byte budget
        let m = f.metrics();
        assert_eq!((m.entries, m.inflight, m.bytes), (0, 0, 0));
        // and the same request under the new digest executes fresh
        let (tx2, _rx2) = chan();
        assert!(matches!(
            f.admit(req(8, true, CacheMode::Use), tx2),
            Admission::Execute { .. }
        ));
    }

    #[test]
    fn inert_front_passes_through() {
        let f = front(false, false);
        assert!(f.is_inert());
        let (tx, rx) = chan();
        let Admission::Execute { request, on_done } = f.admit(req(6, false, CacheMode::Use), tx)
        else {
            panic!()
        };
        assert!(!request.return_images, "inert front must not rewrite the request");
        on_done(ok_resp(1, Vec::new()));
        assert!(!rx.recv().unwrap().cached);
    }
}
