//! Canonical cache keys: a 128-bit FNV-1a digest over every field that
//! participates in the sampling function, and nothing else.
//!
//! The determinism contract (DDIM §4.3 extended to η > 0 by seeded PCG64
//! noise streams) says a response is a pure function of:
//!
//!   (manifest digest, backend, dataset, steps, τ kind, η mode, sampler,
//!    body kind + seed-or-state-bits)
//!
//! `return_images` is **explicitly excluded** — it only controls whether
//! the outputs ride the wire, not what they are — as is the per-request
//! `"cache"` directive itself. Provided states (decode latents / encode
//! images) are hashed at full f32-bit fidelity: two latents that differ in
//! one mantissa bit are different requests.
//!
//! Collisions: 128-bit FNV-1a ([`crate::rng::Fnv128`] — the hashing
//! primitives live in the rng substrate) over tagged, length-prefixed
//! fields. A digest collision would serve the wrong sample bitwise, so
//! the key is twice the width a hash table would need.

use crate::artifacts::Manifest;
use crate::coordinator::request::{Request, RequestBody};
use crate::rng::{Fnv128, Fnv64};
use crate::runtime::BackendKind;
use crate::schedule::{NoiseMode, TauKind};

/// The canonical identity of one cacheable response.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey(pub u128);

/// Key-format version — bump when the field encoding changes so stale
/// processes can never agree on a digest by accident.
/// v2: `"tau":"opt"` requests additionally hash the optimized schedule's
/// *content* digest (`opt_digest`).
pub const KEY_VERSION: u8 = 2;

impl CacheKey {
    /// Digest every sampling-relevant field of `req`. `return_images` and
    /// the request's own `"cache"` directive are deliberately not hashed.
    ///
    /// `opt_digest` is the content digest of the optimized schedule file
    /// resolved for this request (0 unless `req.tau` is [`TauKind::Opt`]).
    /// The kind tag alone is not enough for `opt`: re-optimizing a
    /// (dataset, S) cell changes the sample a request produces while every
    /// request field stays identical, so the key must hash what the
    /// schedule *is*, not what it is called.
    pub fn of(
        req: &Request,
        manifest_digest: u64,
        backend: BackendKind,
        opt_digest: u64,
    ) -> CacheKey {
        let mut h = Fnv128::new();
        h.byte(KEY_VERSION);
        h.u64(manifest_digest);
        h.byte(backend_tag(backend));
        h.str(&req.dataset);
        h.u64(req.steps as u64);
        h.byte(tau_tag(req.tau));
        if req.tau == TauKind::Opt {
            h.u64(opt_digest);
        }
        match req.mode {
            NoiseMode::Eta(e) => {
                // normalise -0.0 (parseable from the wire) onto +0.0: both
                // mean "deterministic" and must map to one key
                let e = if e == 0.0 { 0.0 } else { e };
                h.byte(0).u64(e.to_bits());
            }
            NoiseMode::SigmaHat => {
                h.byte(1);
            }
        }
        h.byte(req.sampler.index() as u8);
        match &req.body {
            RequestBody::Generate { count, seed } => {
                h.byte(0).u64(*count as u64).u64(*seed);
            }
            RequestBody::Decode { latents } => {
                h.byte(1);
                hash_rows(&mut h, latents);
            }
            RequestBody::Encode { images } => {
                h.byte(2);
                hash_rows(&mut h, images);
            }
        }
        CacheKey(h.finish())
    }

    /// Which store shard this key lives in (xor-folded to 64 bits first so
    /// every digest bit participates).
    pub fn shard(&self, n: usize) -> usize {
        debug_assert!(n > 0);
        let folded = (self.0 as u64) ^ ((self.0 >> 64) as u64);
        (folded % n as u64) as usize
    }
}

fn hash_rows(h: &mut Fnv128, rows: &[Vec<f32>]) {
    h.u64(rows.len() as u64);
    for row in rows {
        h.u64(row.len() as u64);
        for &v in row {
            h.u32(v.to_bits());
        }
    }
}

fn backend_tag(b: BackendKind) -> u8 {
    match b {
        BackendKind::Reference => 0,
        BackendKind::Xla => 1,
    }
}

fn tau_tag(t: TauKind) -> u8 {
    match t {
        TauKind::Linear => 0,
        TauKind::Quadratic => 1,
        TauKind::Opt => 2,
    }
}

/// Digest of everything in the manifest that can change what a sample
/// looks like: geometry, horizon, buckets, and the per-dataset model
/// identity (HLO paths + trained-parameter fingerprint — the reference
/// backend derives its synthetic ε-model from exactly these fields).
/// Embedded in every [`CacheKey`], so entries minted against one artifact
/// tree can never answer requests against another; the store is also
/// flushed outright when the digest changes ([`super::CacheFront`]).
pub fn manifest_digest(m: &Manifest) -> u64 {
    let mut h = Fnv64::new();
    h.u64(m.img as u64);
    h.u64(m.channels as u64);
    h.u64(m.t_max as u64);
    h.u64(m.buckets.len() as u64);
    for &b in &m.buckets {
        h.u64(b as u64);
    }
    h.u64(m.datasets.len() as u64);
    for (name, ds) in &m.datasets {
        h.str(name);
        h.u64(ds.params);
        h.u64(ds.final_loss.to_bits());
        h.u64(ds.ref_n as u64);
        for p in &ds.hlo {
            h.str(p);
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::CacheMode;
    use crate::sampler::SamplerKind;

    fn base_req() -> Request {
        Request {
            dataset: "sprites".into(),
            steps: 20,
            mode: NoiseMode::Eta(0.0),
            tau: TauKind::Linear,
            sampler: SamplerKind::Ddim,
            body: RequestBody::Generate { count: 4, seed: 7 },
            return_images: false,
            cache: CacheMode::Use,
            qos: Default::default(),
        }
    }

    fn key(r: &Request) -> CacheKey {
        CacheKey::of(r, 0xabcd, BackendKind::Reference, 0)
    }

    #[test]
    fn excluded_fields_do_not_change_the_key() {
        let a = base_req();
        let mut b = base_req();
        b.return_images = true;
        b.cache = CacheMode::Bypass;
        // QoS is delivery policy, not sampling input: an interactive
        // request with a tight deadline wants the *same bits* as a
        // best-effort one. (Degradation rewrites `steps` itself, which IS
        // keyed, before admission — so degraded flights still fork keys.)
        b.qos.priority = crate::coordinator::request::Priority::Interactive;
        b.qos.deadline_ms = Some(250);
        b.qos.arrived = Some(std::time::Instant::now());
        assert_eq!(key(&a), key(&b));
    }

    #[test]
    fn every_sampling_relevant_field_changes_the_key() {
        let base = key(&base_req());
        let perturbed: Vec<Request> = vec![
            Request { dataset: "blobs".into(), ..base_req() },
            Request { steps: 21, ..base_req() },
            Request { mode: NoiseMode::Eta(0.5), ..base_req() },
            Request { mode: NoiseMode::SigmaHat, ..base_req() },
            Request { tau: TauKind::Quadratic, ..base_req() },
            Request { sampler: SamplerKind::PfOde, ..base_req() },
            Request { body: RequestBody::Generate { count: 5, seed: 7 }, ..base_req() },
            Request { body: RequestBody::Generate { count: 4, seed: 8 }, ..base_req() },
        ];
        for p in &perturbed {
            assert_ne!(key(p), base, "{p:?} should not collide with the base request");
        }
        // environment axes
        assert_ne!(CacheKey::of(&base_req(), 0xabce, BackendKind::Reference, 0), base);
        assert_ne!(CacheKey::of(&base_req(), 0xabcd, BackendKind::Xla, 0), base);
    }

    #[test]
    fn opt_schedule_content_is_keyed() {
        let opt = Request { tau: TauKind::Opt, ..base_req() };
        let a = CacheKey::of(&opt, 0xabcd, BackendKind::Reference, 111);
        let b = CacheKey::of(&opt, 0xabcd, BackendKind::Reference, 222);
        // same request, same kind tag — a re-optimized schedule file must
        // still mint a fresh key
        assert_ne!(a, b);
        assert_eq!(a, CacheKey::of(&opt, 0xabcd, BackendKind::Reference, 111));
        // opt requests never collide with the closed-form kinds
        assert_ne!(a, key(&base_req()));
        assert_ne!(a, key(&Request { tau: TauKind::Quadratic, ..base_req() }));
        // the digest is inert for closed-form kinds (call sites pass 0,
        // but a sloppy non-zero must not fork the key space)
        let lin = base_req();
        assert_eq!(
            CacheKey::of(&lin, 0xabcd, BackendKind::Reference, 7),
            CacheKey::of(&lin, 0xabcd, BackendKind::Reference, 0)
        );
    }

    #[test]
    fn eta_zero_is_canonical() {
        let pos = Request { mode: NoiseMode::Eta(0.0), ..base_req() };
        let neg = Request { mode: NoiseMode::Eta(-0.0), ..base_req() };
        assert_eq!(key(&pos), key(&neg));
    }

    #[test]
    fn state_bits_and_body_kind_are_keyed() {
        let lat = vec![vec![0.5f32, -0.25], vec![1.0, 2.0]];
        let dec = Request { body: RequestBody::Decode { latents: lat.clone() }, ..base_req() };
        let enc = Request { body: RequestBody::Encode { images: lat.clone() }, ..base_req() };
        assert_ne!(key(&dec), key(&enc), "decode and encode of the same matrix differ");
        // one mantissa bit flip is a different request
        let mut flipped = lat.clone();
        flipped[1][0] = f32::from_bits(flipped[1][0].to_bits() ^ 1);
        let dec2 = Request { body: RequestBody::Decode { latents: flipped }, ..base_req() };
        assert_ne!(key(&dec), key(&dec2));
        // row-boundary ambiguity: [[a,b],[c]] vs [[a],[b,c]]
        let ragged1 = Request {
            body: RequestBody::Decode { latents: vec![vec![1.0, 2.0], vec![3.0]] },
            ..base_req()
        };
        let ragged2 = Request {
            body: RequestBody::Decode { latents: vec![vec![1.0], vec![2.0, 3.0]] },
            ..base_req()
        };
        assert_ne!(key(&ragged1), key(&ragged2));
    }

    #[test]
    fn shard_is_stable_and_in_range() {
        let k = key(&base_req());
        for n in [1usize, 2, 8, 16] {
            assert!(k.shard(n) < n);
            assert_eq!(k.shard(n), k.shard(n));
        }
    }
}
