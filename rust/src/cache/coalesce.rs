//! Single-flight coalescing: at most one execution per cache key at a
//! time. The first arrival for a key becomes the **leader** and is
//! dispatched to a shard; every concurrent identical request parks as a
//! waiter. When the leader's engine response arrives, one fan-out answers
//! everybody — each waiter gets its own response, tailored to its own
//! `return_images`, with latency measured from its own arrival.
//!
//! The table holds waiters only; the eviction-pinned in-flight marker
//! lives in the store ([`super::store`]) and the decision logic in
//! [`super::CacheFront`]. Entries are created by `lead_or_park` and
//! removed by exactly one `complete` call — the shard layer guarantees
//! every dispatched request is answered exactly once (success, rejection,
//! or shutdown error), so no entry can leak.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::cache::key::CacheKey;
use crate::cache::DoneFn;

/// One parked client: how to answer it, whether it wants pixels, and
/// when it arrived (for per-waiter latency). Delivery is a callback, not
/// a channel: callers that block on a channel wrap one themselves, while
/// event-loop callers (the v2 transport reactors) hand the response
/// straight to the owning reactor without any thread parked waiting.
pub struct ParkedWaiter {
    pub deliver: DoneFn,
    pub return_images: bool,
    pub arrived: Instant,
}

/// Outcome of [`Coalescer::lead_or_park`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Caller is the first arrival: dispatch the execution.
    Leader,
    /// An identical execution is in flight; the waiter was parked.
    Parked,
}

/// The single-flight table.
#[derive(Default)]
pub struct Coalescer {
    table: Mutex<HashMap<u128, Vec<ParkedWaiter>>>,
}

impl Coalescer {
    pub fn new() -> Coalescer {
        Coalescer::default()
    }

    /// Atomically either register `waiter` as the leader of a new flight
    /// (the leader's own waiter is parked too — the fan-out answers it
    /// like any other) or append it to an existing flight.
    pub fn lead_or_park(&self, key: CacheKey, waiter: ParkedWaiter) -> Role {
        let mut table = self.table.lock().unwrap();
        match table.entry(key.0) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                e.get_mut().push(waiter);
                Role::Parked
            }
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(vec![waiter]);
                Role::Leader
            }
        }
    }

    /// Close the flight: remove the entry and hand back every waiter
    /// (leader first, in arrival order) for fan-out.
    pub fn complete(&self, key: CacheKey) -> Vec<ParkedWaiter> {
        self.table.lock().unwrap().remove(&key.0).unwrap_or_default()
    }

    /// Flights currently open (metrics).
    pub fn open_flights(&self) -> usize {
        self.table.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Response;
    use std::sync::mpsc;

    fn waiter() -> (ParkedWaiter, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let deliver: DoneFn = Box::new(move |r| {
            let _ = tx.send(r);
        });
        (ParkedWaiter { deliver, return_images: false, arrived: Instant::now() }, rx)
    }

    #[test]
    fn first_leads_rest_park_and_complete_drains() {
        let co = Coalescer::new();
        let k = CacheKey(42);
        let (w1, _r1) = waiter();
        let (w2, _r2) = waiter();
        let (w3, _r3) = waiter();
        assert_eq!(co.lead_or_park(k, w1), Role::Leader);
        assert_eq!(co.lead_or_park(k, w2), Role::Parked);
        assert_eq!(co.lead_or_park(k, w3), Role::Parked);
        assert_eq!(co.open_flights(), 1);
        let drained = co.complete(k);
        assert_eq!(drained.len(), 3, "leader + both waiters come back");
        assert_eq!(co.open_flights(), 0);
        // the key is free again: a new arrival leads a fresh flight
        let (w4, _r4) = waiter();
        assert_eq!(co.lead_or_park(k, w4), Role::Leader);
    }

    #[test]
    fn distinct_keys_are_independent_flights() {
        let co = Coalescer::new();
        let (w1, _r1) = waiter();
        let (w2, _r2) = waiter();
        assert_eq!(co.lead_or_park(CacheKey(1), w1), Role::Leader);
        assert_eq!(co.lead_or_park(CacheKey(2), w2), Role::Leader);
        assert_eq!(co.open_flights(), 2);
        assert_eq!(co.complete(CacheKey(1)).len(), 1);
        assert_eq!(co.complete(CacheKey(2)).len(), 1);
    }

    #[test]
    fn complete_on_unknown_key_is_empty() {
        let co = Coalescer::new();
        assert!(co.complete(CacheKey(7)).is_empty());
    }
}
