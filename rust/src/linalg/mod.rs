//! Dense linear algebra substrate for the proxy-FID metric (DESIGN.md §2):
//! a small f64 matrix type, a Jacobi eigensolver for symmetric matrices,
//! the SPD matrix square root built on it, and Cholesky (used by tests and
//! by the workload generator's correlated-arrival model).
//!
//! The paper's FID needs `Tr((Σ₁Σ₂)^{1/2})`; we compute it through the
//! symmetric form `sqrtm(√Σ₁ Σ₂ √Σ₁)` so every eigen-decomposition stays on
//! a symmetric matrix, where Jacobi is simple, robust, and — at 24×24 —
//! plenty fast.

mod cholesky;
mod jacobi;
mod matrix;

pub use cholesky::cholesky;
pub use jacobi::{eigh, sqrtm_spd};
pub use matrix::Mat;
