//! Cyclic Jacobi eigensolver for symmetric matrices, plus the SPD matrix
//! square root built on it. At the proxy-FID's 24×24 this converges in a
//! handful of sweeps and is numerically very well-behaved (every rotation
//! is orthogonal), which is exactly what a metric underpinning every
//! Table-1 cell needs.

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Eigendecomposition of a symmetric matrix: returns (eigenvalues,
/// eigenvectors-as-columns) with `A ≈ V diag(w) Vᵀ`. Eigenvalues are
/// ascending.
pub fn eigh(a: &Mat, tol: f64, max_sweeps: usize) -> Result<(Vec<f64>, Mat)> {
    if a.rows() != a.cols() {
        return Err(Error::Linalg("eigh wants a square matrix".into()));
    }
    if !a.is_symmetric(1e-8) {
        return Err(Error::Linalg("eigh wants a symmetric matrix".into()));
    }
    let n = a.rows();
    let mut m = a.clone();
    let mut v = Mat::identity(n);

    for _sweep in 0..max_sweeps {
        let mut off: f64 = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() <= tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < f64::EPSILON {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                // stable tangent of the rotation angle
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // A <- Jᵀ A J applied to rows/cols p, q
                for k in 0..n {
                    let akp = m[(k, p)];
                    let akq = m[(k, q)];
                    m[(k, p)] = c * akp - s * akq;
                    m[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[(p, k)];
                    let aqk = m[(q, k)];
                    m[(p, k)] = c * apk - s * aqk;
                    m[(q, k)] = s * apk + c * aqk;
                }
                // V <- V J
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // sort ascending and permute columns of V to match
    let mut idx: Vec<usize> = (0..n).collect();
    let w_raw: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&i, &j| w_raw[i].partial_cmp(&w_raw[j]).unwrap());
    let w: Vec<f64> = idx.iter().map(|&i| w_raw[i]).collect();
    let mut vs = Mat::zeros(n, n);
    for (new_col, &old_col) in idx.iter().enumerate() {
        for r in 0..n {
            vs[(r, new_col)] = v[(r, old_col)];
        }
    }
    Ok((w, vs))
}

/// Matrix square root of a symmetric PSD matrix: `sqrtm(A) = V √w Vᵀ`.
/// Small negative eigenvalues (fp noise from covariance estimation) are
/// clamped to zero; genuinely negative spectra are an error.
pub fn sqrtm_spd(a: &Mat) -> Result<Mat> {
    let n = a.rows();
    let (w, v) = eigh(a, 1e-12, 64)?;
    let wmax = w.iter().cloned().fold(0.0f64, f64::max);
    let floor = -1e-8 * wmax.max(1.0);
    let mut d = Mat::zeros(n, n);
    for (i, &wi) in w.iter().enumerate() {
        if wi < floor {
            return Err(Error::Linalg(format!(
                "sqrtm: matrix not PSD (eigenvalue {wi})"
            )));
        }
        d[(i, i)] = wi.max(0.0).sqrt();
    }
    v.matmul(&d)?.matmul(&v.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        // A = B Bᵀ + n·I is SPD
        let mut rng = Pcg64::seeded(seed);
        let mut b = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.uniform(-1.0, 1.0);
            }
        }
        b.matmul(&b.transpose())
            .unwrap()
            .add(&Mat::identity(n).scale(0.1))
            .unwrap()
    }

    #[test]
    fn eigh_diagonal_matrix() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 2.0;
        let (w, _) = eigh(&a, 1e-12, 32).unwrap();
        assert_eq!(w, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn eigh_known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]).unwrap();
        let (w, v) = eigh(&a, 1e-14, 32).unwrap();
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] - 3.0).abs() < 1e-12);
        // V is orthogonal
        let vtv = v.transpose().matmul(&v).unwrap();
        assert!(vtv.max_abs_diff(&Mat::identity(2)) < 1e-12);
    }

    #[test]
    fn eigh_reconstructs() {
        for seed in [1, 2, 3] {
            let a = random_spd(8, seed).symmetrize();
            let (w, v) = eigh(&a, 1e-13, 64).unwrap();
            let mut d = Mat::zeros(8, 8);
            for i in 0..8 {
                d[(i, i)] = w[i];
            }
            let rec = v.matmul(&d).unwrap().matmul(&v.transpose()).unwrap();
            assert!(rec.max_abs_diff(&a) < 1e-9, "seed {seed}: {}", rec.max_abs_diff(&a));
        }
    }

    #[test]
    fn eigh_rejects_asymmetric() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![0.0, 1.0]]).unwrap();
        assert!(eigh(&a, 1e-12, 16).is_err());
        assert!(eigh(&Mat::zeros(2, 3), 1e-12, 16).is_err());
    }

    #[test]
    fn sqrtm_squares_back() {
        for seed in [5, 6, 7] {
            let a = random_spd(12, seed).symmetrize();
            let r = sqrtm_spd(&a).unwrap();
            let back = r.matmul(&r).unwrap();
            assert!(back.max_abs_diff(&a) < 1e-8, "seed {seed}");
            assert!(r.is_symmetric(1e-9));
        }
    }

    #[test]
    fn sqrtm_identity_and_zero() {
        let i4 = Mat::identity(4);
        assert!(sqrtm_spd(&i4).unwrap().max_abs_diff(&i4) < 1e-12);
        let z = Mat::zeros(4, 4);
        assert!(sqrtm_spd(&z).unwrap().max_abs_diff(&z) < 1e-12);
    }

    #[test]
    fn sqrtm_rejects_negative_definite() {
        let a = Mat::identity(3).scale(-1.0);
        assert!(sqrtm_spd(&a).is_err());
    }
}
