//! Cholesky factorisation (lower-triangular). Used to sample correlated
//! gaussians in the workload generator and as an independent SPD check in
//! the FID pipeline's tests.

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Return lower-triangular `L` with `L Lᵀ = A` for SPD `A`.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    if a.rows() != a.cols() {
        return Err(Error::Linalg("cholesky wants square".into()));
    }
    let n = a.rows();
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)];
            for k in 0..j {
                sum -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(Error::Linalg(format!(
                        "cholesky: not positive definite at pivot {i} ({sum})"
                    )));
                }
                l[(i, j)] = sum.sqrt();
            } else {
                l[(i, j)] = sum / l[(j, j)];
            }
        }
    }
    Ok(l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn known_3x3() {
        let a = Mat::from_rows(&[
            vec![4.0, 12.0, -16.0],
            vec![12.0, 37.0, -43.0],
            vec![-16.0, -43.0, 98.0],
        ])
        .unwrap();
        let l = cholesky(&a).unwrap();
        let want = Mat::from_rows(&[
            vec![2.0, 0.0, 0.0],
            vec![6.0, 1.0, 0.0],
            vec![-8.0, 5.0, 3.0],
        ])
        .unwrap();
        assert!(l.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn reconstructs_random_spd() {
        let mut rng = Pcg64::seeded(17);
        let n = 10;
        let mut b = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.uniform(-1.0, 1.0);
            }
        }
        let a = b
            .matmul(&b.transpose())
            .unwrap()
            .add(&Mat::identity(n).scale(0.5))
            .unwrap();
        let l = cholesky(&a).unwrap();
        let back = l.matmul(&l.transpose()).unwrap();
        assert!(back.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]).unwrap();
        assert!(cholesky(&a).is_err()); // eigenvalues 3, -1
        assert!(cholesky(&Mat::zeros(2, 3)).is_err());
    }
}
