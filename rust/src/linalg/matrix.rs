//! Row-major f64 matrix with exactly the operations the Fréchet metric and
//! its tests need. Not a general-purpose linalg crate on purpose.

use crate::error::{Error, Result};

/// Dense row-major f64 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Result<Self> {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        if rows.iter().any(|x| x.len() != c) {
            return Err(Error::Linalg("ragged rows".into()));
        }
        Ok(Self { rows: r, cols: c, data: rows.concat() })
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Linalg(format!(
                "{rows}x{cols} wants {} elems, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(Self { rows, cols, data })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// A·B.
    pub fn matmul(&self, other: &Mat) -> Result<Mat> {
        if self.cols != other.rows {
            return Err(Error::Linalg(format!(
                "matmul {}x{} by {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out[(i, j)] += a * other[(k, j)];
                }
            }
        }
        Ok(out)
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn add(&self, other: &Mat) -> Result<Mat> {
        if (self.rows, self.cols) != (other.rows, other.cols) {
            return Err(Error::Linalg("add shape mismatch".into()));
        }
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        Ok(out)
    }

    pub fn scale(&self, s: f64) -> Mat {
        let mut out = self.clone();
        for a in &mut out.data {
            *a *= s;
        }
        out
    }

    pub fn trace(&self) -> f64 {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).sum()
    }

    /// Max |a_ij - b_ij| — comparator for tests.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Symmetrise: (A + Aᵀ)/2 — cleans fp asymmetry before eigensolves.
    pub fn symmetrize(&self) -> Mat {
        let t = self.transpose();
        self.add(&t).unwrap().scale(0.5)
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let i = Mat::identity(2);
        assert_eq!(a.matmul(&i).unwrap(), a);
        assert_eq!(i.matmul(&a).unwrap(), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[19.0, 22.0, 43.0, 50.0]);
        assert!(a.matmul(&Mat::zeros(3, 3)).is_err());
    }

    #[test]
    fn transpose_trace_symmetrize() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![0.0, 3.0]]).unwrap();
        assert_eq!(a.trace(), 4.0);
        let s = a.symmetrize();
        assert!(s.is_symmetric(0.0));
        assert_eq!(s[(0, 1)], 1.0);
        assert!(!a.is_symmetric(1e-12));
    }

    #[test]
    fn from_vec_checks() {
        assert!(Mat::from_vec(2, 2, vec![0.0; 3]).is_err());
        assert!(Mat::from_rows(&[vec![1.0], vec![1.0, 2.0]]).is_err());
    }
}
