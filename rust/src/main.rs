//! `ddim-serve` — leader binary: CLI over the coordinator.
//!
//! Subcommands:
//!   serve         start the JSON-line TCP server
//!   generate      sample images offline and write a PGM grid
//!   encode        round-trip an image through encode→decode, print the MSE
//!   info          print manifest / schedule / artifact summary
//!   optimize-tau  search an optimized τ schedule for one (dataset, S) cell

use ddim_serve::cli::Args;
use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::request::{Request, RequestBody};
use ddim_serve::coordinator::{Engine, Server};
use ddim_serve::error::Result;
use ddim_serve::runtime::Runtime;
use ddim_serve::sampler::{BatchRunner, SamplerKind};
use ddim_serve::schedule::{NoiseMode, SamplePlan, TauKind};
use ddim_serve::tensor::{save_pgm, tile_grid};

const HELP: &str = "\
ddim-serve — DDIM (Song et al., ICLR 2021) as a rust+JAX+Pallas serving stack

USAGE: ddim-serve <command> [--flag value]...

COMMANDS
  serve       --artifacts D --backend ref|xla --dataset NAME --listen ADDR --max-batch N
              --queue-cap N --max-lanes N --shards N
              --placement ds=N[,ds=N...] --drain-timeout-ms MS
              --default-sampler ddim|pf_ode|ab2
              --pipeline-depth N (1 = serial; >= 2 overlaps pack/advance
                with device execution, bitwise-identical output)
              --max-padding-waste F (0..1; selections padding more than
                this split into exact sub-batches on bucket boundaries)
              --cache on|off (default on: identical requests are answered
                from the deterministic sample cache without re-executing)
              --cache-bytes N (byte budget of the sample cache, LRU;
                default 67108864)
              --coalesce on|off (default on: concurrent identical requests
                share a single execution)
              --ref-threads N (reference-backend kernel threads per
                sub-batch; 0 = available parallelism, bitwise-identical at
                any count)
              --ref-precision f32|f16 (reference-backend weight storage;
                f32 default is bitwise-exact, f16 halves weight bandwidth)
              --reactors N (transport event-loop threads; each multiplexes
                its share of the connections over epoll, default
                min(4, cores))
              --tau linear|quadratic|opt (τ selection when a request omits
                \"tau\"; opt serves the bundle's optimized schedules)
              --queue-lane-cap N (bound on *queued lanes* per shard, on top
                of the item cap; 0 = auto: max(queue-cap, max-lanes))
              --deadline-default-ms MS (deadline applied to requests that
                name none; 0 = unlimited. Expired work is cancelled with a
                typed reject, never finished late)
              --degrade on|off (default on: under queued-lane pressure,
                best-effort requests are shed to smaller step budgets
                S→20→10 — the DDIM quality/steps dial — and the response
                carries a \"degraded\":{\"from\",\"to\"} record)
              --degrade-mid F / --degrade-high F (pressure watermarks as
                fractions of pool lane capacity; defaults 1.0 / 3.0)
              --access-log PATH (structured access log: one JSON line per
                completed request, written off the hot path; empty = off)
              --log-rotate-bytes N / --log-rotate-secs N (rotate the access
                log when it exceeds N bytes or N seconds of age; defaults
                67108864 / 0)
              --log-keep K (rotated generations to retain, PATH.1..PATH.K;
                default 4)
              --trace-sample N (record stage spans — queue/pack/device/
                advance/publish — for every Nth request; 0 = only requests
                that ask with \"trace\":true. Also GET /metrics and
                {\"op\":\"metrics\",\"format\":\"prometheus\"} serve a
                Prometheus scrape; see docs/observability.md)
  generate    --artifacts D --dataset NAME --steps S --eta E|hat
              --tau linear|quadratic|opt
              --sampler ddim|pf_ode|ab2 --count N --seed K --out FILE.pgm
  encode      --artifacts D --dataset NAME --steps S --seed K
  info        --artifacts D
  fixtures    --out DIR   (materialise a synthetic artifact bundle for the
              hermetic reference backend: manifest, alphas, goldens, stats,
              and optimized tau schedules)
  optimize-tau --artifacts D --dataset NAME --steps S --out DIR
              (beam-search an optimized τ for one (dataset, S) budget and
              write schedules/opt_{dataset}_{S}.json; deterministic, runs
              on the reference backend)
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}\n{HELP}");
            std::process::exit(2);
        }
    };
    let code = match args.command.as_deref() {
        Some("serve") => run(cmd_serve(&args)),
        Some("generate") => run(cmd_generate(&args)),
        Some("encode") => run(cmd_encode(&args)),
        Some("info") => run(cmd_info(&args)),
        Some("fixtures") => run(cmd_fixtures(&args)),
        Some("optimize-tau") => run(cmd_optimize_tau(&args)),
        _ => {
            println!("{HELP}");
            0
        }
    };
    std::process::exit(code);
}

fn run(r: Result<()>) -> i32 {
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn config_from(args: &Args) -> Result<ServeConfig> {
    let mut cfg = ServeConfig::default();
    cfg.artifact_root = args.get_or("artifacts", "artifacts").to_string();
    cfg.backend = match args.get("backend") {
        Some(b) => ddim_serve::runtime::BackendKind::parse(b)?,
        None => ddim_serve::runtime::BackendKind::from_env()?,
    };
    cfg.dataset = args.get_or("dataset", "sprites").to_string();
    cfg.max_batch = args.get_usize("max-batch", cfg.max_batch)?;
    cfg.queue_capacity = args.get_usize("queue-cap", cfg.queue_capacity)?;
    cfg.max_lanes = args.get_usize("max-lanes", cfg.max_lanes)?;
    cfg.listen = args.get_or("listen", &cfg.listen).to_string();
    cfg.shards = args.get_usize("shards", cfg.shards)?;
    if let Some(p) = args.get("placement") {
        cfg.placement = ddim_serve::cli::parse_placement(p)?;
    }
    if let Some(s) = args.get("default-sampler") {
        cfg.default_sampler = SamplerKind::parse(s)?;
    }
    if let Some(t) = args.get("tau") {
        cfg.default_tau = TauKind::parse(t)?;
    }
    cfg.drain_timeout_ms = args.get_u64("drain-timeout-ms", cfg.drain_timeout_ms)?;
    cfg.pipeline_depth = args.get_usize("pipeline-depth", cfg.pipeline_depth)?;
    cfg.max_padding_waste = args.get_f64("max-padding-waste", cfg.max_padding_waste)?;
    if let Some(v) = args.get("cache") {
        cfg.cache_enabled = ddim_serve::cli::parse_on_off("cache", v)?;
    }
    if let Some(v) = args.get("coalesce") {
        cfg.coalesce_enabled = ddim_serve::cli::parse_on_off("coalesce", v)?;
    }
    cfg.cache_bytes = args.get_usize("cache-bytes", cfg.cache_bytes)?;
    cfg.ref_threads = args.get_usize("ref-threads", cfg.ref_threads)?;
    if let Some(p) = args.get("ref-precision") {
        cfg.ref_precision = ddim_serve::runtime::RefPrecision::parse(p)?;
    }
    cfg.reactors = args.get_usize("reactors", cfg.reactors)?;
    cfg.queue_lane_cap = args.get_usize("queue-lane-cap", cfg.queue_lane_cap)?;
    cfg.deadline_default_ms = args.get_u64("deadline-default-ms", cfg.deadline_default_ms)?;
    if let Some(v) = args.get("degrade") {
        cfg.degrade_enabled = ddim_serve::cli::parse_on_off("degrade", v)?;
    }
    cfg.degrade_mid = args.get_f64("degrade-mid", cfg.degrade_mid)?;
    cfg.degrade_high = args.get_f64("degrade-high", cfg.degrade_high)?;
    if let Some(p) = args.get("access-log") {
        cfg.access_log = p.to_string();
    }
    cfg.log_rotate_bytes = args.get_u64("log-rotate-bytes", cfg.log_rotate_bytes)?;
    cfg.log_rotate_secs = args.get_u64("log-rotate-secs", cfg.log_rotate_secs)?;
    cfg.log_keep = args.get_usize("log-keep", cfg.log_keep)?;
    cfg.trace_sample = args.get_u64("trace-sample", cfg.trace_sample)?;
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    println!(
        "starting ddim-serve: dataset={} artifacts={} backend={} listen={} shards/dataset={} \
         cache={} ({} MiB) coalesce={}",
        cfg.dataset,
        cfg.artifact_root,
        cfg.backend.label(),
        cfg.listen,
        cfg.shards_for(&cfg.dataset),
        if cfg.cache_enabled { "on" } else { "off" },
        cfg.cache_bytes >> 20,
        if cfg.coalesce_enabled { "on" } else { "off" },
    );
    let server = Server::start(cfg)?;
    println!("listening on {} (ctrl-c to stop)", server.addr());
    // Block forever; the engine thread does the work.
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let steps = args.get_usize("steps", 20)?;
    let mode = NoiseMode::parse(args.get_or("eta", "0.0"))?;
    let tau = TauKind::parse(args.get_or("tau", "linear"))?;
    let count = args.get_usize("count", 16)?;
    let seed = args.get_u64("seed", 0)?;
    let sampler = SamplerKind::parse(args.get_or("sampler", "ddim"))?;
    let out = args.get_or("out", "out/generate.pgm").to_string();

    let mut engine = Engine::new(cfg.clone())?;
    let id = engine.submit(Request {
        dataset: cfg.dataset.clone(),
        steps,
        mode,
        tau,
        sampler,
        body: RequestBody::Generate { count, seed },
        return_images: true,
        cache: ddim_serve::coordinator::CacheMode::Use,
        qos: Default::default(),
    })?;
    let t0 = std::time::Instant::now();
    let responses = engine.run_until_idle()?;
    let resp = responses.into_iter().find(|r| r.id == id).unwrap();
    let images = match resp.body {
        ddim_serve::coordinator::ResponseBody::Ok { outputs } => outputs,
        ddim_serve::coordinator::ResponseBody::Error { message } => {
            return Err(ddim_serve::Error::Coordinator(message))
        }
        ddim_serve::coordinator::ResponseBody::Reject(r) => {
            return Err(ddim_serve::Error::Coordinator(r.message))
        }
    };
    let img = engine.manifest().img;
    let cols = (count as f64).sqrt().ceil() as usize;
    let rows = count.div_ceil(cols);
    let mut padded: Vec<Vec<f32>> = images;
    while padded.len() < rows * cols {
        padded.push(vec![0.0; img * img]);
    }
    let refs: Vec<&[f32]> = padded.iter().map(|v| v.as_slice()).collect();
    let grid = tile_grid(&refs, rows, cols, img, img)?;
    save_pgm(&out, &grid)?;
    println!(
        "wrote {count} samples (S={steps}, {}, sampler={}) to {out} in {:.2}s  [{}]",
        mode.label(),
        sampler.label(),
        t0.elapsed().as_secs_f64(),
        engine.metrics().summary()
    );
    Ok(())
}

fn cmd_encode(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let steps = args.get_usize("steps", 100)?;
    let seed = args.get_u64("seed", 0)?;
    let mut rt = Runtime::load_full(&cfg.artifact_root, cfg.backend, cfg.ref_options())?;
    // generate a sample first, then encode and decode it back
    let gen_plan = SamplePlan::generate(rt.alphas(), TauKind::Linear, steps, NoiseMode::Eta(0.0))?;
    let enc_plan = SamplePlan::encode(rt.alphas(), TauKind::Linear, steps)?;
    let mut runner = BatchRunner::new(&rt, &cfg.dataset, 1)?;
    let x0 = runner.generate(&mut rt, &gen_plan, 1, seed)?;
    let latent = runner.run_from(&mut rt, &enc_plan, x0.clone(), 0)?;
    let recon = runner.run_from(&mut rt, &gen_plan, latent, 0)?;
    let mse = ddim_serve::eval::per_dim_mse(&x0, &recon)?;
    println!("encode/decode round trip (S={steps}): per-dim MSE = {mse:.6}");
    Ok(())
}

fn cmd_fixtures(args: &Args) -> Result<()> {
    let out = args.get_or("out", "fixture-artifacts");
    ddim_serve::testing::fixtures::write_into(std::path::Path::new(out))?;
    let rt = Runtime::load_with(out, ddim_serve::runtime::BackendKind::Reference)?;
    println!(
        "wrote synthetic artifact bundle to {out}: {} datasets, T={}, buckets {:?}",
        rt.manifest().datasets.len(),
        rt.manifest().t_max,
        rt.manifest().buckets
    );
    Ok(())
}

fn cmd_optimize_tau(args: &Args) -> Result<()> {
    let root = args.get_or("artifacts", "artifacts").to_string();
    let out = args.get_or("out", &root).to_string();
    let dataset = args.get_or("dataset", "sprites").to_string();
    let steps = args.get_usize("steps", 20)?;
    // the optimizer's scores are part of the committed schedule bytes, so
    // it always runs on the deterministic reference backend
    let mut rt = Runtime::load_with(&root, ddim_serve::runtime::BackendKind::Reference)?;
    let t0 = std::time::Instant::now();
    let report = ddim_serve::schedule::optimize_tau(&mut rt, &dataset, steps)?;
    let path =
        ddim_serve::schedule::write_schedule(std::path::Path::new(&out), &report.schedule)?;
    let s = &report.schedule;
    println!(
        "optimized {dataset} S={steps} in {:.2}s: frechet {:.5} \
         (linear {:.5}, quadratic {:.5}) over {} candidates, \
         {} delta pairs, {} trajectory evals",
        t0.elapsed().as_secs_f64(),
        s.score,
        s.linear_score,
        s.quadratic_score,
        report.candidates,
        report.pairs_scored,
        report.evals,
    );
    println!("tau = {:?}", s.tau);
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let root = args.get_or("artifacts", "artifacts");
    // info only reads manifest/alphas metadata, never executes a step:
    // load the always-available reference backend regardless of
    // --backend/DDIM_BACKEND so it works on any build
    let rt = Runtime::load_with(root, ddim_serve::runtime::BackendKind::Reference)?;
    let m = rt.manifest();
    println!("artifact root : {}", m.root.display());
    println!("image         : {}x{} x{} ch", m.img, m.img, m.channels);
    println!("T             : {}", m.t_max);
    println!("buckets       : {:?}", m.buckets);
    println!("alpha_bar(T)  : {:.3e}", rt.alphas().abar(m.t_max));
    for (name, ds) in &m.datasets {
        println!(
            "dataset {name:10}: {} params, final train loss {:.4}, ref_n {}",
            ds.params, ds.final_loss, ds.ref_n
        );
    }
    Ok(())
}
