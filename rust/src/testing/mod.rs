//! Test support: the mini property-testing harness (the offline build has
//! no `proptest`) and the synthetic-artifact generator ([`fixtures`]) that
//! lets the integration suite run hermetically on the reference backend.
//!
//! [`check`] runs a property over `n` seeded random cases; on failure it
//! retries the failing case with progressively "smaller" generator budgets
//! (a crude shrink) and reports the seed so the case is replayable:
//! `CASE_SEED=<seed> cargo test <name>`.

pub mod fixtures;

use crate::rng::Pcg64;

/// Context handed to each property case: a seeded RNG plus size helpers.
pub struct Gen {
    pub rng: Pcg64,
    /// Size budget for this case (grows across cases, shrinks on failure).
    pub size: usize,
}

impl Gen {
    /// Uniform usize in [lo, hi] scaled by the current size budget:
    /// the effective upper bound interpolates from lo toward hi as the
    /// case index grows — small cases first, like proptest.
    pub fn int_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let span = hi - lo;
        let eff = lo + (span * self.size.min(100)) / 100;
        let eff = eff.max(lo);
        lo + self.rng.next_below((eff - lo + 1) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Random f32 vector with entries in [lo, hi).
    pub fn vec_f32(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n)
            .map(|_| self.rng.uniform(lo as f64, hi as f64) as f32)
            .collect()
    }

    /// Pick one of the provided items.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.next_below(items.len() as u64) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `prop` over `n` random cases. Panics with the failing seed (and
/// honours `CASE_SEED` to replay one exact case).
pub fn check<F>(name: &str, n: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    if let Ok(seed_s) = std::env::var("CASE_SEED") {
        let seed: u64 = seed_s.parse().expect("CASE_SEED must be u64");
        let mut g = Gen { rng: Pcg64::seeded(seed), size: 100 };
        if let Err(msg) = prop(&mut g) {
            panic!("property '{name}' failed on CASE_SEED={seed}: {msg}");
        }
        return;
    }
    for case in 0..n {
        // derive a per-case seed deterministically from the property name
        let mut h: u64 = 0xcbf29ce484222325;
        for b in name.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        let seed = h.wrapping_add((case as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let size = 1 + (case * 100) / n.max(1);
        let mut g = Gen { rng: Pcg64::seeded(seed), size };
        if let Err(msg) = prop(&mut g) {
            // crude shrink: retry the same seed at smaller size budgets and
            // report the smallest still-failing configuration
            let mut best = (size, msg.clone());
            for s in [1usize, 5, 10, 25, 50] {
                if s >= size {
                    break;
                }
                let mut g2 = Gen { rng: Pcg64::seeded(seed), size: s };
                if let Err(m2) = prop(&mut g2) {
                    best = (s, m2);
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, size {}, replay with CASE_SEED={seed}): {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always_ok", 50, |g| {
            count += 1;
            let v = g.int_in(0, 10);
            if v <= 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always_bad' failed")]
    fn failing_property_reports_seed() {
        check("always_bad", 10, |_| Err("nope".into()));
    }

    #[test]
    fn sizes_grow_across_cases() {
        let mut sizes = Vec::new();
        check("size_probe", 20, |g| {
            sizes.push(g.size);
            Ok(())
        });
        assert!(sizes[0] < *sizes.last().unwrap());
    }

    #[test]
    fn int_in_bounds_hold() {
        check("int_in_bounds", 200, |g| {
            let lo = g.int_in(0, 5);
            let hi = lo + g.int_in(0, 20);
            let v = g.int_in(lo, hi);
            if v < lo || v > hi {
                return Err(format!("{v} outside [{lo}, {hi}]"));
            }
            Ok(())
        });
    }
}
