//! Synthetic-artifact generator: materialises a complete, valid artifact
//! bundle (`manifest.json`, `alphas.json`, golden tensorfiles, reference
//! feature statistics) in a tempdir, so `Runtime::load`, the engine, the
//! router, the planner and the pipelined executor all run unmodified on
//! the hermetic reference backend — no `make artifacts`, no python, no XLA.
//!
//! Layout mirrors what `python/compile/aot.py` writes:
//!
//! ```text
//! <root>/manifest.json
//! <root>/alphas.json
//! <root>/<dataset>/goldens/b{1,4}_{x,t,alpha_t,alpha_prev,sigma,noise,
//!                                  x_prev,eps,x0}.bin(+.json)
//! <root>/<dataset>/goldens/{feat_imgs,feat_out}.bin(+.json)
//! <root>/<dataset>/{ref_mu,ref_cov}.bin(+.json)
//! ```
//!
//! The manifest's `hlo` entries point at files that are *not* created:
//! the reference backend never reads them, and an accidental
//! `--backend xla` run over fixtures fails loudly instead of silently.
//!
//! Step goldens are computed from the same [`RefModel`] the reference
//! backend derives from this manifest, composed through the *host* Eq.-12
//! arithmetic ([`crate::sampler::ddim_update_host_sigma`]) — so
//! `tests/golden_step.rs` pins the executable path (Runtime → cache →
//! submit/wait) against an independently-composed expectation.
//!
//! The horizon is T = 400 (not the paper's 1000): σ̄_T ≈ 7 instead of 158,
//! which keeps the Eq.-13 vs Eq.-15 discretisation gap at S = 100 well
//! inside the tolerance the §4.3 convergence tests pin, while preserving
//! every qualitative property (kernels differ at S = 10, η = 1 is
//! stochastic, encode→decode error shrinks with S). Real artifacts keep
//! T = 1000; the `#[ignore]`d real-artifact tests cover that tier.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::OnceLock;

use crate::error::{Error, Result};
use crate::jobj;
use crate::json::{self, Value};
use crate::rng::{GaussianSource, Pcg64};
use crate::runtime::reference::fnv1a;
use crate::runtime::RefModel;
use crate::sampler::ddim_update_host_sigma;
use crate::schedule::{sigma_eta, AlphaTable};
use crate::stats::{extract_features, GaussianFit};

/// Image side length of the synthetic datasets (the feature extractor is
/// hard-wired to 16×16, like the python build).
pub const IMG: usize = 16;
/// Diffusion horizon of the fixture schedule (see module docs).
pub const T_FIXTURE: usize = 400;
/// Compiled batch buckets, matching the real build's ladder.
pub const BUCKETS: [usize; 5] = [1, 2, 4, 8, 16];
/// Datasets in the fixture manifest: `(name, params, final_loss)`.
pub const DATASETS: [(&str, u64, f64); 2] =
    [("sprites", 123_456, 0.0421), ("blobs", 654_321, 0.0537)];

/// The process-wide fixture tree, generated once on first use. Each test
/// process writes its own copy under the OS tempdir (pid-keyed, a few tens
/// of KB); parallel test threads share it through the `OnceLock`.
///
/// Panics if the tempdir is unwritable — fixtures back the test suite, and
/// a skipped suite is exactly what this module exists to abolish.
pub fn root() -> PathBuf {
    static ROOT: OnceLock<PathBuf> = OnceLock::new();
    ROOT.get_or_init(|| {
        gc_stale_fixture_trees();
        let dir = std::env::temp_dir().join(format!("ddim-fixtures-{}", std::process::id()));
        write_into(&dir).unwrap_or_else(|e| panic!("fixture generation in {dir:?} failed: {e}"));
        dir
    })
    .clone()
}

/// Best-effort GC: remove `ddim-fixtures-*` trees left by earlier test
/// processes (pids differ per run, so without this every `cargo test`
/// would leak a few dozen KB into the tempdir forever). Age-gated to an
/// hour so concurrently-running test binaries never see their tree
/// vanish mid-suite.
fn gc_stale_fixture_trees() {
    let Ok(entries) = fs::read_dir(std::env::temp_dir()) else { return };
    for e in entries.flatten() {
        if !e.file_name().to_string_lossy().starts_with("ddim-fixtures-") {
            continue;
        }
        let stale = e
            .metadata()
            .ok()
            .and_then(|m| m.modified().ok())
            .and_then(|m| m.elapsed().ok())
            .is_some_and(|age| age > std::time::Duration::from_secs(3600));
        if stale {
            let _ = fs::remove_dir_all(e.path());
        }
    }
}

/// [`root`] as a `String`, the form `ServeConfig.artifact_root` wants.
pub fn root_string() -> String {
    root().display().to_string()
}

/// Write a full fixture bundle into `dir` (created if absent, contents
/// overwritten). Exposed so tests can build variant trees in their own
/// tempdirs without fighting the shared one.
pub fn write_into(dir: &Path) -> Result<()> {
    fs::create_dir_all(dir)?;
    let abar = AlphaTable::linear(T_FIXTURE);
    write_manifest(dir)?;
    write_alphas(dir, &abar)?;
    for (name, params, final_loss) in DATASETS {
        let ds_dir = dir.join(name);
        fs::create_dir_all(ds_dir.join("goldens"))?;
        let info = crate::artifacts::DatasetInfo {
            hlo: hlo_paths(name),
            params,
            final_loss,
            ref_n: 4096,
        };
        let model = RefModel::from_manifest(name, &info, IMG * IMG, T_FIXTURE);
        write_step_goldens(&ds_dir.join("goldens"), name, &model, &abar)?;
        write_feature_goldens(&ds_dir.join("goldens"), name)?;
        write_ref_stats(&ds_dir, name)?;
    }
    for sched in opt_schedules_for(dir)? {
        crate::schedule::write_schedule(dir, sched)?;
    }
    Ok(())
}

/// Step budgets that get a DP-optimized τ schedule in the bundle
/// (`schedules/opt_{dataset}_{S}.json`), matching the serve-time
/// `"tau":"opt"` cells the tests and benches exercise.
pub const OPT_STEPS: [usize; 3] = [10, 20, 50];

/// Optimized schedules for the fixture manifest, computed once per process.
///
/// Every tree `write_into` produces has byte-identical `manifest.json` /
/// `alphas.json`, hence the same manifest digest — so the DP search (the
/// expensive part: probe trajectories + beam over per-step deltas) runs on
/// the first bundle only and later variant trees just re-serialize the
/// cached result.
fn opt_schedules_for(dir: &Path) -> Result<&'static Vec<crate::schedule::OptSchedule>> {
    static SCHEDS: OnceLock<Vec<crate::schedule::OptSchedule>> = OnceLock::new();
    if let Some(s) = SCHEDS.get() {
        return Ok(s);
    }
    let mut rt =
        crate::runtime::Runtime::load_with(dir, crate::runtime::BackendKind::Reference)?;
    let mut out = Vec::with_capacity(DATASETS.len() * OPT_STEPS.len());
    for (name, ..) in DATASETS {
        for s in OPT_STEPS {
            out.push(crate::schedule::optimize_tau(&mut rt, name, s)?.schedule);
        }
    }
    Ok(SCHEDS.get_or_init(|| out))
}

fn hlo_paths(name: &str) -> Vec<String> {
    BUCKETS.iter().map(|b| format!("{name}/b{b}.hlo.txt")).collect()
}

fn write_manifest(dir: &Path) -> Result<()> {
    let mut datasets = std::collections::BTreeMap::new();
    for (name, params, final_loss) in DATASETS {
        datasets.insert(
            name.to_string(),
            jobj![
                ("hlo", hlo_paths(name)),
                ("params", params),
                ("final_loss", final_loss),
                ("ref_n", 4096usize),
            ],
        );
    }
    let manifest = jobj![
        ("img", IMG),
        ("channels", 1usize),
        ("T", T_FIXTURE),
        ("buckets", BUCKETS.to_vec()),
        ("feat_dim", crate::stats::FEAT_DIM),
        ("datasets", Value::Obj(datasets)),
    ];
    fs::write(dir.join("manifest.json"), json::to_string(&manifest))?;
    Ok(())
}

fn write_alphas(dir: &Path, abar: &AlphaTable) -> Result<()> {
    // the serializer prints floats in shortest exact form, so the loader's
    // 1e-9 cross-check against the native table holds bit-for-bit
    let alpha_bar: Vec<f64> = (0..=T_FIXTURE).map(|t| abar.abar(t)).collect();
    let v = jobj![("T", T_FIXTURE), ("alpha_bar", alpha_bar)];
    fs::write(dir.join("alphas.json"), json::to_string(&v))?;
    Ok(())
}

/// Fixed step-golden inputs for one bucket: realistic schedule points,
/// a stochastic lane mix (σ = 0, η = 1, σ̂-style), seeded states/noise.
fn write_step_goldens(
    goldens: &Path,
    dataset: &str,
    model: &RefModel,
    abar: &AlphaTable,
) -> Result<()> {
    let dim = IMG * IMG;
    let mut rng = GaussianSource::new(Pcg64::seeded(fnv1a(dataset) ^ 0x90_1d)); // per-dataset stream
    for bucket in [1usize, 4] {
        // schedule endpoints per lane: (t_cur, t_prev) pairs inside [1, T]
        let pairs = [(360usize, 240usize), (240, 120), (120, 40), (40, 0)];
        let mut x = vec![0.0f32; bucket * dim];
        let mut noise = vec![0.0f32; bucket * dim];
        let mut t = vec![0.0f32; bucket];
        let mut a_t = vec![0.0f32; bucket];
        let mut a_p = vec![0.0f32; bucket];
        let mut sigma = vec![0.0f32; bucket];
        for slot in 0..bucket {
            let (tc, tp) = pairs[slot % pairs.len()];
            t[slot] = tc as f32;
            a_t[slot] = abar.abar(tc) as f32;
            a_p[slot] = abar.abar(tp) as f32;
            // lane 0 deterministic, lane 1 DDPM-style, others in between
            let eta = [0.0, 1.0, 0.5, 0.25][slot % 4];
            sigma[slot] = sigma_eta(abar, tc, tp, eta) as f32;
            for i in 0..dim {
                x[slot * dim + i] = rng.next() as f32;
                noise[slot * dim + i] =
                    if sigma[slot] > 0.0 { rng.next() as f32 } else { 0.0 };
            }
        }
        // expected outputs: the model's ε on the f32-rounded inputs, then
        // the host Eq.-12 composition (independent of the backend's code
        // path through Runtime/StepExecutable)
        let mut eps = vec![0.0f32; bucket * dim];
        let mut x0 = vec![0.0f32; bucket * dim];
        let mut x_prev = vec![0.0f32; bucket * dim];
        for slot in 0..bucket {
            let (a, ap, sg, tm) =
                (a_t[slot] as f64, a_p[slot] as f64, sigma[slot] as f64, t[slot] as f64);
            for i in 0..dim {
                let idx = slot * dim + i;
                let e = model.eps(i, x[idx] as f64, tm, a);
                eps[idx] = e as f32;
                x0[idx] = ((x[idx] as f64 - (1.0 - a).max(0.0).sqrt() * e) / a.sqrt()) as f32;
            }
            let r = slot * dim..(slot + 1) * dim;
            x_prev[r.clone()].copy_from_slice(&ddim_update_host_sigma(
                &x[r.clone()],
                &eps[r.clone()],
                &noise[r.clone()],
                a,
                ap,
                sg,
            ));
        }
        let img_shape = [bucket, 1, IMG, IMG];
        let vec_shape = [bucket];
        for (name, data, shape) in [
            ("x", &x, &img_shape[..]),
            ("noise", &noise, &img_shape[..]),
            ("x_prev", &x_prev, &img_shape[..]),
            ("eps", &eps, &img_shape[..]),
            ("x0", &x0, &img_shape[..]),
            ("t", &t, &vec_shape[..]),
            ("alpha_t", &a_t, &vec_shape[..]),
            ("alpha_prev", &a_p, &vec_shape[..]),
            ("sigma", &sigma, &vec_shape[..]),
        ] {
            write_tensor_f32(&goldens.join(format!("b{bucket}_{name}.bin")), shape, data)?;
        }
    }
    Ok(())
}

/// `feat_imgs` / `feat_out`: random images plus their extracted features,
/// pinning the tensorfile round trip (f32 images, f64 features) and the
/// extractor's stability against the on-disk interchange format.
fn write_feature_goldens(goldens: &Path, dataset: &str) -> Result<()> {
    let dim = IMG * IMG;
    let n = 8usize;
    let mut rng = GaussianSource::new(Pcg64::seeded(fnv1a(dataset) ^ 0xfea7));
    let mut imgs = vec![0.0f32; n * dim];
    for v in imgs.iter_mut() {
        *v = (rng.next() * 0.5).clamp(-1.0, 1.0) as f32;
    }
    let mut feats = Vec::with_capacity(n * crate::stats::FEAT_DIM);
    for i in 0..n {
        feats.extend_from_slice(&extract_features(&imgs[i * dim..(i + 1) * dim]));
    }
    write_tensor_f32(&goldens.join("feat_imgs.bin"), &[n, dim], &imgs)?;
    write_tensor_f64(&goldens.join("feat_out.bin"), &[n, crate::stats::FEAT_DIM], &feats)?;
    Ok(())
}

/// Reference feature statistics: a gaussian fitted over smooth synthetic
/// "blob" images (the shape the eval pipeline's proxy-FID discriminates),
/// written as the f64 tensorfile pair `load_ref_stats` expects.
fn write_ref_stats(ds_dir: &Path, dataset: &str) -> Result<()> {
    let mut rng = Pcg64::seeded(fnv1a(dataset) ^ 0x5afe);
    let mut fit = GaussianFit::new();
    for _ in 0..256 {
        let cx = rng.uniform(0.3, 0.7);
        let cy = rng.uniform(0.3, 0.7);
        let s = rng.uniform(0.05, 0.15);
        let img: Vec<f32> = (0..IMG * IMG)
            .map(|i| {
                let x = (i % IMG) as f64 / IMG as f64;
                let y = (i / IMG) as f64 / IMG as f64;
                let d = ((x - cx).powi(2) + (y - cy).powi(2)) / (2.0 * s * s);
                ((-d).exp() * 2.0 - 1.0) as f32
            })
            .collect();
        fit.push(&extract_features(&img));
    }
    let cov = fit.covariance()?;
    let fd = crate::stats::FEAT_DIM;
    let mut cov_flat = Vec::with_capacity(fd * fd);
    for i in 0..fd {
        for j in 0..fd {
            cov_flat.push(cov[(i, j)]);
        }
    }
    write_tensor_f64(&ds_dir.join("ref_mu.bin"), &[fd], fit.mean())?;
    write_tensor_f64(&ds_dir.join("ref_cov.bin"), &[fd, fd], &cov_flat)?;
    Ok(())
}

fn write_sidecar(path: &Path, shape: &[usize], dtype: &str) -> Result<()> {
    let mut side = path.as_os_str().to_os_string();
    side.push(".json");
    fs::write(side, json::to_string(&jobj![("shape", shape.to_vec()), ("dtype", dtype)]))?;
    Ok(())
}

/// Write an f32 tensorfile (`.bin` + `.bin.json` sidecar).
pub fn write_tensor_f32(path: &Path, shape: &[usize], data: &[f32]) -> Result<()> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(Error::Shape(format!(
            "tensorfile {path:?}: shape {shape:?} vs {} elems",
            data.len()
        )));
    }
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    fs::write(path, bytes)?;
    write_sidecar(path, shape, "f32")
}

/// Write an f64 tensorfile (`.bin` + `.bin.json` sidecar).
pub fn write_tensor_f64(path: &Path, shape: &[usize], data: &[f64]) -> Result<()> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        return Err(Error::Shape(format!(
            "tensorfile {path:?}: shape {shape:?} vs {} elems",
            data.len()
        )));
    }
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    fs::write(path, bytes)?;
    write_sidecar(path, shape, "f64")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts::{read_tensor, read_tensor_f64, Manifest};

    #[test]
    fn fixture_tree_loads_as_a_valid_artifact_bundle() {
        let dir = root();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.img, IMG);
        assert_eq!(m.t_max, T_FIXTURE);
        assert_eq!(m.buckets, BUCKETS.to_vec());
        assert_eq!(m.datasets.len(), DATASETS.len());
        for (name, ..) in DATASETS {
            m.dataset(name).unwrap();
        }
        let abar = AlphaTable::from_artifact(dir.join("alphas.json")).unwrap();
        abar.validate().unwrap();
        assert_eq!(abar.t_max(), T_FIXTURE);
    }

    #[test]
    fn goldens_and_stats_are_readable_and_shaped() {
        let dir = root();
        let m = Manifest::load(&dir).unwrap();
        let dim = m.sample_dim();
        for (name, ..) in DATASETS {
            for bucket in [1usize, 4] {
                let x = read_tensor(m.golden_path(name, &format!("b{bucket}_x"))).unwrap();
                assert_eq!(x.data().len(), bucket * dim);
                let t = read_tensor(m.golden_path(name, &format!("b{bucket}_t"))).unwrap();
                assert_eq!(t.data().len(), bucket);
                // schedule scalars must be inside the open unit interval
                let a = read_tensor(m.golden_path(name, &format!("b{bucket}_alpha_t"))).unwrap();
                assert!(a.data().iter().all(|&v| v > 0.0 && v < 1.0));
            }
            let (shape, _) = read_tensor_f64(m.golden_path(name, "feat_out")).unwrap();
            assert_eq!(shape[1], crate::stats::FEAT_DIM);
            let (mu_shape, _) = read_tensor_f64(m.ref_stats_paths(name).0).unwrap();
            assert_eq!(mu_shape, vec![crate::stats::FEAT_DIM]);
        }
    }

    #[test]
    fn write_into_is_idempotent_and_relocatable() {
        let dir = std::env::temp_dir()
            .join(format!("ddim-fixtures-reloc-{}", std::process::id()));
        write_into(&dir).unwrap();
        write_into(&dir).unwrap(); // overwrite must succeed
        assert!(Manifest::load(&dir).is_ok());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tensorfile_writers_validate_shape() {
        let dir = std::env::temp_dir().join(format!("ddim-fixtures-shape-{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.bin");
        assert!(write_tensor_f32(&p, &[3], &[0.0; 2]).is_err());
        assert!(write_tensor_f64(&p, &[2, 2], &[0.0; 3]).is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
