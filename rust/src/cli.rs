//! Tiny `--flag value` argument parser (the offline build has no `clap`).
//! Subcommand + flags; every consumer documents its own flags in `--help`.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: a subcommand plus `--key value` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    /// flags that appeared without a value (`--verbose`)
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.command = it.next();
            }
        }
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| Error::Request(format!("expected --flag, got '{a}'")))?
                .to_string();
            if key.is_empty() {
                return Err(Error::Request("empty flag name".into()));
            }
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    out.flags.insert(key, it.next().unwrap());
                }
                _ => out.switches.push(key),
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Request(format!("--{key} wants an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Request(format!("--{key} wants a number, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Request(format!("--{key} wants an integer, got '{v}'"))),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

/// Parse an `on|off` toggle flag value (`--cache on`, `--coalesce off`).
pub fn parse_on_off(flag: &str, s: &str) -> Result<bool> {
    match s {
        "on" => Ok(true),
        "off" => Ok(false),
        other => Err(Error::Request(format!("--{flag} wants on|off, got '{other}'"))),
    }
}

/// Parse a `--placement` value: `dataset=shards[,dataset=shards...]`,
/// e.g. `sprites=4,blobs=2`. Duplicate datasets are rejected here (and
/// again by `ServeConfig::validate`, for placements built in code).
pub fn parse_placement(s: &str) -> Result<Vec<(String, usize)>> {
    let mut out: Vec<(String, usize)> = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (ds, n) = part
            .split_once('=')
            .ok_or_else(|| Error::Request(format!("placement '{part}' wants dataset=shards")))?;
        let ds = ds.trim();
        if ds.is_empty() {
            return Err(Error::Request(format!("placement '{part}' has an empty dataset")));
        }
        let n: usize = n.trim().parse().map_err(|_| {
            Error::Request(format!("placement '{part}' wants an integer shard count"))
        })?;
        if out.iter().any(|(d, _)| d == ds) {
            return Err(Error::Request(format!("placement lists '{ds}' twice")));
        }
        out.push((ds.to_string(), n));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn command_and_flags() {
        let a = parse("serve --dataset blobs --steps 50 --verbose");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get("dataset"), Some("blobs"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 50);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn no_command() {
        let a = parse("--x 1");
        assert!(a.command.is_none());
        assert_eq!(a.get_usize("x", 0).unwrap(), 1);
    }

    #[test]
    fn type_errors() {
        let a = parse("run --n abc");
        assert!(a.get_usize("n", 0).is_err());
        assert!(a.get_f64("n", 0.0).is_err());
    }

    #[test]
    fn rejects_stray_positional() {
        assert!(Args::parse(vec!["cmd".into(), "stray".into()]).is_err());
    }

    #[test]
    fn trailing_switch() {
        let a = parse("bench --quick");
        assert!(a.has("quick"));
    }

    #[test]
    fn placement_parses_pairs() {
        assert_eq!(
            parse_placement("sprites=4,blobs=2").unwrap(),
            vec![("sprites".to_string(), 4), ("blobs".to_string(), 2)]
        );
        assert_eq!(parse_placement(" a = 1 ").unwrap(), vec![("a".to_string(), 1)]);
        assert!(parse_placement("").unwrap().is_empty());
    }

    #[test]
    fn on_off_parses() {
        assert!(parse_on_off("cache", "on").unwrap());
        assert!(!parse_on_off("cache", "off").unwrap());
        for bad in ["true", "1", "ON", ""] {
            let err = parse_on_off("coalesce", bad).unwrap_err().to_string();
            assert!(err.contains("--coalesce"), "{err}");
        }
    }

    #[test]
    fn placement_rejects_malformed() {
        for s in ["sprites", "=3", "a=x", "a=1,a=2"] {
            assert!(parse_placement(s).is_err(), "{s}");
        }
    }
}
