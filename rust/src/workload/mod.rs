//! Workload generation for `serve_e2e` and the coordinator benches: a
//! Poisson (exponential inter-arrival) open-loop generator over a mix of
//! request classes — the standard serving-evaluation setup.

use crate::coordinator::request::{Request, RequestBody};
use crate::rng::Pcg64;
use crate::schedule::{NoiseMode, TauKind};

/// One request class in the mix.
#[derive(Debug, Clone)]
pub struct RequestClass {
    /// relative weight within the mix
    pub weight: f64,
    pub steps: usize,
    pub mode: NoiseMode,
    pub count: usize,
}

/// Open-loop Poisson workload over a class mix.
#[derive(Debug, Clone)]
pub struct Workload {
    pub dataset: String,
    pub classes: Vec<RequestClass>,
    /// mean arrivals per second
    pub rate_hz: f64,
}

impl Workload {
    /// The default mixed workload used in EXPERIMENTS.md: interactive
    /// low-step DDIM requests, batch high-quality requests, and a few
    /// stochastic DDPM ones.
    pub fn standard(dataset: &str, rate_hz: f64) -> Self {
        Self {
            dataset: dataset.to_string(),
            rate_hz,
            classes: vec![
                RequestClass { weight: 0.5, steps: 10, mode: NoiseMode::Eta(0.0), count: 1 },
                RequestClass { weight: 0.25, steps: 20, mode: NoiseMode::Eta(0.0), count: 4 },
                RequestClass { weight: 0.15, steps: 50, mode: NoiseMode::Eta(0.0), count: 1 },
                RequestClass { weight: 0.1, steps: 20, mode: NoiseMode::Eta(1.0), count: 1 },
            ],
        }
    }

    /// Generate `n` (arrival_offset_seconds, request) pairs.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<(f64, Request)> {
        let mut rng = Pcg64::seeded(seed);
        let total_w: f64 = self.classes.iter().map(|c| c.weight).sum();
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            // exponential inter-arrival
            let u = 1.0 - rng.next_f64();
            t += -u.ln() / self.rate_hz;
            // pick a class by weight
            let mut pick = rng.next_f64() * total_w;
            let mut class = &self.classes[0];
            for c in &self.classes {
                pick -= c.weight;
                if pick <= 0.0 {
                    class = c;
                    break;
                }
            }
            out.push((
                t,
                Request {
                    dataset: self.dataset.clone(),
                    steps: class.steps,
                    mode: class.mode,
                    tau: TauKind::Linear,
                    body: RequestBody::Generate { count: class.count, seed: seed * 1000 + i as u64 },
                    return_images: false,
                },
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_increasing_and_rate_is_right() {
        let w = Workload::standard("sprites", 100.0);
        let reqs = w.generate(2000, 7);
        assert_eq!(reqs.len(), 2000);
        assert!(reqs.windows(2).all(|p| p[1].0 > p[0].0));
        let span = reqs.last().unwrap().0;
        let rate = 2000.0 / span;
        assert!((rate - 100.0).abs() < 10.0, "measured rate {rate}");
    }

    #[test]
    fn mix_respects_weights() {
        let w = Workload::standard("sprites", 10.0);
        let reqs = w.generate(4000, 3);
        let s10 = reqs.iter().filter(|(_, r)| r.steps == 10).count() as f64 / 4000.0;
        assert!((s10 - 0.5).abs() < 0.05, "class-1 fraction {s10}");
        let stoch = reqs
            .iter()
            .filter(|(_, r)| !r.mode.is_deterministic())
            .count() as f64
            / 4000.0;
        assert!((stoch - 0.1).abs() < 0.03, "stochastic fraction {stoch}");
    }

    #[test]
    fn deterministic_per_seed() {
        let w = Workload::standard("sprites", 10.0);
        let a = w.generate(50, 1);
        let b = w.generate(50, 1);
        for ((ta, ra), (tb, rb)) in a.iter().zip(&b) {
            assert_eq!(ta, tb);
            assert_eq!(ra.steps, rb.steps);
        }
    }
}
