//! Workload generation for `serve_e2e` and the coordinator benches: a
//! Poisson (exponential inter-arrival) open-loop generator over a mix of
//! request classes — the standard serving-evaluation setup.

use crate::coordinator::request::{Request, RequestBody};
use crate::rng::Pcg64;
use crate::sampler::SamplerKind;
use crate::schedule::{NoiseMode, TauKind};

/// One request class in the mix.
#[derive(Debug, Clone)]
pub struct RequestClass {
    /// relative weight within the mix
    pub weight: f64,
    pub steps: usize,
    pub mode: NoiseMode,
    pub sampler: SamplerKind,
    pub count: usize,
}

/// Open-loop Poisson workload over a class mix.
#[derive(Debug, Clone)]
pub struct Workload {
    pub dataset: String,
    pub classes: Vec<RequestClass>,
    /// mean arrivals per second
    pub rate_hz: f64,
}

fn class(
    weight: f64,
    steps: usize,
    mode: NoiseMode,
    sampler: SamplerKind,
    count: usize,
) -> RequestClass {
    RequestClass { weight, steps, mode, sampler, count }
}

impl Workload {
    /// The default mixed workload used in EXPERIMENTS.md: interactive
    /// low-step DDIM requests, batch high-quality requests, a few
    /// stochastic DDPM ones, and a slice of the alternative update
    /// kernels (PF-ODE / AB2) now that they are first-class scenarios.
    pub fn standard(dataset: &str, rate_hz: f64) -> Self {
        let d = SamplerKind::Ddim;
        Self {
            dataset: dataset.to_string(),
            rate_hz,
            classes: vec![
                class(0.4, 10, NoiseMode::Eta(0.0), d, 1),
                class(0.25, 20, NoiseMode::Eta(0.0), d, 4),
                class(0.15, 50, NoiseMode::Eta(0.0), d, 1),
                class(0.1, 20, NoiseMode::Eta(1.0), d, 1),
                class(0.05, 10, NoiseMode::Eta(0.0), SamplerKind::PfOde, 1),
                class(0.05, 10, NoiseMode::Eta(0.0), SamplerKind::Ab2, 1),
            ],
        }
    }

    /// Generate `n` (arrival_offset_seconds, request) pairs.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<(f64, Request)> {
        let mut rng = Pcg64::seeded(seed);
        let total_w: f64 = self.classes.iter().map(|c| c.weight).sum();
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            // exponential inter-arrival
            let u = 1.0 - rng.next_f64();
            t += -u.ln() / self.rate_hz;
            // pick a class by weight
            let mut pick = rng.next_f64() * total_w;
            let mut class = &self.classes[0];
            for c in &self.classes {
                pick -= c.weight;
                if pick <= 0.0 {
                    class = c;
                    break;
                }
            }
            out.push((
                t,
                Request {
                    dataset: self.dataset.clone(),
                    steps: class.steps,
                    mode: class.mode,
                    tau: TauKind::Linear,
                    sampler: class.sampler,
                    body: RequestBody::Generate { count: class.count, seed: seed * 1000 + i as u64 },
                    return_images: false,
                },
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_increasing_and_rate_is_right() {
        let w = Workload::standard("sprites", 100.0);
        let reqs = w.generate(2000, 7);
        assert_eq!(reqs.len(), 2000);
        assert!(reqs.windows(2).all(|p| p[1].0 > p[0].0));
        let span = reqs.last().unwrap().0;
        let rate = 2000.0 / span;
        assert!((rate - 100.0).abs() < 10.0, "measured rate {rate}");
    }

    #[test]
    fn mix_respects_weights() {
        let w = Workload::standard("sprites", 10.0);
        let reqs = w.generate(4000, 3);
        let s10 = reqs.iter().filter(|(_, r)| r.steps == 10).count() as f64 / 4000.0;
        assert!((s10 - 0.5).abs() < 0.05, "class-1 fraction {s10}");
        let stoch = reqs
            .iter()
            .filter(|(_, r)| !r.mode.is_deterministic())
            .count() as f64
            / 4000.0;
        assert!((stoch - 0.1).abs() < 0.03, "stochastic fraction {stoch}");
        let host_kernels = reqs
            .iter()
            .filter(|(_, r)| r.sampler != SamplerKind::Ddim)
            .count() as f64
            / 4000.0;
        assert!((host_kernels - 0.1).abs() < 0.03, "pf_ode+ab2 fraction {host_kernels}");
        // the mix never pairs a host kernel with a stochastic plan
        assert!(reqs.iter().all(|(_, r)| r.sampler.supports(r.mode)));
    }

    #[test]
    fn deterministic_per_seed() {
        let w = Workload::standard("sprites", 10.0);
        let a = w.generate(50, 1);
        let b = w.generate(50, 1);
        for ((ta, ra), (tb, rb)) in a.iter().zip(&b) {
            assert_eq!(ta, tb);
            assert_eq!(ra.steps, rb.steps);
        }
    }
}
