//! Workload generation for `serve_e2e` and the coordinator benches: a
//! Poisson (exponential inter-arrival) open-loop generator over a mix of
//! request classes — the standard serving-evaluation setup.
//!
//! Classes cover all three body kinds (Generate / Decode / Encode), and a
//! workload can draw its request identities from a finite **seed pool**
//! under a Zipf popularity model — the canonical cache-evaluation shape:
//! a small set of hot requests recurs, so the sample cache and the
//! single-flight coalescer actually have something to hit. `seed_pool:
//! None` reproduces the old behavior (every request unique, cache-cold).

use crate::coordinator::request::{CacheMode, Request, RequestBody};
use crate::rng::{GaussianSource, Pcg64};
use crate::sampler::SamplerKind;
use crate::schedule::{NoiseMode, TauKind};

/// Which request body a class emits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClassKind {
    /// `count` fresh samples from the prior, seeded from the pool.
    Generate,
    /// Caller-supplied latents (drawn ~N(0,1) from the pooled seed).
    Decode,
    /// Caller-supplied images (drawn uniform [-1,1] from the pooled seed).
    Encode,
}

/// One request class in the mix.
#[derive(Debug, Clone)]
pub struct RequestClass {
    /// relative weight within the mix
    pub weight: f64,
    pub steps: usize,
    pub mode: NoiseMode,
    pub sampler: SamplerKind,
    pub count: usize,
    pub kind: ClassKind,
}

/// Finite request-identity pool with Zipf(s) popularity: identity `k`
/// (0-based popularity rank) is drawn with weight `1/(k+1)^s`. `s = 0`
/// is uniform over the pool; `s ≈ 1` is the classic web-traffic skew.
#[derive(Debug, Clone)]
pub struct SeedPool {
    pub size: usize,
    pub exponent: f64,
}

/// Open-loop Poisson workload over a class mix.
#[derive(Debug, Clone)]
pub struct Workload {
    pub dataset: String,
    pub classes: Vec<RequestClass>,
    /// mean arrivals per second
    pub rate_hz: f64,
    /// `Some` draws request identities Zipf-distributed from a finite
    /// pool (repeats → cache hits); `None` makes every request unique.
    pub seed_pool: Option<SeedPool>,
    /// Elements per lane for Decode/Encode bodies (a workload with such
    /// classes must set this to the model's `sample_dim`).
    pub sample_dim: usize,
}

fn class(
    weight: f64,
    steps: usize,
    mode: NoiseMode,
    sampler: SamplerKind,
    count: usize,
    kind: ClassKind,
) -> RequestClass {
    RequestClass { weight, steps, mode, sampler, count, kind }
}

impl Workload {
    /// The default mixed workload used in EXPERIMENTS.md: interactive
    /// low-step DDIM requests, batch high-quality requests, a few
    /// stochastic DDPM ones, and a slice of the alternative update
    /// kernels (PF-ODE / AB2). Generate-only, unique seeds (cache-cold).
    pub fn standard(dataset: &str, rate_hz: f64) -> Self {
        let d = SamplerKind::Ddim;
        let g = ClassKind::Generate;
        Self {
            dataset: dataset.to_string(),
            rate_hz,
            seed_pool: None,
            sample_dim: 0,
            classes: vec![
                class(0.4, 10, NoiseMode::Eta(0.0), d, 1, g),
                class(0.25, 20, NoiseMode::Eta(0.0), d, 4, g),
                class(0.15, 50, NoiseMode::Eta(0.0), d, 1, g),
                class(0.1, 20, NoiseMode::Eta(1.0), d, 1, g),
                class(0.05, 10, NoiseMode::Eta(0.0), SamplerKind::PfOde, 1, g),
                class(0.05, 10, NoiseMode::Eta(0.0), SamplerKind::Ab2, 1, g),
            ],
        }
    }

    /// A cache-evaluation workload: the standard interactive/batch split
    /// plus Decode and Encode classes, all drawing identities from a
    /// Zipf(`exponent`) pool of `pool_size` seeds — repeated identities
    /// make cache hits (and, at high rates, coalesced flights) reachable
    /// from `serve_e2e` and the benches. `sample_dim` is the model's
    /// elements-per-sample (decode/encode bodies are materialised here).
    pub fn zipf(
        dataset: &str,
        rate_hz: f64,
        sample_dim: usize,
        pool_size: usize,
        exponent: f64,
    ) -> Self {
        let d = SamplerKind::Ddim;
        Self {
            dataset: dataset.to_string(),
            rate_hz,
            seed_pool: Some(SeedPool { size: pool_size.max(1), exponent }),
            sample_dim,
            classes: vec![
                class(0.35, 10, NoiseMode::Eta(0.0), d, 1, ClassKind::Generate),
                class(0.2, 20, NoiseMode::Eta(0.0), d, 4, ClassKind::Generate),
                class(0.1, 20, NoiseMode::Eta(1.0), d, 1, ClassKind::Generate),
                class(0.2, 10, NoiseMode::Eta(0.0), d, 1, ClassKind::Decode),
                class(0.1, 20, NoiseMode::Eta(0.0), d, 1, ClassKind::Encode),
                class(0.05, 10, NoiseMode::Eta(0.0), SamplerKind::PfOde, 1, ClassKind::Decode),
            ],
        }
    }

    /// Generate `n` (arrival_offset_seconds, request) pairs.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<(f64, Request)> {
        let mut rng = Pcg64::seeded(seed);
        let total_w: f64 = self.classes.iter().map(|c| c.weight).sum();
        // Zipf CDF over popularity ranks, precomputed once
        let zipf_cum: Vec<f64> = match &self.seed_pool {
            Some(pool) => {
                let mut acc = 0.0;
                (0..pool.size)
                    .map(|k| {
                        acc += 1.0 / ((k + 1) as f64).powf(pool.exponent);
                        acc
                    })
                    .collect()
            }
            None => Vec::new(),
        };
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            // exponential inter-arrival
            let u = 1.0 - rng.next_f64();
            t += -u.ln() / self.rate_hz;
            // pick a class by weight
            let mut pick = rng.next_f64() * total_w;
            let mut class = &self.classes[0];
            for c in &self.classes {
                pick -= c.weight;
                if pick <= 0.0 {
                    class = c;
                    break;
                }
            }
            // request identity: Zipf rank from the pool, or unique
            let req_seed = match &self.seed_pool {
                Some(_) => {
                    let u = rng.next_f64() * zipf_cum.last().copied().unwrap_or(1.0);
                    let rank = zipf_cum.partition_point(|&c| c < u);
                    // identity depends on (workload seed, rank) only — the
                    // same rank recurs with the same body bits, which is
                    // exactly what makes it cacheable
                    seed.wrapping_mul(7919).wrapping_add(rank as u64)
                }
                None => seed * 1000 + i as u64,
            };
            let body = match class.kind {
                ClassKind::Generate => {
                    RequestBody::Generate { count: class.count, seed: req_seed }
                }
                ClassKind::Decode => RequestBody::Decode {
                    latents: latent_rows(req_seed, class.count, self.sample_dim),
                },
                ClassKind::Encode => RequestBody::Encode {
                    images: image_rows(req_seed, class.count, self.sample_dim),
                },
            };
            out.push((
                t,
                Request {
                    dataset: self.dataset.clone(),
                    steps: class.steps,
                    mode: class.mode,
                    tau: TauKind::Linear,
                    sampler: class.sampler,
                    body,
                    return_images: false,
                    cache: CacheMode::Use,
                    qos: Default::default(),
                },
            ));
        }
        out
    }
}

/// Deterministic ~N(0,1) latents for a pooled decode identity: same
/// (seed, count, dim) → bitwise-identical rows, on any machine.
pub fn latent_rows(seed: u64, count: usize, dim: usize) -> Vec<Vec<f32>> {
    assert!(dim > 0, "decode/encode workload classes need sample_dim set");
    (0..count)
        .map(|lane| {
            let mut root = Pcg64::seeded(seed);
            GaussianSource::new(root.fork(lane as u64)).vec(dim)
        })
        .collect()
}

/// Deterministic uniform [-1, 1] images for a pooled encode identity.
pub fn image_rows(seed: u64, count: usize, dim: usize) -> Vec<Vec<f32>> {
    assert!(dim > 0, "decode/encode workload classes need sample_dim set");
    (0..count)
        .map(|lane| {
            let mut root = Pcg64::seeded(seed);
            let mut rng = root.fork(lane as u64);
            (0..dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_increasing_and_rate_is_right() {
        let w = Workload::standard("sprites", 100.0);
        let reqs = w.generate(2000, 7);
        assert_eq!(reqs.len(), 2000);
        assert!(reqs.windows(2).all(|p| p[1].0 > p[0].0));
        let span = reqs.last().unwrap().0;
        let rate = 2000.0 / span;
        assert!((rate - 100.0).abs() < 10.0, "measured rate {rate}");
    }

    #[test]
    fn mix_respects_weights() {
        let w = Workload::standard("sprites", 10.0);
        let reqs = w.generate(4000, 3);
        let s10 = reqs.iter().filter(|(_, r)| r.steps == 10).count() as f64 / 4000.0;
        assert!((s10 - 0.5).abs() < 0.05, "class-1 fraction {s10}");
        let stoch = reqs
            .iter()
            .filter(|(_, r)| !r.mode.is_deterministic())
            .count() as f64
            / 4000.0;
        assert!((stoch - 0.1).abs() < 0.03, "stochastic fraction {stoch}");
        let host_kernels = reqs
            .iter()
            .filter(|(_, r)| r.sampler != SamplerKind::Ddim)
            .count() as f64
            / 4000.0;
        assert!((host_kernels - 0.1).abs() < 0.03, "pf_ode+ab2 fraction {host_kernels}");
        // the mix never pairs a host kernel with a stochastic plan
        assert!(reqs.iter().all(|(_, r)| r.sampler.supports(r.mode)));
        // standard stays cache-cold: every generate seed is unique
        let mut seeds: Vec<u64> = reqs
            .iter()
            .filter_map(|(_, r)| match r.body {
                RequestBody::Generate { seed, .. } => Some(seed),
                _ => None,
            })
            .collect();
        let n = seeds.len();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), n, "standard workload must not repeat seeds");
    }

    #[test]
    fn deterministic_per_seed() {
        let w = Workload::standard("sprites", 10.0);
        let a = w.generate(50, 1);
        let b = w.generate(50, 1);
        for ((ta, ra), (tb, rb)) in a.iter().zip(&b) {
            assert_eq!(ta, tb);
            assert_eq!(ra.steps, rb.steps);
        }
    }

    #[test]
    fn zipf_pool_repeats_identities_and_skews_hot() {
        let w = Workload::zipf("sprites", 50.0, 16, 8, 1.1);
        let reqs = w.generate(400, 5);
        assert_eq!(reqs.len(), 400);
        // identities come from a pool of 8 → heavy reuse
        let mut gen_seeds: Vec<u64> = reqs
            .iter()
            .filter_map(|(_, r)| match r.body {
                RequestBody::Generate { seed, .. } => Some(seed),
                _ => None,
            })
            .collect();
        assert!(!gen_seeds.is_empty());
        let total = gen_seeds.len();
        gen_seeds.sort_unstable();
        gen_seeds.dedup();
        assert!(gen_seeds.len() <= 8, "at most pool-size identities");
        assert!(gen_seeds.len() < total, "identities must repeat");
        // Zipf skew: the hottest identity (rank 0 = seed*7919) dominates
        let hot = 5u64.wrapping_mul(7919);
        let hot_n = reqs
            .iter()
            .filter(|(_, r)| {
                matches!(r.body, RequestBody::Generate { seed, .. } if seed == hot)
            })
            .count();
        let uniform_share = total / 8;
        assert!(
            hot_n > uniform_share,
            "rank-0 identity ({hot_n} hits) should beat the uniform share ({uniform_share})"
        );
    }

    #[test]
    fn decode_and_encode_bodies_are_pool_deterministic() {
        let w = Workload::zipf("sprites", 50.0, 16, 4, 1.0);
        let reqs = w.generate(300, 9);
        let mut decodes: Vec<&Vec<Vec<f32>>> = Vec::new();
        let mut encodes = 0usize;
        for (_, r) in &reqs {
            match &r.body {
                RequestBody::Decode { latents } => {
                    assert!(latents.iter().all(|row| row.len() == 16));
                    decodes.push(latents);
                }
                RequestBody::Encode { images } => {
                    assert!(images.iter().all(|row| row.len() == 16));
                    assert!(images.iter().flatten().all(|v| (-1.0..=1.0).contains(v)));
                    encodes += 1;
                }
                RequestBody::Generate { .. } => {}
            }
            assert!(r.sampler.supports(r.mode));
        }
        assert!(!decodes.is_empty() && encodes > 0, "mixed body kinds present");
        // pooled identities ⇒ some pair of decode bodies is bitwise equal
        let repeated = decodes
            .iter()
            .enumerate()
            .any(|(i, a)| decodes[..i].iter().any(|b| b == a));
        assert!(repeated, "pool of 4 over {} decodes must repeat a body", decodes.len());
        // and the rows really are ~N(0,1) latents, not junk
        let flat: Vec<f32> = decodes[0].iter().flatten().copied().collect();
        assert!(flat.iter().all(|v| v.is_finite()));
    }
}
