//! Artifact-tree access: `manifest.json` (what `python/compile/aot.py`
//! wrote) plus the tensorfile interchange format (`<name>.bin` raw
//! little-endian f32/f64 + `<name>.bin.json` `{"shape":[...],"dtype":...}`
//! sidecar — see `python/compile/tensorfile.py`, the other half of the
//! mirror).
//!
//! The manifest is the runtime's single source of truth for image
//! geometry, the compiled batch buckets, and the per-dataset HLO paths;
//! nothing else in the crate touches the artifact directory layout.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::json::{self, Value};
use crate::tensor::Tensor;

/// One trained dataset's entry in the manifest.
#[derive(Debug, Clone)]
pub struct DatasetInfo {
    /// Relative HLO-text paths, one per bucket, in `Manifest::buckets` order.
    pub hlo: Vec<String>,
    /// Trained parameter count (reporting only).
    pub params: u64,
    /// Final training loss (reporting only).
    pub final_loss: f64,
    /// Sample count behind the reference feature statistics (proxy-FID).
    pub ref_n: usize,
}

/// Parsed `manifest.json` + the artifact root it was loaded from.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    /// Image side length (samples are `img × img`).
    pub img: usize,
    pub channels: usize,
    /// Diffusion horizon T of the training schedule.
    pub t_max: usize,
    /// Compiled batch buckets, ascending (one executable per dataset × bucket).
    pub buckets: Vec<usize>,
    /// Feature dimension of the proxy-FID extractor.
    pub feat_dim: usize,
    /// Datasets in deterministic (BTreeMap) order.
    pub datasets: BTreeMap<String, DatasetInfo>,
}

impl Manifest {
    /// Load `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = fs::read_to_string(&path)
            .map_err(|e| Error::Artifact(format!("{}: {e}", path.display())))?;
        let v = json::parse(&text)?;
        let img = v.get("img")?.as_usize()?;
        let channels = v.get("channels")?.as_usize()?;
        let t_max = v.get("T")?.as_usize()?;
        let buckets = v.get("buckets")?.as_usize_vec()?;
        if buckets.is_empty() || buckets[0] == 0 {
            return Err(Error::Artifact("manifest buckets empty or zero".into()));
        }
        if buckets.windows(2).any(|w| w[0] >= w[1]) {
            return Err(Error::Artifact(format!(
                "manifest buckets must be strictly ascending, got {buckets:?}"
            )));
        }
        let feat_dim = match v.get_opt("feat_dim") {
            Some(fd) => fd.as_usize()?,
            None => crate::stats::FEAT_DIM,
        };
        let mut datasets = BTreeMap::new();
        let Value::Obj(ds_map) = v.get("datasets")? else {
            return Err(Error::Artifact("manifest 'datasets' is not an object".into()));
        };
        for (name, d) in ds_map {
            let hlo: Vec<String> = d
                .get("hlo")?
                .as_arr()?
                .iter()
                .map(|p| p.as_str().map(str::to_string))
                .collect::<Result<_>>()?;
            if hlo.len() != buckets.len() {
                return Err(Error::Artifact(format!(
                    "dataset '{name}': {} HLO files for {} buckets",
                    hlo.len(),
                    buckets.len()
                )));
            }
            datasets.insert(
                name.clone(),
                DatasetInfo {
                    hlo,
                    params: d.get("params")?.as_u64()?,
                    final_loss: d.get("final_loss")?.as_f64()?,
                    ref_n: d.get("ref_n")?.as_usize()?,
                },
            );
        }
        if datasets.is_empty() {
            return Err(Error::Artifact("manifest has no datasets".into()));
        }
        Ok(Self { root, img, channels, t_max, buckets, feat_dim, datasets })
    }

    /// Elements per sample (`img * img * channels`).
    pub fn sample_dim(&self) -> usize {
        self.img * self.img * self.channels
    }

    /// Look up a dataset or error with the known names.
    pub fn dataset(&self, name: &str) -> Result<&DatasetInfo> {
        self.datasets.get(name).ok_or_else(|| {
            let known: Vec<&str> = self.datasets.keys().map(String::as_str).collect();
            Error::Artifact(format!("unknown dataset '{name}' (manifest has {known:?})"))
        })
    }

    /// Index of an exactly-compiled bucket (for HLO path lookup).
    pub fn bucket_index(&self, bucket: usize) -> Result<usize> {
        self.buckets.iter().position(|&b| b == bucket).ok_or_else(|| {
            Error::Artifact(format!("no compiled bucket {bucket} (have {:?})", self.buckets))
        })
    }

    /// Smallest compiled bucket that fits `n` lanes (the largest bucket
    /// when nothing fits — callers split such selections into sub-batches).
    pub fn bucket_for(&self, n: usize) -> usize {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| *self.buckets.last().expect("non-empty buckets"))
    }

    /// Absolute path of one dataset × bucket HLO module.
    pub fn hlo_path(&self, ds: &DatasetInfo, bucket_idx: usize) -> PathBuf {
        self.root.join(&ds.hlo[bucket_idx])
    }

    /// Absolute path of a golden tensorfile (`<root>/<ds>/goldens/<name>.bin`).
    pub fn golden_path(&self, dataset: &str, name: &str) -> PathBuf {
        self.root.join(dataset).join("goldens").join(format!("{name}.bin"))
    }

    /// Reference feature statistics `(mu, cov)` tensorfile paths.
    pub fn ref_stats_paths(&self, dataset: &str) -> (PathBuf, PathBuf) {
        let d = self.root.join(dataset);
        (d.join("ref_mu.bin"), d.join("ref_cov.bin"))
    }
}

/// Read a tensorfile's `.bin.json` sidecar: `(shape, dtype)`.
fn read_meta(path: &Path) -> Result<(Vec<usize>, String)> {
    let mut side = path.as_os_str().to_os_string();
    side.push(".json");
    let text = fs::read_to_string(&side)
        .map_err(|e| Error::Artifact(format!("{}: {e}", Path::new(&side).display())))?;
    let v = json::parse(&text)?;
    Ok((v.get("shape")?.as_usize_vec()?, v.get("dtype")?.as_str()?.to_string()))
}

fn read_bytes(path: &Path, want: usize) -> Result<Vec<u8>> {
    let bytes = fs::read(path).map_err(|e| Error::Artifact(format!("{}: {e}", path.display())))?;
    if bytes.len() != want {
        return Err(Error::Artifact(format!(
            "{}: {} bytes on disk, sidecar shape wants {want}",
            path.display(),
            bytes.len()
        )));
    }
    Ok(bytes)
}

/// Read a tensorfile as f32 (f64 files are narrowed — the python build
/// writes float64 for some goldens, the runtime consumes f32 throughout).
pub fn read_tensor(path: impl AsRef<Path>) -> Result<Tensor> {
    let path = path.as_ref();
    let (shape, dtype) = read_meta(path)?;
    let n: usize = shape.iter().product();
    let data: Vec<f32> = match dtype.as_str() {
        "f32" => read_bytes(path, n * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("chunk of 4")))
            .collect(),
        "f64" => read_bytes(path, n * 8)?
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().expect("chunk of 8")) as f32)
            .collect(),
        other => {
            return Err(Error::Artifact(format!("{}: unknown dtype '{other}'", path.display())))
        }
    };
    Tensor::new(shape, data)
}

/// Read a tensorfile at full f64 precision (reference statistics).
pub fn read_tensor_f64(path: impl AsRef<Path>) -> Result<(Vec<usize>, Vec<f64>)> {
    let path = path.as_ref();
    let (shape, dtype) = read_meta(path)?;
    let n: usize = shape.iter().product();
    let data: Vec<f64> = match dtype.as_str() {
        "f32" => read_bytes(path, n * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().expect("chunk of 4")) as f64)
            .collect(),
        "f64" => read_bytes(path, n * 8)?
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().expect("chunk of 8")))
            .collect(),
        other => {
            return Err(Error::Artifact(format!("{}: unknown dtype '{other}'", path.display())))
        }
    };
    Ok((shape, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("ddim-artifacts-test-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    const MANIFEST: &str = r#"{
        "img": 16, "channels": 1, "T": 1000,
        "buckets": [1, 2, 4, 8, 16], "feat_dim": 24,
        "datasets": {
            "sprites": {
                "hlo": ["sprites/b1.hlo.txt", "sprites/b2.hlo.txt",
                        "sprites/b4.hlo.txt", "sprites/b8.hlo.txt",
                        "sprites/b16.hlo.txt"],
                "params": 123456, "final_loss": 0.0421, "ref_n": 4096
            }
        }
    }"#;

    #[test]
    fn manifest_round_trip_and_lookups() {
        let dir = tmpdir("manifest");
        write_manifest(&dir, MANIFEST);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.sample_dim(), 256);
        assert_eq!(m.t_max, 1000);
        assert_eq!(m.buckets, vec![1, 2, 4, 8, 16]);
        assert_eq!(m.dataset("sprites").unwrap().ref_n, 4096);
        assert!(m.dataset("blobs").is_err());
        assert_eq!(m.bucket_index(8).unwrap(), 3);
        assert!(m.bucket_index(5).is_err());
        // bucket_for: smallest bucket >= n, clamped to the largest
        assert_eq!(m.bucket_for(1), 1);
        assert_eq!(m.bucket_for(3), 4);
        assert_eq!(m.bucket_for(16), 16);
        assert_eq!(m.bucket_for(33), 16);
        let hlo = m.hlo_path(m.dataset("sprites").unwrap(), 2);
        assert!(hlo.ends_with("sprites/b4.hlo.txt"));
        assert!(m.golden_path("sprites", "b1_x").ends_with("sprites/goldens/b1_x.bin"));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn manifest_rejects_malformed() {
        let dir = tmpdir("reject");
        for bad in [
            r#"{"img":16,"channels":1,"T":10,"buckets":[],"datasets":{}}"#,
            r#"{"img":16,"channels":1,"T":10,"buckets":[4,2],"datasets":{}}"#,
            r#"{"img":16,"channels":1,"T":10,"buckets":[1,2],"datasets":{}}"#,
            r#"{"img":16,"channels":1,"T":10,"buckets":[1,2],
                "datasets":{"a":{"hlo":["x"],"params":1,"final_loss":0.1,"ref_n":8}}}"#,
        ] {
            write_manifest(&dir, bad);
            assert!(Manifest::load(&dir).is_err(), "{bad}");
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tensorfile_f32_and_f64_round_trip() {
        let dir = tmpdir("tensor");
        let path = dir.join("t.bin");
        let vals32: Vec<f32> = vec![0.5, -1.25, 3.0, 0.0, 2.5, -0.125];
        let bytes: Vec<u8> = vals32.iter().flat_map(|v| v.to_le_bytes()).collect();
        fs::write(&path, bytes).unwrap();
        fs::write(
            dir.join("t.bin.json"),
            r#"{"shape": [2, 3], "dtype": "f32"}"#,
        )
        .unwrap();
        let t = read_tensor(&path).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.data(), &vals32[..]);
        let (shape, d64) = read_tensor_f64(&path).unwrap();
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(d64, vals32.iter().map(|&v| v as f64).collect::<Vec<_>>());

        let path64 = dir.join("u.bin");
        let vals64: Vec<f64> = vec![1.5, -2.25];
        let bytes: Vec<u8> = vals64.iter().flat_map(|v| v.to_le_bytes()).collect();
        fs::write(&path64, bytes).unwrap();
        fs::write(dir.join("u.bin.json"), r#"{"shape": [2], "dtype": "f64"}"#).unwrap();
        assert_eq!(read_tensor(&path64).unwrap().data(), &[1.5f32, -2.25]);
        assert_eq!(read_tensor_f64(&path64).unwrap().1, vals64);
        // byte-length mismatch is an error, not a truncation
        fs::write(dir.join("u.bin.json"), r#"{"shape": [3], "dtype": "f64"}"#).unwrap();
        assert!(read_tensor(&path64).is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
