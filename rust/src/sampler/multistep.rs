//! §7 (Discussion) extension: multistep ODE integration — the paper
//! explicitly suggests "multistep methods such as Adams–Bashforth could be
//! helpful for further improving sample quality in fewer steps".
//!
//! In the paper's ODE coordinates (Eq. 14), with x̄ = x/√ᾱ and
//! σ̄ = √((1−ᾱ)/ᾱ), DDIM is the *one-step* Euler rule
//!   x̄_{i−1} = x̄_i + (σ̄_{i−1} − σ̄_i) ε_i .
//! AB2 replaces ε_i with the linear extrapolation of the last two ε
//! evaluations *in σ̄-time* (the steps are non-uniform, so the classic 3/2,
//! −1/2 coefficients generalise to h-ratios):
//!   ε̂ = ε_i + (ε_i − ε_{i+1}) · h_i / (2 h_{i+1})
//! where h_i = σ̄_{i−1} − σ̄_i is the current step and h_{i+1} the previous
//! one. The first step (no history) falls back to Euler — exactly PLMS/PNDM
//! -style warmup. Same trained model, same executable: ε comes back from
//! the fused step's second output; only the host-side combination changes.

/// Non-uniform-step AB2 state: remembers the previous ε and step size in
/// σ̄-time.
#[derive(Debug, Default)]
pub struct Ab2State {
    prev_eps: Option<Vec<f32>>,
    prev_h: f64,
}

impl Ab2State {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance one step: given x at ᾱ_t, the model's ε there, and the target
    /// ᾱ_prev, produce x at ᾱ_prev. Internally updates the history.
    pub fn step(&mut self, x: &[f32], eps: &[f32], alpha_t: f64, alpha_prev: f64) -> Vec<f32> {
        let mut out = x.to_vec();
        self.step_inplace(&mut out, eps, alpha_t, alpha_prev);
        out
    }

    /// In-place [`Ab2State::step`] — the serving hot path. The update is
    /// elementwise so overwriting `x` is safe, and the ε-history buffer is
    /// reused after the first step: zero steady-state allocation.
    pub fn step_inplace(&mut self, x: &mut [f32], eps: &[f32], alpha_t: f64, alpha_prev: f64) {
        let sb_t = ((1.0 - alpha_t) / alpha_t).sqrt();
        let sb_p = ((1.0 - alpha_prev) / alpha_prev).sqrt();
        let h = sb_p - sb_t; // negative while denoising (σ̄ decreases)
        let scale_in = 1.0 / alpha_t.sqrt();
        let scale_out = alpha_prev.sqrt();

        match &self.prev_eps {
            Some(pe) if self.prev_h.abs() > 1e-12 => {
                let r = h / (2.0 * self.prev_h);
                for (xv, (&e, &ep)) in x.iter_mut().zip(eps.iter().zip(pe)) {
                    let e_hat = e as f64 + (e as f64 - ep as f64) * r;
                    *xv = ((*xv as f64 * scale_in + h * e_hat) * scale_out) as f32;
                }
            }
            _ => {
                for (xv, &e) in x.iter_mut().zip(eps) {
                    *xv = ((*xv as f64 * scale_in + h * e as f64) * scale_out) as f32;
                }
            }
        }
        match &mut self.prev_eps {
            Some(pe) if pe.len() == eps.len() => pe.copy_from_slice(eps),
            slot => *slot = Some(eps.to_vec()),
        }
        self.prev_h = h;
    }

    pub fn reset(&mut self) {
        self.prev_eps = None;
        self.prev_h = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::ddim_update_host;
    use crate::schedule::AlphaTable;

    #[test]
    fn first_step_equals_euler_ddim() {
        let abar = AlphaTable::linear(1000);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.3).sin()).collect();
        let eps: Vec<f32> = (0..32).map(|i| (i as f32 * 0.7).cos()).collect();
        let (a_t, a_p) = (abar.abar(800), abar.abar(600));
        let mut ab = Ab2State::new();
        let got = ab.step(&x, &eps, a_t, a_p);
        let want = ddim_update_host(&x, &eps, a_t, a_p);
        let max: f32 = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max < 1e-5, "warmup step should be plain DDIM Euler, diff {max}");
    }

    #[test]
    fn constant_eps_reduces_to_euler_every_step() {
        // with constant ε the extrapolation term vanishes: AB2 == Euler
        let abar = AlphaTable::linear(1000);
        let eps = vec![0.25f32; 16];
        let mut x_ab = vec![1.0f32; 16];
        let mut x_eu = vec![1.0f32; 16];
        let mut ab = Ab2State::new();
        let ts = [1000usize, 750, 500, 250, 1];
        for w in ts.windows(2) {
            let (a_t, a_p) = (abar.abar(w[0]), abar.abar(w[1]));
            x_ab = ab.step(&x_ab, &eps, a_t, a_p);
            x_eu = ddim_update_host(&x_eu, &eps, a_t, a_p);
        }
        for (a, b) in x_ab.iter().zip(&x_eu) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn ab2_integrates_linear_drift_better_than_euler() {
        // ODE dx̄/dσ̄ = ε(σ̄) = σ̄ (linear in σ̄-time): exact solution
        // x̄(σ̄) = x̄0 + σ̄²/2. AB2's truncation error is O(h³) vs Euler O(h²),
        // so over few steps AB2 must land closer.
        let sb = |a: f64| ((1.0 - a) / a).sqrt();
        let abar = AlphaTable::linear(1000);
        // moderate-σ̄ regime (σ̄ ≈ 3.4 → 0.4) so truncation order dominates
        let ts = [500usize, 450, 400, 350, 300, 250, 200];
        let exact = |a: f64, x0: f64| x0 + sb(a) * sb(a) / 2.0;
        let x_start = 0.0f64;
        // integrate in xbar coordinates directly via the state machinery:
        // wrap scalars in 1-element slices, converting x <-> xbar per step
        let mut ab = Ab2State::new();
        let mut x_ab = vec![(x_start + sb(abar.abar(ts[0])).powi(2) / 2.0) as f32];
        let mut x_eu = x_ab.clone();
        // scale into un-normalised x coordinates at the start
        x_ab[0] *= abar.abar(ts[0]).sqrt() as f32;
        x_eu[0] *= abar.abar(ts[0]).sqrt() as f32;
        for w in ts.windows(2) {
            let (a_t, a_p) = (abar.abar(w[0]), abar.abar(w[1]));
            let eps_val = sb(a_t) as f32; // ε(σ̄) = σ̄, evaluated at current point
            x_ab = ab.step(&x_ab, &[eps_val], a_t, a_p);
            x_eu = ddim_update_host(&x_eu, &[eps_val], a_t, a_p);
        }
        let a_end = abar.abar(*ts.last().unwrap());
        let want = exact(a_end, x_start);
        let got_ab = x_ab[0] as f64 / a_end.sqrt();
        let got_eu = x_eu[0] as f64 / a_end.sqrt();
        let (err_ab, err_eu) = ((got_ab - want).abs(), (got_eu - want).abs());
        assert!(
            err_ab < err_eu * 0.6,
            "AB2 should beat Euler on a smooth ODE: {err_ab} vs {err_eu}"
        );
    }

    #[test]
    fn reset_clears_history() {
        let abar = AlphaTable::linear(1000);
        let x = vec![0.5f32; 8];
        let e1 = vec![1.0f32; 8];
        let e2 = vec![-1.0f32; 8];
        let (a1, a2, a3) = (abar.abar(900), abar.abar(600), abar.abar(300));
        let mut ab = Ab2State::new();
        ab.step(&x, &e1, a1, a2);
        ab.reset();
        let after_reset = ab.step(&x, &e2, a2, a3);
        let fresh = ddim_update_host(&x, &e2, a2, a3);
        assert_eq!(after_reset, fresh);
    }
}
