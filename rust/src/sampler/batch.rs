//! The one audited pack/pad/run path for batched step execution.
//!
//! Both drivers — the homogeneous [`BatchRunner`](super::BatchRunner) and
//! the coordinator's heterogeneous `Engine` — used to carry their own copy
//! of the lane-packing loop (state, schedule scalars, seeded noise, inert
//! padding). Packing is exactly where a batching bug silently corrupts a
//! *different* request's sample, so it lives here once, unit-tested without
//! a runtime, and everything above goes through it.

use crate::error::Result;
use crate::runtime::{LaneStep, PendingStep, StepExecutable, StepOutput};
use crate::sampler::Trajectory;

/// Reusable input/output buffers for one batched `denoise_step` call,
/// sized for `capacity` lanes but runnable at any bucket ≤ capacity.
pub struct StepBatch {
    dim: usize,
    capacity: usize,
    x: Vec<f32>,
    t: Vec<f32>,
    a_in: Vec<f32>,
    a_out: Vec<f32>,
    sigma: Vec<f32>,
    noise: Vec<f32>,
    out: StepOutput,
}

/// Read-back view of one packed input lane (golden tests pin the fused
/// executable against the host kernels from exactly these values).
#[derive(Debug, Clone, Copy)]
pub struct PackedLane<'a> {
    pub x: &'a [f32],
    pub noise: &'a [f32],
    pub t: f32,
    pub alpha_in: f32,
    pub alpha_out: f32,
    pub sigma: f32,
}

impl StepBatch {
    pub fn new(capacity: usize, dim: usize) -> Self {
        Self {
            dim,
            capacity,
            x: vec![0.0; capacity * dim],
            t: vec![0.0; capacity],
            a_in: vec![0.0; capacity],
            a_out: vec![0.0; capacity],
            sigma: vec![0.0; capacity],
            noise: vec![0.0; capacity * dim],
            out: StepOutput::zeros(capacity * dim),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Pack `traj`'s next step into `slot`: current state, the step's
    /// schedule scalars, and the lane's seeded (pre-scaled) noise.
    pub fn pack(&mut self, slot: usize, traj: &mut Trajectory) -> Result<()> {
        debug_assert!(slot < self.capacity);
        let d = self.dim;
        let p = traj.next_params()?;
        self.x[slot * d..(slot + 1) * d].copy_from_slice(traj.state());
        self.t[slot] = p.t_model as f32;
        self.a_in[slot] = p.alpha_in as f32;
        self.a_out[slot] = p.alpha_out as f32;
        self.sigma[slot] = p.sigma_dir as f32;
        traj.fill_noise(&mut self.noise[slot * d..(slot + 1) * d])
    }

    /// Fill slots `filled..bucket` with inert padding: zero state/noise/σ
    /// and slot 0's schedule scalars clamped away from 0 so the kernel's
    /// divisions stay finite. Padding outputs are never read back — lane
    /// independence of the executable is what makes this sound (tested in
    /// `engine_integration::lanes_are_independent_bitwise`).
    pub fn pad(&mut self, filled: usize, bucket: usize) {
        debug_assert!(filled > 0, "pad wants at least one real lane to mirror");
        debug_assert!(filled <= bucket && bucket <= self.capacity);
        let d = self.dim;
        for slot in filled..bucket {
            self.x[slot * d..(slot + 1) * d].fill(0.0);
            self.t[slot] = self.t[0];
            self.a_in[slot] = self.a_in[0].max(1e-4);
            self.a_out[slot] = self.a_out[0].max(1e-4);
            self.sigma[slot] = 0.0;
            self.noise[slot * d..(slot + 1) * d].fill(0.0);
        }
    }

    /// Hand the first `bucket` packed slots to the device without waiting
    /// (the pipelined half of [`StepBatch::run`]). The inputs are
    /// snapshotted during submission, so this batch may be re-packed for a
    /// later step while the returned [`PendingStep`] is still in flight —
    /// but [`StepBatch::finish`] must run first if this batch's own
    /// outputs are still wanted.
    pub fn submit(&mut self, exe: &StepExecutable, bucket: usize) -> Result<PendingStep> {
        let d = self.dim;
        exe.submit(
            &self.x[..bucket * d],
            &self.t[..bucket],
            &self.a_in[..bucket],
            &self.a_out[..bucket],
            &self.sigma[..bucket],
            &self.noise[..bucket * d],
        )
    }

    /// Wait for a submitted step and land its outputs in this batch
    /// (readable through [`StepBatch::lane`]).
    pub fn finish(&mut self, pending: PendingStep) -> Result<()> {
        pending.wait_into(&mut self.out)
    }

    /// Execute `exe` over the first `bucket` packed slots synchronously.
    /// Goes through the executable's one-shot run path, which on the
    /// reference backend writes straight into this batch's output buffers
    /// — no pending copy, no allocation — and is equivalent to
    /// [`StepBatch::submit`] + [`StepBatch::finish`] on every backend.
    pub fn run(&mut self, exe: &StepExecutable, bucket: usize) -> Result<()> {
        let d = self.dim;
        exe.run(
            &self.x[..bucket * d],
            &self.t[..bucket],
            &self.a_in[..bucket],
            &self.a_out[..bucket],
            &self.sigma[..bucket],
            &self.noise[..bucket * d],
            &mut self.out,
        )
    }

    /// Output view of `slot` from the last [`StepBatch::run`].
    pub fn lane(&self, slot: usize) -> LaneStep<'_> {
        self.out.lane(slot, self.dim)
    }

    /// Input view of `slot` as packed (for golden tests / audits).
    pub fn packed(&self, slot: usize) -> PackedLane<'_> {
        let d = self.dim;
        PackedLane {
            x: &self.x[slot * d..(slot + 1) * d],
            noise: &self.noise[slot * d..(slot + 1) * d],
            t: self.t[slot],
            alpha_in: self.a_in[slot],
            alpha_out: self.a_out[slot],
            sigma: self.sigma[slot],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{AlphaTable, NoiseMode, SamplePlan, TauKind};

    fn plan(s: usize, mode: NoiseMode) -> SamplePlan {
        let t = AlphaTable::linear(1000);
        SamplePlan::generate(&t, TauKind::Linear, s, mode).unwrap()
    }

    #[test]
    fn pack_writes_the_lane_slot() {
        let dim = 4;
        let mut b = StepBatch::new(3, dim);
        let mut tr = Trajectory::from_prior(plan(5, NoiseMode::Eta(0.0)), dim, 7);
        let want_state = tr.state().to_vec();
        let p = tr.next_params().unwrap();
        b.pack(1, &mut tr).unwrap();
        let lane = b.packed(1);
        assert_eq!(lane.x, &want_state[..]);
        assert_eq!(lane.t, p.t_model as f32);
        assert_eq!(lane.alpha_in, p.alpha_in as f32);
        assert_eq!(lane.alpha_out, p.alpha_out as f32);
        assert_eq!(lane.sigma, p.sigma_dir as f32);
        assert_eq!(lane.noise, &[0.0; 4][..], "eta=0 lane noise is zero");
        // untouched slots stay zero
        assert_eq!(b.packed(0).x, &[0.0; 4][..]);
    }

    #[test]
    fn pack_fails_on_finished_trajectory() {
        let dim = 2;
        let mut b = StepBatch::new(1, dim);
        let mut tr = Trajectory::from_prior(plan(1, NoiseMode::Eta(0.0)), dim, 1);
        b.pack(0, &mut tr).unwrap();
        let step: Vec<f32> = vec![0.5; dim];
        tr.advance(LaneStep { x_prev: &step, eps: &step, x0: &step }).unwrap();
        assert!(tr.is_done());
        assert!(b.pack(0, &mut tr).is_err());
    }

    #[test]
    fn pad_mirrors_slot_zero_and_clamps() {
        let dim = 2;
        let mut b = StepBatch::new(4, dim);
        // a final-step lane: alpha_out = 1, fine; force tiny alpha_in via a
        // raw write to check the clamp instead of depending on the table
        let mut tr = Trajectory::from_prior(plan(3, NoiseMode::Eta(1.0)), dim, 3);
        b.pack(0, &mut tr).unwrap();
        b.a_in[0] = 0.0; // simulate a degenerate schedule scalar
        b.pad(1, 4);
        for slot in 1..4 {
            let lane = b.packed(slot);
            assert_eq!(lane.x, &[0.0; 2][..]);
            assert_eq!(lane.noise, &[0.0; 2][..]);
            assert_eq!(lane.sigma, 0.0, "padding lanes are deterministic");
            assert_eq!(lane.t, b.packed(0).t);
            assert!(lane.alpha_in >= 1e-4, "alpha_in clamped away from 0");
            assert_eq!(lane.alpha_out, b.packed(0).alpha_out.max(1e-4));
        }
        // slot 0 itself is untouched by pad
        assert_eq!(b.packed(0).alpha_in, 0.0);
    }
}
