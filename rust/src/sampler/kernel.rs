//! Pluggable per-lane update kernels — the §4.3 / §7 samplers as
//! first-class serving scenarios.
//!
//! The fused executable always returns `(x_prev, eps, x0)` per lane; which
//! of those a trajectory *commits* is the sampler choice:
//!
//! - **DDIM** (Eq. 13): commit the executable's fused `x_prev` — the exact
//!   AOT-graph arithmetic, stochastic plans included.
//! - **PF-ODE** (Eq. 15): one host-side Euler step on the probability-flow
//!   ODE, rebuilt from the executable's `eps` output. Same model call, no
//!   extra executable.
//! - **AB2** (§7 Discussion): Adams–Bashforth-2 in σ̄-time with per-lane ε
//!   history; the first step (no history) falls back to Euler — PLMS-style
//!   warmup. History lives inside the lane's kernel, so it is born with the
//!   trajectory and dies with it; it is never shared across lanes and never
//!   survives a request.
//!
//! The host-integrated kernels rebuild the next iterate from ε alone, so
//! they are defined only for deterministic (η = 0) plans — the paper's
//! stochastic processes (η > 0, σ̂) exist only under the DDIM/DDPM update
//! family, and requests pairing them with `pf_ode`/`ab2` are rejected at
//! admission.

use crate::error::{Error, Result};
use crate::runtime::LaneStep;
use crate::sampler::{pf_euler_update_inplace, Ab2State};
use crate::schedule::{NoiseMode, StepParams};

/// Wire-level sampler selector (the request's `"sampler"` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SamplerKind {
    #[default]
    Ddim,
    PfOde,
    Ab2,
}

impl SamplerKind {
    /// Stable ordering for per-kernel counters ([`SamplerKind::index`]).
    pub const ALL: [SamplerKind; 3] = [SamplerKind::Ddim, SamplerKind::PfOde, SamplerKind::Ab2];

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "ddim" => Ok(SamplerKind::Ddim),
            "pf_ode" => Ok(SamplerKind::PfOde),
            "ab2" => Ok(SamplerKind::Ab2),
            other => Err(Error::Request(format!(
                "unknown sampler '{other}' (want ddim | pf_ode | ab2)"
            ))),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SamplerKind::Ddim => "ddim",
            SamplerKind::PfOde => "pf_ode",
            SamplerKind::Ab2 => "ab2",
        }
    }

    /// Index into per-kernel counter arrays, in [`SamplerKind::ALL`] order.
    pub fn index(&self) -> usize {
        match self {
            SamplerKind::Ddim => 0,
            SamplerKind::PfOde => 1,
            SamplerKind::Ab2 => 2,
        }
    }

    /// Whether this kernel is defined under `mode`'s noise injection. The
    /// host-integrated kernels (PF-ODE, AB2) deterministically re-integrate
    /// from ε and have no σ > 0 counterpart — only DDIM's Eq.-12 family does.
    pub fn supports(&self, mode: NoiseMode) -> bool {
        matches!(self, SamplerKind::Ddim) || mode.is_deterministic()
    }

    /// Fresh per-lane kernel state.
    pub fn instantiate(&self) -> UpdateKernel {
        match self {
            SamplerKind::Ddim => UpdateKernel::Ddim,
            SamplerKind::PfOde => UpdateKernel::PfOde,
            SamplerKind::Ab2 => UpdateKernel::Ab2(Ab2State::new()),
        }
    }
}

/// Per-lane update rule plus whatever state it carries (AB2's ε history).
#[derive(Debug)]
pub enum UpdateKernel {
    /// Commit the executable's fused `x_prev` (Eq. 13 / Eq. 12, σ ≥ 0).
    Ddim,
    /// Host Euler step on the probability-flow ODE (Eq. 15) from `eps`.
    PfOde,
    /// Adams–Bashforth-2 in σ̄-time; Euler warmup on the first step.
    Ab2(Ab2State),
}

impl UpdateKernel {
    pub fn kind(&self) -> SamplerKind {
        match self {
            UpdateKernel::Ddim => SamplerKind::Ddim,
            UpdateKernel::PfOde => SamplerKind::PfOde,
            UpdateKernel::Ab2(_) => SamplerKind::Ab2,
        }
    }

    /// Advance `x` in place using this lane's slice of the executable
    /// outputs and the [`StepParams`] the call was packed with. `alpha_in`
    /// is ᾱ at the evaluation point and `alpha_out` at the target, so the
    /// same rule serves both plan directions (generate and encode). All
    /// three paths are allocation-free in steady state.
    pub fn advance(&mut self, x: &mut [f32], step: LaneStep<'_>, p: StepParams) {
        match self {
            UpdateKernel::Ddim => x.copy_from_slice(step.x_prev),
            UpdateKernel::PfOde => {
                pf_euler_update_inplace(x, step.eps, p.alpha_in, p.alpha_out)
            }
            UpdateKernel::Ab2(ab) => ab.step_inplace(x, step.eps, p.alpha_in, p.alpha_out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::{ddim_update_host, pf_euler_update};
    use crate::schedule::AlphaTable;

    fn params(alpha_in: f64, alpha_out: f64) -> StepParams {
        StepParams { t_model: 500.0, alpha_in, alpha_out, sigma_dir: 0.0, sigma_noise: 0.0 }
    }

    fn lane<'a>(x_prev: &'a [f32], eps: &'a [f32]) -> LaneStep<'a> {
        LaneStep { x_prev, eps, x0: x_prev }
    }

    #[test]
    fn parse_and_label_round_trip() {
        for k in SamplerKind::ALL {
            assert_eq!(SamplerKind::parse(k.label()).unwrap(), k);
        }
        assert!(SamplerKind::parse("euler").is_err());
        assert_eq!(SamplerKind::default(), SamplerKind::Ddim);
        // counter indices are a permutation of 0..3 in ALL order
        for (i, k) in SamplerKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
            assert_eq!(k.instantiate().kind(), *k);
        }
    }

    #[test]
    fn stochastic_modes_are_ddim_only() {
        for k in SamplerKind::ALL {
            assert!(k.supports(NoiseMode::Eta(0.0)), "{k:?} must allow eta=0");
        }
        for mode in [NoiseMode::Eta(0.5), NoiseMode::Eta(1.0), NoiseMode::SigmaHat] {
            assert!(SamplerKind::Ddim.supports(mode));
            assert!(!SamplerKind::PfOde.supports(mode), "{mode:?}");
            assert!(!SamplerKind::Ab2.supports(mode), "{mode:?}");
        }
    }

    #[test]
    fn ddim_kernel_commits_x_prev_verbatim() {
        let mut x = vec![0.0f32; 4];
        let committed = [1.0f32, -2.0, 0.5, 3.0];
        let eps = [9.0f32; 4];
        UpdateKernel::Ddim.advance(&mut x, lane(&committed, &eps), params(0.3, 0.6));
        assert_eq!(x, committed);
    }

    #[test]
    fn pf_ode_kernel_matches_host_euler() {
        let abar = AlphaTable::linear(1000);
        let x0: Vec<f32> = (0..16).map(|i| (i as f32 * 0.2).sin()).collect();
        let eps: Vec<f32> = (0..16).map(|i| (i as f32 * 0.5).cos()).collect();
        let (a_t, a_p) = (abar.abar(800), abar.abar(600));
        let mut x = x0.clone();
        let ignored = vec![7.0f32; 16]; // PF-ODE must not read x_prev
        UpdateKernel::PfOde.advance(&mut x, lane(&ignored, &eps), params(a_t, a_p));
        assert_eq!(x, pf_euler_update(&x0, &eps, a_t, a_p));
    }

    #[test]
    fn ab2_kernel_warms_up_as_euler_then_extrapolates() {
        let abar = AlphaTable::linear(1000);
        let x0: Vec<f32> = (0..8).map(|i| (i as f32 * 0.3).sin()).collect();
        let e1 = vec![0.5f32; 8];
        let e2 = vec![-0.25f32; 8];
        let (a1, a2, a3) = (abar.abar(900), abar.abar(600), abar.abar(300));
        let mut kernel = SamplerKind::Ab2.instantiate();
        let ignored = vec![0.0f32; 8];

        let mut x = x0.clone();
        kernel.advance(&mut x, lane(&ignored, &e1), params(a1, a2));
        let euler1 = ddim_update_host(&x0, &e1, a1, a2);
        let warm_diff: f32 =
            x.iter().zip(&euler1).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(warm_diff < 1e-5, "warmup step is plain Euler, diff {warm_diff}");

        // second step must consult history: differs from memoryless Euler,
        // and matches a reference Ab2State driven over the same sequence
        let euler2 = ddim_update_host(&x, &e2, a2, a3);
        let mut reference = Ab2State::new();
        let first = reference.step(&x0, &e1, a1, a2);
        assert_eq!(x, first, "kernel warmup is exactly Ab2State's warmup");
        let want = reference.step(&first, &e2, a2, a3);
        kernel.advance(&mut x, lane(&ignored, &e2), params(a2, a3));
        assert_eq!(x, want);
        assert_ne!(x, euler2, "AB2's second step must use the ε history");
    }
}
