//! Occupancy-aware batch formation: decompose one tick's lane selection
//! into exactly-sized sub-batches on compiled-bucket boundaries.
//!
//! The old policy ran the whole selection in the smallest bucket that
//! fits, padding the rest — 9 selected lanes with buckets {…,8,16} ran
//! bucket 16 with 7 dead lanes, ~44% wasted FLOPs on every such tick.
//! The planner instead fills buckets exactly (9 → 8+1) and only pads the
//! final remainder, with a tunable threshold deciding when a padded
//! single call beats extra per-call overhead. Pure arithmetic over the
//! bucket list — no runtime needed — so the greedy policy is
//! property-tested exhaustively below.

/// One device call of a planned tick: lanes `sel[start..start+lanes]`
/// packed into slots `0..lanes` of a batch run at `bucket`
/// (`bucket - lanes` slots are inert padding).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubBatch {
    /// Offset into the tick's selection order.
    pub start: usize,
    /// Occupied lanes (≥ 1).
    pub lanes: usize,
    /// Compiled bucket the call runs at (≥ `lanes`).
    pub bucket: usize,
}

impl SubBatch {
    /// Dead slots this call executes.
    pub fn padding(&self) -> usize {
        self.bucket - self.lanes
    }
}

/// Default padding-waste threshold (`ServeConfig::max_padding_waste`):
/// a remainder whose padded fraction is at most this runs as one padded
/// call; anything worse is decomposed into exact buckets first. 0.25
/// keeps e.g. 3 lanes in a single bucket-4 call (25% waste, matching the
/// old policy bitwise) while splitting 9 → 8+1 instead of padding to 16.
pub const DEFAULT_MAX_PADDING_WASTE: f64 = 0.25;

/// Greedily decompose `n` selected lanes over the ascending compiled
/// `buckets` (only buckets ≤ `capacity` are eligible), appending to
/// `out`. Guarantees, property-tested below:
///
/// - the sub-batches tile `0..n` exactly (each selected lane covered once);
/// - every `lanes`/`bucket` is ≤ `capacity`;
/// - total padding never exceeds the old single-bucket policy's
///   (`bucket_for(n) - n`), whatever `max_waste` is;
/// - `max_waste >= 1.0` reproduces the old single-bucket selection
///   whenever one bucket can hold all `n` lanes.
///
/// `max_waste` is the padded fraction (`padding / bucket`) above which a
/// pad-up call is rejected in favour of exact decomposition.
pub fn plan_sub_batches(n: usize, buckets: &[usize], capacity: usize, max_waste: f64, out: &mut Vec<SubBatch>) {
    out.clear();
    if n == 0 {
        return;
    }
    let eligible = |b: usize| b <= capacity;
    // fallback for a degenerate bucket list: one exactly-sized call
    if !buckets.iter().any(|&b| eligible(b)) {
        out.push(SubBatch { start: 0, lanes: n, bucket: n });
        return;
    }
    let up = |r: usize| buckets.iter().copied().filter(|&b| eligible(b)).find(|&b| b >= r);
    let down = |r: usize| buckets.iter().copied().filter(|&b| eligible(b) && b <= r).last();

    let mut start = 0usize;
    let mut rem = n;
    while rem > 0 {
        let fits = up(rem);
        if let Some(b) = fits {
            let waste = (b - rem) as f64 / b as f64;
            if waste <= max_waste || down(rem).is_none() {
                out.push(SubBatch { start, lanes: rem, bucket: b });
                break;
            }
        }
        match down(rem) {
            Some(b) => {
                // exact fill with the largest bucket that fits
                out.push(SubBatch { start, lanes: b, bucket: b });
                start += b;
                rem -= b;
            }
            None => {
                // no bucket ≤ rem: forced pad-up (up() must exist here,
                // since some bucket is eligible and all of them are > rem)
                let b = fits.expect("some eligible bucket >= rem");
                out.push(SubBatch { start, lanes: rem, bucket: b });
                break;
            }
        }
    }

    // Never do worse than the old policy: if greedy decomposition pads
    // more than one big padded call would (possible for irregular,
    // non-doubling bucket lists), fall back to the single bucket.
    if let Some(single) = up(n) {
        let plan_padding: usize = out.iter().map(SubBatch::padding).sum();
        if plan_padding > single - n {
            out.clear();
            out.push(SubBatch { start: 0, lanes: n, bucket: single });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(n: usize, buckets: &[usize], capacity: usize, max_waste: f64) -> Vec<SubBatch> {
        let mut out = Vec::new();
        plan_sub_batches(n, buckets, capacity, max_waste, &mut out);
        out
    }

    const POW2: &[usize] = &[1, 2, 4, 8, 16];

    #[test]
    fn exact_bucket_is_one_full_call() {
        for &n in POW2 {
            assert_eq!(
                plan(n, POW2, 16, DEFAULT_MAX_PADDING_WASTE),
                vec![SubBatch { start: 0, lanes: n, bucket: n }]
            );
        }
    }

    #[test]
    fn off_bucket_counts_decompose() {
        // 9 → 8 + 1 instead of one bucket-16 call with 7 dead lanes
        assert_eq!(
            plan(9, POW2, 16, DEFAULT_MAX_PADDING_WASTE),
            vec![
                SubBatch { start: 0, lanes: 8, bucket: 8 },
                SubBatch { start: 8, lanes: 1, bucket: 1 },
            ]
        );
        // 33 exceeds the largest bucket: 16 + 16 + 1
        assert_eq!(
            plan(33, POW2, 16, DEFAULT_MAX_PADDING_WASTE),
            vec![
                SubBatch { start: 0, lanes: 16, bucket: 16 },
                SubBatch { start: 16, lanes: 16, bucket: 16 },
                SubBatch { start: 32, lanes: 1, bucket: 1 },
            ]
        );
    }

    #[test]
    fn threshold_keeps_cheap_padding_in_one_call() {
        // 3 lanes → bucket 4 is 25% waste: at the default threshold this
        // stays a single padded call (bitwise-identical to the old policy)
        assert_eq!(
            plan(3, POW2, 16, DEFAULT_MAX_PADDING_WASTE),
            vec![SubBatch { start: 0, lanes: 3, bucket: 4 }]
        );
        // but a stricter threshold splits it
        assert_eq!(
            plan(3, POW2, 16, 0.1),
            vec![
                SubBatch { start: 0, lanes: 2, bucket: 2 },
                SubBatch { start: 2, lanes: 1, bucket: 1 },
            ]
        );
    }

    #[test]
    fn max_waste_one_reproduces_old_single_bucket_policy() {
        for n in 1..=16 {
            let got = plan(n, POW2, 16, 1.0);
            let old_bucket = POW2.iter().copied().find(|&b| b >= n).unwrap();
            assert_eq!(got, vec![SubBatch { start: 0, lanes: n, bucket: old_bucket }], "n={n}");
        }
    }

    #[test]
    fn capacity_restricts_eligible_buckets() {
        // capacity 8: bucket 16 may not be used even for 9+ lanes
        let got = plan(12, POW2, 8, DEFAULT_MAX_PADDING_WASTE);
        assert!(got.iter().all(|s| s.bucket <= 8), "{got:?}");
        assert_eq!(got.iter().map(|s| s.lanes).sum::<usize>(), 12);
    }

    #[test]
    fn missing_small_buckets_force_padding() {
        // buckets {4, 8}: a remainder of 1 has to pad up to 4
        let got = plan(9, &[4, 8], 8, DEFAULT_MAX_PADDING_WASTE);
        assert_eq!(got.iter().map(|s| s.lanes).sum::<usize>(), 9);
        let padding: usize = got.iter().map(SubBatch::padding).sum();
        assert!(padding <= 3, "{got:?}"); // old policy (no bucket ≥ 9) can't even run this
    }

    #[test]
    fn degenerate_bucket_list_runs_exact() {
        assert_eq!(plan(5, &[], 16, 0.25), vec![SubBatch { start: 0, lanes: 5, bucket: 5 }]);
        assert_eq!(plan(5, &[32], 16, 0.25), vec![SubBatch { start: 0, lanes: 5, bucket: 5 }]);
        assert!(plan(0, POW2, 16, 0.25).is_empty());
    }

    /// The load-bearing properties: every selected lane covered exactly
    /// once by in-order contiguous sub-batches, capacity respected, and
    /// padding never worse than the old single-bucket policy — over
    /// random bucket lists (not just powers of two), selection sizes,
    /// capacities and thresholds.
    #[test]
    fn property_plan_tiles_selection_within_capacity_and_padding_bound() {
        crate::testing::check("planner_greedy_decomposition", 300, |g| {
            // random strictly-ascending bucket list, possibly without 1
            let mut buckets: Vec<usize> = Vec::new();
            let mut b = g.int_in(1, 4);
            for _ in 0..g.int_in(1, 6) {
                buckets.push(b);
                b += g.int_in(1, 2) * b.max(1); // irregular growth
            }
            let largest = *buckets.last().unwrap();
            let capacity = if g.bool() { largest } else { g.int_in(1, largest).max(1) };
            let n = g.int_in(1, 2 * largest + 1).max(1);
            let max_waste = g.f64_in(0.0, 1.0);
            let mut out = Vec::new();
            plan_sub_batches(n, &buckets, capacity, max_waste, &mut out);

            // (1) tiles 0..n contiguously, in order, each lane exactly once
            let mut cursor = 0usize;
            for s in &out {
                if s.start != cursor {
                    return Err(format!("gap/overlap at {s:?} (cursor {cursor}) in {out:?}"));
                }
                if s.lanes == 0 || s.lanes > s.bucket {
                    return Err(format!("bad sub-batch {s:?}"));
                }
                cursor += s.lanes;
            }
            if cursor != n {
                return Err(format!("covered {cursor} of {n} lanes: {out:?}"));
            }
            // (2) capacity respected whenever any compiled bucket fits it
            if buckets.iter().any(|&b| b <= capacity) {
                if let Some(s) = out.iter().find(|s| s.bucket > capacity) {
                    return Err(format!("bucket over capacity {capacity}: {s:?}"));
                }
            }
            // (3) padding never exceeds the old single-bucket policy
            if let Some(single) = buckets.iter().copied().find(|&b| b >= n && b <= capacity) {
                let padding: usize = out.iter().map(SubBatch::padding).sum();
                if padding > single - n {
                    return Err(format!(
                        "padding {padding} worse than single bucket {single} for n={n}: {out:?}"
                    ));
                }
            }
            Ok(())
        });
    }
}
