//! Sec. 4.3 ablation: the probability-flow-ODE Euler update (Eq. 15,
//! Song et al. 2020's discretisation) as an alternative to the DDIM update
//! (Eq. 13). The paper notes the two coincide as Δt→0 but "in fewer
//! sampling steps these choices will make a difference" — the
//! `ablation_pf_ode` bench quantifies exactly that.
//!
//! Because the fused executable returns ε alongside x_prev, this update is
//! computed host-side from the same model call — no extra executable.

/// One PF-Euler step (Eq. 15):
/// x̄(t−Δt) = x̄(t) + ½ ((1−ᾱ_p)/ᾱ_p − (1−ᾱ_t)/ᾱ_t) · sqrt(ᾱ_t/(1−ᾱ_t)) · ε
/// with x̄ = x/√ᾱ; returns x(t−Δt) in un-rescaled coordinates.
pub fn pf_euler_update(x: &[f32], eps: &[f32], alpha_t: f64, alpha_prev: f64) -> Vec<f32> {
    let mut out = x.to_vec();
    pf_euler_update_inplace(&mut out, eps, alpha_t, alpha_prev);
    out
}

/// In-place [`pf_euler_update`] — the serving hot path (the update is
/// elementwise, so overwriting `x` is safe and keeps the engine's
/// zero-steady-state-allocation property for PF-ODE lanes).
pub fn pf_euler_update_inplace(x: &mut [f32], eps: &[f32], alpha_t: f64, alpha_prev: f64) {
    assert_eq!(x.len(), eps.len());
    let lam = 0.5
        * ((1.0 - alpha_prev) / alpha_prev - (1.0 - alpha_t) / alpha_t)
        * (alpha_t / (1.0 - alpha_t)).sqrt();
    let scale_in = 1.0 / alpha_t.sqrt();
    let scale_out = alpha_prev.sqrt();
    for (xv, &ev) in x.iter_mut().zip(eps) {
        *xv = ((*xv as f64 * scale_in + lam * ev as f64) * scale_out) as f32;
    }
}

/// The DDIM update (Eq. 13 / Eq. 12 with σ=0), host-side, for apples-to-
/// apples comparison in the ablation (identical to the kernel's arithmetic).
pub fn ddim_update_host(x: &[f32], eps: &[f32], alpha_t: f64, alpha_prev: f64) -> Vec<f32> {
    assert_eq!(x.len(), eps.len());
    let c_x0 = (alpha_prev / alpha_t).sqrt();
    let c_eps = (1.0 - alpha_prev).sqrt() - (alpha_prev * (1.0 - alpha_t) / alpha_t).sqrt();
    x.iter()
        .zip(eps)
        .map(|(&xv, &ev)| (xv as f64 * c_x0 + ev as f64 * c_eps) as f32)
        .collect()
}

/// The full stochastic Eq.-12 update exactly as the fused executable
/// composes it (see `python/compile/kernels/ddim_step.py`):
///   x0   = (x − √(1−ᾱ_t) ε) / √ᾱ_t
///   out  = √ᾱ_p x0 + √max(1−ᾱ_p−σ², 0) ε + σ·noise
/// `noise` is the pre-scaled per-lane buffer the engine feeds the kernel
/// (N(0,1) × `noise_scale`). With σ = 0 and zero noise this reduces to
/// [`ddim_update_host`]. The golden tests pin the AOT graph's `x_prev`
/// against this, lane by lane, so host kernels and the compiled graph can
/// never drift apart silently.
pub fn ddim_update_host_sigma(
    x: &[f32],
    eps: &[f32],
    noise: &[f32],
    alpha_t: f64,
    alpha_prev: f64,
    sigma: f64,
) -> Vec<f32> {
    assert_eq!(x.len(), eps.len());
    assert_eq!(x.len(), noise.len());
    let dir = (1.0 - alpha_prev - sigma * sigma).max(0.0).sqrt();
    x.iter()
        .zip(eps.iter().zip(noise))
        .map(|(&xv, (&ev, &nv))| {
            let x0 = (xv as f64 - (1.0 - alpha_t).sqrt() * ev as f64) / alpha_t.sqrt();
            (alpha_prev.sqrt() * x0 + dir * ev as f64 + sigma * nv as f64) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn updates_agree_in_small_step_limit() {
        // adjacent timesteps on a fine schedule: Eq. 13 ≈ Eq. 15
        let abar = crate::schedule::AlphaTable::linear(1000);
        let x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.1).sin()).collect();
        let eps: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).cos()).collect();
        let (a_t, a_p) = (abar.abar(500), abar.abar(499));
        let d = ddim_update_host(&x, &eps, a_t, a_p);
        let p = pf_euler_update(&x, &eps, a_t, a_p);
        let max: f32 = d
            .iter()
            .zip(&p)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max < 2e-4, "small-step disagreement {max}");
    }

    #[test]
    fn updates_differ_for_large_jumps() {
        // S=10-style jump: the discretisations genuinely differ (Sec. 4.3)
        let abar = crate::schedule::AlphaTable::linear(1000);
        let x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.1).sin()).collect();
        let eps: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).cos()).collect();
        let (a_t, a_p) = (abar.abar(1000), abar.abar(900));
        let d = ddim_update_host(&x, &eps, a_t, a_p);
        let p = pf_euler_update(&x, &eps, a_t, a_p);
        let max: f32 = d
            .iter()
            .zip(&p)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(max > 1e-2, "large-step updates should differ, max {max}");
    }

    #[test]
    fn sigma_form_reduces_to_deterministic_ddim() {
        let abar = crate::schedule::AlphaTable::linear(1000);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.21).sin()).collect();
        let eps: Vec<f32> = (0..32).map(|i| (i as f32 * 0.43).cos()).collect();
        let zeros = vec![0.0f32; 32];
        let (a_t, a_p) = (abar.abar(700), abar.abar(350));
        let det = ddim_update_host(&x, &eps, a_t, a_p);
        let gen = ddim_update_host_sigma(&x, &eps, &zeros, a_t, a_p, 0.0);
        let max: f32 =
            det.iter().zip(&gen).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(max < 1e-6, "sigma=0 form should match Eq. 13, diff {max}");
    }

    #[test]
    fn sigma_form_adds_scaled_noise_and_shrinks_direction() {
        let (a_t, a_p) = (0.25f64, 0.81f64);
        let x = vec![1.0f32];
        let eps = vec![0.5f32];
        let noise = vec![2.0f32];
        let sigma = 0.3f64;
        let got = ddim_update_host_sigma(&x, &eps, &noise, a_t, a_p, sigma)[0] as f64;
        let x0 = (1.0 - (1.0 - a_t).sqrt() * 0.5) / a_t.sqrt();
        let want = a_p.sqrt() * x0
            + (1.0 - a_p - sigma * sigma).sqrt() * 0.5
            + sigma * 2.0;
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        // direction coefficient is clamped at 0 when sigma^2 > 1 - alpha_prev
        let clamped = ddim_update_host_sigma(&x, &eps, &noise, a_t, a_p, 0.9)[0] as f64;
        let want_clamped = a_p.sqrt() * x0 + 0.9 * 2.0;
        assert!((clamped - want_clamped).abs() < 1e-6);
    }

    #[test]
    fn ddim_host_matches_eq12_form() {
        // cross-check the rearranged Eq. 13 form against the explicit
        // predicted-x0 composition of Eq. 12
        let (a_t, a_p) = (0.25f64, 0.81f64);
        let x = vec![1.0f32];
        let eps = vec![0.5f32];
        let got = ddim_update_host(&x, &eps, a_t, a_p)[0] as f64;
        let x0 = (1.0 - (1.0 - a_t).sqrt() * 0.5) / a_t.sqrt();
        let want = a_p.sqrt() * x0 + (1.0 - a_p).sqrt() * 0.5;
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }
}
