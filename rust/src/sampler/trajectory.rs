//! One diffusion trajectory: the current iterate x, its position in a
//! [`SamplePlan`], its private noise stream, and the update kernel that
//! decides how each executable step is committed. This is the unit the
//! coordinator schedules — a *lane* in a batched executable call.

use crate::error::{Error, Result};
use crate::rng::{GaussianSource, Pcg64};
use crate::runtime::LaneStep;
use crate::sampler::{SamplerKind, UpdateKernel};
use crate::schedule::{SamplePlan, StepParams};

/// What the trajectory starts from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrajectoryKind {
    /// x_T ~ N(0, I) (generation) — prior drawn from the seed.
    FromPrior,
    /// caller-provided start (encoding x_0, or interpolation latents x_T).
    FromState,
}

/// A single sample's walk through its plan.
#[derive(Debug)]
pub struct Trajectory {
    plan: SamplePlan,
    x: Vec<f32>,
    step: usize,
    noise: GaussianSource,
    kind: TrajectoryKind,
    kernel: UpdateKernel,
}

impl Trajectory {
    /// Generation from the prior: x_T filled from `seed`'s stream, stepped
    /// by the DDIM kernel (the fused executable's own `x_prev`).
    pub fn from_prior(plan: SamplePlan, dim: usize, seed: u64) -> Self {
        Self::from_prior_with(plan, dim, seed, SamplerKind::Ddim)
    }

    /// Generation from the prior with an explicit update kernel.
    pub fn from_prior_with(plan: SamplePlan, dim: usize, seed: u64, kernel: SamplerKind) -> Self {
        let mut root = Pcg64::seeded(seed);
        let mut prior = GaussianSource::new(root.fork(0));
        let noise = GaussianSource::new(root.fork(1));
        let x = prior.vec(dim);
        Self {
            plan,
            x,
            step: 0,
            noise,
            kind: TrajectoryKind::FromPrior,
            kernel: kernel.instantiate(),
        }
    }

    /// Start from caller-provided state (encode / interpolation).
    pub fn from_state(plan: SamplePlan, x: Vec<f32>, seed: u64) -> Self {
        Self::from_state_with(plan, x, seed, SamplerKind::Ddim)
    }

    /// Caller-provided start with an explicit update kernel.
    pub fn from_state_with(plan: SamplePlan, x: Vec<f32>, seed: u64, kernel: SamplerKind) -> Self {
        let mut root = Pcg64::seeded(seed);
        let noise = GaussianSource::new(root.fork(1));
        Self {
            plan,
            x,
            step: 0,
            noise,
            kind: TrajectoryKind::FromState,
            kernel: kernel.instantiate(),
        }
    }

    pub fn kind(&self) -> TrajectoryKind {
        self.kind
    }

    /// Which update kernel steps this lane.
    pub fn kernel_kind(&self) -> SamplerKind {
        self.kernel.kind()
    }

    pub fn plan(&self) -> &SamplePlan {
        &self.plan
    }

    /// Steps completed so far.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Steps remaining.
    pub fn steps_left(&self) -> usize {
        self.plan.len() - self.step
    }

    pub fn is_done(&self) -> bool {
        self.step >= self.plan.len()
    }

    /// Current iterate (x_t during sampling; the final x_0 / x_T when done).
    pub fn state(&self) -> &[f32] {
        &self.x
    }

    pub fn into_state(self) -> Vec<f32> {
        self.x
    }

    /// Schedule parameters for the *next* step.
    pub fn next_params(&self) -> Result<StepParams> {
        self.plan
            .steps()
            .get(self.step)
            .copied()
            .ok_or_else(|| Error::Coordinator("next_params on finished trajectory".into()))
    }

    /// Fill this lane's noise buffer for the next step: N(0,1) scaled by the
    /// step's `noise_scale` (σ̂ handling — see [`StepParams`]), or zeros for
    /// deterministic steps.
    pub fn fill_noise(&mut self, out: &mut [f32]) -> Result<()> {
        let p = self.next_params()?;
        if p.is_stochastic() {
            let scale = p.noise_scale() as f32;
            for v in out.iter_mut() {
                *v = self.noise.next() as f32 * scale;
            }
        } else {
            out.fill(0.0);
        }
        Ok(())
    }

    /// Commit the executable's outputs for this lane through the update
    /// kernel and advance. DDIM copies `step.x_prev`; PF-ODE and AB2
    /// re-integrate host-side from `step.eps`.
    pub fn advance(&mut self, step: LaneStep<'_>) -> Result<()> {
        if self.is_done() {
            return Err(Error::Coordinator("advance on finished trajectory".into()));
        }
        if step.x_prev.len() != self.x.len() || step.eps.len() != self.x.len() {
            return Err(Error::Shape(format!(
                "advance: x_prev {} / eps {} vs {}",
                step.x_prev.len(),
                step.eps.len(),
                self.x.len()
            )));
        }
        let p = self.next_params()?;
        self.kernel.advance(&mut self.x, step, p);
        self.step += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::{ddim_update_host, pf_euler_update};
    use crate::schedule::{AlphaTable, NoiseMode, SamplePlan, TauKind};

    fn plan(s: usize, mode: NoiseMode) -> SamplePlan {
        let t = AlphaTable::linear(1000);
        SamplePlan::generate(&t, TauKind::Linear, s, mode).unwrap()
    }

    /// A DDIM-style step view where every output carries `buf`.
    fn lane(buf: &[f32]) -> LaneStep<'_> {
        LaneStep { x_prev: buf, eps: buf, x0: buf }
    }

    #[test]
    fn prior_is_seed_deterministic() {
        let a = Trajectory::from_prior(plan(5, NoiseMode::Eta(0.0)), 16, 42);
        let b = Trajectory::from_prior(plan(5, NoiseMode::Eta(0.0)), 16, 42);
        let c = Trajectory::from_prior(plan(5, NoiseMode::Eta(0.0)), 16, 43);
        assert_eq!(a.state(), b.state());
        assert_ne!(a.state(), c.state());
        // the kernel choice must not perturb the prior draw
        let d = Trajectory::from_prior_with(plan(5, NoiseMode::Eta(0.0)), 16, 42, SamplerKind::Ab2);
        assert_eq!(a.state(), d.state());
        assert_eq!(d.kernel_kind(), SamplerKind::Ab2);
    }

    #[test]
    fn lifecycle() {
        let mut t = Trajectory::from_prior(plan(3, NoiseMode::Eta(0.0)), 4, 1);
        assert_eq!(t.steps_left(), 3);
        assert_eq!(t.kernel_kind(), SamplerKind::Ddim);
        assert!(!t.is_done());
        for i in 0..3 {
            let p = t.next_params().unwrap();
            assert!(p.alpha_out > p.alpha_in);
            t.advance(lane(&[i as f32; 4])).unwrap();
        }
        assert!(t.is_done());
        assert_eq!(t.state(), &[2.0; 4]);
        assert!(t.next_params().is_err());
        assert!(t.advance(lane(&[0.0; 4])).is_err());
    }

    #[test]
    fn deterministic_plan_noise_is_zero() {
        let mut t = Trajectory::from_prior(plan(3, NoiseMode::Eta(0.0)), 4, 1);
        let mut buf = [1.0f32; 4];
        t.fill_noise(&mut buf).unwrap();
        assert_eq!(buf, [0.0; 4]);
    }

    #[test]
    fn stochastic_noise_streams_differ_from_prior() {
        let mut t = Trajectory::from_prior(plan(3, NoiseMode::Eta(1.0)), 4, 7);
        let prior = t.state().to_vec();
        let mut buf = [0.0f32; 4];
        t.fill_noise(&mut buf).unwrap();
        assert!(buf.iter().any(|&v| v != 0.0));
        assert_ne!(&prior[..], &buf[..], "prior and step noise use forked streams");
    }

    #[test]
    fn advance_checks_len() {
        let mut t = Trajectory::from_prior(plan(2, NoiseMode::Eta(0.0)), 4, 1);
        assert!(t.advance(lane(&[0.0; 3])).is_err());
    }

    #[test]
    fn from_state_keeps_input() {
        let x = vec![0.5f32; 8];
        let t = Trajectory::from_state(plan(2, NoiseMode::Eta(0.0)), x.clone(), 0);
        assert_eq!(t.state(), &x[..]);
        assert_eq!(t.kind(), TrajectoryKind::FromState);
    }

    #[test]
    fn pf_ode_trajectory_integrates_from_eps_not_x_prev() {
        let p = plan(3, NoiseMode::Eta(0.0));
        let sp = p.steps()[0];
        let mut t = Trajectory::from_prior_with(p.clone(), 4, 9, SamplerKind::PfOde);
        let x0 = t.state().to_vec();
        let eps = [0.25f32, -0.5, 0.75, -1.0];
        let bogus_x_prev = [99.0f32; 4];
        t.advance(LaneStep { x_prev: &bogus_x_prev, eps: &eps, x0: &bogus_x_prev }).unwrap();
        assert_eq!(t.state(), &pf_euler_update(&x0, &eps, sp.alpha_in, sp.alpha_out)[..]);
        assert_eq!(t.steps_done(), 1);
    }

    #[test]
    fn ab2_trajectory_first_step_is_euler() {
        let p = plan(3, NoiseMode::Eta(0.0));
        let sp = p.steps()[0];
        let mut t = Trajectory::from_prior_with(p.clone(), 4, 9, SamplerKind::Ab2);
        let x0 = t.state().to_vec();
        let eps = [0.25f32, -0.5, 0.75, -1.0];
        let bogus = [99.0f32; 4];
        t.advance(LaneStep { x_prev: &bogus, eps: &eps, x0: &bogus }).unwrap();
        let want = ddim_update_host(&x0, &eps, sp.alpha_in, sp.alpha_out);
        let diff: f32 =
            t.state().iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(diff < 1e-5, "AB2 warmup should be the Euler/DDIM step, diff {diff}");
    }
}
