//! One diffusion trajectory: the current iterate x, its position in a
//! [`SamplePlan`], and its private noise stream. This is the unit the
//! coordinator schedules — a *lane* in a batched executable call.

use crate::error::{Error, Result};
use crate::rng::{GaussianSource, Pcg64};
use crate::schedule::{SamplePlan, StepParams};

/// What the trajectory starts from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrajectoryKind {
    /// x_T ~ N(0, I) (generation) — prior drawn from the seed.
    FromPrior,
    /// caller-provided start (encoding x_0, or interpolation latents x_T).
    FromState,
}

/// A single sample's walk through its plan.
#[derive(Debug)]
pub struct Trajectory {
    plan: SamplePlan,
    x: Vec<f32>,
    step: usize,
    noise: GaussianSource,
    kind: TrajectoryKind,
}

impl Trajectory {
    /// Generation from the prior: x_T filled from `seed`'s stream.
    pub fn from_prior(plan: SamplePlan, dim: usize, seed: u64) -> Self {
        let mut root = Pcg64::seeded(seed);
        let mut prior = GaussianSource::new(root.fork(0));
        let noise = GaussianSource::new(root.fork(1));
        let x = prior.vec(dim);
        Self { plan, x, step: 0, noise, kind: TrajectoryKind::FromPrior }
    }

    /// Start from caller-provided state (encode / interpolation).
    pub fn from_state(plan: SamplePlan, x: Vec<f32>, seed: u64) -> Self {
        let mut root = Pcg64::seeded(seed);
        let noise = GaussianSource::new(root.fork(1));
        Self { plan, x, step: 0, noise, kind: TrajectoryKind::FromState }
    }

    pub fn kind(&self) -> TrajectoryKind {
        self.kind
    }

    pub fn plan(&self) -> &SamplePlan {
        &self.plan
    }

    /// Steps completed so far.
    pub fn steps_done(&self) -> usize {
        self.step
    }

    /// Steps remaining.
    pub fn steps_left(&self) -> usize {
        self.plan.len() - self.step
    }

    pub fn is_done(&self) -> bool {
        self.step >= self.plan.len()
    }

    /// Current iterate (x_t during sampling; the final x_0 / x_T when done).
    pub fn state(&self) -> &[f32] {
        &self.x
    }

    pub fn into_state(self) -> Vec<f32> {
        self.x
    }

    /// Schedule parameters for the *next* step.
    pub fn next_params(&self) -> Result<StepParams> {
        self.plan
            .steps()
            .get(self.step)
            .copied()
            .ok_or_else(|| Error::Coordinator("next_params on finished trajectory".into()))
    }

    /// Fill this lane's noise buffer for the next step: N(0,1) scaled by the
    /// step's `noise_scale` (σ̂ handling — see [`StepParams`]), or zeros for
    /// deterministic steps.
    pub fn fill_noise(&mut self, out: &mut [f32]) -> Result<()> {
        let p = self.next_params()?;
        if p.is_stochastic() {
            let scale = p.noise_scale() as f32;
            for v in out.iter_mut() {
                *v = self.noise.next() as f32 * scale;
            }
        } else {
            out.fill(0.0);
        }
        Ok(())
    }

    /// Commit the executable's output for this lane and advance.
    pub fn advance(&mut self, x_next: &[f32]) -> Result<()> {
        if self.is_done() {
            return Err(Error::Coordinator("advance on finished trajectory".into()));
        }
        if x_next.len() != self.x.len() {
            return Err(Error::Shape(format!(
                "advance: {} vs {}",
                x_next.len(),
                self.x.len()
            )));
        }
        self.x.copy_from_slice(x_next);
        self.step += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{AlphaTable, NoiseMode, SamplePlan, TauKind};

    fn plan(s: usize, mode: NoiseMode) -> SamplePlan {
        let t = AlphaTable::linear(1000);
        SamplePlan::generate(&t, TauKind::Linear, s, mode).unwrap()
    }

    #[test]
    fn prior_is_seed_deterministic() {
        let a = Trajectory::from_prior(plan(5, NoiseMode::Eta(0.0)), 16, 42);
        let b = Trajectory::from_prior(plan(5, NoiseMode::Eta(0.0)), 16, 42);
        let c = Trajectory::from_prior(plan(5, NoiseMode::Eta(0.0)), 16, 43);
        assert_eq!(a.state(), b.state());
        assert_ne!(a.state(), c.state());
    }

    #[test]
    fn lifecycle() {
        let mut t = Trajectory::from_prior(plan(3, NoiseMode::Eta(0.0)), 4, 1);
        assert_eq!(t.steps_left(), 3);
        assert!(!t.is_done());
        for i in 0..3 {
            let p = t.next_params().unwrap();
            assert!(p.alpha_out > p.alpha_in);
            t.advance(&[i as f32; 4]).unwrap();
        }
        assert!(t.is_done());
        assert_eq!(t.state(), &[2.0; 4]);
        assert!(t.next_params().is_err());
        assert!(t.advance(&[0.0; 4]).is_err());
    }

    #[test]
    fn deterministic_plan_noise_is_zero() {
        let mut t = Trajectory::from_prior(plan(3, NoiseMode::Eta(0.0)), 4, 1);
        let mut buf = [1.0f32; 4];
        t.fill_noise(&mut buf).unwrap();
        assert_eq!(buf, [0.0; 4]);
    }

    #[test]
    fn stochastic_noise_streams_differ_from_prior() {
        let mut t = Trajectory::from_prior(plan(3, NoiseMode::Eta(1.0)), 4, 7);
        let prior = t.state().to_vec();
        let mut buf = [0.0f32; 4];
        t.fill_noise(&mut buf).unwrap();
        assert!(buf.iter().any(|&v| v != 0.0));
        assert_ne!(&prior[..], &buf[..], "prior and step noise use forked streams");
    }

    #[test]
    fn advance_checks_len() {
        let mut t = Trajectory::from_prior(plan(2, NoiseMode::Eta(0.0)), 4, 1);
        assert!(t.advance(&[0.0; 3]).is_err());
    }

    #[test]
    fn from_state_keeps_input() {
        let x = vec![0.5f32; 8];
        let t = Trajectory::from_state(plan(2, NoiseMode::Eta(0.0)), x.clone(), 0);
        assert_eq!(t.state(), &x[..]);
        assert_eq!(t.kind(), TrajectoryKind::FromState);
    }
}
