//! Homogeneous batch driver: N trajectories sharing one [`SamplePlan`]
//! marched bucket-by-bucket through the runtime. This is the evaluation
//! harness's workhorse (Table 1/2/3, Figs. 3–6) — every lane is at the same
//! step index, so it pads only the final partial chunk.
//!
//! Packing goes through the shared [`StepBatch`] — the same audited path
//! the coordinator engine uses for heterogeneous lanes (see
//! `coordinator::engine`).

use crate::error::Result;
use crate::runtime::Runtime;
use crate::sampler::planner::{plan_sub_batches, SubBatch, DEFAULT_MAX_PADDING_WASTE};
use crate::sampler::{SamplerKind, StepBatch, Trajectory};
use crate::schedule::SamplePlan;

/// Reusable buffers + batch loop for same-plan sampling.
pub struct BatchRunner {
    dataset: String,
    bucket: usize,
    dim: usize,
    buckets: Vec<usize>,
    // shared pack/pad/run path; reused across calls: zero steady-state
    // allocation on the DDIM path
    batch: StepBatch,
    plan_scratch: Vec<SubBatch>,
    /// executable calls issued (for Fig. 4 accounting)
    pub calls: u64,
}

impl BatchRunner {
    /// Build a runner for `dataset` using the largest bucket ≤ preferred
    /// (or the best bucket for the workload size).
    pub fn new(rt: &Runtime, dataset: &str, preferred_bucket: usize) -> Result<Self> {
        let bucket = rt.manifest().bucket_for(preferred_bucket);
        let dim = rt.manifest().sample_dim();
        Ok(Self {
            dataset: dataset.to_string(),
            bucket,
            dim,
            buckets: rt.manifest().buckets.clone(),
            batch: StepBatch::new(bucket, dim),
            plan_scratch: Vec::new(),
            calls: 0,
        })
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }

    /// Drive a set of same-plan trajectories to completion; returns the
    /// final states in input order.
    pub fn run_all(
        &mut self,
        rt: &mut Runtime,
        mut trajs: Vec<Trajectory>,
    ) -> Result<Vec<Vec<f32>>> {
        let total_steps = trajs.first().map_or(0, |t| t.plan().len());
        for t in &trajs {
            debug_assert_eq!(t.plan().len(), total_steps, "BatchRunner wants same-length plans");
        }
        for _ in 0..total_steps {
            for chunk in (0..trajs.len()).collect::<Vec<_>>().chunks(self.bucket) {
                self.step_chunk(rt, &mut trajs, chunk)?;
            }
        }
        Ok(trajs.into_iter().map(Trajectory::into_state).collect())
    }

    /// Advance the listed lanes (≤ bucket of them) one step. The chunk is
    /// run through the occupancy planner, so a partial tail (e.g. 5 lanes
    /// left on a bucket-16 runner) fills small exact buckets instead of
    /// padding the full preferred bucket with dead lanes.
    fn step_chunk(
        &mut self,
        rt: &mut Runtime,
        trajs: &mut [Trajectory],
        idxs: &[usize],
    ) -> Result<()> {
        assert!(!idxs.is_empty() && idxs.len() <= self.bucket);
        let mut plan = std::mem::take(&mut self.plan_scratch);
        plan_sub_batches(idxs.len(), &self.buckets, self.bucket, DEFAULT_MAX_PADDING_WASTE, &mut plan);
        for sb in &plan {
            let sub = &idxs[sb.start..sb.start + sb.lanes];
            for (slot, &i) in sub.iter().enumerate() {
                self.batch.pack(slot, &mut trajs[i])?;
            }
            self.batch.pad(sb.lanes, sb.bucket);
            let exe = rt.executable(&self.dataset, sb.bucket)?;
            self.batch.run(exe, sb.bucket)?;
            self.calls += 1;
            for (slot, &i) in sub.iter().enumerate() {
                trajs[i].advance(self.batch.lane(slot))?;
            }
        }
        self.plan_scratch = plan;
        Ok(())
    }

    /// Generate `n` samples from the prior under `plan`, seeds
    /// `seed_base..seed_base+n`. Returns final x_0 images.
    pub fn generate(
        &mut self,
        rt: &mut Runtime,
        plan: &SamplePlan,
        n: usize,
        seed_base: u64,
    ) -> Result<Vec<Vec<f32>>> {
        self.generate_with(rt, plan, n, seed_base, SamplerKind::Ddim)
    }

    /// [`BatchRunner::generate`] under an explicit update kernel (the
    /// §4.3/§7 ablations: PF-ODE Euler, AB2 multistep).
    pub fn generate_with(
        &mut self,
        rt: &mut Runtime,
        plan: &SamplePlan,
        n: usize,
        seed_base: u64,
        kernel: SamplerKind,
    ) -> Result<Vec<Vec<f32>>> {
        let trajs: Vec<Trajectory> = (0..n)
            .map(|i| {
                Trajectory::from_prior_with(plan.clone(), self.dim, seed_base + i as u64, kernel)
            })
            .collect();
        self.run_all(rt, trajs)
    }

    /// Run caller-provided start states through `plan` (encode, or decode of
    /// given latents). Deterministic plans ignore the seeds.
    pub fn run_from(
        &mut self,
        rt: &mut Runtime,
        plan: &SamplePlan,
        states: Vec<Vec<f32>>,
        seed_base: u64,
    ) -> Result<Vec<Vec<f32>>> {
        let trajs: Vec<Trajectory> = states
            .into_iter()
            .enumerate()
            .map(|(i, x)| Trajectory::from_state(plan.clone(), x, seed_base + i as u64))
            .collect();
        self.run_all(rt, trajs)
    }
}
