//! Homogeneous batch driver: N trajectories sharing one [`SamplePlan`]
//! marched bucket-by-bucket through the runtime. This is the evaluation
//! harness's workhorse (Table 1/2/3, Figs. 3–6) — every lane is at the same
//! step index, so it pads only the final partial chunk.
//!
//! (The coordinator generalises this to *heterogeneous* lanes; see
//! `coordinator::engine`.)

use crate::error::Result;
use crate::runtime::{Runtime, StepOutput};
use crate::sampler::Trajectory;
use crate::schedule::SamplePlan;

/// Reusable buffers + batch loop for same-plan sampling.
pub struct BatchRunner {
    dataset: String,
    bucket: usize,
    dim: usize,
    // reused across calls: zero steady-state allocation
    x: Vec<f32>,
    t: Vec<f32>,
    a_in: Vec<f32>,
    a_out: Vec<f32>,
    sigma: Vec<f32>,
    noise: Vec<f32>,
    out: StepOutput,
    /// executable calls issued (for Fig. 4 accounting)
    pub calls: u64,
}

impl BatchRunner {
    /// Build a runner for `dataset` using the largest bucket ≤ preferred
    /// (or the best bucket for the workload size).
    pub fn new(rt: &Runtime, dataset: &str, preferred_bucket: usize) -> Result<Self> {
        let bucket = rt.manifest().bucket_for(preferred_bucket);
        let dim = rt.manifest().sample_dim();
        Ok(Self {
            dataset: dataset.to_string(),
            bucket,
            dim,
            x: vec![0.0; bucket * dim],
            t: vec![0.0; bucket],
            a_in: vec![0.0; bucket],
            a_out: vec![0.0; bucket],
            sigma: vec![0.0; bucket],
            noise: vec![0.0; bucket * dim],
            out: StepOutput::zeros(bucket * dim),
            calls: 0,
        })
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }

    /// Drive a set of same-plan trajectories to completion; returns the
    /// final states in input order.
    pub fn run_all(
        &mut self,
        rt: &mut Runtime,
        mut trajs: Vec<Trajectory>,
    ) -> Result<Vec<Vec<f32>>> {
        let total_steps = trajs.first().map_or(0, |t| t.plan().len());
        for t in &trajs {
            debug_assert_eq!(t.plan().len(), total_steps, "BatchRunner wants same-length plans");
        }
        for _ in 0..total_steps {
            for chunk in (0..trajs.len()).collect::<Vec<_>>().chunks(self.bucket) {
                self.step_chunk(rt, &mut trajs, chunk)?;
            }
        }
        Ok(trajs.into_iter().map(Trajectory::into_state).collect())
    }

    /// Advance the listed lanes (≤ bucket of them) one step.
    fn step_chunk(
        &mut self,
        rt: &mut Runtime,
        trajs: &mut [Trajectory],
        idxs: &[usize],
    ) -> Result<()> {
        let b = self.bucket;
        let dim = self.dim;
        assert!(idxs.len() <= b);
        // pack lanes; pad dead lanes by repeating lane 0's params (harmless:
        // outputs of padding lanes are never read back)
        for (lane, &i) in idxs.iter().enumerate() {
            let tr = &mut trajs[i];
            let p = tr.next_params()?;
            self.x[lane * dim..(lane + 1) * dim].copy_from_slice(tr.state());
            self.t[lane] = p.t_model as f32;
            self.a_in[lane] = p.alpha_in as f32;
            self.a_out[lane] = p.alpha_out as f32;
            self.sigma[lane] = p.sigma_dir as f32;
            tr.fill_noise(&mut self.noise[lane * dim..(lane + 1) * dim])?;
        }
        for lane in idxs.len()..b {
            self.x[lane * dim..(lane + 1) * dim].fill(0.0);
            self.t[lane] = self.t[0];
            self.a_in[lane] = self.a_in[0].max(1e-4);
            self.a_out[lane] = self.a_out[0].max(1e-4);
            self.sigma[lane] = 0.0;
            self.noise[lane * dim..(lane + 1) * dim].fill(0.0);
        }
        let exe = rt.executable(&self.dataset, b)?;
        exe.run(&self.x, &self.t, &self.a_in, &self.a_out, &self.sigma, &self.noise, &mut self.out)?;
        self.calls += 1;
        for (lane, &i) in idxs.iter().enumerate() {
            trajs[i].advance(&self.out.x_prev[lane * dim..(lane + 1) * dim])?;
        }
        Ok(())
    }

    /// Generate `n` samples from the prior under `plan`, seeds
    /// `seed_base..seed_base+n`. Returns final x_0 images.
    pub fn generate(
        &mut self,
        rt: &mut Runtime,
        plan: &SamplePlan,
        n: usize,
        seed_base: u64,
    ) -> Result<Vec<Vec<f32>>> {
        let trajs: Vec<Trajectory> = (0..n)
            .map(|i| Trajectory::from_prior(plan.clone(), self.dim, seed_base + i as u64))
            .collect();
        self.run_all(rt, trajs)
    }

    /// Run caller-provided start states through `plan` (encode, or decode of
    /// given latents). Deterministic plans ignore the seeds.
    pub fn run_from(
        &mut self,
        rt: &mut Runtime,
        plan: &SamplePlan,
        states: Vec<Vec<f32>>,
        seed_base: u64,
    ) -> Result<Vec<Vec<f32>>> {
        let trajs: Vec<Trajectory> = states
            .into_iter()
            .enumerate()
            .map(|(i, x)| Trajectory::from_state(plan.clone(), x, seed_base + i as u64))
            .collect();
        self.run_all(rt, trajs)
    }
}
