//! Sampler layer: per-request trajectory state ([`Trajectory`]) and a
//! direct batch driver ([`BatchRunner`]) used by the evaluation harnesses.
//!
//! The coordinator (continuous batching across *heterogeneous* requests)
//! builds on the same [`Trajectory`] type; `BatchRunner` is the simpler
//! homogeneous case — N lanes marching through one shared [`SamplePlan`] —
//! which is exactly the shape of the paper's Table-1/2/3 sweeps.

mod multistep;
mod pf_ode;
mod runner;
mod trajectory;

pub use multistep::Ab2State;
pub use pf_ode::{ddim_update_host, pf_euler_update};
pub use runner::BatchRunner;
pub use trajectory::{Trajectory, TrajectoryKind};
