//! Sampler layer: per-request trajectory state ([`Trajectory`]), the
//! pluggable per-lane update kernels ([`UpdateKernel`]: DDIM Eq. 13,
//! PF-ODE Euler Eq. 15, AB2 multistep), the shared batched-step packer
//! ([`StepBatch`]), the occupancy-aware tick planner ([`planner`]), and a
//! direct batch driver ([`BatchRunner`]) used by the evaluation
//! harnesses.
//!
//! The coordinator (continuous batching across *heterogeneous* requests)
//! builds on the same [`Trajectory`] + [`StepBatch`] types; `BatchRunner`
//! is the simpler homogeneous case — N lanes marching through one shared
//! [`SamplePlan`](crate::schedule::SamplePlan) — which is exactly the shape
//! of the paper's Table-1/2/3 sweeps.

mod batch;
mod kernel;
mod multistep;
mod pf_ode;
pub mod planner;
mod runner;
mod trajectory;

pub use batch::{PackedLane, StepBatch};
pub use kernel::{SamplerKind, UpdateKernel};
pub use multistep::Ab2State;
pub use pf_ode::{
    ddim_update_host, ddim_update_host_sigma, pf_euler_update, pf_euler_update_inplace,
};
pub use planner::{plan_sub_batches, SubBatch, DEFAULT_MAX_PADDING_WASTE};
pub use runner::BatchRunner;
pub use trajectory::{Trajectory, TrajectoryKind};
