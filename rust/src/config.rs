//! Serving configuration: one plain struct, parsed from CLI flags (and
//! defaulted sensibly) — no config-file indirection needed at this scale,
//! but everything the paper's experiments vary is a field here.

use crate::error::{Error, Result};
use crate::sampler::SamplerKind;

/// Coordinator / server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Artifact directory produced by `make artifacts`.
    pub artifact_root: String,
    /// Dataset whose executables serve this process.
    pub dataset: String,
    /// Largest batch bucket the engine may use (≤ largest compiled bucket).
    pub max_batch: usize,
    /// Admission queue capacity (requests) — beyond this, reject (backpressure).
    pub queue_capacity: usize,
    /// Max lanes (in-flight samples) resident in the engine at once.
    pub max_lanes: usize,
    /// TCP listen address for `serve`.
    pub listen: String,
    /// Default number of sampling steps when a request omits it.
    pub default_steps: usize,
    /// Update kernel used when a wire request omits `"sampler"`
    /// (`--default-sampler ddim|pf_ode|ab2`).
    pub default_sampler: SamplerKind,
    /// Engine shards (worker threads, each with its own runtime) per
    /// dataset, unless overridden by `placement`.
    pub shards: usize,
    /// Per-dataset shard-count overrides: `(dataset, shards)`. Datasets
    /// not listed use `shards`.
    pub placement: Vec<(String, usize)>,
    /// On shutdown, in-flight lanes get this long to finish before the
    /// remaining waiters are answered with a "shutting down" error.
    pub drain_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            artifact_root: "artifacts".into(),
            dataset: "sprites".into(),
            max_batch: 16,
            queue_capacity: 256,
            max_lanes: 64,
            listen: "127.0.0.1:7878".into(),
            default_steps: 20,
            default_sampler: SamplerKind::Ddim,
            shards: 1,
            placement: Vec::new(),
            drain_timeout_ms: 2000,
        }
    }
}

impl ServeConfig {
    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            return Err(Error::Coordinator("max_batch must be > 0".into()));
        }
        if self.max_lanes < self.max_batch {
            return Err(Error::Coordinator(format!(
                "max_lanes ({}) must be >= max_batch ({})",
                self.max_lanes, self.max_batch
            )));
        }
        if self.queue_capacity == 0 {
            return Err(Error::Coordinator("queue_capacity must be > 0".into()));
        }
        if self.default_steps == 0 {
            return Err(Error::Coordinator("default_steps must be > 0".into()));
        }
        if self.shards == 0 {
            return Err(Error::Coordinator("shards must be > 0".into()));
        }
        for (i, (ds, n)) in self.placement.iter().enumerate() {
            if ds.is_empty() {
                return Err(Error::Coordinator("placement has an empty dataset name".into()));
            }
            if *n == 0 {
                return Err(Error::Coordinator(format!(
                    "placement '{ds}' wants 0 shards"
                )));
            }
            if self.placement[..i].iter().any(|(d, _)| d == ds) {
                return Err(Error::Coordinator(format!(
                    "placement lists dataset '{ds}' twice"
                )));
            }
        }
        Ok(())
    }

    /// How many shards serve `dataset`: the `placement` override if one
    /// exists, else the global `shards` default.
    pub fn shards_for(&self, dataset: &str) -> usize {
        self.placement
            .iter()
            .find(|(ds, _)| ds == dataset)
            .map(|&(_, n)| n)
            .unwrap_or(self.shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_combinations() {
        let bad = [
            ServeConfig { max_batch: 0, ..Default::default() },
            ServeConfig { max_lanes: 4, max_batch: 16, ..Default::default() },
            ServeConfig { queue_capacity: 0, ..Default::default() },
            ServeConfig { shards: 0, ..Default::default() },
            ServeConfig { placement: vec![("sprites".into(), 0)], ..Default::default() },
            ServeConfig {
                placement: vec![("a".into(), 1), ("a".into(), 2)],
                ..Default::default()
            },
        ];
        for c in bad {
            assert!(c.validate().is_err(), "{c:?}");
        }
    }

    #[test]
    fn placement_overrides_shard_default() {
        let c = ServeConfig {
            shards: 2,
            placement: vec![("blobs".into(), 4)],
            ..Default::default()
        };
        c.validate().unwrap();
        assert_eq!(c.shards_for("blobs"), 4);
        assert_eq!(c.shards_for("sprites"), 2);
    }
}
