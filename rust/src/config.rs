//! Serving configuration: one plain struct, parsed from CLI flags (and
//! defaulted sensibly) — no config-file indirection needed at this scale,
//! but everything the paper's experiments vary is a field here.

use crate::error::{Error, Result};
use crate::runtime::{BackendKind, RefOptions, RefPrecision};
use crate::sampler::{SamplerKind, DEFAULT_MAX_PADDING_WASTE};
use crate::schedule::TauKind;

/// Coordinator / server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Artifact directory produced by `make artifacts` (or
    /// `testing::fixtures` for the hermetic tier).
    pub artifact_root: String,
    /// Step backend every engine/executor runtime loads on
    /// (`--backend ref|xla`). The default honours the `DDIM_BACKEND` env
    /// override, matching `Runtime::load` — so a bench or test process
    /// lives entirely on one backend — and, like `Runtime::load`, fails
    /// loudly (panics, since `Default` cannot return errors) on an
    /// unparseable value rather than silently serving the wrong backend.
    /// `xla` needs the non-default `xla` cargo feature.
    pub backend: BackendKind,
    /// Dataset whose executables serve this process.
    pub dataset: String,
    /// Largest batch bucket the engine may use (≤ largest compiled bucket).
    pub max_batch: usize,
    /// Admission queue capacity (requests) — beyond this, reject (backpressure).
    pub queue_capacity: usize,
    /// Admission queue lane budget (`--queue-lane-cap`): max lanes of
    /// backlog the queue may hold, enforced alongside the item cap (a
    /// count=8 generate is 8 lanes of work, not 1 item — the item cap
    /// alone is not a latency bound). 0 = auto:
    /// `max(queue_capacity, max_lanes)`.
    pub queue_lane_cap: usize,
    /// Max lanes (in-flight samples) resident in the engine at once.
    pub max_lanes: usize,
    /// Default completion budget in ms (`--deadline-default-ms`) applied
    /// to wire requests that omit `"deadline_ms"`. 0 = no default
    /// deadline. Expired work is cancelled with a typed
    /// `"reject":{"reason":"deadline"}`, never finished.
    pub deadline_default_ms: u64,
    /// Adaptive quality degradation (`--degrade on|off`): under
    /// queued-lane pressure, *best-effort* requests have their step
    /// budget S transparently rewritten down the ladder →20→10 (§4.3:
    /// DDIM quality degrades gracefully with S), preferring the
    /// pre-optimized `"tau":"opt"` schedule for the downgraded budget
    /// when the artifact bundle has that cell. The response carries
    /// `"degraded":{"from":S,"to":S'}`.
    pub degrade_enabled: bool,
    /// Lower degradation watermark (`--degrade-mid`), as a fraction of
    /// pool lane capacity (shards × max_lanes). Pressure at or above it
    /// degrades best-effort requests to S=20.
    pub degrade_mid: f64,
    /// Upper degradation watermark (`--degrade-high`), same unit.
    /// Pressure at or above it degrades best-effort requests to S=10.
    pub degrade_high: f64,
    /// TCP listen address for `serve`.
    pub listen: String,
    /// Default number of sampling steps when a request omits it.
    pub default_steps: usize,
    /// Update kernel used when a wire request omits `"sampler"`
    /// (`--default-sampler ddim|pf_ode|ab2`).
    pub default_sampler: SamplerKind,
    /// τ selection used when a wire request omits `"tau"`
    /// (`--tau linear|quadratic|opt`). `opt` serves the pre-optimized
    /// schedules from the artifact bundle; requests whose (dataset, S)
    /// cell has no schedule get a typed error.
    pub default_tau: TauKind,
    /// Engine shards (worker threads, each with its own runtime) per
    /// dataset, unless overridden by `placement`.
    pub shards: usize,
    /// Per-dataset shard-count overrides: `(dataset, shards)`. Datasets
    /// not listed use `shards`.
    pub placement: Vec<(String, usize)>,
    /// On shutdown, in-flight lanes get this long to finish before the
    /// remaining waiters are answered with a "shutting down" error.
    pub drain_timeout_ms: u64,
    /// Step-execution pipeline depth (`--pipeline-depth`): number of
    /// sub-batch buffers in flight per engine. 1 = serial (pack → run →
    /// advance on the engine thread, exactly the pre-pipeline behavior);
    /// ≥ 2 runs execution on a dedicated executor thread so packing and
    /// retiring overlap device time. Output is bitwise-identical at every
    /// depth.
    pub pipeline_depth: usize,
    /// Batch-formation padding threshold (`--max-padding-waste`): a tick
    /// selection whose padded fraction would exceed this is decomposed
    /// into exactly-sized sub-batches on bucket boundaries instead of
    /// running one padded call. 0 splits maximally; 1 restores the old
    /// single-bucket policy.
    pub max_padding_waste: f64,
    /// Completed-sample cache (`--cache on|off`): identical requests
    /// (same dataset/steps/τ/η/sampler/seed-or-state, keyed by
    /// [`crate::cache::key`]) are answered from memory without touching
    /// any engine. Sound because sampling is a deterministic function of
    /// those fields (η > 0 included — noise streams are request-seeded).
    pub cache_enabled: bool,
    /// Byte budget of the sample cache (`--cache-bytes`), split evenly
    /// across the store's shards; strict LRU within the budget.
    pub cache_bytes: usize,
    /// Single-flight coalescing (`--coalesce on|off`): concurrent
    /// identical requests share one execution instead of each running.
    pub coalesce_enabled: bool,
    /// Reference-backend kernel threads per sub-batch (`--ref-threads`):
    /// total compute threads the runtime's worker pool spreads a
    /// sub-batch's slots over (slot-granular, bitwise-safe). 0 = available
    /// parallelism. Ignored by the xla backend.
    pub ref_threads: usize,
    /// Reference-backend weight precision (`--ref-precision f32|f16`).
    /// f32 (default) is bitwise-identical to the scalar composition; f16
    /// stores the ε-model fields as binary16 and accumulates in f32.
    pub ref_precision: RefPrecision,
    /// Transport event-loop threads (`--reactors`): each multiplexes a
    /// slice of the accepted connections over epoll. The transport is
    /// I/O-bound — a handful of reactors carries thousands of
    /// connections — so the default is min(4, cores), not cores.
    pub reactors: usize,
    /// Structured access log path (`--access-log PATH`); one JSON line
    /// per completed request, written by a dedicated thread behind a
    /// bounded channel (drops are counted, reactors never block). Empty
    /// = off.
    pub access_log: String,
    /// Rotate the access log when it reaches this size
    /// (`--log-rotate-bytes`). Numbered shift: PATH → PATH.1 → … →
    /// PATH.K, oldest deleted.
    pub log_rotate_bytes: u64,
    /// Also rotate every N seconds (`--log-rotate-secs`); 0 = size-only.
    pub log_rotate_secs: u64,
    /// Rotated files kept (`--log-keep K`), the live file excluded.
    pub log_keep: usize,
    /// Trace-span sampling rate (`--trace-sample N` = 1-in-N requests
    /// get stage spans recorded into the access log); 0 = off. Explicit
    /// `"trace":true` requests are always traced regardless.
    pub trace_sample: u64,
}

/// Default reactor count: min(4, available cores).
pub fn default_reactors() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4)
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            artifact_root: "artifacts".into(),
            backend: BackendKind::from_env().expect("DDIM_BACKEND must be ref|xla"),
            dataset: "sprites".into(),
            max_batch: 16,
            queue_capacity: 256,
            queue_lane_cap: 0, // auto: max(queue_capacity, max_lanes)
            max_lanes: 64,
            deadline_default_ms: 0, // no default deadline
            degrade_enabled: true,  // only touches "priority":"best_effort"
            degrade_mid: 1.0,       // backlog ≥ 1× pool capacity → S=20
            degrade_high: 3.0,      // backlog ≥ 3× pool capacity → S=10
            listen: "127.0.0.1:7878".into(),
            default_steps: 20,
            default_sampler: SamplerKind::Ddim,
            default_tau: TauKind::Linear,
            shards: 1,
            placement: Vec::new(),
            drain_timeout_ms: 2000,
            pipeline_depth: 1,
            max_padding_waste: DEFAULT_MAX_PADDING_WASTE,
            cache_enabled: true,
            cache_bytes: 64 << 20, // 64 MiB ≈ 60k cached 16×16 lanes
            coalesce_enabled: true,
            // like `backend`, honour the env overrides (and fail loudly on
            // garbage) so whole processes switch tuning without re-plumbing
            ref_threads: RefOptions::from_env()
                .expect("DDIM_REF_THREADS must be an integer")
                .threads,
            ref_precision: RefOptions::from_env()
                .expect("DDIM_REF_PRECISION must be f32|f16")
                .precision,
            reactors: default_reactors(),
            access_log: String::new(), // off
            log_rotate_bytes: 64 << 20,
            log_rotate_secs: 0, // size-only
            log_keep: 4,
            trace_sample: 0, // explicit "trace":true requests only
        }
    }
}

impl ServeConfig {
    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            return Err(Error::Coordinator("max_batch must be > 0".into()));
        }
        if self.max_lanes < self.max_batch {
            return Err(Error::Coordinator(format!(
                "max_lanes ({}) must be >= max_batch ({})",
                self.max_lanes, self.max_batch
            )));
        }
        if self.queue_capacity == 0 {
            return Err(Error::Coordinator("queue_capacity must be > 0".into()));
        }
        if self.queue_lane_cap != 0 && self.queue_lane_cap < self.max_lanes {
            return Err(Error::Coordinator(format!(
                "queue_lane_cap ({}) must be >= max_lanes ({}) so a full-size \
                 request can queue at all (0 = auto)",
                self.queue_lane_cap, self.max_lanes
            )));
        }
        if !self.degrade_mid.is_finite() || self.degrade_mid <= 0.0 {
            return Err(Error::Coordinator(format!(
                "degrade_mid must be a positive pressure fraction, got {}",
                self.degrade_mid
            )));
        }
        if !self.degrade_high.is_finite() || self.degrade_high < self.degrade_mid {
            return Err(Error::Coordinator(format!(
                "degrade_high ({}) must be >= degrade_mid ({}) — the ladder \
                 tightens as pressure grows",
                self.degrade_high, self.degrade_mid
            )));
        }
        if self.default_steps == 0 {
            return Err(Error::Coordinator("default_steps must be > 0".into()));
        }
        if self.shards == 0 {
            return Err(Error::Coordinator("shards must be > 0".into()));
        }
        if self.pipeline_depth == 0 {
            return Err(Error::Coordinator(
                "pipeline_depth must be >= 1 (1 = serial)".into(),
            ));
        }
        if self.pipeline_depth > 8 {
            return Err(Error::Coordinator(format!(
                "pipeline_depth {} is absurd: each unit is a full batch buffer \
                 and anything past ~3 only adds latency (max 8)",
                self.pipeline_depth
            )));
        }
        if self.cache_enabled && self.cache_bytes == 0 {
            return Err(Error::Coordinator(
                "cache_bytes must be > 0 when the cache is enabled (use --cache off \
                 to disable it instead of a zero budget)"
                    .into(),
            ));
        }
        if !(0.0..=1.0).contains(&self.max_padding_waste) {
            return Err(Error::Coordinator(format!(
                "max_padding_waste must be a fraction in [0, 1], got {}",
                self.max_padding_waste
            )));
        }
        if self.ref_threads > 1024 {
            return Err(Error::Coordinator(format!(
                "ref_threads {} is absurd (max 1024; 0 = auto)",
                self.ref_threads
            )));
        }
        if self.reactors == 0 {
            return Err(Error::Coordinator("reactors must be > 0".into()));
        }
        if self.reactors > 256 {
            return Err(Error::Coordinator(format!(
                "reactors {} is absurd: each is a whole event-loop thread \
                 and a handful multiplexes thousands of connections (max 256)",
                self.reactors
            )));
        }
        if !self.access_log.is_empty() {
            if self.log_keep == 0 {
                return Err(Error::Coordinator(
                    "log_keep must be >= 1: rotation shifts PATH to PATH.1 \
                     before reopening, so at least one rotated file exists"
                        .into(),
                ));
            }
            if self.log_rotate_bytes == 0 && self.log_rotate_secs == 0 {
                return Err(Error::Coordinator(
                    "access log needs a rotation trigger: set log_rotate_bytes \
                     and/or log_rotate_secs"
                        .into(),
                ));
            }
        }
        for (i, (ds, n)) in self.placement.iter().enumerate() {
            if ds.is_empty() {
                return Err(Error::Coordinator("placement has an empty dataset name".into()));
            }
            if *n == 0 {
                return Err(Error::Coordinator(format!(
                    "placement '{ds}' wants 0 shards"
                )));
            }
            if self.placement[..i].iter().any(|(d, _)| d == ds) {
                return Err(Error::Coordinator(format!(
                    "placement lists dataset '{ds}' twice"
                )));
            }
        }
        Ok(())
    }

    /// Reference-backend tuning bundle handed to `Runtime::load_full` by
    /// every engine / executor worker this config spawns.
    pub fn ref_options(&self) -> RefOptions {
        RefOptions { threads: self.ref_threads, precision: self.ref_precision }
    }

    /// Effective queue lane budget: the explicit `queue_lane_cap`, or the
    /// auto policy `max(queue_capacity, max_lanes)` — all-single-lane
    /// traffic is bounded by the item cap exactly as before, while heavy
    /// requests can no longer stack `queue_capacity × max_lanes` lanes of
    /// backlog behind a capacity-sized queue.
    pub fn queue_lane_budget(&self) -> usize {
        if self.queue_lane_cap == 0 {
            self.queue_capacity.max(self.max_lanes)
        } else {
            self.queue_lane_cap
        }
    }

    /// How many shards serve `dataset`: the `placement` override if one
    /// exists, else the global `shards` default.
    pub fn shards_for(&self, dataset: &str) -> usize {
        self.placement
            .iter()
            .find(|(ds, _)| ds == dataset)
            .map(|&(_, n)| n)
            .unwrap_or(self.shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_combinations() {
        let bad = [
            ServeConfig { max_batch: 0, ..Default::default() },
            ServeConfig { max_lanes: 4, max_batch: 16, ..Default::default() },
            ServeConfig { queue_capacity: 0, ..Default::default() },
            ServeConfig { queue_lane_cap: 8, max_lanes: 64, ..Default::default() },
            ServeConfig { degrade_mid: 0.0, ..Default::default() },
            ServeConfig { degrade_mid: -1.0, ..Default::default() },
            ServeConfig { degrade_mid: f64::NAN, ..Default::default() },
            ServeConfig { degrade_mid: 2.0, degrade_high: 1.0, ..Default::default() },
            ServeConfig { degrade_high: f64::NAN, ..Default::default() },
            ServeConfig { shards: 0, ..Default::default() },
            ServeConfig { pipeline_depth: 0, ..Default::default() },
            ServeConfig { pipeline_depth: 9, ..Default::default() },
            ServeConfig { cache_enabled: true, cache_bytes: 0, ..Default::default() },
            ServeConfig { max_padding_waste: -0.1, ..Default::default() },
            ServeConfig { max_padding_waste: 1.5, ..Default::default() },
            ServeConfig { max_padding_waste: f64::NAN, ..Default::default() },
            ServeConfig { ref_threads: 2000, ..Default::default() },
            ServeConfig { reactors: 0, ..Default::default() },
            ServeConfig { reactors: 257, ..Default::default() },
            ServeConfig { placement: vec![("sprites".into(), 0)], ..Default::default() },
            ServeConfig {
                placement: vec![("a".into(), 1), ("a".into(), 2)],
                ..Default::default()
            },
        ];
        for c in bad {
            assert!(c.validate().is_err(), "{c:?}");
        }
    }

    #[test]
    fn pipeline_and_planner_knobs_validate() {
        ServeConfig { pipeline_depth: 2, ..Default::default() }.validate().unwrap();
        ServeConfig { pipeline_depth: 8, max_padding_waste: 0.0, ..Default::default() }
            .validate()
            .unwrap();
        ServeConfig { max_padding_waste: 1.0, ..Default::default() }.validate().unwrap();
    }

    #[test]
    fn cache_knobs_validate() {
        // off + zero budget is fine (the budget is simply unused)
        ServeConfig { cache_enabled: false, cache_bytes: 0, ..Default::default() }
            .validate()
            .unwrap();
        ServeConfig { coalesce_enabled: false, ..Default::default() }.validate().unwrap();
        ServeConfig { cache_bytes: 4096, ..Default::default() }.validate().unwrap();
    }

    #[test]
    fn ref_knobs_validate_and_bundle() {
        ServeConfig { ref_threads: 0, ..Default::default() }.validate().unwrap();
        ServeConfig { ref_threads: 16, ..Default::default() }.validate().unwrap();
        let c = ServeConfig {
            ref_threads: 3,
            ref_precision: RefPrecision::F16,
            ..Default::default()
        };
        c.validate().unwrap();
        assert_eq!(c.ref_options(), RefOptions { threads: 3, precision: RefPrecision::F16 });
    }

    #[test]
    fn reactor_knob_validates() {
        assert!(default_reactors() >= 1);
        assert!(default_reactors() <= 4);
        ServeConfig { reactors: 1, ..Default::default() }.validate().unwrap();
        ServeConfig { reactors: 256, ..Default::default() }.validate().unwrap();
    }

    #[test]
    fn overload_knobs_validate_and_default() {
        // auto lane budget: item cap dominates for single-lane traffic,
        // max_lanes floors it for small queues
        let c = ServeConfig::default();
        assert_eq!(c.queue_lane_budget(), 256);
        let c = ServeConfig { queue_capacity: 8, max_lanes: 64, ..Default::default() };
        assert_eq!(c.queue_lane_budget(), 64);
        let c = ServeConfig { queue_lane_cap: 100, ..Default::default() };
        c.validate().unwrap();
        assert_eq!(c.queue_lane_budget(), 100);
        // degradation knobs
        ServeConfig { degrade_enabled: false, ..Default::default() }.validate().unwrap();
        ServeConfig { degrade_mid: 0.5, degrade_high: 0.5, ..Default::default() }
            .validate()
            .unwrap();
        ServeConfig { deadline_default_ms: 5000, ..Default::default() }.validate().unwrap();
    }

    #[test]
    fn observability_knobs_validate() {
        // off by default, and the rotation knobs are then unchecked
        let c = ServeConfig::default();
        assert!(c.access_log.is_empty());
        assert_eq!(c.trace_sample, 0);
        ServeConfig { log_keep: 0, ..Default::default() }.validate().unwrap();
        // an enabled log demands a sane retention/trigger pair
        let on = |f: fn(ServeConfig) -> ServeConfig| {
            f(ServeConfig { access_log: "/tmp/a.log".into(), ..Default::default() })
        };
        on(|c| c).validate().unwrap();
        assert!(on(|c| ServeConfig { log_keep: 0, ..c }).validate().is_err());
        assert!(on(|c| ServeConfig { log_rotate_bytes: 0, ..c }).validate().is_err());
        on(|c| ServeConfig { log_rotate_bytes: 0, log_rotate_secs: 60, ..c })
            .validate()
            .unwrap();
        ServeConfig { trace_sample: 16, ..Default::default() }.validate().unwrap();
    }

    #[test]
    fn placement_overrides_shard_default() {
        let c = ServeConfig {
            shards: 2,
            placement: vec![("blobs".into(), 4)],
            ..Default::default()
        };
        c.validate().unwrap();
        assert_eq!(c.shards_for("blobs"), 4);
        assert_eq!(c.shards_for("sprites"), 2);
    }
}
