//! Serving configuration: one plain struct, parsed from CLI flags (and
//! defaulted sensibly) — no config-file indirection needed at this scale,
//! but everything the paper's experiments vary is a field here.

use crate::error::{Error, Result};

/// Coordinator / server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Artifact directory produced by `make artifacts`.
    pub artifact_root: String,
    /// Dataset whose executables serve this process.
    pub dataset: String,
    /// Largest batch bucket the engine may use (≤ largest compiled bucket).
    pub max_batch: usize,
    /// Admission queue capacity (requests) — beyond this, reject (backpressure).
    pub queue_capacity: usize,
    /// Max lanes (in-flight samples) resident in the engine at once.
    pub max_lanes: usize,
    /// TCP listen address for `serve`.
    pub listen: String,
    /// Default number of sampling steps when a request omits it.
    pub default_steps: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            artifact_root: "artifacts".into(),
            dataset: "sprites".into(),
            max_batch: 16,
            queue_capacity: 256,
            max_lanes: 64,
            listen: "127.0.0.1:7878".into(),
            default_steps: 20,
        }
    }
}

impl ServeConfig {
    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            return Err(Error::Coordinator("max_batch must be > 0".into()));
        }
        if self.max_lanes < self.max_batch {
            return Err(Error::Coordinator(format!(
                "max_lanes ({}) must be >= max_batch ({})",
                self.max_lanes, self.max_batch
            )));
        }
        if self.queue_capacity == 0 {
            return Err(Error::Coordinator("queue_capacity must be > 0".into()));
        }
        if self.default_steps == 0 {
            return Err(Error::Coordinator("default_steps must be > 0".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_combinations() {
        let mut c = ServeConfig::default();
        c.max_batch = 0;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.max_lanes = 4;
        c.max_batch = 16;
        assert!(c.validate().is_err());
        let mut c = ServeConfig::default();
        c.queue_capacity = 0;
        assert!(c.validate().is_err());
    }
}
