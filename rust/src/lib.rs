//! `ddim-serve` — Denoising Diffusion Implicit Models (Song, Meng & Ermon,
//! ICLR 2021) as a production-shaped diffusion *serving* stack.
//!
//! Three layers (see `DESIGN.md`):
//! - **L1** (build-time Pallas kernels) and **L2** (build-time JAX U-Net +
//!   fused Eq.-12 update) live under `python/compile/` and are AOT-lowered
//!   to HLO text in `artifacts/` by `make artifacts`.
//! - **L3** (this crate) is the runtime: a request coordinator that performs
//!   *continuous step-level batching* over the compiled `denoise_step`
//!   executables — the diffusion analogue of vLLM/Orca iteration-level
//!   batching. Per-sample schedule vectors (`alpha_t[B]`, `alpha_prev[B]`,
//!   `sigma[B]`) mean one executable call can advance B trajectories that
//!   are at *different* timesteps on *different* (τ, η) schedules.
//!
//! Python never runs on the request path; the binary is self-contained
//! against `artifacts/`.
//!
//! Module map:
//! - substrates: [`json`], [`tensor`], [`rng`], [`linalg`], [`stats`],
//!   [`schedule`], [`artifacts`], [`testing`]
//! - runtime: [`runtime`] (PJRT executables), [`sampler`] (trajectories)
//! - the serving contribution: [`coordinator`], fronted by [`cache`]
//!   (deterministic sample cache + single-flight request coalescing)
//! - evaluation: [`eval`] (proxy-FID, consistency, reconstruction),
//!   [`workload`] (request generators for benches/examples)
//! - operations: [`obs`] (Prometheus exposition, rotating access logs,
//!   per-request trace spans)

pub mod artifacts;
pub mod cache;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod discrete;
pub mod error;
pub mod eval;
pub mod json;
pub mod linalg;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod sampler;
pub mod schedule;
pub mod stats;
pub mod tensor;
pub mod testing;
pub mod workload;

pub use error::{Error, Result};
