//! Streaming gaussian fit (mean + covariance) over feature vectors, using
//! Welford/Chan-style accumulation so the Table-1 harness can stream
//! thousands of generated samples without holding them.

use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::stats::FEAT_DIM;

/// Accumulates mean and covariance of FEAT_DIM-dim vectors.
#[derive(Debug, Clone)]
pub struct GaussianFit {
    n: usize,
    mean: Vec<f64>,
    // sum of outer products of deviations (co-moment matrix M2)
    m2: Mat,
}

impl Default for GaussianFit {
    fn default() -> Self {
        Self::new()
    }
}

impl GaussianFit {
    pub fn new() -> Self {
        Self { n: 0, mean: vec![0.0; FEAT_DIM], m2: Mat::zeros(FEAT_DIM, FEAT_DIM) }
    }

    /// Add one observation (Welford update generalised to covariance).
    pub fn push(&mut self, x: &[f64; FEAT_DIM]) {
        self.n += 1;
        let nf = self.n as f64;
        let mut delta = [0.0f64; FEAT_DIM];
        for i in 0..FEAT_DIM {
            delta[i] = x[i] - self.mean[i];
            self.mean[i] += delta[i] / nf;
        }
        // M2 += delta ⊗ (x - new_mean)
        for i in 0..FEAT_DIM {
            let d2i = x[i] - self.mean[i];
            for j in 0..FEAT_DIM {
                self.m2[(i, j)] += delta[j] * d2i;
            }
        }
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> &[f64] {
        &self.mean
    }

    /// Sample covariance (1/(n-1)), symmetrised against fp drift.
    pub fn covariance(&self) -> Result<Mat> {
        if self.n < 2 {
            return Err(Error::Linalg(format!("covariance needs n >= 2, have {}", self.n)));
        }
        Ok(self.m2.scale(1.0 / (self.n as f64 - 1.0)).symmetrize())
    }

    /// Build directly from precomputed (mu, cov) — how the python-dumped
    /// reference stats enter the pipeline.
    pub fn from_moments(mean: Vec<f64>, cov: Mat, n: usize) -> Result<Self> {
        if mean.len() != FEAT_DIM || cov.rows() != FEAT_DIM || cov.cols() != FEAT_DIM {
            return Err(Error::Shape("from_moments dims".into()));
        }
        let m2 = cov.scale((n as f64 - 1.0).max(1.0));
        Ok(Self { n, mean, m2 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::GaussianSource;

    #[test]
    fn matches_two_pass_computation() {
        let mut g = GaussianSource::seeded(4);
        let n = 500;
        let data: Vec<[f64; FEAT_DIM]> = (0..n)
            .map(|_| {
                let mut x = [0.0; FEAT_DIM];
                for v in &mut x {
                    *v = g.next();
                }
                x
            })
            .collect();
        let mut fit = GaussianFit::new();
        for x in &data {
            fit.push(x);
        }
        // two-pass reference
        let mut mu = [0.0f64; FEAT_DIM];
        for x in &data {
            for i in 0..FEAT_DIM {
                mu[i] += x[i] / n as f64;
            }
        }
        let mut cov = Mat::zeros(FEAT_DIM, FEAT_DIM);
        for x in &data {
            for i in 0..FEAT_DIM {
                for j in 0..FEAT_DIM {
                    cov[(i, j)] += (x[i] - mu[i]) * (x[j] - mu[j]) / (n as f64 - 1.0);
                }
            }
        }
        for i in 0..FEAT_DIM {
            assert!((fit.mean()[i] - mu[i]).abs() < 1e-12);
        }
        assert!(fit.covariance().unwrap().max_abs_diff(&cov) < 1e-10);
    }

    #[test]
    fn needs_two_points() {
        let mut fit = GaussianFit::new();
        assert!(fit.covariance().is_err());
        fit.push(&[0.0; FEAT_DIM]);
        assert!(fit.covariance().is_err());
        fit.push(&[1.0; FEAT_DIM]);
        assert!(fit.covariance().is_ok());
    }

    #[test]
    fn from_moments_round_trips() {
        let mut g = GaussianSource::seeded(9);
        let mut fit = GaussianFit::new();
        for _ in 0..50 {
            let mut x = [0.0; FEAT_DIM];
            for v in &mut x {
                *v = g.next();
            }
            fit.push(&x);
        }
        let cov = fit.covariance().unwrap();
        let re = GaussianFit::from_moments(fit.mean().to_vec(), cov.clone(), fit.count()).unwrap();
        assert!(re.covariance().unwrap().max_abs_diff(&cov) < 1e-12);
    }
}
