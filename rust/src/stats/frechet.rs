//! Fréchet distance between two gaussians — the functional behind FID
//! (Heusel et al. 2017, used throughout the paper's Table 1/3):
//!
//!   d² = |μ₁ − μ₂|² + Tr(Σ₁ + Σ₂ − 2 (Σ₁Σ₂)^{1/2})
//!
//! `(Σ₁Σ₂)^{1/2}` is evaluated through the symmetric form
//! `√Σ₁ · sqrtm(√Σ₁ Σ₂ √Σ₁) · √Σ₁⁻¹`-free trace identity:
//! `Tr((Σ₁Σ₂)^{1/2}) = Tr(sqrtm(√Σ₁ Σ₂ √Σ₁))`, keeping every
//! decomposition on a symmetric PSD matrix.

use crate::error::Result;
use crate::linalg::{sqrtm_spd, Mat};
use crate::stats::GaussianFit;

/// Squared Fréchet distance between two fitted gaussians.
pub fn frechet_distance(a: &GaussianFit, b: &GaussianFit) -> Result<f64> {
    let mu_a = a.mean();
    let mu_b = b.mean();
    let cov_a = a.covariance()?;
    let cov_b = b.covariance()?;

    let mean_term: f64 = mu_a
        .iter()
        .zip(mu_b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum();

    // tiny ridge: covariance estimates from finite samples can be
    // rank-deficient (constant feature dims), same epsilon both sides so
    // d(N, N) stays 0.
    let eps = 1e-10;
    let n = cov_a.rows();
    let ridge = Mat::identity(n).scale(eps);
    let ca = cov_a.add(&ridge)?;
    let cb = cov_b.add(&ridge)?;

    let sa = sqrtm_spd(&ca)?;
    let inner = sa.matmul(&cb)?.matmul(&sa)?.symmetrize();
    let cross = sqrtm_spd(&inner)?.trace();

    let d2 = mean_term + ca.trace() + cb.trace() - 2.0 * cross;
    // clamp fp negatives around zero
    Ok(d2.max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::GaussianSource;
    use crate::stats::FEAT_DIM;

    fn fit_from(seed: u64, n: usize, shift: f64, scale: f64) -> GaussianFit {
        let mut g = GaussianSource::seeded(seed);
        let mut fit = GaussianFit::new();
        for _ in 0..n {
            let mut x = [0.0f64; FEAT_DIM];
            for v in &mut x {
                *v = shift + scale * g.next();
            }
            fit.push(&x);
        }
        fit
    }

    #[test]
    fn identical_fit_is_zero() {
        let a = fit_from(1, 400, 0.0, 1.0);
        let d = frechet_distance(&a, &a).unwrap();
        assert!(d < 1e-9, "{d}");
    }

    #[test]
    fn symmetry() {
        let a = fit_from(1, 300, 0.0, 1.0);
        let b = fit_from(2, 300, 0.5, 1.5);
        let ab = frechet_distance(&a, &b).unwrap();
        let ba = frechet_distance(&b, &a).unwrap();
        assert!((ab - ba).abs() < 1e-6 * ab.max(1.0), "{ab} vs {ba}");
        assert!(ab > 0.0);
    }

    #[test]
    fn mean_shift_analytic() {
        // For equal covariances, d² ≈ |Δμ|² = FEAT_DIM · shift²
        let a = fit_from(3, 4000, 0.0, 1.0);
        let b = fit_from(4, 4000, 1.0, 1.0);
        let d = frechet_distance(&a, &b).unwrap();
        let want = FEAT_DIM as f64;
        assert!((d - want).abs() / want < 0.15, "d² = {d}, want ≈ {want}");
    }

    #[test]
    fn scale_mismatch_analytic() {
        // μ equal, Σ₁ = I, Σ₂ = s²I: d² = FEAT_DIM (s - 1)²
        let a = fit_from(5, 6000, 0.0, 1.0);
        let b = fit_from(6, 6000, 0.0, 2.0);
        let d = frechet_distance(&a, &b).unwrap();
        let want = FEAT_DIM as f64; // (2-1)^2 * 24
        assert!((d - want).abs() / want < 0.2, "d² = {d}, want ≈ {want}");
    }

    #[test]
    fn monotone_in_shift() {
        let a = fit_from(7, 1000, 0.0, 1.0);
        let mut last = -1.0;
        for (i, shift) in [0.2, 0.5, 1.0, 2.0].iter().enumerate() {
            let b = fit_from(100 + i as u64, 1000, *shift, 1.0);
            let d = frechet_distance(&a, &b).unwrap();
            assert!(d > last, "shift {shift}: {d} <= {last}");
            last = d;
        }
    }

    #[test]
    fn degenerate_constant_dims_tolerated() {
        // all-constant features: rank-0 covariance on both sides
        let mut a = GaussianFit::new();
        let mut b = GaussianFit::new();
        for _ in 0..10 {
            a.push(&[1.0; FEAT_DIM]);
            b.push(&[2.0; FEAT_DIM]);
        }
        let d = frechet_distance(&a, &b).unwrap();
        assert!((d - FEAT_DIM as f64).abs() < 1e-6);
    }
}
