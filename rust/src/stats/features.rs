//! The 24-dim proxy-FID feature map. EXACT mirror of
//! `python/compile/features.py` (see that file for the rationale per dim);
//! the cross-language agreement is enforced by the `feat_imgs/feat_out`
//! golden pair in every dataset's artifact directory.

/// Feature dimensionality (must match `features.FEAT_DIM` in python).
pub const FEAT_DIM: usize = 24;

const H: usize = 16;
const W: usize = 16;

/// Extract features from one 16×16 image (flattened, row-major, [-1,1]).
pub fn extract_features(img: &[f32]) -> [f64; FEAT_DIM] {
    assert_eq!(img.len(), H * W, "feature extractor wants 16x16");
    let x: Vec<f64> = img.iter().map(|&v| v as f64).collect();
    let at = |r: usize, c: usize| x[r * W + c];
    let mut f = [0.0f64; FEAT_DIM];

    // dims 0..16: 4x4 average pooling
    for br in 0..4 {
        for bc in 0..4 {
            let mut s = 0.0;
            for r in 0..4 {
                for c in 0..4 {
                    s += at(br * 4 + r, bc * 4 + c);
                }
            }
            f[br * 4 + bc] = s / 16.0;
        }
    }

    // 16: global mean, 17: global std (population, like numpy's default)
    let n = (H * W) as f64;
    let mean = x.iter().sum::<f64>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    f[16] = mean;
    f[17] = var.sqrt();

    // 18: mean |horizontal gradient| (np.diff axis=2 -> 16x15 values)
    let mut gx = 0.0;
    for r in 0..H {
        for c in 0..W - 1 {
            gx += (at(r, c + 1) - at(r, c)).abs();
        }
    }
    f[18] = gx / (H * (W - 1)) as f64;

    // 19: mean |vertical gradient| (15x16 values)
    let mut gy = 0.0;
    for r in 0..H - 1 {
        for c in 0..W {
            gy += (at(r + 1, c) - at(r, c)).abs();
        }
    }
    f[19] = gy / ((H - 1) * W) as f64;

    // 20: mean |4-neighbour laplacian| over the 14x14 interior
    let mut lap = 0.0;
    for r in 1..H - 1 {
        for c in 1..W - 1 {
            lap += (4.0 * at(r, c) - at(r - 1, c) - at(r + 1, c) - at(r, c - 1) - at(r, c + 1))
                .abs();
        }
    }
    f[20] = lap / ((H - 2) * (W - 2)) as f64;

    // 21: std of the high band (x - 3x3 box blur with edge clamping)
    let clamp_at = |r: isize, c: isize| {
        let rr = r.clamp(0, (H - 1) as isize) as usize;
        let cc = c.clamp(0, (W - 1) as isize) as usize;
        at(rr, cc)
    };
    let mut hb = Vec::with_capacity(H * W);
    for r in 0..H as isize {
        for c in 0..W as isize {
            let mut s = 0.0;
            for dr in -1..=1 {
                for dc in -1..=1 {
                    s += clamp_at(r + dr, c + dc);
                }
            }
            hb.push(at(r as usize, c as usize) - s / 9.0);
        }
    }
    let hm = hb.iter().sum::<f64>() / n;
    f[21] = (hb.iter().map(|v| (v - hm) * (v - hm)).sum::<f64>() / n).sqrt();

    // 22: std of row means, 23: std of column means
    let mut row_means = [0.0f64; H];
    let mut col_means = [0.0f64; W];
    for r in 0..H {
        for c in 0..W {
            row_means[r] += at(r, c) / W as f64;
            col_means[c] += at(r, c) / H as f64;
        }
    }
    let std_of = |v: &[f64]| {
        let m = v.iter().sum::<f64>() / v.len() as f64;
        (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
    };
    f[22] = std_of(&row_means);
    f[23] = std_of(&col_means);
    f
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_image_features() {
        let img = vec![0.5f32; 256];
        let f = extract_features(&img);
        for d in 0..16 {
            assert!((f[d] - 0.5).abs() < 1e-12);
        }
        assert!((f[16] - 0.5).abs() < 1e-12);
        for d in 17..24 {
            assert!(f[d].abs() < 1e-12, "dim {d} = {}", f[d]);
        }
    }

    #[test]
    fn vertical_edge_has_horizontal_gradient_only() {
        // left half -1, right half +1
        let mut img = vec![-1.0f32; 256];
        for r in 0..16 {
            for c in 8..16 {
                img[r * 16 + c] = 1.0;
            }
        }
        let f = extract_features(&img);
        assert!(f[18] > 0.0, "gx {}", f[18]);
        assert!(f[19] == 0.0, "gy {}", f[19]);
        assert!(f[23] > f[22], "col-structure should dominate");
        // pooled: left blocks -1, right blocks +1
        assert!((f[0] + 1.0).abs() < 1e-12 && (f[3] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noise_raises_laplacian_band() {
        use crate::rng::GaussianSource;
        let mut g = GaussianSource::seeded(8);
        let clean = vec![0.0f32; 256];
        let noisy: Vec<f32> = (0..256).map(|_| 0.3 * g.next() as f32).collect();
        let fc = extract_features(&clean);
        let fnz = extract_features(&noisy);
        assert!(fnz[20] > fc[20] + 0.1);
        assert!(fnz[21] > fc[21] + 0.1);
    }

    #[test]
    #[should_panic]
    fn wrong_size_panics() {
        extract_features(&[0.0; 100]);
    }
}
