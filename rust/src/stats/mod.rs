//! Statistics substrate for the evaluation pipeline: the fixed proxy-FID
//! feature map (mirrors `python/compile/features.py` bit-for-bit in float64
//! — enforced by a golden test against python-dumped features), a streaming
//! gaussian fitter, and the Fréchet distance.

mod features;
mod frechet;
mod gaussian;

pub use features::{extract_features, FEAT_DIM};
pub use frechet::frechet_distance;
pub use gaussian::GaussianFit;
