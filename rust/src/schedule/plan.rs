//! A [`SamplePlan`] is the fully materialised per-request schedule: for each
//! executable call it records the timestep fed to the ε-model, the two
//! cumulative alphas of Eq. (12), and the noise scales. Plans cover both
//! directions of the ODE view (Sec. 4.3): *generation* walks reversed(τ),
//! *encoding* walks τ forward with σ = 0 (Eq. 12 is direction-agnostic — the
//! same fused executable serves both).

use crate::error::{Error, Result};
use crate::schedule::{sigma_eta, sigma_hat, tau_subsequence_cached, AlphaTable, TauKind};

/// How much stochasticity the generative process injects (paper Table 1's
/// rows): `Eta(0.0)` is DDIM, `Eta(1.0)` is DDPM, `SigmaHat` is the larger
/// variance of App. D.3 (Ho et al.'s CIFAR10 setting).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NoiseMode {
    Eta(f64),
    SigmaHat,
}

impl NoiseMode {
    pub fn parse(s: &str) -> Result<Self> {
        if s == "hat" || s == "sigma_hat" {
            return Ok(NoiseMode::SigmaHat);
        }
        let eta: f64 = s
            .parse()
            .map_err(|_| Error::Schedule(format!("bad noise mode '{s}'")))?;
        if !(0.0..=2.0).contains(&eta) {
            return Err(Error::Schedule(format!("eta {eta} out of [0, 2]")));
        }
        Ok(NoiseMode::Eta(eta))
    }

    pub fn label(&self) -> String {
        match self {
            NoiseMode::Eta(e) => format!("eta={e:.1}"),
            NoiseMode::SigmaHat => "sigma_hat".into(),
        }
    }

    /// Deterministic processes need no per-step noise and yield the paper's
    /// consistency / interpolation / encoding properties (Secs. 5.2–5.4).
    pub fn is_deterministic(&self) -> bool {
        matches!(self, NoiseMode::Eta(e) if *e == 0.0)
    }
}

/// Parameters of one `denoise_step` executable call for one lane.
///
/// The fused kernel computes (per sample):
///   x0   = (x - sqrt(1 - alpha_in) ε) / sqrt(alpha_in)
///   out  = sqrt(alpha_out) x0 + sqrt(max(1 - alpha_out - σ_dir², 0)) ε
///          + σ_dir · noise
/// σ̂ mode wants a *larger* noise coefficient than the direction term uses
/// (App. D.3), so the plan carries both: the engine passes `sigma_dir` to
/// the kernel and pre-scales the noise lane by `sigma_noise / sigma_dir`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepParams {
    /// Timestep fed to the ε-model's time embedding.
    pub t_model: f64,
    /// ᾱ at the point where ε is evaluated (the "from" end).
    pub alpha_in: f64,
    /// ᾱ at the target point (the "to" end).
    pub alpha_out: f64,
    /// σ used inside the kernel (direction coefficient *and* noise).
    pub sigma_dir: f64,
    /// Effective noise std; equals `sigma_dir` except in σ̂ mode.
    pub sigma_noise: f64,
}

impl StepParams {
    /// Multiplier the engine applies to the raw N(0,1) noise lane.
    pub fn noise_scale(&self) -> f64 {
        if self.sigma_noise == 0.0 {
            0.0
        } else if self.sigma_dir > 0.0 {
            self.sigma_noise / self.sigma_dir
        } else {
            // only reachable when alpha_out == 1 (final σ̂ step), where the
            // direction coefficient is clamped to 0 regardless of σ_dir —
            // the engine passes σ_noise straight through as σ_dir.
            1.0
        }
    }

    /// Does this step consume random noise at all?
    pub fn is_stochastic(&self) -> bool {
        self.sigma_noise > 0.0
    }
}

/// Direction of a plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// noise -> data, reversed(τ) (the paper's sampling trajectory)
    Generate,
    /// data -> noise, forward τ with σ=0 (Sec. 5.4 reconstruction)
    Encode,
}

/// The materialised schedule for one request.
#[derive(Debug, Clone)]
pub struct SamplePlan {
    pub direction: Direction,
    pub tau: Vec<usize>,
    pub mode: NoiseMode,
    steps: Vec<StepParams>,
}

impl SamplePlan {
    /// Build a generation plan: S steps walking reversed(τ) down to ᾱ_0 = 1.
    pub fn generate(
        abar: &AlphaTable,
        kind: TauKind,
        s: usize,
        mode: NoiseMode,
    ) -> Result<Self> {
        let tau = tau_subsequence_cached(kind, s, abar.t_max())?;
        Self::generate_with_tau(abar, tau, mode)
    }

    /// Build a generation plan over an *explicit* τ (an optimized schedule
    /// from the artifact bundle, or the optimizer's own trial paths).
    pub fn generate_with_tau(
        abar: &AlphaTable,
        tau: Vec<usize>,
        mode: NoiseMode,
    ) -> Result<Self> {
        Self::validate_tau(&tau, abar.t_max())?;
        let s = tau.len();
        let mut steps = Vec::with_capacity(s);
        // walk pairs (τ_i, τ_{i-1}) from i = S down to 1, τ_0 := 0
        for i in (0..s).rev() {
            let t_cur = tau[i];
            let t_prev = if i == 0 { 0 } else { tau[i - 1] };
            let (sigma_dir, sigma_noise) = match mode {
                NoiseMode::Eta(eta) => {
                    let sg = sigma_eta(abar, t_cur, t_prev, eta);
                    (sg, sg)
                }
                NoiseMode::SigmaHat => {
                    let s1 = sigma_eta(abar, t_cur, t_prev, 1.0);
                    let sh = sigma_hat(abar, t_cur, t_prev);
                    if t_prev == 0 {
                        // ᾱ_out = 1 ⇒ direction coefficient is 0 anyway;
                        // pass σ̂ straight through as the kernel sigma.
                        (sh, sh)
                    } else {
                        (s1, sh)
                    }
                }
            };
            steps.push(StepParams {
                t_model: t_cur as f64,
                alpha_in: abar.abar(t_cur),
                alpha_out: abar.abar(t_prev),
                sigma_dir,
                sigma_noise,
            });
        }
        Ok(Self { direction: Direction::Generate, tau, mode, steps })
    }

    /// Build an encoding plan (deterministic, σ = 0): walk τ forward,
    /// evaluating ε at the left end of each interval (Euler on Eq. 14's
    /// reverse). `x_0 -> x_{τ_1} -> ... -> x_{τ_S}`.
    pub fn encode(abar: &AlphaTable, kind: TauKind, s: usize) -> Result<Self> {
        let tau = tau_subsequence_cached(kind, s, abar.t_max())?;
        Self::encode_with_tau(abar, tau)
    }

    /// Encoding plan over an explicit τ (see [`SamplePlan::generate_with_tau`]).
    pub fn encode_with_tau(abar: &AlphaTable, tau: Vec<usize>) -> Result<Self> {
        Self::validate_tau(&tau, abar.t_max())?;
        let mut steps = Vec::with_capacity(tau.len());
        let mut t_prev = 0usize;
        for &t_next in &tau {
            steps.push(StepParams {
                // model trained on t ∈ [1, T]; clamp the t=0 start
                t_model: t_prev.max(1) as f64,
                alpha_in: abar.abar(t_prev),
                alpha_out: abar.abar(t_next),
                sigma_dir: 0.0,
                sigma_noise: 0.0,
            });
            t_prev = t_next;
        }
        Ok(Self { direction: Direction::Encode, tau, mode: NoiseMode::Eta(0.0), steps })
    }

    /// One deterministic DDIM step `t_cur -> t_prev` (σ = 0), as a
    /// single-entry generation plan. The optimizer chains these to probe
    /// per-step quality deltas through the real step backend, so each
    /// probe step is bitwise-identical to the same step inside a full
    /// serving plan.
    pub fn single_step(abar: &AlphaTable, t_cur: usize, t_prev: usize) -> Result<Self> {
        if t_cur == 0 || t_cur > abar.t_max() || t_prev >= t_cur {
            return Err(Error::Schedule(format!(
                "bad single step {t_cur} -> {t_prev} for T={}",
                abar.t_max()
            )));
        }
        let steps = vec![StepParams {
            t_model: t_cur as f64,
            alpha_in: abar.abar(t_cur),
            alpha_out: abar.abar(t_prev),
            sigma_dir: 0.0,
            sigma_noise: 0.0,
        }];
        Ok(Self {
            direction: Direction::Generate,
            tau: vec![t_cur],
            mode: NoiseMode::Eta(0.0),
            steps,
        })
    }

    /// An explicit τ must be non-empty and strictly increasing within
    /// [1, T] — the same contract `tau_subsequence` guarantees.
    pub fn validate_tau(tau: &[usize], t_max: usize) -> Result<()> {
        if tau.is_empty() {
            return Err(Error::Schedule("empty tau".into()));
        }
        if tau[0] < 1 || *tau.last().unwrap() > t_max {
            return Err(Error::Schedule(format!(
                "tau out of [1, {t_max}]: {}..{}",
                tau[0],
                tau.last().unwrap()
            )));
        }
        if !tau.windows(2).all(|w| w[1] > w[0]) {
            return Err(Error::Schedule("tau must be strictly increasing".into()));
        }
        Ok(())
    }

    pub fn steps(&self) -> &[StepParams] {
        &self.steps
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn abar() -> AlphaTable {
        AlphaTable::linear(1000)
    }

    #[test]
    fn generate_plan_shape() {
        let t = abar();
        let p = SamplePlan::generate(&t, TauKind::Linear, 10, NoiseMode::Eta(0.0)).unwrap();
        assert_eq!(p.len(), 10);
        // first step starts at tau_S (largest t), last step ends at abar=1
        assert_eq!(p.steps()[0].t_model, *p.tau.last().unwrap() as f64);
        assert_eq!(p.steps().last().unwrap().alpha_out, 1.0);
        // alpha_in decreasing across steps, alpha_out > alpha_in everywhere
        for st in p.steps() {
            assert!(st.alpha_out > st.alpha_in);
            assert_eq!(st.sigma_dir, 0.0);
            assert!(!st.is_stochastic());
        }
    }

    #[test]
    fn ddpm_plan_is_stochastic_except_final_step() {
        let t = abar();
        let p = SamplePlan::generate(&t, TauKind::Linear, 10, NoiseMode::Eta(1.0)).unwrap();
        let (last, rest) = p.steps().split_last().unwrap();
        for st in rest {
            assert!(st.is_stochastic());
            assert!((st.noise_scale() - 1.0).abs() < 1e-12);
        }
        // final step lands on alpha_bar_0 = 1, where Eq. 16 gives sigma = 0:
        // even DDPM's last hop (t=tau_1 -> 0) is deterministic.
        assert_eq!(last.alpha_out, 1.0);
        assert!(!last.is_stochastic());
    }

    #[test]
    fn sigma_hat_noise_dominates_direction_sigma() {
        let t = abar();
        let p = SamplePlan::generate(&t, TauKind::Linear, 10, NoiseMode::SigmaHat).unwrap();
        for st in &p.steps()[..p.len() - 1] {
            assert!(st.sigma_noise > st.sigma_dir, "{st:?}");
            assert!(st.noise_scale() > 1.0);
        }
        // final step: alpha_out = 1, sigma passes through
        let last = p.steps().last().unwrap();
        assert_eq!(last.alpha_out, 1.0);
        assert_eq!(last.sigma_dir, last.sigma_noise);
    }

    #[test]
    fn encode_plan_is_generation_reversed() {
        let t = abar();
        let g = SamplePlan::generate(&t, TauKind::Quadratic, 20, NoiseMode::Eta(0.0)).unwrap();
        let e = SamplePlan::encode(&t, TauKind::Quadratic, 20).unwrap();
        assert_eq!(g.tau, e.tau);
        // encode alpha endpoints mirror generate's, reversed
        let g_pairs: Vec<(f64, f64)> =
            g.steps().iter().map(|s| (s.alpha_out, s.alpha_in)).collect();
        let e_pairs: Vec<(f64, f64)> =
            e.steps().iter().rev().map(|s| (s.alpha_in, s.alpha_out)).collect();
        for (a, b) in g_pairs.iter().zip(&e_pairs) {
            assert!((a.0 - b.0).abs() < 1e-15 && (a.1 - b.1).abs() < 1e-15);
        }
    }

    #[test]
    fn explicit_tau_matches_kind_built_plan() {
        use crate::schedule::tau_subsequence;
        let t = abar();
        let tau = tau_subsequence(TauKind::Quadratic, 15, 1000).unwrap();
        let a = SamplePlan::generate(&t, TauKind::Quadratic, 15, NoiseMode::Eta(0.3)).unwrap();
        let b = SamplePlan::generate_with_tau(&t, tau.clone(), NoiseMode::Eta(0.3)).unwrap();
        assert_eq!(a.tau, b.tau);
        assert_eq!(a.steps(), b.steps());
        let ea = SamplePlan::encode(&t, TauKind::Quadratic, 15).unwrap();
        let eb = SamplePlan::encode_with_tau(&t, tau).unwrap();
        assert_eq!(ea.steps(), eb.steps());
    }

    #[test]
    fn explicit_tau_is_validated() {
        let t = abar();
        for bad in [vec![], vec![0, 5], vec![5, 5, 9], vec![9, 5], vec![5, 1001]] {
            assert!(
                SamplePlan::generate_with_tau(&t, bad.clone(), NoiseMode::Eta(0.0)).is_err(),
                "{bad:?}"
            );
            assert!(SamplePlan::encode_with_tau(&t, bad).is_err());
        }
    }

    #[test]
    fn single_step_matches_tail_of_full_plan() {
        let t = abar();
        let full = SamplePlan::generate(&t, TauKind::Linear, 10, NoiseMode::Eta(0.0)).unwrap();
        let tau = full.tau.clone();
        let single = SamplePlan::single_step(&t, tau[1], tau[0]).unwrap();
        assert_eq!(single.len(), 1);
        // the 2nd-to-last step of the full plan walks tau[1] -> tau[0]
        assert_eq!(single.steps()[0], full.steps()[full.len() - 2]);
        assert!(SamplePlan::single_step(&t, 0, 0).is_err());
        assert!(SamplePlan::single_step(&t, 5, 9).is_err());
        assert!(SamplePlan::single_step(&t, 1001, 0).is_err());
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(NoiseMode::parse("0").unwrap(), NoiseMode::Eta(0.0));
        assert_eq!(NoiseMode::parse("0.5").unwrap(), NoiseMode::Eta(0.5));
        assert_eq!(NoiseMode::parse("hat").unwrap(), NoiseMode::SigmaHat);
        assert!(NoiseMode::parse("nope").is_err());
        assert!(NoiseMode::parse("-1").is_err());
        assert!(NoiseMode::Eta(0.0).is_deterministic());
        assert!(!NoiseMode::Eta(0.2).is_deterministic());
        assert!(!NoiseMode::SigmaHat.is_deterministic());
    }
}
