//! The cumulative ᾱ table. Paper notation (Sec. 2 / App. C.2): we store the
//! paper's `alpha_t` — Ho et al.'s ᾱ_t — for t = 0..T with ᾱ_0 := 1.

use std::path::Path;

use crate::error::{Error, Result};
use crate::json;

/// Default diffusion length (paper: T = 1000 for every dataset).
pub const T_DEFAULT: usize = 1000;
const BETA_START: f64 = 1e-4;
const BETA_END: f64 = 0.02;

/// ᾱ_{0..T} with ᾱ_0 = 1, strictly decreasing into (0, 1).
#[derive(Debug, Clone)]
pub struct AlphaTable {
    abar: Vec<f64>,
}

impl AlphaTable {
    /// Ho et al. linear-β schedule, the one used for every paper dataset.
    pub fn linear(t_max: usize) -> Self {
        let mut abar = Vec::with_capacity(t_max + 1);
        abar.push(1.0);
        let mut prod = 1.0f64;
        for i in 0..t_max {
            // beta_t linearly spaced over [BETA_START, BETA_END]
            let beta = if t_max == 1 {
                BETA_START
            } else {
                BETA_START + (BETA_END - BETA_START) * i as f64 / (t_max - 1) as f64
            };
            prod *= 1.0 - beta;
            abar.push(prod);
        }
        Self { abar }
    }

    /// Load `alphas.json` produced by the python build and verify it matches
    /// the native computation (guards against schedule drift between layers).
    pub fn from_artifact(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let v = json::parse(&text)?;
        let t_max = v.get("T")?.as_usize()?;
        let abar = v.get("alpha_bar")?.as_f64_vec()?;
        if abar.len() != t_max + 1 {
            return Err(Error::Artifact(format!(
                "alphas.json: expected {} entries, got {}",
                t_max + 1,
                abar.len()
            )));
        }
        let native = Self::linear(t_max);
        for (i, (a, b)) in abar.iter().zip(&native.abar).enumerate() {
            if (a - b).abs() > 1e-9 {
                return Err(Error::Artifact(format!(
                    "alphas.json disagrees with native schedule at t={i}: {a} vs {b}"
                )));
            }
        }
        Ok(Self { abar })
    }

    /// Number of diffusion steps T.
    pub fn t_max(&self) -> usize {
        self.abar.len() - 1
    }

    /// ᾱ_t for t in 0..=T.
    pub fn abar(&self, t: usize) -> f64 {
        self.abar[t]
    }

    /// Validate the table's defining invariants (also exercised by tests).
    pub fn validate(&self) -> Result<()> {
        if self.abar.first() != Some(&1.0) {
            return Err(Error::Schedule("alpha_bar[0] != 1".into()));
        }
        for w in self.abar.windows(2) {
            if !(w[1] > 0.0 && w[1] < w[0]) {
                return Err(Error::Schedule(format!(
                    "alpha_bar not strictly decreasing in (0,1): {} -> {}",
                    w[0], w[1]
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invariants() {
        let t = AlphaTable::linear(T_DEFAULT);
        t.validate().unwrap();
        assert_eq!(t.t_max(), 1000);
        assert_eq!(t.abar(0), 1.0);
        // alpha_bar(T) should be near zero (prior ~ N(0, I)); Ho et al.
        // report ~4e-5 for this schedule.
        assert!(t.abar(1000) < 1e-4, "{}", t.abar(1000));
        assert!(t.abar(1000) > 0.0);
    }

    #[test]
    fn first_step_matches_beta_start() {
        let t = AlphaTable::linear(1000);
        assert!((t.abar(1) - (1.0 - 1e-4)).abs() < 1e-12);
    }

    #[test]
    fn from_artifact_rejects_mismatch() {
        let dir = std::env::temp_dir().join("ddim_alpha_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("alphas.json");
        // wrong values
        std::fs::write(&path, r#"{"T": 2, "alpha_bar": [1.0, 0.9, 0.5]}"#).unwrap();
        assert!(AlphaTable::from_artifact(&path).is_err());
        // wrong length
        std::fs::write(&path, r#"{"T": 3, "alpha_bar": [1.0, 0.9]}"#).unwrap();
        assert!(AlphaTable::from_artifact(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn from_artifact_accepts_native_dump() {
        // serialize the native table the way python does and read it back
        let t = AlphaTable::linear(50);
        let vals: Vec<String> = t.abar.iter().map(|a| format!("{a:?}")).collect();
        let text = format!("{{\"T\": 50, \"alpha_bar\": [{}]}}", vals.join(","));
        let dir = std::env::temp_dir().join("ddim_alpha_test_ok");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("alphas.json");
        std::fs::write(&path, text).unwrap();
        let loaded = AlphaTable::from_artifact(&path).unwrap();
        assert_eq!(loaded.t_max(), 50);
        std::fs::remove_dir_all(dir).ok();
    }
}
