//! Offline τ-schedule optimizer: a budget-limited beam search (dynamic
//! program) over per-step quality deltas, scored against the fixture
//! reference statistics with the existing Fréchet machinery.
//!
//! DDIM's quality at a small step budget S depends heavily on *which*
//! sub-sequence τ ⊂ [1, T] is kept (Song et al. §4.2 only ever tries the
//! linear and quadratic grids). Following the schedule-search line of
//! Watson et al. (DP over per-step deltas) and BDDM (cheap offline
//! scoring against reference statistics), [`optimize_tau`] searches the
//! τ space for one (dataset, S) cell:
//!
//! 1. **Candidates** — the union of the linear grid, the quadratic grid,
//!    and a uniform grid at 3S resolution (clamped to [1, T]): a few
//!    hundred boundaries at most, not 2^T subsets.
//! 2. **Probe** — a fine deterministic trajectory over the full candidate
//!    list (8 lanes, η = 0) through the real step backend records the
//!    reference state at every boundary.
//! 3. **Delta table** — `cost(hi → lo)` is the mean squared deviation
//!    between one direct DDIM step `hi → lo` and the fine trajectory's
//!    state at `lo`: the quality penalty of skipping the boundaries in
//!    between. Costs are computed lazily and memoized — the beam touches
//!    a fraction of the O(|C|²) pairs.
//! 4. **Beam DP** — width-8 beam descends from τ_S = T choosing S
//!    boundaries that minimise accumulated delta cost (ties broken by
//!    path, so the search is fully deterministic).
//! 5. **Final eval** — the top beam paths *and both paper grids* are
//!    scored by true fixture Fréchet distance over [`EVAL_LANES`]
//!    deterministic lanes (memoized per τ); the argmin wins. Because the
//!    grids are in the candidate set, the emitted schedule is ≤ both by
//!    construction.
//!
//! Everything is seeded from (dataset, S) alone — see [`optimizer_seed`]
//! — so two runs against the same manifest are byte-identical, on any
//! host. The winning schedule is written as
//! `schedules/opt_{dataset}_{S}.json` next to the manifest and loaded at
//! serve time by [`OptSchedules`]; the JSON records the manifest digest
//! it was optimized against (stale schedules are skipped at load) and
//! its own content digest feeds the cache key (re-optimization must
//! invalidate cached samples even though the kind tag is unchanged).

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::eval::{fid_of_images, load_ref_stats};
use crate::json::{self, Value};
use crate::rng::{Fnv64, GaussianSource, Pcg64};
use crate::runtime::Runtime;
use crate::sampler::BatchRunner;
use crate::schedule::{tau_subsequence, NoiseMode, SamplePlan, TauKind};
use crate::stats::GaussianFit;

/// Lanes behind every true-Fréchet evaluation (probe states use fewer).
pub const EVAL_LANES: usize = 48;
/// Lanes in the boundary-state probe trajectory.
const PROBE_LANES: usize = 8;
/// Beam width of the DP over τ boundaries.
const BEAM_WIDTH: usize = 8;
/// How many beam survivors get a true-Fréchet evaluation.
const FINAL_EVALS: usize = 4;

/// Deterministic seed for one optimizer stage: FNV-64 over
/// (dataset, S, stage tag), masked to 63 bits so `seed + lane` can never
/// overflow. Tag 1 = probe, tag 2 = eval. Deliberately *not* derived from
/// the manifest digest: the same (dataset, S) cell optimizes identically
/// regardless of which artifact root it was materialised under, which is
/// what makes fixture regeneration reproducible across machines.
pub fn optimizer_seed(dataset: &str, steps: usize, tag: u64) -> u64 {
    Fnv64::new().str(dataset).u64(steps as u64).u64(tag).finish() & (u64::MAX >> 1)
}

/// One optimized schedule, as stored in `schedules/opt_{dataset}_{S}.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct OptSchedule {
    pub dataset: String,
    /// Step budget S (`tau.len() == steps`).
    pub steps: usize,
    /// Horizon T the schedule was optimized for.
    pub t_max: usize,
    /// The optimized sub-sequence, strictly increasing within [1, T].
    pub tau: Vec<usize>,
    /// Fixture Fréchet score of `tau` at [`EVAL_LANES`] lanes.
    pub score: f64,
    /// Same-protocol score of the linear grid (committed for comparison).
    pub linear_score: f64,
    /// Same-protocol score of the quadratic grid.
    pub quadratic_score: f64,
    /// Manifest digest this schedule was optimized against; schedules
    /// from another artifact tree are skipped at load.
    pub manifest_digest: u64,
    /// FNV-64 over the schedule file bytes — the cache-key content
    /// identity (derived, never serialized).
    pub content_digest: u64,
}

impl OptSchedule {
    /// Deterministic JSON serialization (BTreeMap-ordered keys).
    pub fn to_json(&self) -> String {
        let mut v = crate::jobj![
            ("dataset", self.dataset.as_str()),
            ("steps", self.steps),
            ("t_max", self.t_max),
            ("score", self.score),
            ("linear_score", self.linear_score),
            ("quadratic_score", self.quadratic_score),
            ("manifest_digest", format!("{:016x}", self.manifest_digest)),
        ];
        let tau: Vec<Value> = self.tau.iter().map(|&t| Value::from(t)).collect();
        v.set("tau", Value::Arr(tau)).expect("jobj is an object");
        json::to_string(&v) + "\n"
    }

    /// Parse a schedule file; `content_digest` is recomputed from `text`.
    pub fn from_json(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let digest_hex = v.get("manifest_digest")?.as_str()?;
        let manifest_digest = u64::from_str_radix(digest_hex, 16).map_err(|_| {
            Error::Schedule(format!("bad manifest_digest '{digest_hex}' in opt schedule"))
        })?;
        let out = Self {
            dataset: v.get("dataset")?.as_str()?.to_string(),
            steps: v.get("steps")?.as_usize()?,
            t_max: v.get("t_max")?.as_usize()?,
            tau: v.get("tau")?.as_usize_vec()?,
            score: v.get("score")?.as_f64()?,
            linear_score: v.get("linear_score")?.as_f64()?,
            quadratic_score: v.get("quadratic_score")?.as_f64()?,
            manifest_digest,
            content_digest: content_digest(text.as_bytes()),
        };
        if out.tau.len() != out.steps {
            return Err(Error::Schedule(format!(
                "opt schedule for '{}' has {} boundaries for S={}",
                out.dataset,
                out.tau.len(),
                out.steps
            )));
        }
        SamplePlan::validate_tau(&out.tau, out.t_max)?;
        Ok(out)
    }
}

/// FNV-64 over schedule file bytes — what [`crate::cache::CacheKey`]
/// hashes for `"tau":"opt"` requests.
pub fn content_digest(bytes: &[u8]) -> u64 {
    Fnv64::new().bytes(bytes).finish()
}

/// Relative path of one schedule inside an artifact root.
pub fn schedule_rel_path(dataset: &str, steps: usize) -> String {
    format!("schedules/opt_{dataset}_{steps}.json")
}

/// Absolute path of one schedule inside an artifact root.
pub fn schedule_path(root: &Path, dataset: &str, steps: usize) -> PathBuf {
    root.join(schedule_rel_path(dataset, steps))
}

/// Write a schedule into `<root>/schedules/`, creating the directory.
pub fn write_schedule(root: &Path, sched: &OptSchedule) -> Result<PathBuf> {
    let dir = root.join("schedules");
    fs::create_dir_all(&dir)?;
    let path = schedule_path(root, &sched.dataset, sched.steps);
    fs::write(&path, sched.to_json())?;
    Ok(path)
}

/// The serve-time registry: every valid, non-stale `opt_*.json` under an
/// artifact root, keyed by (dataset, S).
#[derive(Debug, Default)]
pub struct OptSchedules {
    map: BTreeMap<(String, usize), OptSchedule>,
}

impl OptSchedules {
    /// Scan `<root>/schedules/` for `opt_*.json`. Files that fail to
    /// parse, fail τ validation, or carry a manifest digest other than
    /// `expect_digest` are skipped (never fatal): a stale schedule is a
    /// missing schedule, and requests for it get the typed
    /// [`OptSchedules::require`] error.
    pub fn load(root: &Path, expect_digest: u64) -> Self {
        let mut map = BTreeMap::new();
        let Ok(entries) = fs::read_dir(root.join("schedules")) else {
            return Self { map };
        };
        let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
        paths.sort();
        for path in paths {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if !name.starts_with("opt_") || !name.ends_with(".json") {
                continue;
            }
            let Ok(text) = fs::read_to_string(&path) else { continue };
            let Ok(sched) = OptSchedule::from_json(&text) else { continue };
            if sched.manifest_digest != expect_digest {
                continue; // optimized against another artifact tree
            }
            map.insert((sched.dataset.clone(), sched.steps), sched);
        }
        Self { map }
    }

    pub fn get(&self, dataset: &str, steps: usize) -> Option<&OptSchedule> {
        self.map.get(&(dataset.to_string(), steps))
    }

    /// Content digest for the cache key (`None` when no schedule exists).
    pub fn digest(&self, dataset: &str, steps: usize) -> Option<u64> {
        self.get(dataset, steps).map(|s| s.content_digest)
    }

    /// Typed error listing the available cells when a `"tau":"opt"`
    /// request names a (dataset, S) nobody optimized.
    pub fn require(&self, dataset: &str, steps: usize) -> Result<&OptSchedule> {
        self.get(dataset, steps).ok_or_else(|| {
            let cells: Vec<String> =
                self.map.keys().map(|(d, s)| format!("{d}/S={s}")).collect();
            Error::Schedule(format!(
                "no optimized schedule for {dataset}/S={steps} (available: {cells:?}); \
                 run `ddim-serve optimize-tau --dataset {dataset} --steps {steps}`"
            ))
        })
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Every loaded (dataset, S) cell, in deterministic order.
    pub fn cells(&self) -> impl Iterator<Item = (&str, usize)> {
        self.map.keys().map(|(d, s)| (d.as_str(), *s))
    }
}

/// What one [`optimize_tau`] run did (cost accounting for the CLI/bench).
#[derive(Debug, Clone)]
pub struct OptimizeReport {
    pub schedule: OptSchedule,
    /// Candidate boundary count |C|.
    pub candidates: usize,
    /// Delta-table pairs actually scored (lazy memoization).
    pub pairs_scored: usize,
    /// True-Fréchet trajectory evaluations (memoized per τ).
    pub evals: usize,
}

/// Candidate boundary set: linear grid ∪ quadratic grid ∪ uniform grid
/// at 3S resolution ∪ {T}, clamped to [1, T], sorted ascending.
fn candidates(s: usize, t_max: usize) -> Result<Vec<usize>> {
    let mut set = BTreeSet::new();
    set.extend(tau_subsequence(TauKind::Linear, s, t_max)?);
    set.extend(tau_subsequence(TauKind::Quadratic, s, t_max)?);
    let j_max = t_max.min(3 * s);
    for j in 1..=j_max {
        set.insert((t_max * j / j_max).clamp(1, t_max));
    }
    set.insert(t_max);
    Ok(set.into_iter().collect())
}

/// One deterministic DDIM step `t_cur → t_prev` for a batch of states,
/// through the real step backend (bitwise-identical to the same step
/// inside a full serving plan).
fn one_step(
    rt: &mut Runtime,
    runner: &mut BatchRunner,
    states: Vec<Vec<f32>>,
    t_cur: usize,
    t_prev: usize,
) -> Result<Vec<Vec<f32>>> {
    let plan = SamplePlan::single_step(rt.alphas(), t_cur, t_prev)?;
    runner.run_from(rt, &plan, states, 0)
}

/// Sequential f64 mean of squared per-element deviation (lane-major
/// order; the summation order is part of the determinism contract).
fn mean_sq(a: &[Vec<f32>], b: &[Vec<f32>]) -> f64 {
    let mut acc = 0.0f64;
    let mut n = 0usize;
    for (ra, rb) in a.iter().zip(b) {
        for (&va, &vb) in ra.iter().zip(rb) {
            let d = va as f64 - vb as f64;
            acc += d * d;
            n += 1;
        }
    }
    if n == 0 {
        return 0.0;
    }
    acc / n as f64
}

/// Lazily-memoized per-step quality-delta table over probe states.
struct DeltaTable {
    /// Probe state at every candidate boundary (and 0), [`PROBE_LANES`]
    /// lanes each.
    states: BTreeMap<usize, Vec<Vec<f32>>>,
    memo: HashMap<(usize, usize), f64>,
}

impl DeltaTable {
    /// Walk the fine trajectory over the full candidate list once,
    /// recording the state at every boundary.
    fn probe(
        rt: &mut Runtime,
        runner: &mut BatchRunner,
        cand: &[usize],
        seed: u64,
        dim: usize,
    ) -> Result<Self> {
        let mut x: Vec<Vec<f32>> = (0..PROBE_LANES as u64)
            .map(|i| {
                let mut root = Pcg64::seeded(seed + i);
                let mut prior = GaussianSource::new(root.fork(0));
                prior.vec(dim)
            })
            .collect();
        let mut states = BTreeMap::new();
        states.insert(cand[cand.len() - 1], x.clone());
        for i in (0..cand.len()).rev() {
            let t_cur = cand[i];
            let t_prev = if i == 0 { 0 } else { cand[i - 1] };
            x = one_step(rt, runner, x, t_cur, t_prev)?;
            states.insert(t_prev, x.clone());
        }
        Ok(Self { states, memo: HashMap::new() })
    }

    /// Quality penalty of one direct step `hi → lo`: squared deviation
    /// from the fine trajectory's state at `lo`.
    fn cost(
        &mut self,
        rt: &mut Runtime,
        runner: &mut BatchRunner,
        hi: usize,
        lo: usize,
    ) -> Result<f64> {
        if let Some(&c) = self.memo.get(&(hi, lo)) {
            return Ok(c);
        }
        let from = self.states[&hi].clone();
        let y = one_step(rt, runner, from, hi, lo)?;
        let c = mean_sq(&y, &self.states[&lo]);
        self.memo.insert((hi, lo), c);
        Ok(c)
    }
}

/// Width-[`BEAM_WIDTH`] beam over descending boundary choices. Returns
/// completed paths ascending-sorted within each path, best-first; ties
/// broken by path content so the result is order-deterministic.
fn beam_paths(
    rt: &mut Runtime,
    runner: &mut BatchRunner,
    delta: &mut DeltaTable,
    cand: &[usize],
    s: usize,
) -> Result<Vec<Vec<usize>>> {
    let t_max = cand[cand.len() - 1];
    let mut beam: Vec<(f64, Vec<usize>)> = vec![(0.0, vec![t_max])];
    let by_cost_then_path = |a: &(f64, Vec<usize>), b: &(f64, Vec<usize>)| {
        a.0.partial_cmp(&b.0).expect("delta costs are finite").then_with(|| a.1.cmp(&b.1))
    };
    for _ in 0..s.saturating_sub(1) {
        let mut next = Vec::new();
        for (acc, path) in &beam {
            let cur = path[path.len() - 1];
            for &lo in cand {
                if lo >= cur {
                    break; // cand is ascending
                }
                let c = delta.cost(rt, runner, cur, lo)?;
                let mut p = path.clone();
                p.push(lo);
                next.push((acc + c, p));
            }
        }
        next.sort_by(by_cost_then_path);
        next.truncate(BEAM_WIDTH);
        beam = next;
        if beam.is_empty() {
            break; // every partial dead-ended below the candidate floor
        }
    }
    let mut done = Vec::new();
    for (acc, path) in beam {
        let tail = delta.cost(rt, runner, path[path.len() - 1], 0)?;
        done.push((acc + tail, path));
    }
    done.sort_by(by_cost_then_path);
    Ok(done
        .into_iter()
        .map(|(_, mut p)| {
            p.reverse();
            p
        })
        .collect())
}

/// True fixture-Fréchet score of one τ at [`EVAL_LANES`] deterministic
/// lanes, memoized per τ vector.
#[allow(clippy::too_many_arguments)]
fn eval_tau(
    rt: &mut Runtime,
    runner: &mut BatchRunner,
    reference: &GaussianFit,
    tau: &[usize],
    seed: u64,
    memo: &mut HashMap<Vec<usize>, f64>,
    evals: &mut usize,
) -> Result<f64> {
    if let Some(&v) = memo.get(tau) {
        return Ok(v);
    }
    let plan = SamplePlan::generate_with_tau(rt.alphas(), tau.to_vec(), NoiseMode::Eta(0.0))?;
    let images = runner.generate(rt, &plan, EVAL_LANES, seed)?;
    let v = fid_of_images(&images, reference)?;
    memo.insert(tau.to_vec(), v);
    *evals += 1;
    Ok(v)
}

/// Optimize the τ schedule for one (dataset, S) cell. Deterministic:
/// byte-identical output for the same manifest, on any host. The
/// returned schedule's fixture Fréchet score is ≤ both paper grids by
/// construction (they compete in the final argmin).
pub fn optimize_tau(rt: &mut Runtime, dataset: &str, steps: usize) -> Result<OptimizeReport> {
    rt.manifest().dataset(dataset)?; // typed unknown-dataset error up front
    if steps == 0 {
        return Err(Error::Schedule("optimize-tau wants steps >= 1".into()));
    }
    let t_max = rt.alphas().t_max();
    let dim = rt.manifest().sample_dim();
    let manifest_digest = crate::cache::manifest_digest(rt.manifest());
    let cand = candidates(steps, t_max)?;
    let mut runner = BatchRunner::new(rt, dataset, EVAL_LANES)?;

    // probe + beam over the delta table
    let probe_seed = optimizer_seed(dataset, steps, 1);
    let mut delta = DeltaTable::probe(rt, &mut runner, &cand, probe_seed, dim)?;
    let paths = beam_paths(rt, &mut runner, &mut delta, &cand, steps)?;

    // final argmin over {top beam paths} ∪ {linear, quadratic}
    let linear = tau_subsequence(TauKind::Linear, steps, t_max)?;
    let quadratic = tau_subsequence(TauKind::Quadratic, steps, t_max)?;
    let reference = load_ref_stats(rt.manifest(), dataset)?;
    let eval_seed = optimizer_seed(dataset, steps, 2);
    let mut memo: HashMap<Vec<usize>, f64> = HashMap::new();
    let mut evals = 0usize;
    let mut entries: Vec<&[usize]> =
        paths.iter().take(FINAL_EVALS).map(Vec::as_slice).collect();
    entries.push(&linear);
    entries.push(&quadratic);
    let mut best: Option<(f64, Vec<usize>)> = None;
    for tau in entries {
        let score =
            eval_tau(rt, &mut runner, &reference, tau, eval_seed, &mut memo, &mut evals)?;
        if best.as_ref().map_or(true, |(b, _)| score < *b) {
            best = Some((score, tau.to_vec()));
        }
    }
    let (score, tau) = best.expect("linear grid always evaluated");
    let linear_score = memo[&linear];
    let quadratic_score = memo[&quadratic];

    let mut schedule = OptSchedule {
        dataset: dataset.to_string(),
        steps,
        t_max,
        tau,
        score,
        linear_score,
        quadratic_score,
        manifest_digest,
        content_digest: 0,
    };
    schedule.content_digest = content_digest(schedule.to_json().as_bytes());
    Ok(OptimizeReport {
        schedule,
        candidates: cand.len(),
        pairs_scored: delta.memo.len(),
        evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_set_is_ascending_superset_of_both_grids() {
        let c = candidates(10, 400).unwrap();
        assert!(c.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(*c.last().unwrap(), 400);
        for kind in [TauKind::Linear, TauKind::Quadratic] {
            for t in tau_subsequence(kind, 10, 400).unwrap() {
                assert!(c.contains(&t), "{kind} boundary {t} missing");
            }
        }
        assert!(c.len() >= 30, "3S uniform grid contributes, got {}", c.len());
    }

    #[test]
    fn optimizer_seed_separates_cells_and_stages() {
        let a = optimizer_seed("sprites", 10, 1);
        assert_eq!(a, optimizer_seed("sprites", 10, 1));
        assert_ne!(a, optimizer_seed("sprites", 10, 2));
        assert_ne!(a, optimizer_seed("sprites", 20, 1));
        assert_ne!(a, optimizer_seed("blobs", 10, 1));
        assert!(a < 1 << 63, "seed is masked so lane offsets cannot overflow");
    }

    #[test]
    fn schedule_json_round_trips_and_digests_content() {
        let s = OptSchedule {
            dataset: "sprites".into(),
            steps: 3,
            t_max: 400,
            tau: vec![100, 250, 400],
            score: 15.5,
            linear_score: 26.0,
            quadratic_score: 25.5,
            manifest_digest: 0xdead_beef_cafe_f00d,
            content_digest: 0,
        };
        let text = s.to_json();
        let back = OptSchedule::from_json(&text).unwrap();
        assert_eq!(back.tau, s.tau);
        assert_eq!(back.manifest_digest, s.manifest_digest);
        assert_eq!(back.content_digest, content_digest(text.as_bytes()));
        // a different file body is a different content digest
        let other = OptSchedule { tau: vec![99, 250, 400], ..s.clone() };
        let d2 = OptSchedule::from_json(&other.to_json()).unwrap().content_digest;
        assert_ne!(back.content_digest, d2);
        // malformed bodies are typed errors, not panics
        assert!(OptSchedule::from_json("{}").is_err());
        let bad = text.replace("\"steps\":3", "\"steps\":4");
        assert!(OptSchedule::from_json(&bad).is_err(), "len/steps mismatch");
    }

    #[test]
    fn registry_skips_stale_and_garbage_files() {
        let dir = std::env::temp_dir()
            .join(format!("ddim-opt-registry-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let good = OptSchedule {
            dataset: "sprites".into(),
            steps: 3,
            t_max: 400,
            tau: vec![100, 250, 400],
            score: 1.0,
            linear_score: 2.0,
            quadratic_score: 2.0,
            manifest_digest: 7,
            content_digest: 0,
        };
        write_schedule(&dir, &good).unwrap();
        let stale = OptSchedule { steps: 2, tau: vec![100, 400], manifest_digest: 8, ..good.clone() };
        write_schedule(&dir, &stale).unwrap();
        fs::write(dir.join("schedules/opt_garbage_5.json"), "not json").unwrap();
        fs::write(dir.join("schedules/other.txt"), "ignored").unwrap();
        let reg = OptSchedules::load(&dir, 7);
        assert_eq!(reg.len(), 1, "only the digest-matching schedule loads");
        assert!(reg.get("sprites", 3).is_some());
        assert!(reg.get("sprites", 2).is_none(), "stale digest is skipped");
        assert_eq!(reg.digest("sprites", 3), Some(reg.get("sprites", 3).unwrap().content_digest));
        let err = reg.require("sprites", 2).unwrap_err().to_string();
        assert!(err.contains("sprites/S=2") && err.contains("optimize-tau"), "{err}");
        assert_eq!(reg.cells().collect::<Vec<_>>(), vec![("sprites", 3)]);
        // an empty/missing root is an empty registry, not an error
        assert!(OptSchedules::load(&dir.join("nope"), 7).is_empty());
        let _ = fs::remove_dir_all(&dir);
    }
}
