//! The sampling trajectory τ (Sec. 4.2) and noise scales σ(η), σ̂
//! (Eq. 16, App. D.3).

use crate::error::{Error, Result};
use crate::schedule::AlphaTable;

/// τ selection procedure (App. D.2). The paper uses quadratic for CIFAR10
/// and linear elsewhere; our manifest picks per dataset the same way.
/// `Opt` is our extension: a pre-optimized per-(dataset, S) schedule from
/// [`crate::schedule::optimize_tau`], resolved from the artifact bundle at
/// serve time — it has no closed form, so [`tau_subsequence`] rejects it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TauKind {
    Linear,
    Quadratic,
    Opt,
}

impl TauKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "linear" => Ok(TauKind::Linear),
            "quadratic" => Ok(TauKind::Quadratic),
            "opt" => Ok(TauKind::Opt),
            _ => Err(Error::Schedule(format!(
                "unknown tau kind '{s}' (want linear | quadratic | opt)"
            ))),
        }
    }
}

impl std::fmt::Display for TauKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TauKind::Linear => "linear",
            TauKind::Quadratic => "quadratic",
            TauKind::Opt => "opt",
        })
    }
}

/// Build the increasing sub-sequence τ ⊂ [1, T] of length S.
/// `tau_i = floor(c·i)` (linear) or `floor(c·i²)` (quadratic), i = 1..S,
/// with c chosen so τ_S lands near T, then clamped into [1, T] and
/// deduplicated upward to stay strictly increasing for small S/T corners.
pub fn tau_subsequence(kind: TauKind, s: usize, t_max: usize) -> Result<Vec<usize>> {
    if kind == TauKind::Opt {
        return Err(Error::Schedule(
            "tau kind 'opt' has no closed form; resolve it from the \
             artifact bundle's optimized schedules"
                .into(),
        ));
    }
    if s == 0 || s > t_max {
        return Err(Error::Schedule(format!("dim(tau)={s} out of range for T={t_max}")));
    }
    let mut tau = Vec::with_capacity(s);
    for i in 1..=s {
        let v = match kind {
            TauKind::Linear => (t_max as f64 / s as f64) * i as f64,
            TauKind::Quadratic => (t_max as f64 / (s * s) as f64) * (i * i) as f64,
            TauKind::Opt => unreachable!("rejected above"),
        };
        tau.push((v.floor() as usize).clamp(1, t_max));
    }
    // enforce strict monotonicity (quadratic floors can collide at tiny i)
    for i in 1..tau.len() {
        if tau[i] <= tau[i - 1] {
            tau[i] = tau[i - 1] + 1;
        }
    }
    if *tau.last().unwrap() > t_max {
        return Err(Error::Schedule(format!(
            "tau exceeded T after dedup: S={s} too dense for T={t_max}"
        )));
    }
    Ok(tau)
}

/// Number of slots in the [`tau_subsequence_cached`] memo table.
const TAU_MEMO_SLOTS: usize = 64;

/// [`tau_subsequence`] behind a small lock-free memo table. Every plan
/// build recomputes its τ grid; real serving traffic hits a handful of
/// (kind, S, T) triples over and over, so a fixed array of [`OnceLock`]
/// slots (keyed by FNV hash, verified by the full triple) removes the
/// recomputation without any locking on the hit path. Slot collisions
/// and errors simply fall through to the uncached function.
pub fn tau_subsequence_cached(kind: TauKind, s: usize, t_max: usize) -> Result<Vec<usize>> {
    use std::sync::OnceLock;
    type Entry = (TauKind, usize, usize, Vec<usize>);
    // rust 1.75: array-repeat of a `const` item (inline `const {}` blocks
    // in array repeats need 1.79)
    #[allow(clippy::declare_interior_mutable_const)]
    const INIT: OnceLock<Entry> = OnceLock::new();
    static MEMO: [OnceLock<Entry>; TAU_MEMO_SLOTS] = [INIT; TAU_MEMO_SLOTS];

    let tag: u64 = match kind {
        TauKind::Linear => 0,
        TauKind::Quadratic => 1,
        TauKind::Opt => return tau_subsequence(kind, s, t_max), // typed error
    };
    let slot = (crate::rng::Fnv64::new()
        .u64(tag)
        .u64(s as u64)
        .u64(t_max as u64)
        .finish()
        % TAU_MEMO_SLOTS as u64) as usize;
    if let Some((k, cs, ct, tau)) = MEMO[slot].get() {
        if *k == kind && *cs == s && *ct == t_max {
            return Ok(tau.clone());
        }
        return tau_subsequence(kind, s, t_max); // slot collision: recompute
    }
    let tau = tau_subsequence(kind, s, t_max)?; // only memoize successes
    let _ = MEMO[slot].set((kind, s, t_max, tau.clone()));
    Ok(tau)
}

/// Eq. (16): σ_{τ_i}(η) for one step τ_{i-1} -> τ_i boundary, where
/// `a_cur = ᾱ_{τ_i}`, `a_prev = ᾱ_{τ_{i-1}}` (τ_0 := 0 so ᾱ = 1).
pub fn sigma_eta(abar: &AlphaTable, t_cur: usize, t_prev: usize, eta: f64) -> f64 {
    let a_cur = abar.abar(t_cur);
    let a_prev = abar.abar(t_prev);
    eta * ((1.0 - a_prev) / (1.0 - a_cur)).sqrt() * (1.0 - a_cur / a_prev).sqrt()
}

/// App. D.3: the *larger* DDPM variance σ̂ = sqrt(1 - ᾱ_{τ_i}/ᾱ_{τ_{i-1}})
/// (the CIFAR10 setting of Ho et al.; the paper's Table-1 bottom row).
pub fn sigma_hat(abar: &AlphaTable, t_cur: usize, t_prev: usize) -> f64 {
    (1.0 - abar.abar(t_cur) / abar.abar(t_prev)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> AlphaTable {
        AlphaTable::linear(1000)
    }

    #[test]
    fn tau_is_strictly_increasing_in_range() {
        for kind in [TauKind::Linear, TauKind::Quadratic] {
            for s in [1, 2, 5, 10, 20, 50, 100, 500, 1000] {
                let tau = tau_subsequence(kind, s, 1000).unwrap();
                assert_eq!(tau.len(), s);
                assert!(*tau.first().unwrap() >= 1);
                assert!(*tau.last().unwrap() <= 1000);
                assert!(tau.windows(2).all(|w| w[1] > w[0]), "{kind:?} S={s}");
            }
        }
    }

    #[test]
    fn tau_last_lands_near_t() {
        for kind in [TauKind::Linear, TauKind::Quadratic] {
            for s in [10, 50, 100] {
                let tau = tau_subsequence(kind, s, 1000).unwrap();
                assert!(
                    *tau.last().unwrap() >= 990,
                    "{kind:?} S={s}: tau_S = {}",
                    tau.last().unwrap()
                );
            }
        }
    }

    #[test]
    fn tau_full_length_is_identity() {
        let tau = tau_subsequence(TauKind::Linear, 1000, 1000).unwrap();
        assert_eq!(tau, (1..=1000).collect::<Vec<_>>());
    }

    #[test]
    fn tau_rejects_invalid() {
        assert!(tau_subsequence(TauKind::Linear, 0, 1000).is_err());
        assert!(tau_subsequence(TauKind::Linear, 1001, 1000).is_err());
    }

    #[test]
    fn tau_kind_display_parse_round_trip() {
        for kind in [TauKind::Linear, TauKind::Quadratic, TauKind::Opt] {
            assert_eq!(TauKind::parse(&kind.to_string()).unwrap(), kind);
        }
        let err = TauKind::parse("cubic").unwrap_err().to_string();
        for valid in ["linear", "quadratic", "opt"] {
            assert!(err.contains(valid), "error should list '{valid}': {err}");
        }
    }

    #[test]
    fn opt_kind_has_no_closed_form() {
        let err = tau_subsequence(TauKind::Opt, 10, 1000).unwrap_err().to_string();
        assert!(err.contains("opt"), "{err}");
        assert!(tau_subsequence_cached(TauKind::Opt, 10, 1000).is_err());
    }

    #[test]
    fn cached_tau_matches_uncached() {
        for kind in [TauKind::Linear, TauKind::Quadratic] {
            for (s, t) in [(1, 7), (10, 400), (20, 400), (50, 1000), (999, 1000)] {
                assert_eq!(
                    tau_subsequence_cached(kind, s, t).unwrap(),
                    tau_subsequence(kind, s, t).unwrap(),
                    "{kind} S={s} T={t}"
                );
                // second call exercises the hit path
                assert_eq!(
                    tau_subsequence_cached(kind, s, t).unwrap(),
                    tau_subsequence(kind, s, t).unwrap()
                );
            }
        }
        assert!(tau_subsequence_cached(TauKind::Linear, 0, 400).is_err());
    }

    #[test]
    fn sigma_eta_zero_is_zero() {
        let t = table();
        for (cur, prev) in [(100, 50), (1000, 900), (10, 0)] {
            assert_eq!(sigma_eta(&t, cur, prev, 0.0), 0.0);
        }
    }

    #[test]
    fn sigma_eta_one_equals_ddpm_posterior_std() {
        // eta=1 must reproduce the DDPM posterior sqrt((1-ā_prev)/(1-ā_t) β̃)
        let t = table();
        for (cur, prev) in [(500usize, 499usize), (100, 99), (1000, 999)] {
            let s = sigma_eta(&t, cur, prev, 1.0);
            let beta_tilde = (1.0 - t.abar(prev)) / (1.0 - t.abar(cur))
                * (1.0 - t.abar(cur) / t.abar(prev));
            assert!((s * s - beta_tilde).abs() < 1e-12);
        }
    }

    #[test]
    fn sigma_hat_dominates_sigma_one() {
        let t = table();
        let tau = tau_subsequence(TauKind::Linear, 20, 1000).unwrap();
        let mut prev = 0;
        for &cur in &tau {
            let s1 = sigma_eta(&t, cur, prev, 1.0);
            let sh = sigma_hat(&t, cur, prev);
            assert!(sh >= s1 - 1e-12, "t={cur}: sigma_hat {sh} < sigma(1) {s1}");
            prev = cur;
        }
    }

    #[test]
    fn sigma_monotone_in_eta() {
        let t = table();
        let (cur, prev) = (400, 350);
        let mut last = -1.0;
        for eta in [0.0, 0.2, 0.5, 1.0] {
            let s = sigma_eta(&t, cur, prev, eta);
            assert!(s > last);
            last = s;
        }
    }
}
