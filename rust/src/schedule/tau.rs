//! The sampling trajectory τ (Sec. 4.2) and noise scales σ(η), σ̂
//! (Eq. 16, App. D.3).

use crate::error::{Error, Result};
use crate::schedule::AlphaTable;

/// τ selection procedure (App. D.2). The paper uses quadratic for CIFAR10
/// and linear elsewhere; our manifest picks per dataset the same way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TauKind {
    Linear,
    Quadratic,
}

impl TauKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "linear" => Ok(TauKind::Linear),
            "quadratic" => Ok(TauKind::Quadratic),
            _ => Err(Error::Schedule(format!("unknown tau kind '{s}'"))),
        }
    }
}

/// Build the increasing sub-sequence τ ⊂ [1, T] of length S.
/// `tau_i = floor(c·i)` (linear) or `floor(c·i²)` (quadratic), i = 1..S,
/// with c chosen so τ_S lands near T, then clamped into [1, T] and
/// deduplicated upward to stay strictly increasing for small S/T corners.
pub fn tau_subsequence(kind: TauKind, s: usize, t_max: usize) -> Result<Vec<usize>> {
    if s == 0 || s > t_max {
        return Err(Error::Schedule(format!("dim(tau)={s} out of range for T={t_max}")));
    }
    let mut tau = Vec::with_capacity(s);
    for i in 1..=s {
        let v = match kind {
            TauKind::Linear => (t_max as f64 / s as f64) * i as f64,
            TauKind::Quadratic => (t_max as f64 / (s * s) as f64) * (i * i) as f64,
        };
        tau.push((v.floor() as usize).clamp(1, t_max));
    }
    // enforce strict monotonicity (quadratic floors can collide at tiny i)
    for i in 1..tau.len() {
        if tau[i] <= tau[i - 1] {
            tau[i] = tau[i - 1] + 1;
        }
    }
    if *tau.last().unwrap() > t_max {
        return Err(Error::Schedule(format!(
            "tau exceeded T after dedup: S={s} too dense for T={t_max}"
        )));
    }
    Ok(tau)
}

/// Eq. (16): σ_{τ_i}(η) for one step τ_{i-1} -> τ_i boundary, where
/// `a_cur = ᾱ_{τ_i}`, `a_prev = ᾱ_{τ_{i-1}}` (τ_0 := 0 so ᾱ = 1).
pub fn sigma_eta(abar: &AlphaTable, t_cur: usize, t_prev: usize, eta: f64) -> f64 {
    let a_cur = abar.abar(t_cur);
    let a_prev = abar.abar(t_prev);
    eta * ((1.0 - a_prev) / (1.0 - a_cur)).sqrt() * (1.0 - a_cur / a_prev).sqrt()
}

/// App. D.3: the *larger* DDPM variance σ̂ = sqrt(1 - ᾱ_{τ_i}/ᾱ_{τ_{i-1}})
/// (the CIFAR10 setting of Ho et al.; the paper's Table-1 bottom row).
pub fn sigma_hat(abar: &AlphaTable, t_cur: usize, t_prev: usize) -> f64 {
    (1.0 - abar.abar(t_cur) / abar.abar(t_prev)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> AlphaTable {
        AlphaTable::linear(1000)
    }

    #[test]
    fn tau_is_strictly_increasing_in_range() {
        for kind in [TauKind::Linear, TauKind::Quadratic] {
            for s in [1, 2, 5, 10, 20, 50, 100, 500, 1000] {
                let tau = tau_subsequence(kind, s, 1000).unwrap();
                assert_eq!(tau.len(), s);
                assert!(*tau.first().unwrap() >= 1);
                assert!(*tau.last().unwrap() <= 1000);
                assert!(tau.windows(2).all(|w| w[1] > w[0]), "{kind:?} S={s}");
            }
        }
    }

    #[test]
    fn tau_last_lands_near_t() {
        for kind in [TauKind::Linear, TauKind::Quadratic] {
            for s in [10, 50, 100] {
                let tau = tau_subsequence(kind, s, 1000).unwrap();
                assert!(
                    *tau.last().unwrap() >= 990,
                    "{kind:?} S={s}: tau_S = {}",
                    tau.last().unwrap()
                );
            }
        }
    }

    #[test]
    fn tau_full_length_is_identity() {
        let tau = tau_subsequence(TauKind::Linear, 1000, 1000).unwrap();
        assert_eq!(tau, (1..=1000).collect::<Vec<_>>());
    }

    #[test]
    fn tau_rejects_invalid() {
        assert!(tau_subsequence(TauKind::Linear, 0, 1000).is_err());
        assert!(tau_subsequence(TauKind::Linear, 1001, 1000).is_err());
    }

    #[test]
    fn sigma_eta_zero_is_zero() {
        let t = table();
        for (cur, prev) in [(100, 50), (1000, 900), (10, 0)] {
            assert_eq!(sigma_eta(&t, cur, prev, 0.0), 0.0);
        }
    }

    #[test]
    fn sigma_eta_one_equals_ddpm_posterior_std() {
        // eta=1 must reproduce the DDPM posterior sqrt((1-ā_prev)/(1-ā_t) β̃)
        let t = table();
        for (cur, prev) in [(500usize, 499usize), (100, 99), (1000, 999)] {
            let s = sigma_eta(&t, cur, prev, 1.0);
            let beta_tilde = (1.0 - t.abar(prev)) / (1.0 - t.abar(cur))
                * (1.0 - t.abar(cur) / t.abar(prev));
            assert!((s * s - beta_tilde).abs() < 1e-12);
        }
    }

    #[test]
    fn sigma_hat_dominates_sigma_one() {
        let t = table();
        let tau = tau_subsequence(TauKind::Linear, 20, 1000).unwrap();
        let mut prev = 0;
        for &cur in &tau {
            let s1 = sigma_eta(&t, cur, prev, 1.0);
            let sh = sigma_hat(&t, cur, prev);
            assert!(sh >= s1 - 1e-12, "t={cur}: sigma_hat {sh} < sigma(1) {s1}");
            prev = cur;
        }
    }

    #[test]
    fn sigma_monotone_in_eta() {
        let t = table();
        let (cur, prev) = (400, 350);
        let mut last = -1.0;
        for eta in [0.0, 0.2, 0.5, 1.0] {
            let s = sigma_eta(&t, cur, prev, eta);
            assert!(s > last);
            last = s;
        }
    }
}
