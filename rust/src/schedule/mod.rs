//! Schedule substrate: everything the paper parameterises sampling with —
//! the cumulative-alpha table ᾱ (Sec. 2), the sub-sequence τ (Sec. 4.2 /
//! App. D.2), and the noise scale σ(η) / σ̂ (Eq. 16 / App. D.3).
//!
//! The table is computed natively (Ho et al. linear-β) *and* cross-checked
//! against the python-dumped `artifacts/alphas.json` at load time, so a
//! drifting constant can never silently skew an experiment.

mod alpha;
mod optimize;
mod plan;
mod tau;

pub use alpha::{AlphaTable, T_DEFAULT};
pub use optimize::{
    optimize_tau, optimizer_seed, schedule_path, schedule_rel_path, write_schedule,
    OptSchedule, OptSchedules, OptimizeReport, EVAL_LANES,
};
pub use plan::{Direction, NoiseMode, SamplePlan, StepParams};
pub use tau::{sigma_eta, sigma_hat, tau_subsequence, tau_subsequence_cached, TauKind};
