//! Table 2: per-dimension reconstruction error of the encode→decode round
//! trip, pixels scaled from [-1,1] to [0,1] to match the paper's convention
//! ("per-dimension mean squared error (scaled to [0,1])").

use crate::error::{Error, Result};

/// Mean over images of the per-dimension MSE between original and
/// reconstruction, after mapping both from [-1,1] to [0,1].
pub fn per_dim_mse(originals: &[Vec<f32>], recons: &[Vec<f32>]) -> Result<f64> {
    if originals.len() != recons.len() || originals.is_empty() {
        return Err(Error::Coordinator(format!(
            "per_dim_mse: {} originals vs {} recons",
            originals.len(),
            recons.len()
        )));
    }
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (o, r) in originals.iter().zip(recons) {
        if o.len() != r.len() {
            return Err(Error::Shape("per_dim_mse length mismatch".into()));
        }
        for (&a, &b) in o.iter().zip(r) {
            // [-1,1] -> [0,1]
            let d = ((a as f64 + 1.0) * 0.5) - ((b as f64 + 1.0) * 0.5);
            total += d * d;
        }
        count += o.len();
    }
    Ok(total / count as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_for_identical() {
        let a = vec![vec![0.5f32, -0.5, 1.0]];
        assert_eq!(per_dim_mse(&a, &a).unwrap(), 0.0);
    }

    #[test]
    fn known_value_with_scaling() {
        // diff of 1.0 in [-1,1] space = 0.5 in [0,1] space -> mse 0.25
        let a = vec![vec![1.0f32]];
        let b = vec![vec![0.0f32]];
        assert!((per_dim_mse(&a, &b).unwrap() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn rejects_mismatch() {
        let a = vec![vec![0.0f32]];
        let b: Vec<Vec<f32>> = vec![];
        assert!(per_dim_mse(&a, &b).is_err());
        let c = vec![vec![0.0f32, 1.0]];
        assert!(per_dim_mse(&a, &c).is_err());
    }
}
