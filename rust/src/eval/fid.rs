//! Proxy-FID: Fréchet distance between the reference gaussian (fitted by
//! the python build on 4096 true procedural images, shipped as tensorfiles)
//! and a gaussian fitted on generated samples. See DESIGN.md §2 for why
//! this preserves Table 1/3's phenomena.

use crate::artifacts::Manifest;
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::stats::{extract_features, frechet_distance, GaussianFit, FEAT_DIM};

/// Load a dataset's reference feature statistics from the artifact tree.
pub fn load_ref_stats(manifest: &Manifest, dataset: &str) -> Result<GaussianFit> {
    let ds = manifest.dataset(dataset)?;
    let (mu_path, cov_path) = manifest.ref_stats_paths(dataset);
    let (mu_shape, mu) = crate::artifacts::read_tensor_f64(&mu_path)?;
    let (cov_shape, cov) = crate::artifacts::read_tensor_f64(&cov_path)?;
    if mu_shape != vec![FEAT_DIM] || cov_shape != vec![FEAT_DIM, FEAT_DIM] {
        return Err(Error::Artifact(format!(
            "ref stats shapes {mu_shape:?} / {cov_shape:?} (want [{FEAT_DIM}], [{FEAT_DIM},{FEAT_DIM}])"
        )));
    }
    GaussianFit::from_moments(mu, Mat::from_vec(FEAT_DIM, FEAT_DIM, cov)?, ds.ref_n)
}

/// Proxy-FID of a set of generated images against the reference fit.
pub fn fid_of_images(images: &[Vec<f32>], reference: &GaussianFit) -> Result<f64> {
    if images.len() < 2 {
        return Err(Error::Coordinator(format!(
            "FID needs >= 2 images, got {}",
            images.len()
        )));
    }
    let mut fit = GaussianFit::new();
    for img in images {
        fit.push(&extract_features(img));
    }
    frechet_distance(&fit, reference)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{GaussianSource, Pcg64};

    /// Synthetic "dataset": smooth blobs; FID should separate matched from
    /// mismatched distributions even without real artifacts on disk.
    fn blobby(seed: u64, n: usize) -> Vec<Vec<f32>> {
        let mut rng = Pcg64::seeded(seed);
        (0..n)
            .map(|_| {
                let cx = rng.uniform(0.3, 0.7);
                let cy = rng.uniform(0.3, 0.7);
                let s = rng.uniform(0.05, 0.15);
                (0..256)
                    .map(|i| {
                        let x = (i % 16) as f64 / 16.0;
                        let y = (i / 16) as f64 / 16.0;
                        let d = ((x - cx).powi(2) + (y - cy).powi(2)) / (2.0 * s * s);
                        ((-d).exp() * 2.0 - 1.0) as f32
                    })
                    .collect()
            })
            .collect()
    }

    fn noise_images(seed: u64, n: usize) -> Vec<Vec<f32>> {
        let mut g = GaussianSource::seeded(seed);
        (0..n).map(|_| (0..256).map(|_| 0.5 * g.next() as f32).collect()).collect()
    }

    fn fit_of(images: &[Vec<f32>]) -> GaussianFit {
        let mut fit = GaussianFit::new();
        for img in images {
            fit.push(&extract_features(img));
        }
        fit
    }

    #[test]
    fn matched_distribution_scores_low_mismatched_high() {
        let reference = fit_of(&blobby(1, 400));
        let same = fid_of_images(&blobby(2, 200), &reference).unwrap();
        let diff = fid_of_images(&noise_images(3, 200), &reference).unwrap();
        assert!(same < diff * 0.05, "same {same} vs diff {diff}");
    }

    #[test]
    fn noisier_samples_score_worse_monotonically() {
        // mimics the sigma-hat failure mode: blobs + increasing additive noise
        let reference = fit_of(&blobby(1, 400));
        let mut last = -1.0;
        for (i, amp) in [0.0f32, 0.1, 0.3, 0.6].iter().enumerate() {
            let mut imgs = blobby(50 + i as u64, 200);
            let mut g = GaussianSource::seeded(99 + i as u64);
            for img in &mut imgs {
                for v in img.iter_mut() {
                    *v += amp * g.next() as f32;
                }
            }
            let fid = fid_of_images(&imgs, &reference).unwrap();
            assert!(fid > last, "amp {amp}: {fid} <= {last}");
            last = fid;
        }
    }

    #[test]
    fn needs_two_images() {
        let reference = fit_of(&blobby(1, 50));
        assert!(fid_of_images(&blobby(2, 1), &reference).is_err());
    }
}
