//! Fig. 5: sample *consistency* — with the same x_T, DDIM trajectories of
//! different lengths land on images sharing high-level features, while DDPM
//! trajectories diverge. Quantified as the ratio between same-x_T feature
//! distance and different-x_T feature distance (lower = more consistent;
//! 1.0 = x_T carries no information).

use crate::stats::{extract_features, FEAT_DIM};

/// Euclidean distance in proxy-feature space ("high-level features" proxy).
pub fn feature_distance(a: &[f32], b: &[f32]) -> f64 {
    let fa = extract_features(a);
    let fb = extract_features(b);
    fa.iter()
        .zip(&fb)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// Mean feature distance over pairs `(a[i], b[i])`.
fn mean_pair_distance(a: &[Vec<f32>], b: &[Vec<f32>]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    let s: f64 = a.iter().zip(b).map(|(x, y)| feature_distance(x, y)).sum();
    s / a.len() as f64
}

/// Consistency score: distance between matched same-x_T samples divided by
/// the mean distance between mismatched (shuffled) pairs. `short[i]` and
/// `long[i]` must come from the same x_T.
pub fn consistency_score(short: &[Vec<f32>], long: &[Vec<f32>]) -> (f64, f64, f64) {
    let same = mean_pair_distance(short, long);
    // mismatched baseline: rotate `long` by one
    let n = long.len();
    let rotated: Vec<Vec<f32>> = (0..n).map(|i| long[(i + 1) % n].clone()).collect();
    let cross = mean_pair_distance(short, &rotated);
    let _ = FEAT_DIM;
    (same, cross, same / cross.max(1e-12))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::GaussianSource;

    fn imgs(seed: u64, n: usize) -> Vec<Vec<f32>> {
        let mut g = GaussianSource::seeded(seed);
        (0..n).map(|_| (0..256).map(|_| g.next() as f32 * 0.5).collect()).collect()
    }

    #[test]
    fn identical_sets_score_zero_ratio() {
        let a = imgs(1, 8);
        let (same, cross, ratio) = consistency_score(&a, &a);
        assert_eq!(same, 0.0);
        assert!(cross > 0.0);
        assert_eq!(ratio, 0.0);
    }

    #[test]
    fn perturbed_pairs_score_below_one() {
        let a = imgs(2, 16);
        let mut g = GaussianSource::seeded(3);
        let b: Vec<Vec<f32>> = a
            .iter()
            .map(|img| img.iter().map(|&v| v + 0.05 * g.next() as f32).collect())
            .collect();
        let (_, _, ratio) = consistency_score(&a, &b);
        assert!(ratio < 0.5, "ratio {ratio}");
    }

    #[test]
    fn unrelated_pairs_score_near_one() {
        let a = imgs(4, 16);
        let b = imgs(5, 16);
        let (_, _, ratio) = consistency_score(&a, &b);
        assert!(ratio > 0.7, "ratio {ratio}");
    }

    #[test]
    fn feature_distance_symmetry() {
        let a = imgs(6, 2);
        assert!((feature_distance(&a[0], &a[1]) - feature_distance(&a[1], &a[0])).abs() < 1e-12);
        assert_eq!(feature_distance(&a[0], &a[0]), 0.0);
    }
}
