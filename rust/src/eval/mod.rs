//! Evaluation pipeline: the paper's metrics over generated samples.
//!
//! - [`fid`]: proxy-FID against the python-dumped reference statistics
//!   (Tables 1 and 3)
//! - [`recon`]: per-dimension reconstruction MSE (Table 2)
//! - [`consistency`]: same-x_T feature similarity across trajectory lengths
//!   (Fig. 5) and cross-x_T baselines
//! - [`interp`]: interpolation path smoothness (Fig. 6)

pub mod consistency;
pub mod fid;
pub mod interp;
pub mod recon;

pub use consistency::{consistency_score, feature_distance};
pub use fid::{fid_of_images, load_ref_stats};
pub use interp::path_smoothness;
pub use recon::per_dim_mse;
