//! Fig. 6: latent interpolation quality. The paper shows slerp in x_T gives
//! *semantically smooth* morphs under DDIM. We quantify smoothness of a
//! decoded path x_0(α_0), ..., x_0(α_k) as the max adjacent feature jump
//! normalised by the endpoint distance — 1/k for a perfectly even morph,
//! ≈1 for an abrupt jump, and ill-behaved (>1) for a non-monotone path.

use crate::eval::consistency::feature_distance;

/// (max adjacent jump / endpoint distance, mean adjacent jump / endpoint).
pub fn path_smoothness(path: &[Vec<f32>]) -> (f64, f64) {
    assert!(path.len() >= 2, "path needs at least 2 points");
    let endpoint = feature_distance(&path[0], &path[path.len() - 1]).max(1e-9);
    let jumps: Vec<f64> = path
        .windows(2)
        .map(|w| feature_distance(&w[0], &w[1]))
        .collect();
    let max = jumps.iter().cloned().fold(0.0, f64::max);
    let mean = jumps.iter().sum::<f64>() / jumps.len() as f64;
    (max / endpoint, mean / endpoint)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn constant_img(v: f32) -> Vec<f32> {
        vec![v; 256]
    }

    #[test]
    fn linear_path_is_even() {
        let path: Vec<Vec<f32>> =
            (0..=10).map(|i| constant_img(i as f32 / 10.0)).collect();
        let (max, mean) = path_smoothness(&path);
        assert!((max - 0.1).abs() < 1e-6, "max {max}");
        assert!((mean - 0.1).abs() < 1e-6, "mean {mean}");
    }

    #[test]
    fn abrupt_jump_detected() {
        let mut path: Vec<Vec<f32>> = (0..=10).map(|_| constant_img(0.0)).collect();
        path[10] = constant_img(1.0); // all change in the last hop
        let (max, _) = path_smoothness(&path);
        assert!((max - 1.0).abs() < 1e-6, "max {max}");
    }

    #[test]
    #[should_panic]
    fn short_path_panics() {
        path_smoothness(&[constant_img(0.0)]);
    }
}
