//! Row-major f32 n-d array. The request path only ever needs contiguous
//! buffers with shape bookkeeping (PJRT literals are flat); anything fancier
//! (views, strides, broadcasting) would be dead weight.

use crate::error::{Error, Result};

/// A contiguous row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Construct from shape + data; checks the element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {shape:?} wants {n} elements, got {}",
                data.len()
            )));
        }
        Ok(Self { shape, data })
    }

    /// All-zeros tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    /// Fill with a constant.
    pub fn full(shape: Vec<usize>, v: f32) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![v; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {:?} ({} elems) to {shape:?}",
                self.shape,
                self.data.len()
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Row `i` of a 2-d (or higher: leading-axis slice) tensor.
    pub fn slice_outer(&self, i: usize) -> Result<&[f32]> {
        let outer = *self
            .shape
            .first()
            .ok_or_else(|| Error::Shape("slice_outer on rank-0 tensor".into()))?;
        if i >= outer {
            return Err(Error::Shape(format!("index {i} out of bounds for axis 0 ({outer})")));
        }
        let stride = self.data.len() / outer;
        Ok(&self.data[i * stride..(i + 1) * stride])
    }

    /// Mutable leading-axis slice.
    pub fn slice_outer_mut(&mut self, i: usize) -> Result<&mut [f32]> {
        let outer = *self
            .shape
            .first()
            .ok_or_else(|| Error::Shape("slice_outer on rank-0 tensor".into()))?;
        if i >= outer {
            return Err(Error::Shape(format!("index {i} out of bounds for axis 0 ({outer})")));
        }
        let stride = self.data.len() / outer;
        Ok(&mut self.data[i * stride..(i + 1) * stride])
    }

    /// Stack equal-shape tensors along a new leading axis.
    pub fn stack(items: &[&Tensor]) -> Result<Tensor> {
        let first = items
            .first()
            .ok_or_else(|| Error::Shape("stack of zero tensors".into()))?;
        let mut data = Vec::with_capacity(first.len() * items.len());
        for t in items {
            if t.shape != first.shape {
                return Err(Error::Shape(format!(
                    "stack shape mismatch: {:?} vs {:?}",
                    t.shape, first.shape
                )));
            }
            data.extend_from_slice(&t.data);
        }
        let mut shape = vec![items.len()];
        shape.extend_from_slice(&first.shape);
        Ok(Tensor { shape, data })
    }

    /// Mean squared difference against another tensor of the same shape —
    /// the paper's Table-2 per-dimension reconstruction error.
    pub fn mse(&self, other: &Tensor) -> Result<f64> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "mse shape mismatch: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        let s: f64 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum();
        Ok(s / self.data.len() as f64)
    }

    /// Max absolute difference (golden-test comparator).
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "diff shape mismatch: {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn reshape_and_slice() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect()).unwrap();
        assert_eq!(t.slice_outer(1).unwrap(), &[3.0, 4.0, 5.0]);
        assert!(t.slice_outer(2).is_err());
        let r = t.clone().reshape(vec![3, 2]).unwrap();
        assert_eq!(r.slice_outer(2).unwrap(), &[4.0, 5.0]);
        assert!(t.reshape(vec![4, 2]).is_err());
    }

    #[test]
    fn stack_and_mse() {
        let a = Tensor::full(vec![4], 1.0);
        let b = Tensor::full(vec![4], 3.0);
        let s = Tensor::stack(&[&a, &b]).unwrap();
        assert_eq!(s.shape(), &[2, 4]);
        assert_eq!(a.mse(&b).unwrap(), 4.0);
        assert_eq!(a.max_abs_diff(&b).unwrap(), 2.0);
        let c = Tensor::full(vec![5], 0.0);
        assert!(Tensor::stack(&[&a, &c]).is_err());
        assert!(a.mse(&c).is_err());
    }

    #[test]
    fn slice_outer_mut_writes_through() {
        let mut t = Tensor::zeros(vec![2, 2]);
        t.slice_outer_mut(1).unwrap().copy_from_slice(&[7.0, 8.0]);
        assert_eq!(t.data(), &[0.0, 0.0, 7.0, 8.0]);
    }
}
