//! Image output for the paper's qualitative figures (Figs. 3, 5, 6):
//! map [-1, 1] samples to 8-bit grayscale, tile them into grids, and write
//! binary PGM (P5) — viewable everywhere, zero codec dependencies.

use std::fs::File;
use std::io::Write;
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Map a [-1, 1] image to u8 grayscale with clamping.
pub fn to_u8_gray(img: &[f32]) -> Vec<u8> {
    img.iter()
        .map(|&v| {
            let x = (v.clamp(-1.0, 1.0) + 1.0) * 0.5 * 255.0;
            x.round() as u8
        })
        .collect()
}

/// Tile `n = rows*cols` images of `[1, h, w]` (flattened) into one
/// `[rows*h + (rows-1)*pad, cols*w + (cols-1)*pad]` canvas with a mid-gray
/// separator, matching the paper's sample-grid figures.
pub fn tile_grid(images: &[&[f32]], rows: usize, cols: usize, h: usize, w: usize) -> Result<Tensor> {
    if images.len() != rows * cols {
        return Err(Error::Shape(format!(
            "tile_grid wants {} images, got {}",
            rows * cols,
            images.len()
        )));
    }
    for (i, im) in images.iter().enumerate() {
        if im.len() != h * w {
            return Err(Error::Shape(format!(
                "image {i} has {} pixels, expected {}",
                im.len(),
                h * w
            )));
        }
    }
    let pad = 1usize;
    let gh = rows * h + (rows - 1) * pad;
    let gw = cols * w + (cols - 1) * pad;
    let mut canvas = Tensor::full(vec![gh, gw], 0.0);
    for r in 0..rows {
        for c in 0..cols {
            let img = images[r * cols + c];
            let oy = r * (h + pad);
            let ox = c * (w + pad);
            for y in 0..h {
                let dst = &mut canvas.data_mut()[(oy + y) * gw + ox..(oy + y) * gw + ox + w];
                dst.copy_from_slice(&img[y * w..(y + 1) * w]);
            }
        }
    }
    Ok(canvas)
}

/// Write a 2-d tensor in [-1, 1] as a binary PGM file.
pub fn save_pgm(path: impl AsRef<Path>, img: &Tensor) -> Result<()> {
    let shape = img.shape();
    if shape.len() != 2 {
        return Err(Error::Shape(format!("save_pgm wants rank-2, got {shape:?}")));
    }
    let (h, w) = (shape[0], shape[1]);
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = File::create(path)?;
    write!(f, "P5\n{w} {h}\n255\n")?;
    f.write_all(&to_u8_gray(img.data()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u8_mapping_endpoints() {
        assert_eq!(to_u8_gray(&[-1.0, 0.0, 1.0, -5.0, 5.0]), vec![0, 128, 255, 0, 255]);
    }

    #[test]
    fn grid_layout() {
        let a = vec![1.0f32; 4]; // 2x2 white
        let b = vec![-1.0f32; 4]; // 2x2 black
        let g = tile_grid(&[&a, &b, &b, &a], 2, 2, 2, 2).unwrap();
        assert_eq!(g.shape(), &[5, 5]);
        // top-left block is white, top-right black
        assert_eq!(g.data()[0], 1.0);
        assert_eq!(g.data()[3], -1.0);
        // separator column is 0
        assert_eq!(g.data()[2], 0.0);
    }

    #[test]
    fn grid_validates() {
        let a = vec![0.0f32; 4];
        assert!(tile_grid(&[&a], 2, 2, 2, 2).is_err());
        let bad = vec![0.0f32; 3];
        assert!(tile_grid(&[&a, &bad, &a, &a], 2, 2, 2, 2).is_err());
    }

    #[test]
    fn pgm_round_trip_header() {
        let img = Tensor::zeros(vec![3, 4]);
        let dir = std::env::temp_dir().join("ddim_serve_test_pgm");
        let path = dir.join("t.pgm");
        save_pgm(&path, &img).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P5\n4 3\n255\n"));
        assert_eq!(bytes.len(), b"P5\n4 3\n255\n".len() + 12);
        std::fs::remove_dir_all(dir).ok();
    }
}
