//! Tensor substrate: a deliberately small row-major `f32` n-d array used on
//! the request path (sample buffers, literal marshalling) plus image
//! utilities (grids, PGM/PPM writers) for the paper's qualitative figures.

mod image;
mod ndarray;

pub use image::{save_pgm, tile_grid, to_u8_gray};
pub use ndarray::Tensor;
