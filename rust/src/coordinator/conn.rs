//! Per-connection state machine for the event-loop transport: line
//! framing over byte streams, bounded read buffering, and a shared write
//! buffer that interleaves streamed frames with final responses without
//! ever corrupting framing.
//!
//! Pure by construction — no sockets, no syscalls, no clocks. The reactor
//! ([`super::reactor`]) feeds raw bytes in via [`ConnState::ingest`] and
//! drains [`ConnState::pending_write`] when the socket is writable; every
//! framing rule is unit- and property-testable right here.
//!
//! Two safety rules the wire depends on:
//! - **Bounded lines.** A request line longer than `max_line` switches the
//!   reader into discard mode: one [`ConnEvent::Overlong`] is emitted (the
//!   transport answers it with a typed `"line too long"` error), bytes are
//!   thrown away until the next newline, and the connection then resyncs —
//!   one hostile client can cost at most `max_line` bytes of buffer, never
//!   unbounded memory.
//! - **Atomic lines out.** Writers only append *whole* `\n`-terminated
//!   lines; the reactor consumes any prefix. A streamed preview frame is
//!   droppable under backpressure ([`ConnState::queue_frame`] past the
//!   soft cap), but a final response ([`ConnState::queue_line`]) is always
//!   queued — slow clients lose previews, never answers.

/// Default bound on one request line (bytes, newline excluded).
pub const MAX_LINE_BYTES: usize = 1 << 20;

/// Default soft cap on the per-connection write buffer: beyond this,
/// best-effort frames are dropped (final responses still append).
pub const WRITE_SOFT_CAP: usize = 4 << 20;

/// What [`ConnState::ingest`] extracted from a chunk of bytes.
#[derive(Debug, PartialEq, Eq)]
pub enum ConnEvent {
    /// One complete line (newline stripped, trailing `\r` trimmed).
    Line(String),
    /// The current line exceeded `max_line`; its bytes are being
    /// discarded until the next newline. Emitted exactly once per
    /// overlong line, at the moment the bound is crossed.
    Overlong,
}

/// Framing + buffering state for one connection.
pub struct ConnState {
    /// Partial line accumulated across reads.
    rbuf: Vec<u8>,
    /// Inside an overlong line, discarding until `\n`.
    discarding: bool,
    /// Outgoing bytes; `wpos..` is the unsent suffix.
    wbuf: Vec<u8>,
    wpos: usize,
    max_line: usize,
    soft_cap: usize,
    /// Frames dropped because the write buffer was over the soft cap.
    pub frames_dropped: u64,
    /// Overlong lines rejected.
    pub lines_overlong: u64,
    /// Close the connection once the write buffer drains (one-shot HTTP
    /// responses like `GET /metrics`: queue the body, then hang up).
    close_after_flush: bool,
}

impl ConnState {
    pub fn new(max_line: usize, soft_cap: usize) -> ConnState {
        ConnState {
            rbuf: Vec::new(),
            discarding: false,
            wbuf: Vec::new(),
            wpos: 0,
            max_line,
            soft_cap,
            frames_dropped: 0,
            lines_overlong: 0,
            close_after_flush: false,
        }
    }

    /// Arm the close-on-drain latch: the reactor closes this connection
    /// as soon as [`ConnState::wants_write`] goes false. Sticky — there
    /// is no disarm; anything queued before the drain still goes out.
    pub fn mark_close_after_flush(&mut self) {
        self.close_after_flush = true;
    }

    /// Whether the connection should be closed now that (or once) the
    /// write buffer has drained.
    pub fn close_after_flush(&self) -> bool {
        self.close_after_flush
    }

    /// Feed raw bytes from the socket; extracted events append to `out`.
    /// Handles arbitrary fragmentation: a line may arrive one byte per
    /// call (slow loris) or many lines per call — the events are the same.
    pub fn ingest(&mut self, mut data: &[u8], out: &mut Vec<ConnEvent>) {
        while !data.is_empty() {
            match data.iter().position(|&b| b == b'\n') {
                Some(nl) => {
                    let (head, rest) = (&data[..nl], &data[nl + 1..]);
                    if self.discarding {
                        // overlong line ends here; resync on the next one
                        self.discarding = false;
                        self.rbuf.clear();
                    } else if self.rbuf.len() + head.len() > self.max_line {
                        self.lines_overlong += 1;
                        self.rbuf.clear();
                        out.push(ConnEvent::Overlong);
                    } else {
                        self.rbuf.extend_from_slice(head);
                        let mut line = std::mem::take(&mut self.rbuf);
                        if line.last() == Some(&b'\r') {
                            line.pop();
                        }
                        // the wire is JSON (ASCII in practice); junk bytes
                        // become replacement chars and fail JSON parsing
                        // upstream with a normal parse error
                        out.push(ConnEvent::Line(
                            String::from_utf8_lossy(&line).into_owned(),
                        ));
                    }
                    data = rest;
                }
                None => {
                    if !self.discarding {
                        if self.rbuf.len() + data.len() > self.max_line {
                            // crossing the bound mid-line: reject now and
                            // discard until the newline eventually arrives
                            self.lines_overlong += 1;
                            self.discarding = true;
                            self.rbuf.clear();
                            out.push(ConnEvent::Overlong);
                        } else {
                            self.rbuf.extend_from_slice(data);
                        }
                    }
                    return;
                }
            }
        }
    }

    /// Append one final-response line (newline added). Always queued —
    /// a response may not be dropped, whatever the buffer looks like.
    pub fn queue_line(&mut self, line: &str) {
        self.compact();
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
    }

    /// Append one best-effort frame line. Returns `false` (and counts the
    /// drop) when appending would take the unsent backlog past the soft
    /// cap — the *projected* size is checked, not the current one, so a
    /// frame can never itself push the buffer over the bound. (The old
    /// post-hoc check admitted any frame while backlog ≤ cap, letting one
    /// large preview overshoot by a full frame; a slow client still loses
    /// previews, never answers, and frames now keep the backlog ≤
    /// `soft_cap` exactly.)
    pub fn queue_frame(&mut self, line: &str) -> bool {
        if self.write_backlog() + line.len() + 1 > self.soft_cap {
            self.frames_dropped += 1;
            return false;
        }
        self.queue_line(line);
        true
    }

    /// Unsent outgoing bytes.
    pub fn pending_write(&self) -> &[u8] {
        &self.wbuf[self.wpos..]
    }

    /// Record `n` bytes as written to the socket.
    pub fn consume_written(&mut self, n: usize) {
        self.wpos += n;
        debug_assert!(self.wpos <= self.wbuf.len());
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        }
    }

    /// Anything left to write?
    pub fn wants_write(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    /// Unsent byte count (the backpressure signal).
    pub fn write_backlog(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Over the soft cap? The reactor pauses *reading* from such a
    /// connection, so a client that won't drain its socket stops being
    /// able to submit more work (read-side backpressure).
    pub fn over_cap(&self) -> bool {
        self.write_backlog() > self.soft_cap
    }

    /// Reclaim the written prefix once it dominates the buffer, so a
    /// long-lived connection's write buffer doesn't grow monotonically.
    fn compact(&mut self) {
        if self.wpos >= 4096 && self.wpos * 2 >= self.wbuf.len() {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines(st: &mut ConnState, data: &[u8]) -> Vec<ConnEvent> {
        let mut out = Vec::new();
        st.ingest(data, &mut out);
        out
    }

    #[test]
    fn whole_and_split_lines_frame_identically() {
        let mut a = ConnState::new(64, 1024);
        let got = lines(&mut a, b"{\"op\":\"ping\"}\n{\"x\":1}\n");
        assert_eq!(
            got,
            vec![
                ConnEvent::Line("{\"op\":\"ping\"}".into()),
                ConnEvent::Line("{\"x\":1}".into())
            ]
        );
        // the same bytes one at a time (slow loris) — identical events
        let mut b = ConnState::new(64, 1024);
        let mut got = Vec::new();
        for byte in b"{\"op\":\"ping\"}\n{\"x\":1}\n" {
            b.ingest(&[*byte], &mut got);
        }
        assert_eq!(
            got,
            vec![
                ConnEvent::Line("{\"op\":\"ping\"}".into()),
                ConnEvent::Line("{\"x\":1}".into())
            ]
        );
    }

    #[test]
    fn crlf_is_trimmed_and_partial_tail_is_held() {
        let mut st = ConnState::new(64, 1024);
        assert_eq!(lines(&mut st, b"abc\r\nde"), vec![ConnEvent::Line("abc".into())]);
        // the partial "de" waits for its newline
        assert_eq!(lines(&mut st, b"f\n"), vec![ConnEvent::Line("def".into())]);
    }

    #[test]
    fn overlong_line_is_rejected_once_and_resyncs() {
        let mut st = ConnState::new(8, 1024);
        // 20 bytes dribbled in: one Overlong at the crossing, then silence
        let mut out = Vec::new();
        for _ in 0..20 {
            st.ingest(b"xx", &mut out);
        }
        assert_eq!(out, vec![ConnEvent::Overlong]);
        assert_eq!(st.lines_overlong, 1);
        // buffer stays bounded while discarding
        assert!(st.rbuf.is_empty());
        // the newline ends the poison line; the next line parses fine
        out.clear();
        st.ingest(b"yyy\nok\n", &mut out);
        assert_eq!(out, vec![ConnEvent::Line("ok".into())]);
    }

    #[test]
    fn overlong_detected_at_newline_too() {
        // a line that fits per-chunk but crosses the bound exactly when
        // its newline arrives in the same chunk
        let mut st = ConnState::new(4, 1024);
        let got = lines(&mut st, b"abcdefgh\nok\n");
        assert_eq!(got, vec![ConnEvent::Overlong, ConnEvent::Line("ok".into())]);
    }

    #[test]
    fn frames_drop_past_soft_cap_but_lines_never_do() {
        let mut st = ConnState::new(64, 16);
        st.queue_line("final-1");
        assert!(st.queue_frame("frame-1"), "under cap: accepted");
        // 8 + 8 bytes queued > 16-byte cap: next frame drops
        assert!(!st.queue_frame("frame-2"));
        assert_eq!(st.frames_dropped, 1);
        // a final response still appends
        st.queue_line("final-2");
        let s = String::from_utf8(st.pending_write().to_vec()).unwrap();
        assert_eq!(s, "final-1\nframe-1\nfinal-2\n");
    }

    #[test]
    fn frame_admission_is_projected_not_post_hoc() {
        // cap 16: a frame is admitted iff backlog + frame + '\n' fits
        let mut st = ConnState::new(64, 16);
        assert!(st.queue_frame("0123456789abcde"), "15+1 == 16: exactly fills the cap");
        assert_eq!(st.write_backlog(), 16);
        // old behavior would admit this (backlog == cap, not > cap) and
        // overshoot to 32 bytes; projected-size admission refuses it
        assert!(!st.queue_frame("0123456789abcde"));
        assert_eq!((st.frames_dropped, st.write_backlog()), (1, 16));
        // one frame can never overshoot an empty buffer either
        let mut st = ConnState::new(64, 8);
        assert!(!st.queue_frame("123456789"), "9+1 > 8 even when empty");
        assert_eq!(st.write_backlog(), 0);
        // draining restores admission
        let mut st = ConnState::new(64, 16);
        st.queue_line("0123456789abcde");
        assert!(!st.queue_frame("x"));
        st.consume_written(16);
        assert!(st.queue_frame("x"));
    }

    #[test]
    fn partial_writes_consume_and_compact() {
        let mut st = ConnState::new(64, 1 << 20);
        st.queue_line("hello");
        st.queue_line("world");
        assert_eq!(st.pending_write(), b"hello\nworld\n");
        st.consume_written(7);
        assert_eq!(st.pending_write(), b"orld\n");
        assert!(st.wants_write());
        st.consume_written(5);
        assert!(!st.wants_write());
        assert_eq!(st.write_backlog(), 0);
    }

    #[test]
    fn close_after_flush_is_sticky_and_off_by_default() {
        let mut st = ConnState::new(64, 1024);
        assert!(!st.close_after_flush());
        st.queue_line("HTTP/1.0 200 OK");
        st.mark_close_after_flush();
        assert!(st.close_after_flush());
        // queued bytes still drain normally; the latch survives the drain
        let n = st.pending_write().len();
        st.consume_written(n);
        assert!(!st.wants_write());
        assert!(st.close_after_flush());
    }

    #[test]
    fn property_interleaved_frames_never_corrupt_framing() {
        // Shared-buffer property: any interleaving of queue_line /
        // queue_frame, drained in arbitrary chunk sizes and re-ingested
        // by a fresh reader, yields (a) intact whole lines only, (b) every
        // final line in order, (c) frames a subsequence of what was
        // accepted.
        crate::testing::check("conn_shared_buffer_framing", 200, |g| {
            let mut st = ConnState::new(1 << 16, g.int_in(8, 256));
            let mut wire = Vec::new();
            let mut sent_finals = Vec::new();
            let mut sent_frames = Vec::new();
            let n = g.int_in(1, 40);
            for i in 0..n {
                if g.int_in(0, 1) == 0 {
                    let line = format!("{{\"id\":{i},\"ok\":true}}");
                    st.queue_line(&line);
                    sent_finals.push(line);
                } else {
                    let line = format!("{{\"id\":{i},\"frame\":\"x0_preview\"}}");
                    if st.queue_frame(&line) {
                        sent_frames.push(line);
                    }
                }
                let take = g.int_in(0, st.write_backlog());
                wire.extend_from_slice(&st.pending_write()[..take]);
                st.consume_written(take);
            }
            while st.wants_write() {
                let take = g.int_in(1, st.write_backlog());
                wire.extend_from_slice(&st.pending_write()[..take]);
                st.consume_written(take);
            }
            // a reader on the other end sees only whole, uncorrupted lines
            let mut reader = ConnState::new(1 << 16, 0);
            let mut events = Vec::new();
            reader.ingest(&wire, &mut events);
            let mut got_finals = Vec::new();
            let mut got_frames = Vec::new();
            for e in events {
                match e {
                    ConnEvent::Line(l) if l.contains("frame") => got_frames.push(l),
                    ConnEvent::Line(l) => got_finals.push(l),
                    ConnEvent::Overlong => return Err("reader saw overlong".into()),
                }
            }
            if got_finals != sent_finals {
                return Err(format!(
                    "finals corrupted: sent {sent_finals:?}, got {got_finals:?}"
                ));
            }
            if got_frames != sent_frames {
                return Err(format!(
                    "frames corrupted: accepted {sent_frames:?}, got {got_frames:?}"
                ));
            }
            Ok(())
        });
    }
}
