//! A single engine shard: one worker thread owning its own [`Engine`]
//! (and therefore its own `Runtime` — PJRT is single-threaded by
//! construction, so nothing is shared) plus the tick loop that used to
//! live inside the server. The router (see [`super::router`]) owns N of
//! these and dispatches by dataset + load; shards never talk to each
//! other.
//!
//! Lifecycle: [`EngineShard::spawn`] blocks until the engine is built (so
//! unknown-dataset and artifact errors surface synchronously, exactly as
//! the old inline bring-up did), then the worker loops: drain commands,
//! tick, deliver completions, publish load. On stop it *drains* — keeps
//! ticking until idle or `drain_timeout` — then answers every remaining
//! waiter with `Error { message: "shutting down" }` so no connection
//! thread is ever left blocked on its response channel.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::DoneFn;
use crate::config::ServeConfig;
use crate::coordinator::engine::{Engine, ProgressSink};
use crate::coordinator::metrics::{Histogram, MetricsSnapshot};
use crate::coordinator::request::{
    Reject, RejectReason, Request, RequestId, Response, ResponseBody,
};
use crate::error::{Error, Result};

/// Commands a shard worker understands. A submit carries its completion
/// callback ([`DoneFn`]) — for plain requests it just sends on the
/// waiter's channel; for cache-fronted requests it publishes the result
/// to the sample cache and fans it out to every coalesced waiter, right
/// here on the worker thread where the engine completed it.
enum ShardCmd {
    Submit(Request, DoneFn, Option<Arc<ProgressSink>>),
    Stats(Sender<ShardStats>),
}

/// Point-in-time view of one shard, shipped to the router for merging.
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub shard_id: usize,
    pub dataset: String,
    pub snapshot: MetricsSnapshot,
    /// Raw histogram so the router can bucket-merge instead of
    /// max-ing per-shard quantiles.
    pub latency: Histogram,
}

/// Handle to one shard worker thread. Cheap to share behind the router's
/// lock; all cross-thread state is channels + atomics.
pub struct EngineShard {
    id: usize,
    dataset: String,
    cmd_tx: Mutex<Sender<ShardCmd>>,
    /// Lanes active + queued inside the engine, stored by the worker
    /// every loop iteration.
    engine_load: Arc<AtomicUsize>,
    /// Lanes dispatched but not yet received by the worker: incremented
    /// by [`EngineShard::dispatch`], decremented by the worker when the
    /// command is pulled off the channel. `load()` is the sum, so work
    /// sitting in the channel while the worker is mid-tick still counts
    /// toward least-loaded balancing.
    pending: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    handle: Mutex<Option<JoinHandle<()>>>,
}

impl EngineShard {
    /// Spawn a worker for `cfg.dataset`. Blocks until the engine inside is
    /// built (+ optionally warmed), so failures are returned here rather
    /// than discovered by the first request.
    pub fn spawn(id: usize, cfg: ServeConfig, warmup: bool) -> Result<EngineShard> {
        cfg.validate()?;
        let dataset = cfg.dataset.clone();
        let drain_timeout = Duration::from_millis(cfg.drain_timeout_ms);
        let (cmd_tx, cmd_rx) = mpsc::channel::<ShardCmd>();
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
        let stop = Arc::new(AtomicBool::new(false));
        let engine_load = Arc::new(AtomicUsize::new(0));
        let pending = Arc::new(AtomicUsize::new(0));
        let worker_stop = stop.clone();
        let worker_load = engine_load.clone();
        let worker_pending = pending.clone();
        let handle = std::thread::Builder::new()
            .name(format!("ddim-shard-{id}-{dataset}"))
            .spawn(move || {
                worker(WorkerArgs {
                    id,
                    cfg,
                    warmup,
                    cmd_rx,
                    ready_tx,
                    stop: worker_stop,
                    engine_load: worker_load,
                    pending: worker_pending,
                    drain_timeout,
                })
            })
            .map_err(Error::Io)?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = handle.join();
                return Err(Error::Coordinator(format!("shard {id} ({dataset}): {e}")));
            }
            Err(_) => {
                let _ = handle.join();
                return Err(Error::Coordinator(format!("shard {id} ({dataset}): worker died")));
            }
        }
        Ok(EngineShard {
            id,
            dataset,
            cmd_tx: Mutex::new(cmd_tx),
            engine_load,
            pending,
            stop,
            handle: Mutex::new(Some(handle)),
        })
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn dataset(&self) -> &str {
        &self.dataset
    }

    /// Current load estimate for least-loaded dispatch: lanes inside the
    /// engine plus lanes dispatched but still in the command channel.
    pub fn load(&self) -> usize {
        self.engine_load.load(Ordering::SeqCst) + self.pending.load(Ordering::SeqCst)
    }

    /// Hand a request to the worker; `done` is called with exactly one
    /// [`Response`] (success, rejection, or shutdown error) — never zero,
    /// never twice. `progress` (if any) streams per-step x₀ previews from
    /// the engine while the request runs; it is best-effort and fires on
    /// the worker thread.
    pub fn dispatch(&self, req: Request, done: DoneFn, progress: Option<Arc<ProgressSink>>) {
        self.pending.fetch_add(lane_cost(&req), Ordering::SeqCst);
        let sent = self.cmd_tx.lock().unwrap().send(ShardCmd::Submit(req, done, progress));
        if let Err(mpsc::SendError(ShardCmd::Submit(_, done, _))) = sent {
            // worker gone: answer the waiter directly. The pending bump is
            // deliberately NOT undone — the worker's exit-time store(0)
            // may already have run, and an underflowing gauge is worse
            // than a dead shard reading as loaded.
            done(shutdown_response());
        }
    }

    /// Fire a stats request without blocking; pair with the returned
    /// receiver. `None` if the worker is gone. Lets the router release
    /// its locks before waiting on replies.
    pub fn stats_request(&self) -> Option<Receiver<ShardStats>> {
        let (tx, rx) = mpsc::channel();
        self.cmd_tx.lock().unwrap().send(ShardCmd::Stats(tx)).ok()?;
        Some(rx)
    }

    /// Ask the worker for a stats snapshot. `None` if the worker is gone
    /// or does not answer within `timeout`.
    pub fn stats(&self, timeout: Duration) -> Option<ShardStats> {
        self.stats_request()?.recv_timeout(timeout).ok()
    }

    /// Flag the worker to begin its drain-then-exit sequence (non-blocking,
    /// so the router can signal every shard before joining any — shards
    /// drain in parallel).
    pub fn signal_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    /// Join the worker thread (idempotent).
    pub fn join(&self) {
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

/// What a request adds to the load gauge: its lane count (min 1, so even
/// zero-lane rejects count until the worker answers them).
fn lane_cost(req: &Request) -> usize {
    req.lane_count().max(1)
}

fn shutdown_response() -> Response {
    Response {
        id: 0,
        body: ResponseBody::Error { message: "shutting down".into() },
        latency_s: 0.0,
        steps_executed: 0,
        cached: false,
        degraded: None,
        spans: None,
        coalesced: false,
    }
}

/// Map a submit failure onto the wire. Overload and deadline failures are
/// *typed* — `"reject":{"reason":...,"queued_lanes":N}` — so clients can
/// back off mechanically; everything else stays a plain error string.
fn reject_response(e: Error) -> Response {
    let body = match e {
        Error::Overload { queued_lanes, message } => ResponseBody::Reject(Reject {
            reason: RejectReason::Overload,
            queued_lanes,
            message,
        }),
        Error::DeadlineExpired { message } => ResponseBody::Reject(Reject {
            reason: RejectReason::Deadline,
            queued_lanes: 0,
            message,
        }),
        other => ResponseBody::Error { message: other.to_string() },
    };
    Response {
        id: 0,
        body,
        latency_s: 0.0,
        steps_executed: 0,
        cached: false,
        degraded: None,
        spans: None,
        coalesced: false,
    }
}

fn deliver(waiters: &mut HashMap<RequestId, DoneFn>, resp: Response) {
    if let Some(done) = waiters.remove(&resp.id) {
        done(resp);
    }
}

struct WorkerArgs {
    id: usize,
    cfg: ServeConfig,
    warmup: bool,
    cmd_rx: Receiver<ShardCmd>,
    ready_tx: Sender<std::result::Result<(), String>>,
    stop: Arc<AtomicBool>,
    engine_load: Arc<AtomicUsize>,
    pending: Arc<AtomicUsize>,
    drain_timeout: Duration,
}

fn worker(args: WorkerArgs) {
    let WorkerArgs { id, cfg, warmup, cmd_rx, ready_tx, stop, engine_load, pending, drain_timeout } =
        args;
    let dataset = cfg.dataset.clone();
    let mut engine = match Engine::new(cfg).and_then(|mut e| {
        if warmup {
            e.warmup()?;
        }
        Ok(e)
    }) {
        Ok(e) => {
            let _ = ready_tx.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e.to_string()));
            return;
        }
    };
    let mut waiters: HashMap<RequestId, DoneFn> = HashMap::new();

    'run: while !stop.load(Ordering::SeqCst) {
        // drain pending commands; block briefly only when fully idle
        loop {
            let cmd = if engine.is_busy() {
                match cmd_rx.try_recv() {
                    Ok(c) => Some(c),
                    Err(TryRecvError::Empty) => None,
                    Err(TryRecvError::Disconnected) => break 'run,
                }
            } else {
                match cmd_rx.recv_timeout(Duration::from_millis(10)) {
                    Ok(c) => Some(c),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => break 'run,
                }
            };
            let Some(cmd) = cmd else { break };
            if let ShardCmd::Submit(req, _, _) = &cmd {
                // paired with the fetch_add in dispatch: this lane cost now
                // moves from "pending" into the engine's own accounting
                pending.fetch_sub(lane_cost(req), Ordering::SeqCst);
            }
            handle_cmd(cmd, id, &dataset, &mut engine, &mut waiters);
        }
        // publish load *before* the (potentially long) tick: the drain loop
        // above moved lane cost out of `pending`, so waiting until after the
        // tick would let least-loaded dispatch undercount this shard for the
        // whole executable call and dogpile it. Queued load is counted in
        // *lanes* (same unit as `pending`'s lane_cost), not requests.
        engine_load.store(engine.active_lanes() + engine.queued_lanes(), Ordering::SeqCst);
        if let Err(e) = engine.tick() {
            eprintln!("[shard {id}:{dataset}] tick error: {e}");
        }
        for resp in engine.take_completed() {
            deliver(&mut waiters, resp);
        }
        engine_load.store(engine.active_lanes() + engine.queued_lanes(), Ordering::SeqCst);
    }

    // --- drain: finish in-flight work, bounded by drain_timeout
    let deadline = Instant::now() + drain_timeout;
    match engine.drain(deadline) {
        Ok(responses) => {
            for resp in responses {
                deliver(&mut waiters, resp);
            }
        }
        Err(e) => eprintln!("[shard {id}:{dataset}] drain error: {e}"),
    }
    // --- whatever outlived the deadline (or the error) gets an explicit
    // error; no waiter may be left blocked
    engine.abort_pending("shutting down");
    for resp in engine.take_completed() {
        deliver(&mut waiters, resp);
    }
    // commands still sitting in the channel never reached the engine
    while let Ok(cmd) = cmd_rx.try_recv() {
        match cmd {
            ShardCmd::Submit(_, done, _) => {
                done(shutdown_response());
            }
            ShardCmd::Stats(tx) => {
                let _ = tx.send(stats_of(id, &dataset, &engine));
            }
        }
    }
    engine_load.store(0, Ordering::SeqCst);
    pending.store(0, Ordering::SeqCst);
}

fn stats_of(id: usize, dataset: &str, engine: &Engine) -> ShardStats {
    ShardStats {
        shard_id: id,
        dataset: dataset.to_string(),
        snapshot: engine.metrics(),
        latency: engine.latency_histogram(),
    }
}

fn handle_cmd(
    cmd: ShardCmd,
    id: usize,
    dataset: &str,
    engine: &mut Engine,
    waiters: &mut HashMap<RequestId, DoneFn>,
) {
    match cmd {
        ShardCmd::Submit(req, done, progress) => match engine.submit_with(req, progress) {
            Ok(req_id) => {
                waiters.insert(req_id, done);
            }
            Err(e) => {
                done(reject_response(e));
            }
        },
        ShardCmd::Stats(tx) => {
            let _ = tx.send(stats_of(id, dataset, engine));
        }
    }
}
