//! The router: owns every [`EngineShard`] and is the coordinator's whole
//! public surface. The TCP server is a dumb JSON-line transport over this
//! API; tests and benches drive the router directly.
//!
//! Placement: shards are grouped by dataset (`ServeConfig::shards_for`
//! decides how many per dataset — the `--shards` default plus
//! `--placement ds=N` overrides). The default dataset's pool is built
//! eagerly with warmup so startup failures surface before the server
//! reports ready; other datasets come up lazily on first request, exactly
//! like the old single-threaded pool — except bring-up no longer blocks
//! serving traffic on *other* datasets for long, because each shard ticks
//! on its own thread.
//!
//! Dispatch: least-loaded over the dataset's pool, load = active lanes +
//! queued (+ dispatched-not-yet-admitted), with a rotating-cursor scan for
//! ties so equal shards are used round-robin. Starvation-freedom of the
//! tie-break is property-tested below: a shard that stays in the minimum-
//! load set over `n` consecutive dispatches is picked at least once.
//!
//! Metrics: counters are summed across shards and latency histograms are
//! **bucket-merged** ([`Histogram::merge`]) before quantiles are read —
//! the old server reported the max of per-engine p50/p95/p99, which
//! over-weights a cold shard with three slow requests.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::cache::{Admission, CacheFront, DoneFn};
use crate::config::ServeConfig;
use crate::coordinator::engine::ProgressSink;
use crate::coordinator::metrics::{Histogram, MetricsSnapshot};
use crate::coordinator::request::{Priority, Request, Response, ResponseBody};
use crate::coordinator::shard::{EngineShard, ShardStats};
use crate::error::{Error, Result};
use crate::jobj;
use crate::json::{self, Value};
use crate::schedule::TauKind;

/// Step budgets the degradation ladder sheds to, highest rung first. The
/// mid watermark targets the first entry, the high watermark the second —
/// mirroring the paper's S=100 → S=20 → S=10 quality/steps trade-off
/// (DDIM degrades gracefully where DDPM collapses; Figure 3).
const DEGRADE_RUNGS: [usize; 2] = [20, 10];

/// Total budget a metrics poll spends waiting across *all* shards before
/// skipping the stragglers (shared deadline, not per shard — a fleet of
/// wedged shards must not stall a connection thread for 5s × N).
const STATS_TIMEOUT: Duration = Duration::from_secs(5);

/// One dataset's shards plus its dispatch cursor. The cursor is
/// per-pool on purpose: the starvation-freedom guarantee of
/// [`pick_shard`] needs the cursor to advance by exactly 1 per dispatch
/// *to this pool* — a router-global cursor strided by other datasets'
/// traffic could park on the same residue forever.
struct Pool {
    shards: Vec<EngineShard>,
    cursor: AtomicUsize,
}

impl Pool {
    fn new(shards: Vec<EngineShard>) -> Pool {
        Pool { shards, cursor: AtomicUsize::new(0) }
    }
}

/// Routes requests to per-dataset shard pools. All methods take `&self`;
/// the router is shared across connection threads behind an `Arc`.
pub struct Router {
    cfg: ServeConfig,
    pools: RwLock<BTreeMap<String, Pool>>,
    /// Monotonic shard id across all pools (stable in metrics output).
    next_shard_id: AtomicUsize,
    stopping: AtomicBool,
    /// Sample cache + single-flight coalescer, consulted ahead of shard
    /// dispatch (see [`crate::cache`]). Always present; inert when both
    /// halves are disabled in config.
    cache: Arc<CacheFront>,
    /// Requests whose step budget was shed by the degradation ladder.
    /// Router-level on purpose: the rewrite happens *before* cache
    /// admission, so engines never see the original budget and report 0.
    degraded: AtomicU64,
}

/// Least-loaded pick with a rotating-cursor tie-break: scan indices in
/// cyclic order starting at `cursor % n` and take the first that carries
/// the minimum load. Guarantees: (a) the result always has minimal load;
/// (b) a shard that remains in the minimum set over `n` consecutive
/// dispatches (cursor advances by 1 each time) is picked at least once —
/// when the scan starts on it, it wins. No shard starves.
pub fn pick_shard(loads: &[usize], cursor: usize) -> usize {
    debug_assert!(!loads.is_empty());
    let n = loads.len();
    let min = *loads.iter().min().expect("non-empty pool");
    for k in 0..n {
        let i = (cursor + k) % n;
        if loads[i] == min {
            return i;
        }
    }
    unreachable!("min element exists")
}

impl Router {
    /// Validate config and bring up the default dataset's pool (with
    /// warmup, so compile/load failures surface here).
    pub fn start(cfg: ServeConfig) -> Result<Router> {
        cfg.validate()?;
        let cache = Arc::new(CacheFront::from_config(&cfg)?);
        let router = Router {
            pools: RwLock::new(BTreeMap::new()),
            next_shard_id: AtomicUsize::new(0),
            stopping: AtomicBool::new(false),
            cache,
            degraded: AtomicU64::new(0),
            cfg,
        };
        let default = router.cfg.dataset.clone();
        router.bring_up(&default, true)?;
        Ok(router)
    }

    /// Serving configuration (base; per-shard configs differ only in dataset).
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Total shards across all pools.
    pub fn shard_count(&self) -> usize {
        self.pools.read().unwrap().values().map(|p| p.shards.len()).sum()
    }

    /// Datasets with a live pool.
    pub fn datasets(&self) -> Vec<String> {
        self.pools.read().unwrap().keys().cloned().collect()
    }

    /// Spawn `cfg.shards_for(dataset)` shards for `dataset` if it has no
    /// pool yet. Shards are built *outside* any lock — bring-up of a new
    /// dataset (runtime load × n, plus warmup) must not stall serving
    /// traffic on existing pools. Two concurrent first requests may both
    /// build; the loser's pool is torn down.
    fn bring_up(&self, dataset: &str, warmup: bool) -> Result<()> {
        if self.pools.read().unwrap().contains_key(dataset) {
            return Ok(());
        }
        let n = self.cfg.shards_for(dataset);
        let mut shards = Vec::with_capacity(n);
        for _ in 0..n {
            let id = self.next_shard_id.fetch_add(1, Ordering::SeqCst);
            let mut shard_cfg = self.cfg.clone();
            shard_cfg.dataset = dataset.to_string();
            match EngineShard::spawn(id, shard_cfg, warmup) {
                Ok(s) => shards.push(s),
                Err(e) => {
                    // unwind the partial pool; the dataset stays absent so a
                    // later request can retry bring-up
                    teardown(&shards);
                    return Err(e);
                }
            }
        }
        let mut pools = self.pools.write().unwrap();
        if self.stopping.load(Ordering::SeqCst) {
            // raced with shutdown(): it has already signaled/joined every
            // pool in the map, so a pool inserted now would never be
            // stopped — tear the fresh shards down instead
            drop(pools);
            teardown(&shards);
            return Err(Error::Coordinator("shutting down".into()));
        }
        if pools.contains_key(dataset) {
            drop(pools);
            teardown(&shards); // raced: someone else's pool won
            return Ok(());
        }
        pools.insert(dataset.to_string(), Pool::new(shards));
        drop(pools);
        // a fresh pool just re-read the artifact tree: if the manifest was
        // regenerated since the cache's keys were minted, flush them now
        // (stale-digest entries could never be *served* — the digest is in
        // every key — this frees their budget). Best-effort: the engines
        // just loaded this same manifest successfully.
        let _ = self.cache.refresh_manifest(&self.cfg.artifact_root);
        Ok(())
    }

    /// Bring up `dataset`'s pool eagerly with warmed executables. The
    /// request path brings pools up lazily *without* warmup (first
    /// request pays compile latency); benches and latency-sensitive
    /// deployments can prewarm instead. No-op if the pool exists.
    pub fn prewarm(&self, dataset: &str) -> Result<()> {
        self.bring_up(dataset, true)
    }

    /// Route one request through the cache front, then (on a miss that
    /// leads its flight) to the least-loaded shard. The returned channel
    /// yields exactly one [`Response`] — a cache hit, a shared coalesced
    /// result, a fresh execution, a rejection, or an explicit shutdown
    /// error.
    pub fn submit(&self, req: Request) -> Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        self.submit_with(
            req,
            Box::new(move |resp| {
                let _ = tx.send(resp);
            }),
            None,
        );
        rx
    }

    /// Callback-form submission, the primitive `submit` wraps: `done` is
    /// invoked with exactly one [`Response`], on whatever thread completes
    /// the request (cache hit: this one; execution: the shard worker).
    /// `progress` optionally streams per-step predicted-x₀ previews from
    /// the engine; it only takes effect when this request actually
    /// executes — cache hits and coalesced waiters get no frames (their
    /// steps ran elsewhere, or not at all). Event-loop callers hand both
    /// callbacks to the owning reactor, so nothing here ever blocks.
    pub fn submit_with(&self, req: Request, done: DoneFn, progress: Option<Arc<ProgressSink>>) {
        let error = |msg: String| Response {
            id: 0,
            body: ResponseBody::Error { message: msg },
            latency_s: 0.0,
            steps_executed: 0,
            cached: false,
            degraded: None,
            spans: None,
            coalesced: false,
        };
        if self.stopping.load(Ordering::SeqCst) {
            done(error("shutting down".into()));
            return;
        }
        if let Err(e) = self.bring_up(&req.dataset, false) {
            done(error(e.to_string()));
            return;
        }
        let mut req = req;
        let mut done = done;
        if let Some((from, to)) = self.maybe_degrade(&mut req) {
            // stamp this caller's own from→to record onto whatever answer
            // it eventually gets — direct execution, cache hit on the
            // degraded cell, or a parked seat behind a degraded leader —
            // so no degraded response ever masquerades as full-budget
            self.degraded.fetch_add(1, Ordering::Relaxed);
            let inner = done;
            done = Box::new(move |mut resp: Response| {
                if matches!(resp.body, ResponseBody::Ok { .. }) {
                    resp.degraded = Some((from, to));
                }
                inner(resp);
            });
        }
        match self.cache.admit(req, done) {
            // answered from the store / parked behind an identical
            // in-flight execution: nothing reaches any shard
            Admission::Served | Admission::Parked => {}
            Admission::Execute { request, on_done } => {
                let pools = self.pools.read().unwrap();
                match pools.get(&request.dataset) {
                    Some(pool) if !pool.shards.is_empty() => {
                        let loads: Vec<usize> =
                            pool.shards.iter().map(EngineShard::load).collect();
                        let idx =
                            pick_shard(&loads, pool.cursor.fetch_add(1, Ordering::SeqCst));
                        pool.shards[idx].dispatch(request, on_done, progress);
                    }
                    // the completion callback must fire exactly once even
                    // when no shard exists, so coalesced waiters (if any)
                    // are answered and the in-flight pin is released
                    _ => on_done(error(format!(
                        "no shards for dataset '{}'",
                        request.dataset
                    ))),
                }
            }
        }
    }

    /// Adaptive quality degradation — the DDIM-specific shedding axis.
    /// When queued-lane pressure on the request's pool crosses the
    /// configured watermarks, best-effort requests are transparently
    /// rewritten to a smaller step budget (§4.3's quality-vs-steps
    /// trade-off) *before* cache admission, so the key is minted on the
    /// schedule that actually executes and coalesced waiters park behind
    /// the degraded flight. Interactive and batch traffic is never
    /// rewritten. Returns `(from, to)` when a rewrite happened.
    ///
    /// Pressure = Σ shard load over the pool (active + queued +
    /// dispatched lanes); capacity = shards × `max_lanes`. At
    /// `degrade_mid`× capacity the budget drops to 20 steps, at
    /// `degrade_high`× to 10 — and the DP-optimized schedule serves the
    /// shed budget whenever its (dataset, S) cell exists, since the
    /// optimized subsequence loses the least quality at small S.
    fn maybe_degrade(&self, req: &mut Request) -> Option<(usize, usize)> {
        if !self.cfg.degrade_enabled || req.qos.priority != Priority::BestEffort {
            return None;
        }
        let (pressure, shards) = {
            let pools = self.pools.read().unwrap();
            let pool = pools.get(&req.dataset)?;
            (pool.shards.iter().map(EngineShard::load).sum::<usize>(), pool.shards.len())
        };
        let capacity = (shards * self.cfg.max_lanes).max(1) as f64;
        let rung = if pressure as f64 >= self.cfg.degrade_high * capacity {
            DEGRADE_RUNGS[1]
        } else if pressure as f64 >= self.cfg.degrade_mid * capacity {
            DEGRADE_RUNGS[0]
        } else {
            return None;
        };
        if req.steps <= rung {
            return None;
        }
        let from = req.steps;
        req.steps = rung;
        req.tau = if self.cache.has_opt_cell(&req.dataset, rung) {
            TauKind::Opt
        } else if req.tau == TauKind::Opt {
            // the engine treats a missing (dataset, S) cell as a typed
            // schedule error; a shed request must not start failing just
            // because nobody optimized its new budget
            TauKind::Linear
        } else {
            req.tau
        };
        Some((from, rung))
    }

    /// Submit and block for the response (examples / benches).
    pub fn call(&self, req: Request) -> Result<Response> {
        self.submit(req)
            .recv()
            .map_err(|_| Error::Coordinator("request dropped during shutdown".into()))
    }

    /// The sample-cache front (metrics, tests, manual invalidation).
    pub fn cache(&self) -> &Arc<CacheFront> {
        &self.cache
    }

    /// Re-read the manifest from disk and flush the sample cache if its
    /// digest changed (artifact reload). Returns whether an invalidation
    /// happened.
    pub fn refresh_cache_manifest(&self) -> Result<bool> {
        self.cache.refresh_manifest(&self.cfg.artifact_root)
    }

    /// Merged view across every shard: summed counters, bucket-merged
    /// latency quantiles, plus the per-shard breakdown.
    pub fn aggregate(&self) -> (MetricsSnapshot, Vec<ShardStats>) {
        // fire every stats request under the read lock (non-blocking
        // channel sends), then release it before waiting — one wedged
        // shard must not hold the pools lock for STATS_TIMEOUT
        let pending: Vec<_> = {
            let pools = self.pools.read().unwrap();
            pools
                .values()
                .flat_map(|p| p.shards.iter().filter_map(EngineShard::stats_request))
                .collect()
        };
        let deadline = Instant::now() + STATS_TIMEOUT;
        let per_shard: Vec<ShardStats> = pending
            .into_iter()
            .filter_map(|rx| {
                rx.recv_timeout(deadline.saturating_duration_since(Instant::now())).ok()
            })
            .collect();
        let mut agg = MetricsSnapshot::default();
        let mut latency = Histogram::new();
        for s in &per_shard {
            let m = &s.snapshot;
            agg.requests_completed += m.requests_completed;
            agg.requests_rejected += m.requests_rejected;
            agg.lanes_completed += m.lanes_completed;
            agg.executable_calls += m.executable_calls;
            agg.steps_executed += m.steps_executed;
            for (k, v) in agg.kernel_steps.iter_mut().zip(m.kernel_steps) {
                *k += v;
            }
            agg.occupancy_sum += m.occupancy_sum;
            agg.ticks += m.ticks;
            agg.sub_batches += m.sub_batches;
            agg.padded_lanes += m.padded_lanes;
            agg.pipeline_wait_s += m.pipeline_wait_s;
            agg.device_busy_s += m.device_busy_s;
            agg.ref_compute_s += m.ref_compute_s;
            agg.ref_bytes_allocated += m.ref_bytes_allocated;
            agg.ref_bytes_last_tick += m.ref_bytes_last_tick;
            agg.queue_accepted += m.queue_accepted;
            agg.queue_depth += m.queue_depth;
            agg.queued_lanes += m.queued_lanes;
            agg.queue_rejected_items += m.queue_rejected_items;
            agg.queue_rejected_lanes += m.queue_rejected_lanes;
            agg.deadline_expired += m.deadline_expired;
            agg.active_lanes += m.active_lanes;
            agg.wall_s = agg.wall_s.max(m.wall_s);
            latency.merge(&s.latency);
        }
        // shed budgets are counted where the rewrite happens (here), not
        // in the engines — they only ever saw the degraded schedule
        agg.requests_degraded = self.degraded.load(Ordering::Relaxed);
        agg.latency_p50_s = latency.quantile(0.5);
        agg.latency_p95_s = latency.quantile(0.95);
        agg.latency_p99_s = latency.quantile(0.99);
        agg.latency_mean_s = latency.mean();
        (agg, per_shard)
    }

    /// The `{"op":"metrics"}` reply as a [`Value`]: merged totals +
    /// `"shards": [...]` breakdown. The transport layer injects its own
    /// section (`"transport"`) before serializing.
    pub fn metrics_value(&self) -> Value {
        let (agg, per_shard) = self.aggregate();
        let shards: Vec<Value> = per_shard
            .iter()
            .map(|s| {
                let m = &s.snapshot;
                jobj![
                    ("shard", s.shard_id),
                    ("dataset", s.dataset.clone()),
                    ("requests_completed", m.requests_completed),
                    ("requests_rejected", m.requests_rejected),
                    ("steps_executed", m.steps_executed),
                    ("steps_ddim", m.kernel_steps[0]),
                    ("steps_pf_ode", m.kernel_steps[1]),
                    ("steps_ab2", m.kernel_steps[2]),
                    ("executable_calls", m.executable_calls),
                    ("occupancy", m.occupancy()),
                    ("padding_waste", m.padding_waste()),
                    ("ticks", m.ticks),
                    ("sub_batches", m.sub_batches),
                    ("overlap_frac", m.overlap_frac()),
                    ("ref_compute_s", m.ref_compute_s),
                    ("ref_bytes_allocated_per_tick", m.ref_bytes_last_tick),
                    ("latency_p50_s", m.latency_p50_s),
                    ("latency_p95_s", m.latency_p95_s),
                    ("latency_p99_s", m.latency_p99_s),
                    ("active_lanes", m.active_lanes),
                    ("queued", m.queue_depth),
                    ("queued_lanes", m.queued_lanes),
                    ("queue_accepted", m.queue_accepted),
                    ("queue_rejected_items", m.queue_rejected_items),
                    ("queue_rejected_lanes", m.queue_rejected_lanes),
                    ("deadline_expired", m.deadline_expired),
                ]
            })
            .collect();
        jobj![
            ("ok", true),
            ("engines", per_shard.len()),
            ("datasets", self.datasets().len()),
            ("requests_completed", agg.requests_completed),
            ("requests_rejected", agg.requests_rejected),
            ("lanes_completed", agg.lanes_completed),
            ("executable_calls", agg.executable_calls),
            ("steps_executed", agg.steps_executed),
            ("steps_ddim", agg.kernel_steps[0]),
            ("steps_pf_ode", agg.kernel_steps[1]),
            ("steps_ab2", agg.kernel_steps[2]),
            ("occupancy", agg.occupancy()),
            ("padding_waste", agg.padding_waste()),
            ("ticks", agg.ticks),
            ("sub_batches", agg.sub_batches),
            ("overlap_frac", agg.overlap_frac()),
            ("ref_compute_s", agg.ref_compute_s),
            ("ref_bytes_allocated", agg.ref_bytes_allocated),
            ("ref_bytes_allocated_per_tick", agg.ref_bytes_last_tick),
            ("latency_p50_s", agg.latency_p50_s),
            ("latency_p95_s", agg.latency_p95_s),
            ("latency_p99_s", agg.latency_p99_s),
            ("steps_per_second", agg.steps_per_second()),
            ("active_lanes", agg.active_lanes),
            ("queued", agg.queue_depth),
            ("queued_lanes", agg.queued_lanes),
            ("queue_accepted", agg.queue_accepted),
            ("queue_rejected_items", agg.queue_rejected_items),
            ("queue_rejected_lanes", agg.queue_rejected_lanes),
            ("deadline_expired", agg.deadline_expired),
            ("requests_degraded", agg.requests_degraded),
            ("cache", self.cache.metrics().to_json()),
            ("shards", Value::Arr(shards)),
        ]
    }

    /// [`Router::metrics_value`] serialized to one line.
    pub fn metrics_json(&self) -> String {
        json::to_string(&self.metrics_value())
    }

    /// Graceful shutdown: refuse new submissions, signal every shard (so
    /// they drain in parallel, each bounded by `drain_timeout_ms`), then
    /// join them. Idempotent.
    pub fn shutdown(&self) {
        self.stopping.store(true, Ordering::SeqCst);
        let pools = self.pools.read().unwrap();
        for pool in pools.values() {
            for shard in &pool.shards {
                shard.signal_stop();
            }
        }
        for pool in pools.values() {
            for shard in &pool.shards {
                shard.join();
            }
        }
    }
}

/// Stop and join a set of shards (failed or raced bring-up).
fn teardown(shards: &[EngineShard]) {
    for s in shards {
        s.signal_stop();
    }
    for s in shards {
        s.join();
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pick_shard_returns_a_minimum() {
        assert_eq!(pick_shard(&[3], 17), 0);
        assert_eq!(pick_shard(&[2, 1, 5], 0), 1);
        assert_eq!(pick_shard(&[0, 4, 0], 0), 0);
        assert_eq!(pick_shard(&[0, 4, 0], 2), 2);
        // cursor rotates ties round-robin
        assert_eq!(pick_shard(&[1, 1, 1], 0), 0);
        assert_eq!(pick_shard(&[1, 1, 1], 1), 1);
        assert_eq!(pick_shard(&[1, 1, 1], 5), 2);
    }

    #[test]
    fn equal_loads_dispatch_round_robin() {
        // unit jobs that complete instantly: loads stay equal, so the
        // cursor alone decides — hits must be perfectly balanced
        let n = 4;
        let loads = vec![0usize; n];
        let mut hits = vec![0usize; n];
        for cursor in 0..32 {
            hits[pick_shard(&loads, cursor)] += 1;
        }
        assert!(hits.iter().all(|&h| h == 8), "{hits:?}");
    }

    #[test]
    fn property_least_loaded_dispatch_never_starves() {
        // Invariant (see pick_shard docs): a shard continuously in the
        // minimum-load set is picked within n consecutive dispatches.
        crate::testing::check("router_no_starvation", 100, |g| {
            let n = g.int_in(2, 8).max(2);
            let mut loads = vec![0usize; n];
            let mut min_streak_skipped = vec![0usize; n];
            let rounds = g.int_in(20, 300);
            for cursor in 0..rounds {
                let picked = pick_shard(&loads, cursor);
                let min = *loads.iter().min().unwrap();
                if loads[picked] != min {
                    return Err(format!("picked load {} > min {min}", loads[picked]));
                }
                for i in 0..n {
                    if i == picked || loads[i] != min {
                        min_streak_skipped[i] = 0;
                    } else {
                        min_streak_skipped[i] += 1;
                        if min_streak_skipped[i] >= n {
                            return Err(format!(
                                "shard {i} stayed minimal but was skipped {} times (n={n})",
                                min_streak_skipped[i]
                            ));
                        }
                    }
                }
                // picked shard takes on a request's worth of lanes...
                loads[picked] += g.int_in(1, 4);
                // ...and every shard makes random progress
                for l in loads.iter_mut() {
                    *l = l.saturating_sub(g.int_in(0, 2));
                }
            }
            Ok(())
        });
    }
}
