//! Request/response types and their JSON wire forms.
//!
//! A request asks for `count` samples (lanes) under one sampling
//! configuration. Three kinds map onto the paper's experiments:
//! - `Generate`: x_T ~ N(0,I) -> x_0 (Tables 1/3, Figs. 3-5)
//! - `Decode`:   caller-supplied latents x_T -> x_0 (Fig. 6 interpolation)
//! - `Encode`:   caller-supplied images x_0 -> x_T (Table 2 reconstruction)

use crate::error::{Error, Result};
use crate::jobj;
use crate::json::{self, Value};
use crate::sampler::SamplerKind;
use crate::schedule::{NoiseMode, TauKind};

/// Monotonically increasing request identifier (assigned by the engine).
pub type RequestId = u64;

/// Per-request cache directive (the wire's `"cache"` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CacheMode {
    /// Normal path: serve from / publish to the sample cache, coalesce
    /// onto identical in-flight executions.
    #[default]
    Use,
    /// `"cache":"bypass"` — skip lookup, coalescing, and publication;
    /// always execute. For clients probing the live engines (or refusing
    /// a shared result on principle).
    Bypass,
}

impl CacheMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "use" | "default" => Ok(CacheMode::Use),
            "bypass" => Ok(CacheMode::Bypass),
            other => Err(Error::Request(format!(
                "unknown cache directive '{other}' (want use | bypass)"
            ))),
        }
    }
}

/// What the request wants done.
#[derive(Debug, Clone)]
pub enum RequestBody {
    /// Sample `count` fresh images from the prior.
    Generate { count: usize, seed: u64 },
    /// Deterministically decode the provided latents (η forced to the
    /// request's mode; Fig. 6 uses η=0).
    Decode { latents: Vec<Vec<f32>> },
    /// Encode the provided images to latents (always deterministic).
    Encode { images: Vec<Vec<f32>> },
}

/// A fully-specified client request.
#[derive(Debug, Clone)]
pub struct Request {
    pub dataset: String,
    /// dim(τ) — sampling steps.
    pub steps: usize,
    pub mode: NoiseMode,
    pub tau: TauKind,
    /// Update kernel: `ddim` (Eq. 13, the fused executable's `x_prev`),
    /// `pf_ode` (Eq. 15 host Euler), or `ab2` (§7 multistep). The host
    /// kernels are deterministic-only; stochastic plans are DDIM-only.
    pub sampler: SamplerKind,
    pub body: RequestBody,
    /// Return pixel data in the response (else just stats).
    pub return_images: bool,
    /// Cache directive (`"cache":"bypass"` opts this request out of the
    /// sample cache and coalescing). Not part of the cache key — like
    /// `return_images`, it shapes delivery, not the sample.
    pub cache: CacheMode,
}

impl Request {
    /// Number of lanes this request expands to.
    pub fn lane_count(&self) -> usize {
        match &self.body {
            RequestBody::Generate { count, .. } => *count,
            RequestBody::Decode { latents } => latents.len(),
            RequestBody::Encode { images } => images.len(),
        }
    }

    /// Parse the JSON-line wire form with the build-time default sampler.
    /// Minimal example:
    /// `{"op":"generate","dataset":"sprites","steps":20,"eta":"0.0","count":4,"seed":7}`
    pub fn from_json(v: &Value) -> Result<Self> {
        Self::from_json_with(v, SamplerKind::Ddim)
    }

    /// Parse the JSON-line wire form; a missing `"sampler"` field falls
    /// back to `default_sampler` (the server passes its
    /// `--default-sampler` here).
    pub fn from_json_with(v: &Value, default_sampler: SamplerKind) -> Result<Self> {
        Self::from_json_with_defaults(v, default_sampler, TauKind::Linear)
    }

    /// [`Request::from_json_with`] plus the server's `--tau` default: a
    /// missing `"tau"` field falls back to `default_tau` (an explicit
    /// field always wins).
    pub fn from_json_with_defaults(
        v: &Value,
        default_sampler: SamplerKind,
        default_tau: TauKind,
    ) -> Result<Self> {
        let op = v.get("op")?.as_str()?.to_string();
        let dataset = v.get("dataset")?.as_str()?.to_string();
        let steps = v.get("steps")?.as_usize()?;
        let mode = match v.get_opt("eta") {
            Some(Value::Str(s)) => NoiseMode::parse(s)?,
            Some(Value::Num(n)) => NoiseMode::Eta(*n),
            Some(other) => return Err(Error::Request(format!("bad eta {other:?}"))),
            None => NoiseMode::Eta(0.0),
        };
        let tau = match v.get_opt("tau") {
            Some(t) => TauKind::parse(t.as_str()?)?,
            None => default_tau,
        };
        let return_images = match v.get_opt("return_images") {
            Some(b) => b.as_bool()?,
            None => false,
        };
        let sampler = match v.get_opt("sampler") {
            Some(s) => SamplerKind::parse(s.as_str()?)?,
            None => default_sampler,
        };
        let cache = match v.get_opt("cache") {
            Some(c) => CacheMode::parse(c.as_str()?)?,
            None => CacheMode::Use,
        };
        let parse_matrix = |key: &str| -> Result<Vec<Vec<f32>>> {
            v.get(key)?
                .as_arr()?
                .iter()
                .map(|row| {
                    Ok(row
                        .as_f64_vec()?
                        .into_iter()
                        .map(|x| x as f32)
                        .collect::<Vec<f32>>())
                })
                .collect()
        };
        let body = match op.as_str() {
            "generate" => RequestBody::Generate {
                count: v.get("count")?.as_usize()?,
                // strict: negative / fractional / >=2^53 seeds are rejected
                // instead of silently truncated through an f64 cast
                seed: v
                    .get("seed")?
                    .as_u64()
                    .map_err(|e| Error::Request(format!("seed: {e}")))?,
            },
            "decode" => RequestBody::Decode { latents: parse_matrix("latents")? },
            "encode" => RequestBody::Encode { images: parse_matrix("images")? },
            other => return Err(Error::Request(format!("unknown op '{other}'"))),
        };
        let req = Request { dataset, steps, mode, tau, sampler, body, return_images, cache };
        if req.lane_count() == 0 {
            return Err(Error::Request("request has zero lanes".into()));
        }
        // host-integrated kernels are undefined under injected noise; encode
        // plans are always deterministic regardless of the parsed `eta`
        if !matches!(req.body, RequestBody::Encode { .. }) && !sampler.supports(req.mode) {
            return Err(Error::Request(format!(
                "sampler '{}' requires a deterministic plan: \
                 stochastic requests (eta>0, sigma-hat) are DDIM-only",
                sampler.label()
            )));
        }
        Ok(req)
    }
}

/// Per-request completion record.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub body: ResponseBody,
    /// queue-to-completion latency, seconds.
    pub latency_s: f64,
    /// Executable steps the *producing execution* consumed (count ×
    /// dim(τ)). A cached response reports the original run's cost — it is
    /// a property of the sample; `cached` says whether this request paid
    /// it.
    pub steps_executed: usize,
    /// Answered from the completed-sample cache (no engine touched)?
    /// Coalesced waiters report `false`: their execution was shared, not
    /// replayed from the store.
    pub cached: bool,
}

/// Result payload.
#[derive(Debug, Clone)]
pub enum ResponseBody {
    /// Final states (x_0 for generate/decode, x_T for encode); empty when
    /// `return_images` was false.
    Ok { outputs: Vec<Vec<f32>> },
    Error { message: String },
}

impl Response {
    /// JSON wire form.
    pub fn to_json(&self) -> Value {
        match &self.body {
            ResponseBody::Ok { outputs } => {
                let imgs: Vec<Value> = outputs
                    .iter()
                    .map(|img| {
                        Value::Arr(img.iter().map(|&x| Value::Num(x as f64)).collect())
                    })
                    .collect();
                jobj![
                    ("id", self.id),
                    ("ok", true),
                    ("cached", self.cached),
                    ("latency_s", self.latency_s),
                    ("steps_executed", self.steps_executed),
                    ("outputs", Value::Arr(imgs)),
                ]
            }
            ResponseBody::Error { message } => jobj![
                ("id", self.id),
                ("ok", false),
                ("error", message.as_str()),
            ],
        }
    }

    pub fn to_json_line(&self) -> String {
        json::to_string(&self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_generate() {
        let v = json::parse(
            r#"{"op":"generate","dataset":"sprites","steps":20,"eta":0.5,
                "tau":"quadratic","count":4,"seed":7,"return_images":true}"#,
        )
        .unwrap();
        let r = Request::from_json(&v).unwrap();
        assert_eq!(r.steps, 20);
        assert_eq!(r.mode, NoiseMode::Eta(0.5));
        assert_eq!(r.tau, TauKind::Quadratic);
        assert_eq!(r.sampler, SamplerKind::Ddim);
        assert_eq!(r.lane_count(), 4);
        assert!(r.return_images);
        assert_eq!(r.cache, CacheMode::Use);
    }

    #[test]
    fn parse_cache_directive() {
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"cache":"bypass"}"#,
        )
        .unwrap();
        assert_eq!(Request::from_json(&v).unwrap().cache, CacheMode::Bypass);
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"cache":"use"}"#,
        )
        .unwrap();
        assert_eq!(Request::from_json(&v).unwrap().cache, CacheMode::Use);
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"cache":"never"}"#,
        )
        .unwrap();
        let err = Request::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("cache directive"), "{err}");
    }

    #[test]
    fn parse_sampler_field_and_default() {
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"sampler":"ab2"}"#,
        )
        .unwrap();
        assert_eq!(Request::from_json(&v).unwrap().sampler, SamplerKind::Ab2);
        // missing field falls back to the caller's default
        let v = json::parse(r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0}"#)
            .unwrap();
        assert_eq!(
            Request::from_json_with(&v, SamplerKind::PfOde).unwrap().sampler,
            SamplerKind::PfOde
        );
        // an explicit field beats the default
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"sampler":"ddim"}"#,
        )
        .unwrap();
        assert_eq!(
            Request::from_json_with(&v, SamplerKind::Ab2).unwrap().sampler,
            SamplerKind::Ddim
        );
    }

    #[test]
    fn parse_tau_field_and_default() {
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"tau":"opt"}"#,
        )
        .unwrap();
        assert_eq!(Request::from_json(&v).unwrap().tau, TauKind::Opt);
        // missing field falls back to the caller's default
        let v = json::parse(r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0}"#)
            .unwrap();
        assert_eq!(
            Request::from_json_with_defaults(&v, SamplerKind::Ddim, TauKind::Opt)
                .unwrap()
                .tau,
            TauKind::Opt
        );
        // an explicit field beats the default
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"tau":"quadratic"}"#,
        )
        .unwrap();
        assert_eq!(
            Request::from_json_with_defaults(&v, SamplerKind::Ddim, TauKind::Opt)
                .unwrap()
                .tau,
            TauKind::Quadratic
        );
        // unknown kinds list the valid set
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"tau":"cubic"}"#,
        )
        .unwrap();
        let err = Request::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("opt") && err.contains("quadratic"), "{err}");
    }

    #[test]
    fn rejects_host_kernels_on_stochastic_plans() {
        for s in [
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"eta":1.0,"sampler":"ab2"}"#,
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"eta":0.5,"sampler":"pf_ode"}"#,
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"eta":"hat","sampler":"ab2"}"#,
        ] {
            let v = json::parse(s).unwrap();
            let err = Request::from_json(&v).unwrap_err().to_string();
            assert!(err.contains("DDIM-only"), "{s} -> {err}");
        }
        // eta>0 with the default DDIM sampler stays legal
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"eta":1.0}"#,
        )
        .unwrap();
        assert!(Request::from_json(&v).is_ok());
        // encode is deterministic by construction: host kernels are allowed
        let v = json::parse(
            r#"{"op":"encode","dataset":"d","steps":5,"images":[[0.0]],"sampler":"pf_ode"}"#,
        )
        .unwrap();
        assert!(Request::from_json(&v).is_ok());
    }

    #[test]
    fn rejects_bad_seeds() {
        for s in [
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":-1}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":1.5}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":9007199254740994}"#,
        ] {
            let v = json::parse(s).unwrap();
            let err = Request::from_json(&v).unwrap_err().to_string();
            assert!(err.contains("seed"), "{s} -> {err}");
        }
    }

    #[test]
    fn parse_sigma_hat_and_defaults() {
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"eta":"hat","count":1,"seed":0}"#,
        )
        .unwrap();
        let r = Request::from_json(&v).unwrap();
        assert_eq!(r.mode, NoiseMode::SigmaHat);
        assert_eq!(r.tau, TauKind::Linear);
        assert!(!r.return_images);
    }

    #[test]
    fn parse_encode_decode() {
        let v = json::parse(
            r#"{"op":"encode","dataset":"d","steps":5,"images":[[0.0,1.0],[0.5,-0.5]]}"#,
        )
        .unwrap();
        let r = Request::from_json(&v).unwrap();
        assert_eq!(r.lane_count(), 2);
        let v = json::parse(r#"{"op":"decode","dataset":"d","steps":5,"latents":[[0.1]]}"#)
            .unwrap();
        assert_eq!(Request::from_json(&v).unwrap().lane_count(), 1);
    }

    #[test]
    fn rejects_malformed() {
        for s in [
            r#"{"op":"nope","dataset":"d","steps":5,"count":1,"seed":0}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":0,"seed":0}"#,
            r#"{"op":"generate","dataset":"d","count":1,"seed":0}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"eta":true}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"sampler":"euler"}"#,
            r#"{"op":"encode","dataset":"d","steps":5,"images":[]}"#,
        ] {
            let v = json::parse(s).unwrap();
            assert!(Request::from_json(&v).is_err(), "{s}");
        }
    }

    #[test]
    fn response_round_trip() {
        let r = Response {
            id: 3,
            body: ResponseBody::Ok { outputs: vec![vec![0.5, -0.25]] },
            latency_s: 0.125,
            steps_executed: 20,
            cached: true,
        };
        let v = json::parse(&r.to_json_line()).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 3);
        assert!(v.get("cached").unwrap().as_bool().unwrap());
        let outs = v.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs[0].as_f64_vec().unwrap(), vec![0.5, -0.25]);
        let e = Response {
            id: 4,
            body: ResponseBody::Error { message: "queue full".into() },
            latency_s: 0.0,
            steps_executed: 0,
            cached: false,
        };
        let v = json::parse(&e.to_json_line()).unwrap();
        assert!(!v.get("ok").unwrap().as_bool().unwrap());
    }
}
