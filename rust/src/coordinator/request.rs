//! Request/response types and their JSON wire forms.
//!
//! A request asks for `count` samples (lanes) under one sampling
//! configuration. Three kinds map onto the paper's experiments:
//! - `Generate`: x_T ~ N(0,I) -> x_0 (Tables 1/3, Figs. 3-5)
//! - `Decode`:   caller-supplied latents x_T -> x_0 (Fig. 6 interpolation)
//! - `Encode`:   caller-supplied images x_0 -> x_T (Table 2 reconstruction)

use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::jobj;
use crate::json::{self, Value};
use crate::sampler::SamplerKind;
use crate::schedule::{NoiseMode, TauKind};

/// Monotonically increasing request identifier (assigned by the engine).
pub type RequestId = u64;

/// Scheduling class for the overload-control queue (the wire's
/// `"priority"` field). Ordering in the engine queue is *strict*: every
/// queued interactive request is admitted before any batch request,
/// which in turn precedes best-effort. Only best-effort requests are
/// eligible for quality degradation under load.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Priority {
    Interactive,
    /// The default for requests that don't say (`"priority"` absent).
    #[default]
    Batch,
    BestEffort,
}

impl Priority {
    /// Number of priority bands (queue internals size their storage on it).
    pub const COUNT: usize = 3;

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "interactive" => Ok(Priority::Interactive),
            "batch" => Ok(Priority::Batch),
            "best_effort" => Ok(Priority::BestEffort),
            other => Err(Error::Request(format!(
                "unknown priority '{other}' (want interactive | batch | best_effort)"
            ))),
        }
    }

    /// Queue band index: 0 is served first.
    pub fn band(self) -> usize {
        self as usize
    }

    pub fn label(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
            Priority::BestEffort => "best_effort",
        }
    }
}

/// Delivery-shaping metadata that rides with the request but never enters
/// the cache key (like `return_images`): scheduling class, the instant the
/// transport first saw the request, and the optional completion deadline.
#[derive(Debug, Clone, Copy, Default)]
pub struct Qos {
    pub priority: Priority,
    /// When the connection layer read the request line. The latency clock
    /// and every deadline check run from here, so histograms measure
    /// client-observed latency; `None` (direct library use) falls back to
    /// engine-queue push time.
    pub arrived: Option<Instant>,
    /// Completion budget in milliseconds from `arrived`. Expired work is
    /// cancelled with a typed `"reject":{"reason":"deadline"}` — at
    /// admission, at tick boundaries, and before publish — never finished.
    pub deadline_ms: Option<u64>,
    /// Record wall-clock stage spans for this request (set by the
    /// transport for explicit `"trace":true` requests and for requests
    /// picked by `--trace-sample 1/N`). Like the rest of [`Qos`] this
    /// shapes delivery only — it never enters the cache key, so a traced
    /// and an untraced request still coalesce onto one execution.
    pub trace: bool,
}

impl Qos {
    /// Absolute deadline, if one was requested. `fallback` anchors requests
    /// that never crossed the transport (no arrival instant).
    pub fn deadline(&self, fallback: Instant) -> Option<Instant> {
        self.deadline_ms
            .map(|ms| self.arrived.unwrap_or(fallback) + Duration::from_millis(ms))
    }
}

/// Per-request cache directive (the wire's `"cache"` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CacheMode {
    /// Normal path: serve from / publish to the sample cache, coalesce
    /// onto identical in-flight executions.
    #[default]
    Use,
    /// `"cache":"bypass"` — skip lookup, coalescing, and publication;
    /// always execute. For clients probing the live engines (or refusing
    /// a shared result on principle).
    Bypass,
}

impl CacheMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "use" | "default" => Ok(CacheMode::Use),
            "bypass" => Ok(CacheMode::Bypass),
            other => Err(Error::Request(format!(
                "unknown cache directive '{other}' (want use | bypass)"
            ))),
        }
    }
}

/// What the request wants done.
#[derive(Debug, Clone)]
pub enum RequestBody {
    /// Sample `count` fresh images from the prior.
    Generate { count: usize, seed: u64 },
    /// Deterministically decode the provided latents (η forced to the
    /// request's mode; Fig. 6 uses η=0).
    Decode { latents: Vec<Vec<f32>> },
    /// Encode the provided images to latents (always deterministic).
    Encode { images: Vec<Vec<f32>> },
}

/// A fully-specified client request.
#[derive(Debug, Clone)]
pub struct Request {
    pub dataset: String,
    /// dim(τ) — sampling steps.
    pub steps: usize,
    pub mode: NoiseMode,
    pub tau: TauKind,
    /// Update kernel: `ddim` (Eq. 13, the fused executable's `x_prev`),
    /// `pf_ode` (Eq. 15 host Euler), or `ab2` (§7 multistep). The host
    /// kernels are deterministic-only; stochastic plans are DDIM-only.
    pub sampler: SamplerKind,
    pub body: RequestBody,
    /// Return pixel data in the response (else just stats).
    pub return_images: bool,
    /// Cache directive (`"cache":"bypass"` opts this request out of the
    /// sample cache and coalescing). Not part of the cache key — like
    /// `return_images`, it shapes delivery, not the sample.
    pub cache: CacheMode,
    /// Overload-control metadata (priority, arrival instant, deadline).
    /// Shapes scheduling and delivery, not the sample — excluded from the
    /// cache key by construction.
    pub qos: Qos,
}

impl Request {
    /// Number of lanes this request expands to.
    pub fn lane_count(&self) -> usize {
        match &self.body {
            RequestBody::Generate { count, .. } => *count,
            RequestBody::Decode { latents } => latents.len(),
            RequestBody::Encode { images } => images.len(),
        }
    }

    /// Parse the JSON-line wire form with the build-time default sampler.
    /// Minimal example:
    /// `{"op":"generate","dataset":"sprites","steps":20,"eta":"0.0","count":4,"seed":7}`
    pub fn from_json(v: &Value) -> Result<Self> {
        Self::from_json_with(v, SamplerKind::Ddim)
    }

    /// Parse the JSON-line wire form; a missing `"sampler"` field falls
    /// back to `default_sampler` (the server passes its
    /// `--default-sampler` here).
    pub fn from_json_with(v: &Value, default_sampler: SamplerKind) -> Result<Self> {
        Self::from_json_with_defaults(v, default_sampler, TauKind::Linear)
    }

    /// [`Request::from_json_with`] plus the server's `--tau` default: a
    /// missing `"tau"` field falls back to `default_tau` (an explicit
    /// field always wins).
    pub fn from_json_with_defaults(
        v: &Value,
        default_sampler: SamplerKind,
        default_tau: TauKind,
    ) -> Result<Self> {
        let op = v.get("op")?.as_str()?.to_string();
        let dataset = v.get("dataset")?.as_str()?.to_string();
        let steps = v.get("steps")?.as_usize()?;
        let mode = match v.get_opt("eta") {
            Some(Value::Str(s)) => NoiseMode::parse(s)?,
            Some(Value::Num(n)) => NoiseMode::Eta(*n),
            Some(other) => return Err(Error::Request(format!("bad eta {other:?}"))),
            None => NoiseMode::Eta(0.0),
        };
        let tau = match v.get_opt("tau") {
            Some(t) => TauKind::parse(t.as_str()?)?,
            None => default_tau,
        };
        let return_images = match v.get_opt("return_images") {
            Some(b) => b.as_bool()?,
            None => false,
        };
        let sampler = match v.get_opt("sampler") {
            Some(s) => SamplerKind::parse(s.as_str()?)?,
            None => default_sampler,
        };
        let cache = match v.get_opt("cache") {
            Some(c) => CacheMode::parse(c.as_str()?)?,
            None => CacheMode::Use,
        };
        let priority = match v.get_opt("priority") {
            Some(p) => Priority::parse(p.as_str()?)?,
            None => Priority::default(),
        };
        let deadline_ms = match v.get_opt("deadline_ms") {
            Some(d) => {
                let ms = d
                    .as_u64()
                    .map_err(|e| Error::Request(format!("deadline_ms: {e}")))?;
                if ms == 0 {
                    return Err(Error::Request("deadline_ms must be positive".into()));
                }
                Some(ms)
            }
            None => None,
        };
        let parse_matrix = |key: &str| -> Result<Vec<Vec<f32>>> {
            v.get(key)?
                .as_arr()?
                .iter()
                .map(|row| {
                    Ok(row
                        .as_f64_vec()?
                        .into_iter()
                        .map(|x| x as f32)
                        .collect::<Vec<f32>>())
                })
                .collect()
        };
        let body = match op.as_str() {
            "generate" => RequestBody::Generate {
                count: v.get("count")?.as_usize()?,
                // strict: negative / fractional / >=2^53 seeds are rejected
                // instead of silently truncated through an f64 cast
                seed: v
                    .get("seed")?
                    .as_u64()
                    .map_err(|e| Error::Request(format!("seed: {e}")))?,
            },
            "decode" => RequestBody::Decode { latents: parse_matrix("latents")? },
            "encode" => RequestBody::Encode { images: parse_matrix("images")? },
            other => return Err(Error::Request(format!("unknown op '{other}'"))),
        };
        let req = Request {
            dataset,
            steps,
            mode,
            tau,
            sampler,
            body,
            return_images,
            cache,
            qos: Qos { priority, arrived: None, deadline_ms, trace: false },
        };
        if req.lane_count() == 0 {
            return Err(Error::Request("request has zero lanes".into()));
        }
        // host-integrated kernels are undefined under injected noise; encode
        // plans are always deterministic regardless of the parsed `eta`
        if !matches!(req.body, RequestBody::Encode { .. }) && !sampler.supports(req.mode) {
            return Err(Error::Request(format!(
                "sampler '{}' requires a deterministic plan: \
                 stochastic requests (eta>0, sigma-hat) are DDIM-only",
                sampler.label()
            )));
        }
        Ok(req)
    }
}

/// Per-request completion record.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub body: ResponseBody,
    /// queue-to-completion latency, seconds.
    pub latency_s: f64,
    /// Executable steps the *producing execution* consumed (count ×
    /// dim(τ)). A cached response reports the original run's cost — it is
    /// a property of the sample; `cached` says whether this request paid
    /// it.
    pub steps_executed: usize,
    /// Answered from the completed-sample cache (no engine touched)?
    /// Coalesced waiters report `false`: their execution was shared, not
    /// replayed from the store.
    pub cached: bool,
    /// Set when overload shedding rewrote this request's step budget:
    /// `(requested S, executed S)`. Stamped per-request at the router, so
    /// every delivery path (direct, cache hit, coalesced waiter) reports
    /// the budget *this* client's sample was actually produced under.
    pub degraded: Option<(usize, usize)>,
    /// Stage spans recorded by the engine for traced requests
    /// ([`Qos::trace`]); `None` otherwise. Deliberately NOT serialized by
    /// [`Response::to_json`]: the transport injects a `"spans"` object
    /// only when the client explicitly asked (`"trace":true`), so
    /// sampling-traced responses stay byte-identical to untraced ones.
    pub spans: Option<crate::obs::Spans>,
    /// Answered by sharing an identical in-flight execution (parked
    /// waiter)? Reported as the `"coalesced"` access-log disposition;
    /// like [`Response::spans`], not part of the wire body.
    pub coalesced: bool,
}

/// Result payload.
#[derive(Debug, Clone)]
pub enum ResponseBody {
    /// Final states (x_0 for generate/decode, x_T for encode); empty when
    /// `return_images` was false.
    Ok { outputs: Vec<Vec<f32>> },
    Error { message: String },
    /// Typed overload/deadline rejection. On the wire this is structured
    /// (`"reject":{"reason":...,"queued_lanes":N}`), never a bare error
    /// string, so clients can back off or retry-with-budget mechanically.
    Reject(Reject),
}

/// Why admission (or the deadline checker) refused to finish a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectReason {
    /// Queue pressure: the item cap or the lane budget was exhausted.
    Overload,
    /// The request's deadline expired (at admission, a tick boundary, or
    /// the pre-publish check).
    Deadline,
}

impl RejectReason {
    pub fn label(self) -> &'static str {
        match self {
            RejectReason::Overload => "overload",
            RejectReason::Deadline => "deadline",
        }
    }
}

/// Structured rejection record carried by [`ResponseBody::Reject`].
#[derive(Debug, Clone)]
pub struct Reject {
    pub reason: RejectReason,
    /// Queued-lane pressure observed at the decision point (0 when the
    /// decision wasn't pressure-driven, e.g. a deadline expiry).
    pub queued_lanes: usize,
    /// Human-readable detail; supplements the typed fields.
    pub message: String,
}

impl Response {
    /// JSON wire form.
    pub fn to_json(&self) -> Value {
        let mut obj = match &self.body {
            ResponseBody::Ok { outputs } => {
                let imgs: Vec<Value> = outputs
                    .iter()
                    .map(|img| {
                        Value::Arr(img.iter().map(|&x| Value::Num(x as f64)).collect())
                    })
                    .collect();
                jobj![
                    ("id", self.id),
                    ("ok", true),
                    ("cached", self.cached),
                    ("latency_s", self.latency_s),
                    ("steps_executed", self.steps_executed),
                    ("outputs", Value::Arr(imgs)),
                ]
            }
            ResponseBody::Error { message } => jobj![
                ("id", self.id),
                ("ok", false),
                ("error", message.as_str()),
            ],
            ResponseBody::Reject(r) => jobj![
                ("id", self.id),
                ("ok", false),
                ("error", r.message.as_str()),
                (
                    "reject",
                    jobj![
                        ("reason", r.reason.label()),
                        ("queued_lanes", r.queued_lanes),
                    ]
                ),
            ],
        };
        if let (Some((from, to)), Value::Obj(m)) = (self.degraded, &mut obj) {
            m.insert("degraded".into(), jobj![("from", from), ("to", to)]);
        }
        obj
    }

    pub fn to_json_line(&self) -> String {
        json::to_string(&self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_generate() {
        let v = json::parse(
            r#"{"op":"generate","dataset":"sprites","steps":20,"eta":0.5,
                "tau":"quadratic","count":4,"seed":7,"return_images":true}"#,
        )
        .unwrap();
        let r = Request::from_json(&v).unwrap();
        assert_eq!(r.steps, 20);
        assert_eq!(r.mode, NoiseMode::Eta(0.5));
        assert_eq!(r.tau, TauKind::Quadratic);
        assert_eq!(r.sampler, SamplerKind::Ddim);
        assert_eq!(r.lane_count(), 4);
        assert!(r.return_images);
        assert_eq!(r.cache, CacheMode::Use);
    }

    #[test]
    fn parse_cache_directive() {
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"cache":"bypass"}"#,
        )
        .unwrap();
        assert_eq!(Request::from_json(&v).unwrap().cache, CacheMode::Bypass);
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"cache":"use"}"#,
        )
        .unwrap();
        assert_eq!(Request::from_json(&v).unwrap().cache, CacheMode::Use);
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"cache":"never"}"#,
        )
        .unwrap();
        let err = Request::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("cache directive"), "{err}");
    }

    #[test]
    fn parse_sampler_field_and_default() {
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"sampler":"ab2"}"#,
        )
        .unwrap();
        assert_eq!(Request::from_json(&v).unwrap().sampler, SamplerKind::Ab2);
        // missing field falls back to the caller's default
        let v = json::parse(r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0}"#)
            .unwrap();
        assert_eq!(
            Request::from_json_with(&v, SamplerKind::PfOde).unwrap().sampler,
            SamplerKind::PfOde
        );
        // an explicit field beats the default
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"sampler":"ddim"}"#,
        )
        .unwrap();
        assert_eq!(
            Request::from_json_with(&v, SamplerKind::Ab2).unwrap().sampler,
            SamplerKind::Ddim
        );
    }

    #[test]
    fn parse_tau_field_and_default() {
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"tau":"opt"}"#,
        )
        .unwrap();
        assert_eq!(Request::from_json(&v).unwrap().tau, TauKind::Opt);
        // missing field falls back to the caller's default
        let v = json::parse(r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0}"#)
            .unwrap();
        assert_eq!(
            Request::from_json_with_defaults(&v, SamplerKind::Ddim, TauKind::Opt)
                .unwrap()
                .tau,
            TauKind::Opt
        );
        // an explicit field beats the default
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"tau":"quadratic"}"#,
        )
        .unwrap();
        assert_eq!(
            Request::from_json_with_defaults(&v, SamplerKind::Ddim, TauKind::Opt)
                .unwrap()
                .tau,
            TauKind::Quadratic
        );
        // unknown kinds list the valid set
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"tau":"cubic"}"#,
        )
        .unwrap();
        let err = Request::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("opt") && err.contains("quadratic"), "{err}");
    }

    #[test]
    fn rejects_host_kernels_on_stochastic_plans() {
        for s in [
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"eta":1.0,"sampler":"ab2"}"#,
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"eta":0.5,"sampler":"pf_ode"}"#,
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"eta":"hat","sampler":"ab2"}"#,
        ] {
            let v = json::parse(s).unwrap();
            let err = Request::from_json(&v).unwrap_err().to_string();
            assert!(err.contains("DDIM-only"), "{s} -> {err}");
        }
        // eta>0 with the default DDIM sampler stays legal
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"count":1,"seed":0,"eta":1.0}"#,
        )
        .unwrap();
        assert!(Request::from_json(&v).is_ok());
        // encode is deterministic by construction: host kernels are allowed
        let v = json::parse(
            r#"{"op":"encode","dataset":"d","steps":5,"images":[[0.0]],"sampler":"pf_ode"}"#,
        )
        .unwrap();
        assert!(Request::from_json(&v).is_ok());
    }

    #[test]
    fn rejects_bad_seeds() {
        for s in [
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":-1}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":1.5}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":9007199254740994}"#,
        ] {
            let v = json::parse(s).unwrap();
            let err = Request::from_json(&v).unwrap_err().to_string();
            assert!(err.contains("seed"), "{s} -> {err}");
        }
    }

    #[test]
    fn parse_sigma_hat_and_defaults() {
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":10,"eta":"hat","count":1,"seed":0}"#,
        )
        .unwrap();
        let r = Request::from_json(&v).unwrap();
        assert_eq!(r.mode, NoiseMode::SigmaHat);
        assert_eq!(r.tau, TauKind::Linear);
        assert!(!r.return_images);
    }

    #[test]
    fn parse_encode_decode() {
        let v = json::parse(
            r#"{"op":"encode","dataset":"d","steps":5,"images":[[0.0,1.0],[0.5,-0.5]]}"#,
        )
        .unwrap();
        let r = Request::from_json(&v).unwrap();
        assert_eq!(r.lane_count(), 2);
        let v = json::parse(r#"{"op":"decode","dataset":"d","steps":5,"latents":[[0.1]]}"#)
            .unwrap();
        assert_eq!(Request::from_json(&v).unwrap().lane_count(), 1);
    }

    #[test]
    fn rejects_malformed() {
        for s in [
            r#"{"op":"nope","dataset":"d","steps":5,"count":1,"seed":0}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":0,"seed":0}"#,
            r#"{"op":"generate","dataset":"d","count":1,"seed":0}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"eta":true}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"sampler":"euler"}"#,
            r#"{"op":"encode","dataset":"d","steps":5,"images":[]}"#,
        ] {
            let v = json::parse(s).unwrap();
            assert!(Request::from_json(&v).is_err(), "{s}");
        }
    }

    #[test]
    fn response_round_trip() {
        let r = Response {
            id: 3,
            body: ResponseBody::Ok { outputs: vec![vec![0.5, -0.25]] },
            latency_s: 0.125,
            steps_executed: 20,
            cached: true,
            degraded: None,
            spans: None,
            coalesced: false,
        };
        let v = json::parse(&r.to_json_line()).unwrap();
        assert!(v.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 3);
        assert!(v.get("cached").unwrap().as_bool().unwrap());
        assert!(v.get_opt("degraded").is_none());
        let outs = v.get("outputs").unwrap().as_arr().unwrap();
        assert_eq!(outs[0].as_f64_vec().unwrap(), vec![0.5, -0.25]);
        let e = Response {
            id: 4,
            body: ResponseBody::Error { message: "queue full".into() },
            latency_s: 0.0,
            steps_executed: 0,
            cached: false,
            degraded: None,
            spans: None,
            coalesced: false,
        };
        let v = json::parse(&e.to_json_line()).unwrap();
        assert!(!v.get("ok").unwrap().as_bool().unwrap());
        assert!(v.get_opt("reject").is_none());
    }

    #[test]
    fn parse_priority_and_deadline() {
        let v = json::parse(
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,
                "priority":"best_effort","deadline_ms":250}"#,
        )
        .unwrap();
        let r = Request::from_json(&v).unwrap();
        assert_eq!(r.qos.priority, Priority::BestEffort);
        assert_eq!(r.qos.deadline_ms, Some(250));
        assert!(r.qos.arrived.is_none());
        // both default off
        let v = json::parse(r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0}"#)
            .unwrap();
        let r = Request::from_json(&v).unwrap();
        assert_eq!(r.qos.priority, Priority::Batch);
        assert_eq!(r.qos.deadline_ms, None);
        // malformed values are typed errors, not silent defaults
        for s in [
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"priority":"urgent"}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"deadline_ms":0}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"deadline_ms":-5}"#,
            r#"{"op":"generate","dataset":"d","steps":5,"count":1,"seed":0,"deadline_ms":1.5}"#,
        ] {
            let v = json::parse(s).unwrap();
            assert!(Request::from_json(&v).is_err(), "{s}");
        }
    }

    #[test]
    fn priority_bands_are_strictly_ordered() {
        assert!(Priority::Interactive < Priority::Batch);
        assert!(Priority::Batch < Priority::BestEffort);
        assert_eq!(Priority::Interactive.band(), 0);
        assert_eq!(Priority::BestEffort.band(), Priority::COUNT - 1);
        for p in [Priority::Interactive, Priority::Batch, Priority::BestEffort] {
            assert_eq!(Priority::parse(p.label()).unwrap(), p);
        }
    }

    #[test]
    fn qos_deadline_anchors_on_arrival() {
        let t0 = Instant::now();
        let q =
            Qos { priority: Priority::Batch, arrived: Some(t0), deadline_ms: Some(40), trace: false };
        assert_eq!(q.deadline(t0 + Duration::from_secs(9)), Some(t0 + Duration::from_millis(40)));
        // no arrival instant: the fallback anchors the budget
        let q = Qos { arrived: None, ..q };
        assert_eq!(q.deadline(t0), Some(t0 + Duration::from_millis(40)));
        assert_eq!(Qos::default().deadline(t0), None);
    }

    #[test]
    fn reject_is_typed_on_the_wire() {
        let r = Response {
            id: 9,
            body: ResponseBody::Reject(Reject {
                reason: RejectReason::Overload,
                queued_lanes: 17,
                message: "queue full".into(),
            }),
            latency_s: 0.0,
            steps_executed: 0,
            cached: false,
            degraded: None,
            spans: None,
            coalesced: false,
        };
        let v = json::parse(&r.to_json_line()).unwrap();
        assert!(!v.get("ok").unwrap().as_bool().unwrap());
        let rej = v.get("reject").unwrap();
        assert_eq!(rej.get("reason").unwrap().as_str().unwrap(), "overload");
        assert_eq!(rej.get("queued_lanes").unwrap().as_usize().unwrap(), 17);
        // the bare string stays for old clients, but typed fields rule
        assert!(v.get("error").unwrap().as_str().unwrap().contains("queue full"));
    }

    #[test]
    fn spans_and_coalesced_never_leak_into_the_wire_body() {
        // transport v2 pins response payloads bitwise; trace spans reach
        // the wire only when the transport injects them for an explicit
        // "trace":true request, and the coalesced marker is log-only
        let r = Response {
            id: 3,
            body: ResponseBody::Ok { outputs: vec![] },
            latency_s: 0.1,
            steps_executed: 5,
            cached: false,
            degraded: None,
            spans: Some(crate::obs::Spans { total_s: 0.1, ..Default::default() }),
            coalesced: true,
        };
        let v = json::parse(&r.to_json_line()).unwrap();
        assert!(v.get_opt("spans").is_none());
        assert!(v.get_opt("coalesced").is_none());
    }

    #[test]
    fn degraded_record_rides_ok_responses() {
        let r = Response {
            id: 1,
            body: ResponseBody::Ok { outputs: vec![] },
            latency_s: 0.5,
            steps_executed: 20,
            cached: false,
            degraded: Some((100, 20)),
            spans: None,
            coalesced: false,
        };
        let v = json::parse(&r.to_json_line()).unwrap();
        let d = v.get("degraded").unwrap();
        assert_eq!(d.get("from").unwrap().as_usize().unwrap(), 100);
        assert_eq!(d.get("to").unwrap().as_usize().unwrap(), 20);
    }
}
