//! JSON-line TCP front end (std::net + threads; the offline build has no
//! tokio — a thread-per-connection design is plenty for a single-node
//! CPU-bound engine whose real concurrency lives in the batcher).
//!
//! Protocol: one JSON request per line (see [`super::request`]), one JSON
//! response per line, in order. `{"op":"metrics"}` returns a snapshot;
//! `{"op":"ping"}` returns `{"ok":true}`.
//!
//! Threading: the PJRT runtime is single-threaded by construction, so one
//! *engine thread* owns it; connection threads only parse/serialise and
//! exchange messages over channels.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::ServeConfig;
use crate::coordinator::engine::Engine;
use crate::coordinator::request::{Request, Response, ResponseBody};
use crate::error::{Error, Result};
use crate::jobj;
use crate::json::{self, Value};

enum Cmd {
    Submit(Request, Sender<Response>),
    Metrics(Sender<String>),
}

/// A running server: listener + engine threads.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    engine_handle: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind `cfg.listen` (use port 0 for ephemeral), spin up the engine
    /// thread (compiling executables), and start accepting.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.listen)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));

        let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
        let engine_stop = stop.clone();
        let engine_cfg = cfg.clone();
        let engine_handle = std::thread::Builder::new()
            .name("ddim-engine".into())
            .spawn(move || engine_thread(engine_cfg, cmd_rx, ready_tx, engine_stop))
            .map_err(Error::Io)?;
        // wait for the engine (runtime load + warmup) before accepting
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(Error::Coordinator(format!("engine failed: {e}"))),
            Err(_) => return Err(Error::Coordinator("engine thread died".into())),
        }

        let accept_stop = stop.clone();
        let accept_handle = std::thread::Builder::new()
            .name("ddim-accept".into())
            .spawn(move || accept_loop(listener, cmd_tx, accept_stop))
            .map_err(Error::Io)?;

        Ok(Server { addr, stop, accept_handle: Some(accept_handle), engine_handle: Some(engine_handle) })
    }

    /// Bound address (useful with ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Request shutdown and join the threads.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.engine_handle.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: TcpListener, cmd_tx: Sender<Cmd>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let tx = cmd_tx.clone();
                let _ = std::thread::Builder::new()
                    .name("ddim-conn".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, tx);
                    });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

fn handle_conn(stream: TcpStream, cmd_tx: Sender<Cmd>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = dispatch_line(trimmed, &cmd_tx);
        stream.write_all(reply.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
    }
}

fn dispatch_line(line: &str, cmd_tx: &Sender<Cmd>) -> String {
    let err = |msg: String| json::to_string(&jobj![("ok", false), ("error", msg)]);
    let v = match json::parse(line) {
        Ok(v) => v,
        Err(e) => return err(format!("parse: {e}")),
    };
    match v.get_opt("op").and_then(|o| o.as_str().ok().map(str::to_string)) {
        Some(op) if op == "ping" => json::to_string(&jobj![("ok", true), ("pong", true)]),
        Some(op) if op == "metrics" => {
            let (tx, rx) = mpsc::channel();
            if cmd_tx.send(Cmd::Metrics(tx)).is_err() {
                return err("engine gone".into());
            }
            rx.recv().unwrap_or_else(|_| err("engine gone".into()))
        }
        Some(_) => {
            let req = match Request::from_json(&v) {
                Ok(r) => r,
                Err(e) => return err(e.to_string()),
            };
            let (tx, rx) = mpsc::channel();
            if cmd_tx.send(Cmd::Submit(req, tx)).is_err() {
                return err("engine gone".into());
            }
            match rx.recv() {
                Ok(resp) => resp.to_json_line(),
                Err(_) => err("engine dropped request".into()),
            }
        }
        None => err("missing op".into()),
    }
}

/// Multi-model engine pool: one [`Engine`] per dataset, created lazily on
/// first request (the default dataset eagerly, so startup failures surface
/// before the server reports ready). Engines tick round-robin; request ids
/// are disambiguated to waiters per engine.
fn engine_thread(
    cfg: ServeConfig,
    cmd_rx: Receiver<Cmd>,
    ready_tx: Sender<std::result::Result<(), String>>,
    stop: Arc<AtomicBool>,
) {
    let mut engines: std::collections::BTreeMap<String, Engine> =
        std::collections::BTreeMap::new();
    let default = cfg.dataset.clone();
    match Engine::new(cfg.clone()).and_then(|mut e| {
        e.warmup()?;
        Ok(e)
    }) {
        Ok(e) => {
            engines.insert(default.clone(), e);
            let _ = ready_tx.send(Ok(()));
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e.to_string()));
            return;
        }
    }
    // waiters keyed by (dataset, request id)
    let mut waiters: std::collections::HashMap<(String, u64), Sender<Response>> =
        std::collections::HashMap::new();
    while !stop.load(Ordering::SeqCst) {
        // drain pending commands; block briefly only when fully idle
        loop {
            let idle = engines.values().all(|e| e.active_lanes() == 0 && e.queued() == 0);
            let cmd = if idle {
                match cmd_rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(c) => Some(c),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                }
            } else {
                match cmd_rx.try_recv() {
                    Ok(c) => Some(c),
                    Err(mpsc::TryRecvError::Empty) => None,
                    Err(mpsc::TryRecvError::Disconnected) => return,
                }
            };
            let Some(cmd) = cmd else { break };
            match cmd {
                Cmd::Submit(req, tx) => {
                    let ds = req.dataset.clone();
                    // lazily bring up an engine for a new dataset
                    if !engines.contains_key(&ds) {
                        let mut c = cfg.clone();
                        c.dataset = ds.clone();
                        match Engine::new(c) {
                            Ok(e) => {
                                engines.insert(ds.clone(), e);
                            }
                            Err(e) => {
                                let _ = tx.send(Response {
                                    id: 0,
                                    body: ResponseBody::Error { message: e.to_string() },
                                    latency_s: 0.0,
                                    steps_executed: 0,
                                });
                                continue;
                            }
                        }
                    }
                    let engine = engines.get_mut(&ds).unwrap();
                    match engine.submit(req) {
                        Ok(id) => {
                            waiters.insert((ds, id), tx);
                        }
                        Err(e) => {
                            let _ = tx.send(Response {
                                id: 0,
                                body: ResponseBody::Error { message: e.to_string() },
                                latency_s: 0.0,
                                steps_executed: 0,
                            });
                        }
                    }
                }
                Cmd::Metrics(tx) => {
                    // aggregate across engines
                    let mut agg = crate::coordinator::metrics::MetricsSnapshot::default();
                    let mut active = 0usize;
                    let mut queued = 0usize;
                    for e in engines.values() {
                        let m = e.metrics();
                        agg.requests_completed += m.requests_completed;
                        agg.requests_rejected += m.requests_rejected;
                        agg.lanes_completed += m.lanes_completed;
                        agg.executable_calls += m.executable_calls;
                        agg.steps_executed += m.steps_executed;
                        agg.occupancy_sum += m.occupancy_sum;
                        agg.latency_p50_s = agg.latency_p50_s.max(m.latency_p50_s);
                        agg.latency_p95_s = agg.latency_p95_s.max(m.latency_p95_s);
                        agg.latency_p99_s = agg.latency_p99_s.max(m.latency_p99_s);
                        agg.wall_s = agg.wall_s.max(m.wall_s);
                        active += e.active_lanes();
                        queued += e.queued();
                    }
                    let _ = tx.send(json::to_string(&jobj![
                        ("ok", true),
                        ("engines", engines.len()),
                        ("requests_completed", agg.requests_completed),
                        ("requests_rejected", agg.requests_rejected),
                        ("lanes_completed", agg.lanes_completed),
                        ("executable_calls", agg.executable_calls),
                        ("steps_executed", agg.steps_executed),
                        ("occupancy", agg.occupancy()),
                        ("latency_p50_s", agg.latency_p50_s),
                        ("latency_p95_s", agg.latency_p95_s),
                        ("latency_p99_s", agg.latency_p99_s),
                        ("steps_per_second", agg.steps_per_second()),
                        ("active_lanes", active),
                        ("queued", queued),
                    ]));
                }
            }
        }
        for (ds, engine) in engines.iter_mut() {
            if let Err(e) = engine.tick() {
                eprintln!("[engine:{ds}] tick error: {e}");
            }
            for resp in engine.take_completed() {
                if let Some(tx) = waiters.remove(&(ds.clone(), resp.id)) {
                    let _ = tx.send(resp);
                }
            }
        }
    }
}

/// Minimal blocking client for examples, benches and tests: send one JSON
/// line, read one JSON line back, over a persistent connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Self { reader: BufReader::new(stream.try_clone()?), stream })
    }

    /// Send one request line, wait for the response line.
    pub fn roundtrip(&mut self, v: &Value) -> Result<Value> {
        self.stream.write_all(json::to_string(v).as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(Error::Coordinator("server closed connection".into()));
        }
        json::parse(line.trim())
    }
}
