//! Transport v2: a multiplexed JSON-line TCP front end (std::net + raw
//! epoll; the offline build has no tokio).
//!
//! One acceptor thread hands sockets round-robin to N event-loop
//! reactors ([`super::reactor`]); each reactor multiplexes its slice of
//! the connections through a [`super::conn::ConnState`] framing machine
//! and never blocks on any one client. Requests may carry an optional
//! `"id"` — echoed verbatim on the response — so a client can pipeline
//! many requests on one connection and match completions as they arrive
//! **out of order**. An opt-in `"stream":{"every":K}` directive streams
//! `{"frame":"x0_preview",...}` lines while the request runs: the
//! predicted x̂₀ of Eq. 12 every K committed steps (see
//! [`super::engine::ProgressSink`]).
//!
//! Wire protocol (one JSON value per line, see `docs/serving.md`):
//! - `{"op":"ping"}` → `{"ok":true,"pong":true}`
//! - `{"op":"metrics"}` → merged router snapshot + a `"transport"`
//!   section (connections, accept errors, frames streamed/dropped)
//! - `{"op":"generate"|"decode"|"encode",...}` → one final response
//!   line; with `"id"`, pipelined; with `"stream"`, preview frames
//!   interleave ahead of it. `"id"` and `"stream"` shape *delivery*
//!   only — they are parsed here at the transport and never enter
//!   [`Request`], so the sample cache key cannot depend on them.
//!
//! This module is *pure transport*: reactors parse lines, hand requests
//! to the [`Router`], and queue response lines. All scheduling — the
//! sample-cache/coalescing front ([`crate::cache`]), shard placement,
//! least-loaded dispatch, tick loops, drain-on-shutdown — lives in
//! [`super::router`] / [`super::shard`]. A request answered from the
//! cache never leaves the reactor's submit call.

use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::{DoneFn, KEY_VERSION};
use crate::config::ServeConfig;
use crate::coordinator::conn::ConnState;
use crate::coordinator::engine::ProgressSink;
use crate::coordinator::metrics::Histogram;
use crate::coordinator::reactor::{Completion, LineHandler, Reactor, ReactorShared};
use crate::coordinator::request::{CacheMode, Request, RequestBody, ResponseBody};
use crate::coordinator::router::Router;
use crate::error::{Error, Result};
use crate::jobj;
use crate::json::{self, Value};
use crate::obs::{
    prom, AccessLogger, AccessRecord, BuildInfo, ObsSelf, RotationPolicy, TransportCounters,
};
use crate::schedule::TauKind;

/// Acceptor-side counters plus the open-connection gauge the reactors
/// keep honest (decremented on every close path, including drops during
/// reactor shutdown).
#[derive(Default)]
struct TransportStats {
    accepted: AtomicU64,
    accept_errors: AtomicU64,
    open: Arc<AtomicU64>,
}

/// Observability state shared by every reactor's protocol handler: the
/// access-log writer, the trace sampler, and the process start instant
/// behind `uptime_s` / `ddim_build_info`.
struct Obs {
    logger: Option<AccessLogger>,
    /// `--trace-sample N`: every Nth request op gets stage spans (0 = off).
    trace_sample: u64,
    /// Request ops seen by the sampler (the `% trace_sample` clock).
    trace_counter: AtomicU64,
    /// Requests the sampler picked (exported; explicit `"trace":true`
    /// requests are not counted — they didn't consume the sample budget).
    traces_sampled: AtomicU64,
    started: Instant,
}

impl Obs {
    /// Open the access log (failing at startup, not on the first
    /// request) and arm the trace sampler.
    fn from_config(cfg: &ServeConfig) -> Result<Obs> {
        let logger = if cfg.access_log.is_empty() {
            None
        } else {
            let policy = RotationPolicy {
                max_bytes: cfg.log_rotate_bytes,
                max_secs: cfg.log_rotate_secs,
                keep: cfg.log_keep,
            };
            Some(AccessLogger::start(&cfg.access_log, policy).map_err(Error::Io)?)
        };
        Ok(Obs {
            logger,
            trace_sample: cfg.trace_sample,
            trace_counter: AtomicU64::new(0),
            traces_sampled: AtomicU64::new(0),
            started: Instant::now(),
        })
    }

    /// Sampler decision for one request op; counts picks.
    fn sample_trace(&self) -> bool {
        if self.trace_sample == 0 {
            return false;
        }
        if self.trace_counter.fetch_add(1, Ordering::Relaxed) % self.trace_sample == 0 {
            self.traces_sampled.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    fn self_metrics(&self) -> ObsSelf {
        ObsSelf {
            access_log_enabled: self.logger.is_some(),
            lines_written: self.logger.as_ref().map_or(0, AccessLogger::lines_written),
            lines_dropped: self.logger.as_ref().map_or(0, AccessLogger::lines_dropped),
            traces_sampled: self.traces_sampled.load(Ordering::Relaxed),
        }
    }
}

/// A running server: acceptor thread + N reactor threads + router-owned
/// shard threads.
pub struct Server {
    addr: SocketAddr,
    accept_stop: Arc<AtomicBool>,
    reactor_stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    reactor_handles: Vec<JoinHandle<()>>,
    reactors: Vec<Arc<ReactorShared>>,
    router: Option<Arc<Router>>,
    obs: Arc<Obs>,
}

impl Server {
    /// Bind `cfg.listen` (use port 0 for ephemeral), bring up the default
    /// dataset's shard pool (compiling executables), start `cfg.reactors`
    /// event loops, and start accepting.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        cfg.validate()?;
        let n_reactors = cfg.reactors;
        let listener = TcpListener::bind(&cfg.listen)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let obs = Arc::new(Obs::from_config(&cfg)?);
        let router = Arc::new(Router::start(cfg)?);
        let stats = Arc::new(TransportStats::default());

        let reactor_stop = Arc::new(AtomicBool::new(false));
        let mut pairs = Vec::with_capacity(n_reactors);
        for i in 0..n_reactors {
            pairs.push(Reactor::new(i).map_err(Error::Io)?);
        }
        let shareds: Vec<Arc<ReactorShared>> = pairs.iter().map(|(_, s)| s.clone()).collect();
        let all = Arc::new(shareds.clone());
        let mut reactor_handles = Vec::with_capacity(n_reactors);
        for (reactor, shared) in pairs {
            let handler =
                make_handler(router.clone(), shared, all.clone(), stats.clone(), obs.clone());
            reactor_handles.push(
                reactor
                    .start(handler, reactor_stop.clone(), stats.open.clone())
                    .map_err(Error::Io)?,
            );
        }

        let accept_stop = Arc::new(AtomicBool::new(false));
        let accept_handle = {
            let reactors = shareds.clone();
            let stats = stats.clone();
            let stop = accept_stop.clone();
            std::thread::Builder::new()
                .name("ddim-accept".into())
                .spawn(move || accept_loop(listener, reactors, stats, stop))
                .map_err(Error::Io)?
        };

        Ok(Server {
            addr,
            accept_stop,
            reactor_stop,
            accept_handle: Some(accept_handle),
            reactor_handles,
            reactors: shareds,
            router: Some(router),
            obs,
        })
    }

    /// Bound address (useful with ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The router, for in-process callers that want to prewarm pools or
    /// read metrics without a TCP round trip.
    pub fn router(&self) -> Option<&Arc<Router>> {
        self.router.as_ref()
    }

    /// Graceful shutdown, in dependency order: stop accepting, drain the
    /// shard pool (in-flight lanes get up to `drain_timeout_ms`; every
    /// remaining waiter is answered with a "shutting down" error) **while
    /// the reactors are still running** so those answers reach their
    /// sockets, then stop and join the reactors — which give pending
    /// write buffers one bounded flush before closing every connection.
    /// No connection thread outlives this call: the reactors own all
    /// sockets and are joined here.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.accept_stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(router) = self.router.take() {
            router.shutdown();
        }
        self.reactor_stop.store(true, Ordering::SeqCst);
        for r in &self.reactors {
            r.wake();
        }
        for h in self.reactor_handles.drain(..) {
            let _ = h.join();
        }
        // last: the reactors are joined, so no completion path can race
        // new lines into the channel — everything queued gets written
        if let Some(logger) = &self.obs.logger {
            logger.shutdown();
        }
    }
}

impl Drop for Server {
    /// Dropping a server that was not shut down explicitly still joins
    /// every thread and closes every socket (idempotent: after
    /// [`Server::shutdown`] all handles are already taken).
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Accept loop: round-robin sockets over the reactors. Transient errors
/// (fd exhaustion, aborted handshakes, signals) are counted and retried
/// — never a silent exit; only the stop flag ends the loop.
fn accept_loop(
    listener: TcpListener,
    reactors: Vec<Arc<ReactorShared>>,
    stats: Arc<TransportStats>,
    stop: Arc<AtomicBool>,
) {
    let mut rr = 0usize;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                stats.accepted.fetch_add(1, Ordering::Relaxed);
                stats.open.fetch_add(1, Ordering::Relaxed);
                reactors[rr % reactors.len()].push_conn(stream);
                rr += 1;
            }
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(ref e) if transient_accept_error(e) => {
                stats.accept_errors.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                // unexpected: count it, say so, back off, keep serving —
                // the old loop's silent `break` left a zombie server
                stats.accept_errors.fetch_add(1, Ordering::Relaxed);
                eprintln!("ddim-accept: accept failed: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Errors `accept(2)` emits under load that mean "try again", not "the
/// listener is broken": per-process/system fd exhaustion (EMFILE=24 /
/// ENFILE=23 — no stable `ErrorKind`, matched by errno), connections
/// that died in the backlog, and signal interruptions.
fn transient_accept_error(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::ConnectionAborted
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::Interrupted
    ) || matches!(e.raw_os_error(), Some(23) | Some(24))
}

/// Build the protocol handler one reactor runs for every complete line:
/// captures that reactor's own inbox (completions must come back to the
/// thread that owns the socket) plus the full reactor list and acceptor
/// counters for the metrics op.
fn make_handler(
    router: Arc<Router>,
    own: Arc<ReactorShared>,
    all: Arc<Vec<Arc<ReactorShared>>>,
    stats: Arc<TransportStats>,
    obs: Arc<Obs>,
) -> LineHandler {
    Arc::new(move |token, line, state| {
        handle_line(token, line, state, &router, &own, &all, &stats, &obs)
    })
}

#[allow(clippy::too_many_arguments)]
fn handle_line(
    token: u64,
    line: &str,
    state: &mut ConnState,
    router: &Arc<Router>,
    own: &Arc<ReactorShared>,
    all: &[Arc<ReactorShared>],
    stats: &TransportStats,
    obs: &Arc<Obs>,
) {
    // client-observed latency starts when the transport has the complete
    // line, *before* parsing and queueing — not when an engine finally
    // pops the request (which under load hides the whole queue wait)
    let arrived = Instant::now();
    // minimal HTTP/1.0 surface on the same port: a scraper's
    // `GET /metrics` request line is unmistakably not JSON, so route it
    // before the parser and hang up once the response has flushed (the
    // close-after-flush latch also swallows the trailing header lines)
    if line.starts_with("GET ") {
        let path = line.split_whitespace().nth(1).unwrap_or("");
        if path == "/metrics" || path.starts_with("/metrics?") {
            let body = prometheus_text(router, all, stats, obs);
            state.queue_line("HTTP/1.0 200 OK\r");
            state.queue_line("Content-Type: text/plain; version=0.0.4; charset=utf-8\r");
            state.queue_line("Connection: close\r");
            state.queue_line("\r");
            // no Content-Length: HTTP/1.0 + close delimits the body
            state.queue_line(body.trim_end_matches('\n'));
        } else {
            state.queue_line("HTTP/1.0 404 Not Found\r");
            state.queue_line("Connection: close\r");
            state.queue_line("\r");
        }
        state.mark_close_after_flush();
        return;
    }
    let v = match json::parse(line.trim()) {
        Ok(v) => v,
        Err(e) => return queue_err(state, None, format!("parse: {e}")),
    };
    // the pipelining id is any JSON value, echoed verbatim on every line
    // this request produces (frames included)
    let client_id = v.get_opt("id").cloned();
    let Some(op) = v.get_opt("op").and_then(|o| o.as_str().ok().map(str::to_string)) else {
        return queue_err(state, client_id.as_ref(), "missing op".into());
    };
    match op.as_str() {
        "ping" => {
            let mut r = jobj![("ok", true), ("pong", true)];
            if let Some(id) = &client_id {
                let _ = r.set("id", id.clone());
            }
            state.queue_line(&json::to_string(&r));
        }
        "metrics" => {
            // `{"op":"metrics","format":"prometheus"}` returns the same
            // scrape the HTTP responder serves, as a JSON string field
            let prom_fmt =
                v.get_opt("format").and_then(|f| f.as_str().ok()) == Some("prometheus");
            if prom_fmt {
                let mut r =
                    jobj![("ok", true), ("prometheus", prometheus_text(router, all, stats, obs))];
                if let Some(id) = &client_id {
                    let _ = r.set("id", id.clone());
                }
                state.queue_line(&json::to_string(&r));
                return;
            }
            let mut m = router.metrics_value();
            let _ = m.set("transport", transport_value(stats, all));
            let _ = m.set("uptime_s", Value::from(obs.started.elapsed().as_secs_f64()));
            let _ = m.set("version", Value::from(env!("CARGO_PKG_VERSION")));
            let _ = m.set("key_version", Value::from(KEY_VERSION as u64));
            let _ = m.set(
                "manifest_digest",
                Value::from(format!("{:016x}", router.cache().current_digest())),
            );
            let o = obs.self_metrics();
            let _ = m.set(
                "obs",
                jobj![
                    ("access_log_enabled", o.access_log_enabled),
                    ("access_log_lines", o.lines_written),
                    ("access_log_dropped", o.lines_dropped),
                    ("traces_sampled", o.traces_sampled),
                    ("trace_sample", obs.trace_sample),
                ],
            );
            if let Some(id) = &client_id {
                let _ = m.set("id", id.clone());
            }
            state.queue_line(&json::to_string(&m));
        }
        _ => {
            let every = match parse_stream(&v) {
                Ok(e) => e,
                Err(e) => return queue_err(state, client_id.as_ref(), e.to_string()),
            };
            // `"id"`/`"stream"` were peeled off above; the Request parser
            // ignores unknown fields, so neither can reach the cache key
            let mut req = match Request::from_json_with_defaults(
                &v,
                router.config().default_sampler,
                router.config().default_tau,
            ) {
                Ok(r) => r,
                Err(e) => return queue_err(state, client_id.as_ref(), e.to_string()),
            };
            req.qos.arrived = Some(arrived);
            // server-side deadline floor: requests that name no budget get
            // the configured default (0 = unlimited, the old behavior)
            if req.qos.deadline_ms.is_none() && router.config().deadline_default_ms > 0 {
                req.qos.deadline_ms = Some(router.config().deadline_default_ms);
            }
            // trace decision: explicit `"trace":true` always records
            // spans (and is the only thing that puts them on the wire);
            // the `--trace-sample` clock covers everything else. Like
            // `"id"`/`"stream"`, `"trace"` is peeled at the transport and
            // never enters the cache key.
            let explicit_trace = matches!(v.get_opt("trace"), Some(Value::Bool(true)));
            req.qos.trace = explicit_trace || obs.sample_trace();
            // clone what the access-log line will need before submission
            // consumes the request (steps here are pre-degradation)
            let log_ctx = obs.logger.as_ref().map(|_| LogCtx {
                id: client_id.clone().unwrap_or(Value::Null),
                op: match &req.body {
                    RequestBody::Generate { .. } => "generate",
                    RequestBody::Decode { .. } => "decode",
                    RequestBody::Encode { .. } => "encode",
                },
                dataset: req.dataset.clone(),
                lanes: req.lane_count(),
                steps_requested: req.steps,
                sampler: req.sampler.label(),
                tau: tau_label(req.tau),
                priority: req.qos.priority.label(),
                deadline_ms: req.qos.deadline_ms,
                bypass: req.cache == CacheMode::Bypass,
            });
            let progress = every.map(|every| {
                let sh = own.clone();
                let cid = client_id.clone();
                Arc::new(ProgressSink {
                    every,
                    on_step: Box::new(move |lane, step, total, x0| {
                        let mut f = jobj![
                            ("frame", "x0_preview"),
                            ("lane", lane),
                            ("step", step),
                            ("total_steps", total),
                            (
                                "x0",
                                Value::Arr(
                                    x0.iter().map(|&x| Value::Num(x as f64)).collect()
                                )
                            ),
                        ];
                        if let Some(id) = &cid {
                            let _ = f.set("id", id.clone());
                        }
                        sh.push_completion(Completion {
                            token,
                            line: json::to_string(&f),
                            frame: true,
                        });
                    }),
                })
            });
            let sh = own.clone();
            let obs = obs.clone();
            let done: DoneFn = Box::new(move |mut resp| {
                // publish span: everything after engine completion —
                // router/cache fan-out, serialization, queueing. total_s
                // shares the clock with the latency histograms.
                let total_s = arrived.elapsed().as_secs_f64();
                if let Some(sp) = resp.spans.as_mut() {
                    sp.total_s = total_s;
                    sp.publish_s = (total_s - resp.latency_s).max(0.0);
                }
                let mut r = resp.to_json();
                if explicit_trace {
                    if let Some(sp) = &resp.spans {
                        let _ = r.set("spans", sp.to_json());
                    }
                }
                if let Some(id) = client_id {
                    let _ = r.set("id", id);
                }
                let line = json::to_string(&r);
                if let (Some(logger), Some(ctx)) = (&obs.logger, log_ctx) {
                    let (outcome, reject_reason) = match &resp.body {
                        ResponseBody::Ok { .. } => ("ok", None),
                        ResponseBody::Error { .. } => ("error", None),
                        ResponseBody::Reject(rej) => ("reject", Some(rej.reason.label())),
                    };
                    let cache = if ctx.bypass {
                        "bypass"
                    } else if resp.coalesced {
                        // before `cached`: a leader-reprobe follower
                        // carries both flags, and shared-execution is the
                        // disposition that explains its latency
                        "coalesced"
                    } else if resp.cached {
                        "hit"
                    } else {
                        "miss"
                    };
                    logger.log(&AccessRecord {
                        id: ctx.id,
                        op: ctx.op,
                        dataset: ctx.dataset,
                        lanes: ctx.lanes,
                        steps_requested: ctx.steps_requested,
                        steps_executed: resp.steps_executed,
                        sampler: ctx.sampler,
                        tau: ctx.tau,
                        priority: ctx.priority,
                        deadline_ms: ctx.deadline_ms,
                        outcome,
                        reject_reason,
                        cache,
                        degraded: resp.degraded,
                        latency_s: resp.latency_s,
                        total_s,
                        bytes_out: line.len() + 1,
                        spans: resp.spans,
                    });
                }
                sh.push_completion(Completion { token, line, frame: false });
            });
            // may complete synchronously (cache hit) — the completion
            // lands in our own inbox and is drained this same loop pass
            router.submit_with(req, done, progress);
        }
    }
}

/// Parse the opt-in streaming directive `{"stream":{"every":K}}`.
fn parse_stream(v: &Value) -> Result<Option<usize>> {
    let Some(s) = v.get_opt("stream") else {
        return Ok(None);
    };
    let every = s.get("every")?.as_usize()?;
    if every == 0 {
        return Err(Error::Request("stream.every must be >= 1".into()));
    }
    Ok(Some(every))
}

fn queue_err(state: &mut ConnState, id: Option<&Value>, msg: String) {
    let mut e = jobj![("ok", false), ("error", msg)];
    if let Some(id) = id {
        let _ = e.set("id", id.clone());
    }
    state.queue_line(&json::to_string(&e));
}

/// One snapshot of every transport-layer counter — the single source both
/// the JSON `"transport"` section and the Prometheus encoder read from.
fn gather_transport(stats: &TransportStats, reactors: &[Arc<ReactorShared>]) -> TransportCounters {
    let mut t = TransportCounters {
        reactors: reactors.len(),
        connections_total: stats.accepted.load(Ordering::Relaxed),
        connections_open: stats.open.load(Ordering::Relaxed),
        accept_errors: stats.accept_errors.load(Ordering::Relaxed),
        ..TransportCounters::default()
    };
    for r in reactors {
        t.wakeups += r.stats.wakeups.load(Ordering::Relaxed);
        t.frames_streamed += r.stats.frames_streamed.load(Ordering::Relaxed);
        t.frames_dropped += r.stats.frames_dropped.load(Ordering::Relaxed);
        t.lines_overlong += r.stats.lines_overlong.load(Ordering::Relaxed);
        t.writes_coalesced += r.stats.writes_coalesced.load(Ordering::Relaxed);
    }
    t
}

/// The `"transport"` section of the metrics response.
fn transport_value(stats: &TransportStats, reactors: &[Arc<ReactorShared>]) -> Value {
    let t = gather_transport(stats, reactors);
    jobj![
        ("reactors", t.reactors),
        ("connections_total", t.connections_total),
        ("connections_open", t.connections_open),
        ("accept_errors", t.accept_errors),
        ("wakeups", t.wakeups),
        ("frames_streamed", t.frames_streamed),
        ("frames_dropped", t.frames_dropped),
        ("lines_overlong", t.lines_overlong),
        ("writes_coalesced", t.writes_coalesced),
    ]
}

/// The full Prometheus exposition for this process: coordinator counters
/// (merged + per-shard), cache, transport, build identity, and the
/// observability plane's own health.
fn prometheus_text(
    router: &Arc<Router>,
    reactors: &[Arc<ReactorShared>],
    stats: &TransportStats,
    obs: &Obs,
) -> String {
    let (agg, shards) = router.aggregate();
    // aggregate() collapses the merged histogram into quantiles; the
    // exposition wants the buckets themselves, so re-merge here
    let mut latency = Histogram::new();
    for s in &shards {
        latency.merge(&s.latency);
    }
    let build = BuildInfo {
        version: env!("CARGO_PKG_VERSION"),
        key_version: KEY_VERSION,
        manifest_digest: router.cache().current_digest(),
        uptime_s: obs.started.elapsed().as_secs_f64(),
    };
    prom::render(
        &build,
        &agg,
        &latency,
        &shards,
        &router.cache().metrics(),
        &gather_transport(stats, reactors),
        &obs.self_metrics(),
    )
}

/// Request fields captured at admission for the access-log line the
/// completion path writes. Everything here is a copy: the [`Request`]
/// itself is consumed by submission (and `steps` may be rewritten by
/// degradation before it reaches an engine — the log reports both the
/// requested and the executed count).
struct LogCtx {
    id: Value,
    op: &'static str,
    dataset: String,
    lanes: usize,
    steps_requested: usize,
    sampler: &'static str,
    tau: &'static str,
    priority: &'static str,
    deadline_ms: Option<u64>,
    bypass: bool,
}

fn tau_label(t: TauKind) -> &'static str {
    match t {
        TauKind::Linear => "linear",
        TauKind::Quadratic => "quadratic",
        TauKind::Opt => "opt",
    }
}

/// Minimal blocking client for examples, benches and tests, over a
/// persistent connection. [`Client::roundtrip`] is the v1 serial shape;
/// [`Client::submit`] + [`Client::recv_frame`] drive the v2 pipelined /
/// streaming shape (tag requests with ids, read lines as they arrive).
pub struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Self { reader: BufReader::new(stream.try_clone()?), stream })
    }

    /// Send one request line, wait for the response line.
    pub fn roundtrip(&mut self, v: &Value) -> Result<Value> {
        self.send_line(v)?;
        self.recv_frame()
    }

    /// Pipeline: tag `v` with `"id": id` and send it without waiting.
    /// Completions arrive (out of order) via [`Client::recv_frame`].
    pub fn submit(&mut self, id: u64, v: &Value) -> Result<()> {
        let mut tagged = v.clone();
        tagged.set("id", Value::from(id))?;
        self.send_line(&tagged)
    }

    /// Read the next line the server sends: a final response or an
    /// interleaved `"frame"` line.
    pub fn recv_frame(&mut self) -> Result<Value> {
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(Error::Coordinator("server closed connection".into()));
        }
        json::parse(line.trim())
    }

    fn send_line(&mut self, v: &Value) -> Result<()> {
        self.stream.write_all(json::to_string(v).as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        Ok(())
    }
}
