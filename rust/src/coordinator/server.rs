//! JSON-line TCP front end (std::net + threads; the offline build has no
//! tokio — a thread-per-connection design is plenty when the real
//! concurrency lives in the shard pool).
//!
//! Protocol: one JSON request per line (see [`super::request`]), one JSON
//! response per line, in order. `{"op":"metrics"}` returns a merged
//! snapshot with a per-shard breakdown; `{"op":"ping"}` returns
//! `{"ok":true}`. See `docs/serving.md` for the full wire format.
//!
//! This module is *pure transport*: connection threads parse a line, hand
//! the request to the [`Router`], and write the response line back. All
//! scheduling — the sample-cache/coalescing front ([`crate::cache`]),
//! shard placement, least-loaded dispatch, tick loops, drain-on-shutdown
//! — lives in [`super::router`] / [`super::shard`]. A request answered
//! from the cache never leaves the connection thread's submit call.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::ServeConfig;
use crate::coordinator::request::Request;
use crate::coordinator::router::Router;
use crate::error::{Error, Result};
use crate::jobj;
use crate::json::{self, Value};

/// A running server: listener thread + router-owned shard threads.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<JoinHandle<()>>,
    router: Option<Arc<Router>>,
}

impl Server {
    /// Bind `cfg.listen` (use port 0 for ephemeral), bring up the default
    /// dataset's shard pool (compiling executables), and start accepting.
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        cfg.validate()?;
        let listener = TcpListener::bind(&cfg.listen)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));

        let router = Arc::new(Router::start(cfg)?);

        let accept_stop = stop.clone();
        let accept_router = router.clone();
        let accept_handle = std::thread::Builder::new()
            .name("ddim-accept".into())
            .spawn(move || accept_loop(listener, accept_router, accept_stop))
            .map_err(Error::Io)?;

        Ok(Server { addr, stop, accept_handle: Some(accept_handle), router: Some(router) })
    }

    /// Bound address (useful with ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The router, for in-process callers that want to prewarm pools or
    /// read metrics without a TCP round trip.
    pub fn router(&self) -> Option<&Arc<Router>> {
        self.router.as_ref()
    }

    /// Graceful shutdown: stop accepting, then drain the shard pool —
    /// in-flight lanes get up to `drain_timeout_ms` to finish and every
    /// remaining waiter is answered with `Error { message: "shutting
    /// down" }` before the threads are joined.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke accept loop
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(router) = self.router.take() {
            router.shutdown();
        }
    }
}

fn accept_loop(listener: TcpListener, router: Arc<Router>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let conn_router = router.clone();
                let _ = std::thread::Builder::new()
                    .name("ddim-conn".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, conn_router);
                    });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

fn handle_conn(stream: TcpStream, router: Arc<Router>) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let reply = dispatch_line(trimmed, &router);
        stream.write_all(reply.as_bytes())?;
        stream.write_all(b"\n")?;
        stream.flush()?;
    }
}

fn dispatch_line(line: &str, router: &Router) -> String {
    let err = |msg: String| json::to_string(&jobj![("ok", false), ("error", msg)]);
    let v = match json::parse(line) {
        Ok(v) => v,
        Err(e) => return err(format!("parse: {e}")),
    };
    match v.get_opt("op").and_then(|o| o.as_str().ok().map(str::to_string)) {
        Some(op) if op == "ping" => json::to_string(&jobj![("ok", true), ("pong", true)]),
        Some(op) if op == "metrics" => router.metrics_json(),
        Some(_) => {
            let req = match Request::from_json_with(&v, router.config().default_sampler) {
                Ok(r) => r,
                Err(e) => return err(e.to_string()),
            };
            match router.submit(req).recv() {
                Ok(resp) => resp.to_json_line(),
                Err(_) => err("request dropped during shutdown".into()),
            }
        }
        None => err("missing op".into()),
    }
}

/// Minimal blocking client for examples, benches and tests: send one JSON
/// line, read one JSON line back, over a persistent connection.
pub struct Client {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Self { reader: BufReader::new(stream.try_clone()?), stream })
    }

    /// Send one request line, wait for the response line.
    pub fn roundtrip(&mut self, v: &Value) -> Result<Value> {
        self.stream.write_all(json::to_string(v).as_bytes())?;
        self.stream.write_all(b"\n")?;
        self.stream.flush()?;
        let mut line = String::new();
        if self.reader.read_line(&mut line)? == 0 {
            return Err(Error::Coordinator("server closed connection".into()));
        }
        json::parse(line.trim())
    }
}
