//! The pipelined step executor: a dedicated per-engine thread that owns
//! the PJRT runtime and services packed sub-batches, so the engine thread
//! can pack sub-batch *k+1* and advance/retire *k−1* while *k* is on the
//! device.
//!
//! Ownership rules mirror [`super::shard`]: PJRT state never crosses a
//! thread boundary. The executor thread *loads its own* [`Runtime`] and
//! ships plain-data clones of the manifest and α̅-table back to the engine
//! for admission-time validation; only [`StepBatch`] buffers (plain
//! `Vec<f32>`s) and [`PendingStep`]-derived outputs travel between the
//! threads, via a ping-pong pool of `pipeline_depth` buffers.
//!
//! The worker keeps at most one *submitted-but-unawaited* step: on
//! receiving sub-batch *k+1* it submits it to the device **before**
//! waiting on *k*, so back-to-back sub-batches queue on the device with
//! no host gap. Completions are delivered strictly in submission order
//! (single worker, FIFO channels), which the engine's in-flight
//! accounting relies on.

use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::artifacts::Manifest;
use crate::config::ServeConfig;
use crate::error::{Error, Result};
use crate::runtime::{BackendKind, PendingStep, Runtime};
use crate::sampler::StepBatch;
use crate::schedule::AlphaTable;

/// One planned sub-batch travelling between the engine and the executor:
/// the packed buffers plus the lane bookkeeping the engine needs to
/// advance the right trajectories when it comes back.
pub struct SubBatchJob {
    pub batch: StepBatch,
    /// Engine lane indices packed into slots `0..lanes` (entries past
    /// `lanes` are stale scratch).
    pub sel: Vec<usize>,
    /// Occupied slots.
    pub lanes: usize,
    /// Bucket the call runs at (`lanes..bucket` are padding).
    pub bucket: usize,
}

/// A completed sub-batch: the job with its outputs landed, the execution
/// seconds it took, and the execution result.
pub struct SubBatchDone {
    pub job: SubBatchJob,
    /// Executor seconds attributable to *this* sub-batch: its own submit
    /// duration plus its own readback wait — time spent finishing *other*
    /// jobs in between is excluded, so summing `busy_s` across jobs never
    /// double-counts device time.
    pub busy_s: f64,
    /// Reference-kernel seconds inside this sub-batch's submit (subset of
    /// `busy_s`; 0 on the xla backend).
    pub ref_compute_s: f64,
    /// Reference-backend bytes freshly allocated by this sub-batch
    /// (buffer growth; 0 in steady state and on the xla backend).
    pub ref_bytes: u64,
    pub result: Result<()>,
}

enum ExecCmd {
    Run(SubBatchJob),
    Warmup(Sender<Result<()>>),
}

/// Engine-side handle: command channel, completion channel, and the
/// free-buffer pool. Dropping the handle closes the command channel; the
/// worker finishes anything in flight and exits.
pub struct PipelineExecutor {
    cmd_tx: Sender<ExecCmd>,
    done_rx: Receiver<SubBatchDone>,
    handle: Option<JoinHandle<()>>,
    free: Vec<SubBatchJob>,
    in_flight: usize,
    /// Set once a channel to the worker breaks (worker panic). In-flight
    /// buffers are lost with the worker; the engine checks this to fail
    /// its resident work loudly instead of error-looping forever.
    dead: bool,
}

impl PipelineExecutor {
    /// Spawn the executor for `cfg.dataset`, blocking until its runtime
    /// is loaded. Returns the handle plus manifest/α̅ clones for the
    /// engine's own (runtime-free) validation and planning.
    pub fn spawn(cfg: &ServeConfig) -> Result<(PipelineExecutor, Manifest, AlphaTable)> {
        let depth = cfg.pipeline_depth;
        debug_assert!(depth >= 2, "depth-1 engines run inline, without an executor");
        let (cmd_tx, cmd_rx) = mpsc::channel::<ExecCmd>();
        let (done_tx, done_rx) = mpsc::channel::<SubBatchDone>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(Manifest, AlphaTable)>>();
        let artifact_root = cfg.artifact_root.clone();
        let backend = cfg.backend;
        let opts = cfg.ref_options();
        let dataset = cfg.dataset.clone();
        let handle = std::thread::Builder::new()
            .name(format!("ddim-exec-{dataset}"))
            .spawn(move || {
                worker(&artifact_root, backend, opts, &dataset, cmd_rx, done_tx, ready_tx)
            })
            .map_err(Error::Io)?;
        let (manifest, alphas) = match ready_rx.recv() {
            Ok(Ok(pair)) => pair,
            Ok(Err(e)) => {
                let _ = handle.join();
                return Err(e);
            }
            Err(_) => {
                let _ = handle.join();
                return Err(Error::Coordinator("step executor died during bring-up".into()));
            }
        };
        let dim = manifest.sample_dim();
        let capacity = manifest.bucket_for(cfg.max_batch);
        let free = (0..depth)
            .map(|_| SubBatchJob {
                batch: StepBatch::new(capacity, dim),
                sel: Vec::with_capacity(capacity),
                lanes: 0,
                bucket: 0,
            })
            .collect();
        let exec = PipelineExecutor {
            cmd_tx,
            done_rx,
            handle: Some(handle),
            free,
            in_flight: 0,
            dead: false,
        };
        Ok((exec, manifest, alphas))
    }

    /// Take a free buffer if one is available; otherwise the caller must
    /// [`PipelineExecutor::recv_done`] first.
    pub fn take_free(&mut self) -> Option<SubBatchJob> {
        self.free.pop()
    }

    /// Return a completed job's buffers to the pool.
    pub fn put_free(&mut self, job: SubBatchJob) {
        self.free.push(job);
    }

    /// Sub-batches handed to the executor and not yet received back.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Whether the worker thread is gone (see `dead` field).
    pub fn is_dead(&self) -> bool {
        self.dead
    }

    /// Queue a packed job on the executor.
    pub fn submit(&mut self, job: SubBatchJob) -> Result<()> {
        match self.cmd_tx.send(ExecCmd::Run(job)) {
            Ok(()) => {
                self.in_flight += 1;
                Ok(())
            }
            Err(_) => {
                self.dead = true;
                Err(Error::Coordinator("step executor is gone".into()))
            }
        }
    }

    /// Block for the next completion (submission order).
    pub fn recv_done(&mut self) -> Result<SubBatchDone> {
        if self.in_flight == 0 {
            // nothing will ever arrive; reachable only after the worker
            // died and took the pool's in-flight buffers with it
            return Err(Error::Coordinator("step executor has nothing in flight".into()));
        }
        match self.done_rx.recv() {
            Ok(done) => {
                self.in_flight -= 1;
                Ok(done)
            }
            Err(_) => {
                // worker gone: nothing further will ever arrive
                self.in_flight = 0;
                self.dead = true;
                Err(Error::Coordinator("step executor died mid-flight".into()))
            }
        }
    }

    /// Compile every bucket on the executor's runtime (blocking).
    pub fn warmup(&mut self) -> Result<()> {
        debug_assert_eq!(self.in_flight, 0, "warmup with sub-batches in flight");
        let (tx, rx) = mpsc::channel();
        if self.cmd_tx.send(ExecCmd::Warmup(tx)).is_err() {
            self.dead = true;
            return Err(Error::Coordinator("step executor is gone".into()));
        }
        match rx.recv() {
            Ok(result) => result,
            Err(_) => {
                self.dead = true;
                Err(Error::Coordinator("step executor died during warmup".into()))
            }
        }
    }
}

impl Drop for PipelineExecutor {
    fn drop(&mut self) {
        // closing the command channel is the stop signal
        let (dead_tx, _) = mpsc::channel();
        self.cmd_tx = dead_tx;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A job submitted to the device whose completion has not been sent yet.
struct InFlight {
    job: SubBatchJob,
    pending: PendingStep,
    /// seconds already spent on this job (its submit call)
    busy_s: f64,
    /// reference-kernel seconds / fresh bytes harvested at submit
    ref_compute_s: f64,
    ref_bytes: u64,
}

fn finish(done_tx: &Sender<SubBatchDone>, inflight: InFlight) {
    let InFlight { mut job, pending, busy_s, ref_compute_s, ref_bytes } = inflight;
    let t0 = Instant::now();
    let result = job.batch.finish(pending);
    let busy_s = busy_s + t0.elapsed().as_secs_f64();
    let _ = done_tx.send(SubBatchDone { job, busy_s, ref_compute_s, ref_bytes, result });
}

fn worker(
    artifact_root: &str,
    backend: BackendKind,
    opts: crate::runtime::RefOptions,
    dataset: &str,
    cmd_rx: Receiver<ExecCmd>,
    done_tx: Sender<SubBatchDone>,
    ready_tx: Sender<Result<(Manifest, AlphaTable)>>,
) {
    let mut rt = match Runtime::load_full(artifact_root, backend, opts) {
        Ok(rt) => {
            let _ = ready_tx.send(Ok((rt.manifest().clone(), rt.alphas().clone())));
            rt
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    // at most one submitted-but-unawaited step
    let mut pending: Option<InFlight> = None;
    loop {
        // with a step in flight, only *peek* for more work — if none is
        // queued yet, complete the in-flight step instead of blocking
        let cmd = if pending.is_some() {
            match cmd_rx.try_recv() {
                Ok(c) => Some(c),
                Err(TryRecvError::Empty) => None,
                Err(TryRecvError::Disconnected) => break,
            }
        } else {
            match cmd_rx.recv() {
                Ok(c) => Some(c),
                Err(_) => break,
            }
        };
        match cmd {
            Some(ExecCmd::Run(mut job)) => {
                let t0 = Instant::now();
                let submitted = rt.executable(dataset, job.bucket).and_then(|exe| {
                    let p = job.batch.submit(exe, job.bucket)?;
                    // the reference backend computes inside submit, so its
                    // counters are complete here; harvest per sub-batch
                    Ok((p, exe.take_ref_stats()))
                });
                // this job's own submit seconds; its readback wait is added
                // in finish() — time spent finishing the *previous* job
                // below is charged to neither
                let submit_s = t0.elapsed().as_secs_f64();
                // complete the previous step only after the new one is on
                // the device (order of Dones still matches submission)
                match submitted {
                    Ok((p, (ref_compute_s, ref_bytes))) => {
                        let next = InFlight {
                            job,
                            pending: p,
                            busy_s: submit_s,
                            ref_compute_s,
                            ref_bytes,
                        };
                        if let Some(prev) = pending.take() {
                            finish(&done_tx, prev);
                        }
                        pending = Some(next);
                    }
                    Err(e) => {
                        if let Some(prev) = pending.take() {
                            finish(&done_tx, prev);
                        }
                        let _ = done_tx.send(SubBatchDone {
                            job,
                            busy_s: submit_s,
                            ref_compute_s: 0.0,
                            ref_bytes: 0,
                            result: Err(e),
                        });
                    }
                }
            }
            Some(ExecCmd::Warmup(tx)) => {
                if let Some(prev) = pending.take() {
                    finish(&done_tx, prev);
                }
                let _ = tx.send(rt.warmup(dataset));
            }
            None => {
                let prev = pending.take().expect("idle worker only blocks in recv");
                finish(&done_tx, prev);
            }
        }
    }
    if let Some(prev) = pending.take() {
        finish(&done_tx, prev);
    }
}
