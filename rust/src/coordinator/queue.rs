//! Bounded FIFO admission queue. Full queue = immediate rejection — the
//! backpressure signal a latency-SLO serving system wants (queueing deeper
//! only converts rejects into timeouts).

use std::collections::VecDeque;

use crate::error::{Error, Result};

/// FIFO with a hard capacity.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    items: VecDeque<T>,
    capacity: usize,
    /// total accepted / rejected (metrics)
    pub accepted: u64,
    pub rejected: u64,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        Self { items: VecDeque::with_capacity(capacity), capacity, accepted: 0, rejected: 0 }
    }

    /// Admit or reject.
    pub fn push(&mut self, item: T) -> Result<()> {
        if self.items.len() >= self.capacity {
            self.rejected += 1;
            return Err(Error::Coordinator(format!(
                "queue full (capacity {})",
                self.capacity
            )));
        }
        self.items.push_back(item);
        self.accepted += 1;
        Ok(())
    }

    pub fn pop(&mut self) -> Option<T> {
        self.items.pop_front()
    }

    pub fn peek(&self) -> Option<&T> {
        self.items.front()
    }

    /// Iterate queued items front-to-back (metrics / load accounting).
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.items.iter()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = BoundedQueue::new(3);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.push(4).unwrap();
        assert_eq!(q.iter().copied().collect::<Vec<_>>(), vec![3, 4]);
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn rejects_when_full_and_counts() {
        let mut q = BoundedQueue::new(2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert!(q.push(3).is_err());
        assert_eq!(q.accepted, 2);
        assert_eq!(q.rejected, 1);
        q.pop();
        q.push(3).unwrap();
        assert_eq!(q.accepted, 3);
    }

    #[test]
    fn property_never_exceeds_capacity() {
        crate::testing::check("queue_capacity", 100, |g| {
            let cap = g.int_in(1, 16);
            let mut q = BoundedQueue::new(cap);
            let ops = g.int_in(1, 200);
            for _ in 0..ops {
                if g.bool() {
                    let _ = q.push(0u8);
                } else {
                    q.pop();
                }
                if q.len() > cap {
                    return Err(format!("len {} > cap {cap}", q.len()));
                }
            }
            Ok(())
        });
    }
}
