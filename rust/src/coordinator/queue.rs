//! Bounded FIFO admission queue. Full queue = immediate rejection — the
//! backpressure signal a latency-SLO serving system wants (queueing deeper
//! only converts rejects into timeouts).
//!
//! Each item carries a *lane weight* (how many trajectories it will admit)
//! and the queue maintains the running total, because the router's
//! least-loaded dispatch polls the backlog in lanes on every worker-loop
//! iteration — an O(queue) walk there was measurable under load.

use std::collections::VecDeque;

use crate::error::{Error, Result};

/// FIFO with a hard capacity and O(1) lane-weight accounting.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    items: VecDeque<(T, usize)>,
    capacity: usize,
    lanes: usize,
    /// total accepted / rejected (metrics)
    pub accepted: u64,
    pub rejected: u64,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        Self {
            items: VecDeque::with_capacity(capacity),
            capacity,
            lanes: 0,
            accepted: 0,
            rejected: 0,
        }
    }

    /// Admit or reject. `lanes` is the item's weight in the running lane
    /// count (a count=8 generate is 8 lanes of backlog, not 1).
    pub fn push(&mut self, item: T, lanes: usize) -> Result<()> {
        if self.items.len() >= self.capacity {
            self.rejected += 1;
            return Err(Error::Coordinator(format!(
                "queue full (capacity {})",
                self.capacity
            )));
        }
        self.items.push_back((item, lanes));
        self.lanes += lanes;
        self.accepted += 1;
        Ok(())
    }

    pub fn pop(&mut self) -> Option<T> {
        let (item, lanes) = self.items.pop_front()?;
        self.lanes -= lanes;
        Some(item)
    }

    pub fn peek(&self) -> Option<&T> {
        self.items.front().map(|(item, _)| item)
    }

    /// Iterate queued items front-to-back (metrics / load accounting).
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.items.iter().map(|(item, _)| item)
    }

    /// Iterate queued `(item, lane weight)` entries front-to-back.
    pub fn iter_entries(&self) -> impl Iterator<Item = (&T, usize)> {
        self.items.iter().map(|(item, lanes)| (item, *lanes))
    }

    /// Running total of queued lane weights — O(1), maintained on every
    /// push/pop (and therefore across aborts, which drain through `pop`).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = BoundedQueue::new(3);
        q.push(1, 1).unwrap();
        q.push(2, 1).unwrap();
        q.push(3, 1).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.push(4, 1).unwrap();
        assert_eq!(q.iter().copied().collect::<Vec<_>>(), vec![3, 4]);
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn rejects_when_full_and_counts() {
        let mut q = BoundedQueue::new(2);
        q.push(1, 1).unwrap();
        q.push(2, 1).unwrap();
        assert!(q.push(3, 1).is_err());
        assert_eq!(q.accepted, 2);
        assert_eq!(q.rejected, 1);
        q.pop();
        q.push(3, 1).unwrap();
        assert_eq!(q.accepted, 3);
    }

    #[test]
    fn lane_count_tracks_pushes_pops_and_rejects() {
        let mut q = BoundedQueue::new(2);
        assert_eq!(q.lanes(), 0);
        q.push("a", 8).unwrap();
        q.push("b", 1).unwrap();
        assert_eq!(q.lanes(), 9);
        assert!(q.push("c", 4).is_err(), "reject must not count lanes");
        assert_eq!(q.lanes(), 9);
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.lanes(), 1);
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.lanes(), 0);
        assert_eq!(q.pop(), None);
        assert_eq!(q.lanes(), 0);
    }

    #[test]
    fn property_never_exceeds_capacity_and_lane_count_matches_contents() {
        crate::testing::check("queue_capacity_and_lanes", 100, |g| {
            let cap = g.int_in(1, 16);
            let mut q = BoundedQueue::new(cap);
            let ops = g.int_in(1, 200);
            for _ in 0..ops {
                if g.bool() {
                    let w = g.int_in(0, 9);
                    let _ = q.push(0u8, w);
                } else {
                    q.pop();
                }
                if q.len() > cap {
                    return Err(format!("len {} > cap {cap}", q.len()));
                }
                // the running count must equal a fresh walk over the
                // queued entries' weights — the O(1) gauge never drifts
                let walked: usize = q.iter_entries().map(|(_, w)| w).sum();
                if q.lanes() != walked {
                    return Err(format!("lanes() {} != walked {walked}", q.lanes()));
                }
            }
            Ok(())
        });
    }
}
