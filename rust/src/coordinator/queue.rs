//! Bounded priority admission queue. Full queue = immediate typed
//! rejection — the backpressure signal a latency-SLO serving system wants
//! (queueing deeper only converts rejects into timeouts).
//!
//! Each item carries a *lane weight* (how many trajectories it will admit)
//! and the queue maintains the running total, because the router's
//! least-loaded dispatch polls the backlog in lanes on every worker-loop
//! iteration — an O(queue) walk there was measurable under load.
//!
//! Admission enforces two caps: an item cap (queue depth) and a *lane
//! budget*. The item cap alone is not a latency bound — a capacity-64
//! queue would happily admit 64×max_lanes lanes of backlog — so the lane
//! budget caps queued work in the unit the engine actually drains.
//!
//! Items are queued into strict priority bands (see
//! [`crate::coordinator::Priority`]): every band-0 item pops before any
//! band-1 item, FIFO within a band. A heavy high-priority head can
//! therefore block lower bands (head-of-line by design — that is what
//! "strict" means); deadline expiry reaps queued work that waits too long.

use std::collections::VecDeque;

use crate::coordinator::request::Priority;
use crate::error::{Error, Result};

/// Strict-priority FIFO with hard item/lane caps and O(1) lane-weight
/// accounting.
#[derive(Debug)]
pub struct BoundedQueue<T> {
    /// One FIFO per priority band; band 0 drains first.
    bands: Vec<VecDeque<(T, usize)>>,
    capacity: usize,
    lane_budget: usize,
    lanes: usize,
    len: usize,
    /// total accepted / rejected-by-cap (metrics)
    pub accepted: u64,
    /// rejections where the *item* cap was the binding constraint
    pub rejected_items: u64,
    /// rejections where the *lane budget* was the binding constraint
    pub rejected_lanes: u64,
}

impl<T> BoundedQueue<T> {
    /// Item cap only (lane budget unbounded) — library/test convenience.
    pub fn new(capacity: usize) -> Self {
        Self::with_lane_budget(capacity, usize::MAX)
    }

    /// Item cap plus a lane budget: the queue never holds more than
    /// `capacity` items *or* more than `lane_budget` lanes of backlog.
    pub fn with_lane_budget(capacity: usize, lane_budget: usize) -> Self {
        Self {
            bands: (0..Priority::COUNT).map(|_| VecDeque::new()).collect(),
            capacity,
            lane_budget,
            lanes: 0,
            len: 0,
            accepted: 0,
            rejected_items: 0,
            rejected_lanes: 0,
        }
    }

    /// Admit or reject into `priority`'s band. `lanes` is the item's
    /// weight in the running lane count (a count=8 generate is 8 lanes of
    /// backlog, not 1). Rejections are typed ([`Error::Overload`]) and
    /// carry the queued-lane pressure observed at the decision.
    pub fn push(&mut self, item: T, lanes: usize, priority: Priority) -> Result<()> {
        if self.len >= self.capacity {
            self.rejected_items += 1;
            return Err(Error::Overload {
                queued_lanes: self.lanes,
                message: format!("queue full (capacity {})", self.capacity),
            });
        }
        if self.lanes.saturating_add(lanes) > self.lane_budget {
            self.rejected_lanes += 1;
            return Err(Error::Overload {
                queued_lanes: self.lanes,
                message: format!(
                    "queue lane budget exhausted ({} queued + {} > {})",
                    self.lanes, lanes, self.lane_budget
                ),
            });
        }
        self.bands[priority.band()].push_back((item, lanes));
        self.lanes += lanes;
        self.len += 1;
        self.accepted += 1;
        Ok(())
    }

    /// Pop the front of the highest non-empty priority band.
    pub fn pop(&mut self) -> Option<T> {
        for band in &mut self.bands {
            if let Some((item, lanes)) = band.pop_front() {
                self.lanes -= lanes;
                self.len -= 1;
                return Some(item);
            }
        }
        None
    }

    /// The item `pop` would return next.
    pub fn peek(&self) -> Option<&T> {
        self.bands
            .iter()
            .find_map(|band| band.front().map(|(item, _)| item))
    }

    /// Iterate queued items in pop order (metrics / load accounting).
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.bands.iter().flatten().map(|(item, _)| item)
    }

    /// Iterate queued `(item, lane weight)` entries in pop order.
    pub fn iter_entries(&self) -> impl Iterator<Item = (&T, usize)> {
        self.bands.iter().flatten().map(|(item, lanes)| (item, *lanes))
    }

    /// Remove and return every queued item matching `pred`, maintaining
    /// the lane count. Used by the deadline reaper at tick boundaries:
    /// expired work leaves the queue as cancelled, not served.
    pub fn reap<F: FnMut(&T) -> bool>(&mut self, mut pred: F) -> Vec<T> {
        let mut reaped = Vec::new();
        for band in &mut self.bands {
            let mut keep = VecDeque::with_capacity(band.len());
            for (item, lanes) in band.drain(..) {
                if pred(&item) {
                    self.lanes -= lanes;
                    self.len -= 1;
                    reaped.push(item);
                } else {
                    keep.push_back((item, lanes));
                }
            }
            *band = keep;
        }
        reaped
    }

    /// Running total of queued lane weights — O(1), maintained on every
    /// push/pop/reap (and therefore across aborts, which drain via `pop`).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Total rejections, both caps.
    pub fn rejected(&self) -> u64 {
        self.rejected_items + self.rejected_lanes
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn lane_budget(&self) -> usize {
        self.lane_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: Priority = Priority::Batch;

    #[test]
    fn fifo_order_within_a_band() {
        let mut q = BoundedQueue::new(3);
        q.push(1, 1, P).unwrap();
        q.push(2, 1, P).unwrap();
        q.push(3, 1, P).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.push(4, 1, P).unwrap();
        assert_eq!(q.iter().copied().collect::<Vec<_>>(), vec![3, 4]);
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn strict_priority_across_bands() {
        let mut q = BoundedQueue::new(8);
        q.push("be-1", 1, Priority::BestEffort).unwrap();
        q.push("batch-1", 1, Priority::Batch).unwrap();
        q.push("int-1", 1, Priority::Interactive).unwrap();
        q.push("be-2", 1, Priority::BestEffort).unwrap();
        q.push("int-2", 1, Priority::Interactive).unwrap();
        // strict ordering: all interactive, then batch, then best-effort;
        // FIFO within each band
        assert_eq!(q.peek(), Some(&"int-1"));
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec!["int-1", "int-2", "batch-1", "be-1", "be-2"]);
    }

    #[test]
    fn rejects_when_full_and_counts() {
        let mut q = BoundedQueue::new(2);
        q.push(1, 1, P).unwrap();
        q.push(2, 1, P).unwrap();
        let err = q.push(3, 1, P).unwrap_err();
        assert!(matches!(err, Error::Overload { queued_lanes: 2, .. }), "{err}");
        assert_eq!(q.accepted, 2);
        assert_eq!(q.rejected_items, 1);
        assert_eq!(q.rejected(), 1);
        q.pop();
        q.push(3, 1, P).unwrap();
        assert_eq!(q.accepted, 3);
    }

    #[test]
    fn lane_budget_caps_queued_work() {
        // item cap alone would admit 64 items; the lane budget stops a
        // heavy backlog long before that
        let mut q = BoundedQueue::with_lane_budget(64, 10);
        q.push("a", 8, P).unwrap();
        q.push("b", 2, P).unwrap();
        let err = q.push("c", 1, P).unwrap_err();
        assert!(matches!(err, Error::Overload { queued_lanes: 10, .. }), "{err}");
        assert_eq!(q.rejected_lanes, 1);
        assert_eq!(q.rejected_items, 0);
        // light items still fit once lanes drain
        assert_eq!(q.pop(), Some("a"));
        q.push("c", 1, P).unwrap();
        assert_eq!(q.lanes(), 3);
    }

    #[test]
    fn lane_count_tracks_pushes_pops_and_rejects() {
        let mut q = BoundedQueue::new(2);
        assert_eq!(q.lanes(), 0);
        q.push("a", 8, P).unwrap();
        q.push("b", 1, P).unwrap();
        assert_eq!(q.lanes(), 9);
        assert!(q.push("c", 4, P).is_err(), "reject must not count lanes");
        assert_eq!(q.lanes(), 9);
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.lanes(), 1);
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.lanes(), 0);
        assert_eq!(q.pop(), None);
        assert_eq!(q.lanes(), 0);
    }

    #[test]
    fn reap_removes_matching_and_keeps_lane_accounting() {
        let mut q = BoundedQueue::new(8);
        q.push(10, 2, Priority::Interactive).unwrap();
        q.push(11, 3, Priority::Batch).unwrap();
        q.push(12, 4, Priority::BestEffort).unwrap();
        q.push(13, 1, Priority::Batch).unwrap();
        let reaped = q.reap(|x| x % 2 == 1);
        assert_eq!(reaped, vec![11, 13]);
        assert_eq!(q.len(), 2);
        assert_eq!(q.lanes(), 6);
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(12));
    }

    #[test]
    fn property_never_exceeds_caps_and_lane_count_matches_contents() {
        crate::testing::check("queue_capacity_and_lanes", 100, |g| {
            let cap = g.int_in(1, 16);
            let budget = g.int_in(1, 24);
            let mut q = BoundedQueue::with_lane_budget(cap, budget);
            let ops = g.int_in(1, 200);
            for _ in 0..ops {
                match g.int_in(0, 3) {
                    0 | 1 => {
                        let w = g.int_in(0, 9);
                        let band = [Priority::Interactive, Priority::Batch, Priority::BestEffort]
                            [g.int_in(0, 2)];
                        let _ = q.push(0u8, w, band);
                    }
                    2 => {
                        q.pop();
                    }
                    _ => {
                        let cutoff = g.int_in(0, 1) == 0;
                        q.reap(|_| cutoff);
                    }
                }
                if q.len() > cap {
                    return Err(format!("len {} > cap {cap}", q.len()));
                }
                if q.lanes() > budget {
                    return Err(format!("lanes {} > budget {budget}", q.lanes()));
                }
                // the running count must equal a fresh walk over the
                // queued entries' weights — the O(1) gauge never drifts
                let walked: usize = q.iter_entries().map(|(_, w)| w).sum();
                if q.lanes() != walked {
                    return Err(format!("lanes() {} != walked {walked}", q.lanes()));
                }
                let counted = q.iter().count();
                if q.len() != counted {
                    return Err(format!("len() {} != counted {counted}", q.len()));
                }
            }
            Ok(())
        });
    }
}
