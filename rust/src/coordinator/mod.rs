//! The paper's system contribution at L3: a diffusion-serving coordinator
//! with **continuous step-level batching**.
//!
//! DDIM turns sampling into S independent executable calls per request,
//! where S (and η, and the τ shape) is *per request* — a quality/latency
//! knob the client holds (paper Sec. 5.1's trade-off). The serving insight
//! (DESIGN.md §1) is that Eq. 12 is elementwise in the schedule scalars, so
//! the AOT graph takes per-sample vectors `alpha_t[B] / alpha_prev[B] /
//! sigma[B]` — one call can advance B lanes that belong to *different*
//! requests at *different* timesteps on *different* schedules. Requests
//! join the running batch as soon as a lane frees: no generation barrier,
//! exactly the Orca/vLLM iteration-level scheduling argument transplanted
//! to diffusion.
//!
//! Pieces:
//! - [`request`]: wire-level request/response types
//! - [`queue`]:   bounded admission queue (backpressure)
//! - [`engine`]:  lanes + tick loop + bucket selection (the batcher)
//! - [`shard`]:   one worker thread owning one engine + its tick loop
//! - [`router`]:  per-dataset shard pools, least-loaded dispatch, merged
//!   metrics, drain-on-shutdown — fronted by the sample cache +
//!   single-flight coalescer ([`crate::cache`]) ahead of shard dispatch
//! - [`metrics`]: latency histograms (mergeable), occupancy, counters
//! - [`conn`]:    per-connection framing/backpressure state machine
//! - [`reactor`]: epoll event loop (N reactors multiplex all connections)
//! - [`server`]:  non-blocking JSON-line transport v2 over the router
//!   (acceptor + reactors, pipelined `"id"`s, streamed x̂₀ previews)

pub mod conn;
pub mod engine;
pub mod executor;
pub mod metrics;
pub mod queue;
pub mod reactor;
pub mod request;
pub mod router;
pub mod server;
pub mod shard;

pub use engine::Engine;
pub use executor::PipelineExecutor;
pub use metrics::{Histogram, MetricsSnapshot};
pub use queue::BoundedQueue;
pub use reactor::{raise_nofile_limit, PollEvent, Poller, ReactorStats};
pub use request::{
    CacheMode, Priority, Qos, Reject, RejectReason, Request, RequestBody, RequestId, Response,
    ResponseBody,
};
pub use router::Router;
pub use server::Server;
pub use shard::{EngineShard, ShardStats};
