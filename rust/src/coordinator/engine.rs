//! The engine: lanes, admission, bucket selection, and the tick loop —
//! continuous step-level batching over the AOT `denoise_step` executables.
//!
//! Scheduling policy (deliberately simple, measured in §Perf):
//! - admission: FIFO from the bounded queue while lane capacity allows,
//!   whole requests at a time (no partial admission);
//! - selection: round-robin over active lanes, up to `max_batch` per tick —
//!   no lane can starve (tested by property below);
//! - bucket: smallest compiled bucket that fits the selected lanes (pads
//!   dead lanes; padding never leaks — also tested).
//!
//! One engine serves one dataset (executables are per dataset); run several
//! engines for multi-model serving.

use std::collections::HashMap;
use std::time::Instant;

use crate::config::ServeConfig;
use crate::coordinator::metrics::{Histogram, MetricsSnapshot};
use crate::coordinator::queue::BoundedQueue;
use crate::coordinator::request::{Request, RequestBody, RequestId, Response, ResponseBody};
use crate::error::{Error, Result};
use crate::runtime::Runtime;
use crate::sampler::{StepBatch, Trajectory};
use crate::schedule::{Direction, SamplePlan};

struct Lane {
    req: RequestId,
    lane_idx: usize,
    traj: Trajectory,
}

struct Inflight {
    submitted: Instant,
    remaining_lanes: usize,
    outputs: Vec<Option<Vec<f32>>>,
    return_images: bool,
    steps_total: usize,
}

struct Pending {
    id: RequestId,
    request: Request,
    plan: SamplePlan,
    submitted: Instant,
}

/// The coordinator engine. Synchronous API: `submit` + `tick`/`run_until_idle`;
/// the TCP server wraps it in a thread (see [`super::server`]).
pub struct Engine {
    rt: Runtime,
    cfg: ServeConfig,
    queue: BoundedQueue<Pending>,
    lanes: Vec<Lane>,
    inflight: HashMap<RequestId, Inflight>,
    completed: Vec<Response>,
    next_id: RequestId,
    rr_cursor: usize,
    dim: usize,
    // shared pack/pad/run path (max bucket capacity), reused every tick
    batch: StepBatch,
    sel: Vec<usize>,
    // metrics
    latency: Histogram,
    started: Instant,
    calls: u64,
    steps: u64,
    /// steps per update kernel, indexed by
    /// [`crate::sampler::SamplerKind::index`]
    kernel_steps: [u64; 3],
    lanes_done: u64,
    requests_done: u64,
    occupancy_sum: f64,
}

impl Engine {
    /// Build an engine over `artifact_root` for `cfg.dataset`.
    pub fn new(cfg: ServeConfig) -> Result<Self> {
        cfg.validate()?;
        let rt = Runtime::load(&cfg.artifact_root)?;
        Self::with_runtime(rt, cfg)
    }

    /// Build from an existing runtime (tests / benches).
    pub fn with_runtime(rt: Runtime, cfg: ServeConfig) -> Result<Self> {
        cfg.validate()?;
        rt.manifest().dataset(&cfg.dataset)?;
        let max_bucket = rt.manifest().bucket_for(cfg.max_batch);
        let dim = rt.manifest().sample_dim();
        Ok(Self {
            rt,
            queue: BoundedQueue::new(cfg.queue_capacity),
            lanes: Vec::new(),
            inflight: HashMap::new(),
            completed: Vec::new(),
            next_id: 1,
            rr_cursor: 0,
            dim,
            batch: StepBatch::new(max_bucket, dim),
            sel: Vec::with_capacity(max_bucket),
            latency: Histogram::new(),
            started: Instant::now(),
            calls: 0,
            steps: 0,
            kernel_steps: [0; 3],
            lanes_done: 0,
            requests_done: 0,
            occupancy_sum: 0.0,
            cfg,
        })
    }

    /// Pre-compile every bucket (avoids first-request latency spikes).
    pub fn warmup(&mut self) -> Result<()> {
        let ds = self.cfg.dataset.clone();
        self.rt.warmup(&ds)
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Validate + enqueue a request. Errors are immediate (backpressure,
    /// unknown dataset, bad schedule) — nothing is silently dropped.
    pub fn submit(&mut self, request: Request) -> Result<RequestId> {
        if request.dataset != self.cfg.dataset {
            return Err(Error::Coordinator(format!(
                "engine serves '{}', request wants '{}'",
                self.cfg.dataset, request.dataset
            )));
        }
        if request.lane_count() > self.cfg.max_lanes {
            return Err(Error::Coordinator(format!(
                "request wants {} lanes, engine max is {}",
                request.lane_count(),
                self.cfg.max_lanes
            )));
        }
        let abar = self.rt.alphas();
        let plan = match &request.body {
            RequestBody::Encode { .. } => SamplePlan::encode(abar, request.tau, request.steps)?,
            _ => SamplePlan::generate(abar, request.tau, request.steps, request.mode)?,
        };
        // host-integrated kernels re-derive x from ε and have no σ > 0 form:
        // validated against the materialised plan's mode (encode plans are
        // deterministic whatever `eta` the request carried)
        if !request.sampler.supports(plan.mode) {
            return Err(Error::Request(format!(
                "sampler '{}' requires a deterministic plan: \
                 stochastic plans (eta>0, sigma-hat) are DDIM-only",
                request.sampler.label()
            )));
        }
        // validate provided states' dimensionality up front
        let check_dims = |rows: &[Vec<f32>]| -> Result<()> {
            for r in rows {
                if r.len() != self.dim {
                    return Err(Error::Request(format!(
                        "state has {} elements, model wants {}",
                        r.len(),
                        self.dim
                    )));
                }
            }
            Ok(())
        };
        match &request.body {
            RequestBody::Decode { latents } => check_dims(latents)?,
            RequestBody::Encode { images } => check_dims(images)?,
            RequestBody::Generate { .. } => {}
        }
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push(Pending { id, request, plan, submitted: Instant::now() })?;
        Ok(id)
    }

    /// Number of requests waiting for admission.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Lanes represented by the requests still waiting for admission —
    /// the unit the router's least-loaded dispatch balances in (a queued
    /// count=8 generate is 8 lanes of backlog, not 1).
    pub fn queued_lanes(&self) -> usize {
        self.queue.iter().map(|p| p.request.lane_count()).sum()
    }

    /// Number of lanes currently resident.
    pub fn active_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Take all responses completed since the last call.
    pub fn take_completed(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.completed)
    }

    /// Admit queued requests while lane capacity allows (FIFO, whole
    /// requests). Returns how many requests were admitted.
    fn admit(&mut self) -> usize {
        let mut admitted = 0;
        while let Some(p) = self.queue.peek() {
            let want = p.request.lane_count();
            if self.lanes.len() + want > self.cfg.max_lanes {
                break;
            }
            let p = self.queue.pop().unwrap();
            let Pending { id, request, plan, submitted } = p;
            let steps_total = plan.len() * request.lane_count();
            let n = request.lane_count();
            let kernel = request.sampler;
            match request.body {
                RequestBody::Generate { count, seed } => {
                    for i in 0..count {
                        let traj = Trajectory::from_prior_with(
                            plan.clone(),
                            self.dim,
                            seed + i as u64,
                            kernel,
                        );
                        self.lanes.push(Lane { req: id, lane_idx: i, traj });
                    }
                }
                RequestBody::Decode { latents } => {
                    for (i, x) in latents.into_iter().enumerate() {
                        let traj = Trajectory::from_state_with(
                            plan.clone(),
                            x,
                            id * 7919 + i as u64,
                            kernel,
                        );
                        self.lanes.push(Lane { req: id, lane_idx: i, traj });
                    }
                }
                RequestBody::Encode { images } => {
                    debug_assert_eq!(plan.direction, Direction::Encode);
                    for (i, x) in images.into_iter().enumerate() {
                        let traj = Trajectory::from_state_with(
                            plan.clone(),
                            x,
                            id * 7919 + i as u64,
                            kernel,
                        );
                        self.lanes.push(Lane { req: id, lane_idx: i, traj });
                    }
                }
            }
            self.inflight.insert(
                id,
                Inflight {
                    submitted,
                    remaining_lanes: n,
                    outputs: (0..n).map(|_| None).collect(),
                    return_images: request.return_images,
                    steps_total,
                },
            );
            admitted += 1;
        }
        admitted
    }

    /// One scheduling tick: admit, select up to `max_batch` lanes
    /// round-robin, run one fused step, retire finished lanes/requests.
    /// Returns `true` if any work was done.
    pub fn tick(&mut self) -> Result<bool> {
        self.admit();
        if self.lanes.is_empty() {
            return Ok(false);
        }
        // --- select lanes round-robin
        let n_active = self.lanes.len();
        let n_sel = n_active.min(self.cfg.max_batch);
        let bucket = self.rt.manifest().bucket_for(n_sel);
        self.sel.clear();
        for k in 0..n_sel {
            self.sel.push((self.rr_cursor + k) % n_active);
        }
        self.rr_cursor = (self.rr_cursor + n_sel) % n_active.max(1);

        // --- pack + pad through the shared StepBatch path
        for (lane_slot, &li) in self.sel.iter().enumerate() {
            self.batch.pack(lane_slot, &mut self.lanes[li].traj)?;
        }
        self.batch.pad(n_sel, bucket);

        // --- run
        let exe = self.rt.executable(&self.cfg.dataset, bucket)?;
        self.batch.run(exe, bucket)?;
        self.calls += 1;
        self.steps += n_sel as u64;
        self.occupancy_sum += n_sel as f64 / bucket as f64;

        // --- advance + retire (each lane commits through its own kernel)
        let mut finished: Vec<usize> = Vec::new();
        for (lane_slot, &li) in self.sel.iter().enumerate() {
            let lane = &mut self.lanes[li];
            self.kernel_steps[lane.traj.kernel_kind().index()] += 1;
            lane.traj.advance(self.batch.lane(lane_slot))?;
            if lane.traj.is_done() {
                finished.push(li);
            }
        }
        // remove finished lanes (highest index first so swap_remove is safe)
        finished.sort_unstable_by(|a, b| b.cmp(a));
        for li in finished {
            let lane = self.lanes.swap_remove(li);
            self.lanes_done += 1;
            let inf = self
                .inflight
                .get_mut(&lane.req)
                .ok_or_else(|| Error::Coordinator("lane without inflight record".into()))?;
            inf.outputs[lane.lane_idx] = Some(lane.traj.into_state());
            inf.remaining_lanes -= 1;
            if inf.remaining_lanes == 0 {
                let inf = self.inflight.remove(&lane.req).unwrap();
                let latency = inf.submitted.elapsed().as_secs_f64();
                self.latency.record(latency);
                self.requests_done += 1;
                let outputs = if inf.return_images {
                    inf.outputs.into_iter().map(Option::unwrap).collect()
                } else {
                    Vec::new()
                };
                self.completed.push(Response {
                    id: lane.req,
                    body: ResponseBody::Ok { outputs },
                    latency_s: latency,
                    steps_executed: inf.steps_total,
                });
            }
        }
        if self.lanes.is_empty() {
            self.rr_cursor = 0;
        } else {
            self.rr_cursor %= self.lanes.len();
        }
        Ok(true)
    }

    /// Tick until queue and lanes drain; returns everything completed.
    pub fn run_until_idle(&mut self) -> Result<Vec<Response>> {
        while self.tick()? {}
        Ok(self.take_completed())
    }

    /// Whether any request is queued or resident.
    pub fn is_busy(&self) -> bool {
        !self.lanes.is_empty() || !self.queue.is_empty()
    }

    /// Graceful-shutdown path: tick until idle or `deadline`, whichever
    /// comes first, and return everything that completed. Work still
    /// resident after the deadline is left in place for [`Engine::abort_pending`].
    pub fn drain(&mut self, deadline: Instant) -> Result<Vec<Response>> {
        while self.is_busy() && Instant::now() < deadline {
            self.tick()?;
        }
        Ok(self.take_completed())
    }

    /// Answer every queued and in-flight request with an error response
    /// (pushed onto the completed list) and drop their lanes. Returns how
    /// many requests were aborted. Used when a drain deadline expires —
    /// nothing may be left blocked on a response channel.
    pub fn abort_pending(&mut self, message: &str) -> usize {
        let mut aborted = 0;
        while let Some(p) = self.queue.pop() {
            self.completed.push(Response {
                id: p.id,
                body: ResponseBody::Error { message: message.to_string() },
                latency_s: p.submitted.elapsed().as_secs_f64(),
                steps_executed: 0,
            });
            aborted += 1;
        }
        self.lanes.clear();
        self.rr_cursor = 0;
        for (id, inf) in std::mem::take(&mut self.inflight) {
            self.completed.push(Response {
                id,
                body: ResponseBody::Error { message: message.to_string() },
                latency_s: inf.submitted.elapsed().as_secs_f64(),
                steps_executed: 0,
            });
            aborted += 1;
        }
        aborted
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests_completed: self.requests_done,
            requests_rejected: self.queue.rejected,
            lanes_completed: self.lanes_done,
            executable_calls: self.calls,
            steps_executed: self.steps,
            kernel_steps: self.kernel_steps,
            occupancy_sum: self.occupancy_sum,
            latency_p50_s: self.latency.quantile(0.5),
            latency_p95_s: self.latency.quantile(0.95),
            latency_p99_s: self.latency.quantile(0.99),
            latency_mean_s: self.latency.mean(),
            wall_s: self.started.elapsed().as_secs_f64(),
            queue_accepted: self.queue.accepted,
            queue_depth: self.queue.len(),
            active_lanes: self.lanes.len(),
        }
    }

    /// The raw latency histogram, for cross-shard [`Histogram::merge`]
    /// aggregation (quantiles of quantiles are not quantiles).
    pub fn latency_histogram(&self) -> Histogram {
        self.latency.clone()
    }
}
