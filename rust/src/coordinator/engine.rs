//! The engine: lanes, admission, occupancy-aware batch formation, and the
//! pipelined tick loop — continuous step-level batching over the AOT
//! `denoise_step` executables.
//!
//! Scheduling policy (deliberately simple, measured in §Perf):
//! - admission: FIFO from the bounded queue while lane capacity allows,
//!   whole requests at a time (no partial admission);
//! - selection: round-robin over active lanes, up to `max_batch` per tick —
//!   no lane can starve (tested by property below);
//! - batch formation: the selection is decomposed by the tick planner
//!   ([`crate::sampler::planner`]) into exactly-sized sub-batches on
//!   compiled-bucket boundaries (9 lanes → 8+1 instead of one bucket-16
//!   call with 7 dead lanes), bounded by `max_padding_waste`;
//! - execution: with `pipeline_depth` 1 the sub-batches run serially on
//!   this thread; with depth ≥ 2 they stream through a dedicated executor
//!   thread ([`super::executor`]) so sub-batch *k+1* packs and *k−1*
//!   advances/retires while *k* is on the device. The plan is
//!   depth-independent, so pipelined output is **bitwise identical** to
//!   serial (pinned in `engine_integration`).
//!
//! One engine serves one dataset (executables are per dataset); run several
//! engines for multi-model serving.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::artifacts::Manifest;
use crate::config::ServeConfig;
use crate::coordinator::executor::{PipelineExecutor, SubBatchDone};
use crate::coordinator::metrics::{Histogram, MetricsSnapshot};
use crate::coordinator::queue::BoundedQueue;
use crate::coordinator::request::{
    Reject, RejectReason, Request, RequestBody, RequestId, Response, ResponseBody,
};
use crate::error::{Error, Result};
use crate::runtime::Runtime;
use crate::sampler::planner::{plan_sub_batches, SubBatch};
use crate::sampler::{StepBatch, Trajectory};
use crate::schedule::{AlphaTable, Direction, OptSchedules, SamplePlan, TauKind};

/// Streaming preview hook (wire v2 `"stream":{"every":K}`): after each
/// committed step of a subscribed lane whose step index is a multiple of
/// `every`, the engine calls `on_step` with `(lane_idx, step, total_steps,
/// predicted_x0)` — the Eq. 12 x̂₀ the update kernel already materialises
/// in [`crate::runtime::executable::StepOutput`] and previously discarded.
/// The final step is excluded (its x₀ ships in the response itself).
/// Fires on the engine's worker thread; implementations must be cheap and
/// non-blocking — the v2 transport hands the frame to the owning reactor
/// and returns.
pub struct ProgressSink {
    pub every: usize,
    pub on_step: Box<dyn Fn(usize, usize, usize, &[f32]) + Send + Sync>,
}

struct Lane {
    req: RequestId,
    lane_idx: usize,
    traj: Trajectory,
    progress: Option<Arc<ProgressSink>>,
}

/// Stage-time accumulator, allocated (boxed, off the common path) only
/// for traced requests ([`crate::coordinator::request::Qos::trace`]).
/// A shared sub-batch's wall-clock is attributed *in full* to every
/// unique traced request with a lane in it: the spans answer "where did
/// my request spend its time", not "how much device time did it consume
/// exclusively" — so queue + pack + device + advance ≈ the request's
/// engine latency even when its lanes ride shared batches.
struct SpanAccum {
    /// Transport arrival → engine admission.
    queue_s: f64,
    /// Summed pack (+ pad) wall-clock of participating sub-batches.
    pack_s: f64,
    /// Summed device wall-clock of participating sub-batches.
    device_s: f64,
    /// Summed host update-kernel (advance) wall-clock.
    advance_s: f64,
}

struct Inflight {
    /// Latency-clock anchor: the transport arrival instant when the
    /// request crossed a connection, engine-queue push time otherwise —
    /// so histograms measure client-observed latency, not just
    /// queue-to-completion.
    submitted: Instant,
    /// Absolute completion deadline; resident work past it is cancelled
    /// (tick boundary) or suppressed (pre-publish check), never finished.
    deadline: Option<Instant>,
    remaining_lanes: usize,
    outputs: Vec<Option<Vec<f32>>>,
    return_images: bool,
    steps_total: usize,
    /// Span accumulator for traced requests; `None` (the common case)
    /// costs the tick loop no extra clock reads.
    trace: Option<Box<SpanAccum>>,
}

struct Pending {
    id: RequestId,
    request: Request,
    plan: SamplePlan,
    /// See [`Inflight::submitted`] — anchored on transport arrival.
    submitted: Instant,
    deadline: Option<Instant>,
    progress: Option<Arc<ProgressSink>>,
}

/// Execution counters shared by the inline and pipelined paths,
/// identical semantics at every pipeline depth: call-shaped counters
/// move when a sub-batch's device call *succeeds* (`record_call`), and
/// `steps` moves per lane-step actually committed (in `advance_sub`,
/// lock-step with `kernel_steps`) — so a sub-batch that fails on the
/// executor, or an advance error partway through a sub-batch, never
/// breaks the `steps_executed == sum(kernel_steps)` invariant the wire
/// metrics pin.
#[derive(Default)]
struct ExecCounters {
    calls: u64,
    sub_batches: u64,
    steps: u64,
    padded_lanes: u64,
    occupancy_sum: f64,
    /// engine-thread seconds blocked on device completions
    wait_s: f64,
    /// execution-path seconds spent running sub-batches
    busy_s: f64,
    /// seconds inside the reference step kernel (subset of `busy_s`;
    /// 0 on the xla backend)
    ref_compute_s: f64,
    /// reference-backend bytes freshly allocated by step execution
    /// (output-buffer growth; stops moving once buffers are warm)
    ref_bytes: u64,
}

impl ExecCounters {
    fn record_call(&mut self, lanes: usize, bucket: usize) {
        self.calls += 1;
        self.sub_batches += 1;
        self.padded_lanes += (bucket - lanes) as u64;
        self.occupancy_sum += lanes as f64 / bucket as f64;
    }
}

/// Where packed sub-batches execute. PJRT state never crosses threads:
/// inline mode owns the runtime on the engine thread; pipelined mode's
/// executor thread loads (and keeps) its own.
enum ExecBackend {
    /// `pipeline_depth == 1`: pack → run → advance, serially, one buffer.
    Inline { rt: Runtime, batch: StepBatch },
    /// `pipeline_depth >= 2`: a ping-pong pool of buffers streaming
    /// through the executor thread.
    Pipelined(PipelineExecutor),
}

/// The coordinator engine. Synchronous API: `submit` + `tick`/`run_until_idle`;
/// the TCP server wraps it in a thread (see [`super::server`]).
pub struct Engine {
    exec: ExecBackend,
    manifest: Manifest,
    alphas: AlphaTable,
    /// Optimized τ schedules from the artifact bundle (`"tau":"opt"`).
    opt: OptSchedules,
    cfg: ServeConfig,
    queue: BoundedQueue<Pending>,
    lanes: Vec<Lane>,
    inflight: HashMap<RequestId, Inflight>,
    completed: Vec<Response>,
    next_id: RequestId,
    rr_cursor: usize,
    dim: usize,
    /// Largest bucket any sub-batch may run at (= StepBatch capacity).
    batch_capacity: usize,
    sel: Vec<usize>,
    plan: Vec<SubBatch>,
    // metrics
    latency: Histogram,
    started: Instant,
    ctr: ExecCounters,
    /// steps per update kernel, indexed by
    /// [`crate::sampler::SamplerKind::index`]
    kernel_steps: [u64; 3],
    lanes_done: u64,
    requests_done: u64,
    /// Requests cancelled by deadline expiry (admission, tick reaper, or
    /// pre-publish check).
    deadline_expired: u64,
    ticks: u64,
    /// reference-backend bytes allocated by the most recent working tick
    /// — exactly 0 once the engine reaches steady state
    ref_bytes_last_tick: u64,
}

impl Engine {
    /// Build an engine over `artifact_root` for `cfg.dataset`. With
    /// `pipeline_depth >= 2` the runtime is loaded by (and lives on) the
    /// executor thread; otherwise it lives here.
    pub fn new(cfg: ServeConfig) -> Result<Self> {
        cfg.validate()?;
        if cfg.pipeline_depth >= 2 {
            let (exec, manifest, alphas) = PipelineExecutor::spawn(&cfg)?;
            Self::build(ExecBackend::Pipelined(exec), manifest, alphas, cfg)
        } else {
            let rt = Runtime::load_full(&cfg.artifact_root, cfg.backend, cfg.ref_options())?;
            Self::with_runtime(rt, cfg)
        }
    }

    /// Build from an existing runtime (tests / benches). PJRT state must
    /// not cross threads, so with `pipeline_depth >= 2` the executor
    /// thread loads its own runtime from `cfg.artifact_root` and `rt` is
    /// only used for up-front validation — the roots must match, or the
    /// engine would validate against one artifact tree while executing
    /// another.
    pub fn with_runtime(rt: Runtime, cfg: ServeConfig) -> Result<Self> {
        cfg.validate()?;
        rt.manifest().dataset(&cfg.dataset)?;
        if rt.backend_kind() != cfg.backend {
            return Err(Error::Coordinator(format!(
                "runtime is on the '{}' backend but cfg wants '{}'",
                rt.backend_kind().label(),
                cfg.backend.label()
            )));
        }
        if cfg.pipeline_depth >= 2 {
            if rt.manifest().root != std::path::Path::new(&cfg.artifact_root) {
                return Err(Error::Coordinator(format!(
                    "pipelined engines reload their runtime from cfg.artifact_root \
                     ('{}'), which differs from the provided runtime's root ('{}') — \
                     pass a runtime loaded from the same root, or use Engine::new",
                    cfg.artifact_root,
                    rt.manifest().root.display()
                )));
            }
            drop(rt);
            let (exec, manifest, alphas) = PipelineExecutor::spawn(&cfg)?;
            Self::build(ExecBackend::Pipelined(exec), manifest, alphas, cfg)
        } else {
            let manifest = rt.manifest().clone();
            let alphas = rt.alphas().clone();
            let capacity = manifest.bucket_for(cfg.max_batch);
            let dim = manifest.sample_dim();
            let exec = ExecBackend::Inline { rt, batch: StepBatch::new(capacity, dim) };
            Self::build(exec, manifest, alphas, cfg)
        }
    }

    fn build(
        exec: ExecBackend,
        manifest: Manifest,
        alphas: AlphaTable,
        cfg: ServeConfig,
    ) -> Result<Self> {
        manifest.dataset(&cfg.dataset)?;
        let batch_capacity = manifest.bucket_for(cfg.max_batch);
        let dim = manifest.sample_dim();
        let opt = OptSchedules::load(&manifest.root, crate::cache::manifest_digest(&manifest));
        Ok(Self {
            exec,
            manifest,
            alphas,
            opt,
            queue: BoundedQueue::with_lane_budget(cfg.queue_capacity, cfg.queue_lane_budget()),
            lanes: Vec::new(),
            inflight: HashMap::new(),
            completed: Vec::new(),
            next_id: 1,
            rr_cursor: 0,
            dim,
            batch_capacity,
            sel: Vec::with_capacity(batch_capacity),
            plan: Vec::new(),
            latency: Histogram::new(),
            started: Instant::now(),
            ctr: ExecCounters::default(),
            kernel_steps: [0; 3],
            lanes_done: 0,
            requests_done: 0,
            deadline_expired: 0,
            ticks: 0,
            ref_bytes_last_tick: 0,
            cfg,
        })
    }

    /// Pre-compile every bucket (avoids first-request latency spikes).
    pub fn warmup(&mut self) -> Result<()> {
        let ds = self.cfg.dataset.clone();
        match &mut self.exec {
            ExecBackend::Inline { rt, .. } => rt.warmup(&ds),
            ExecBackend::Pipelined(pipe) => pipe.warmup(),
        }
    }

    /// The artifact manifest (geometry, buckets, datasets).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Validate + enqueue a request. Errors are immediate (backpressure,
    /// unknown dataset, bad schedule) — nothing is silently dropped.
    pub fn submit(&mut self, request: Request) -> Result<RequestId> {
        self.submit_with(request, None)
    }

    /// [`Engine::submit`] with an optional streaming preview sink; the
    /// sink is shared by every lane of the request and fired from
    /// [`Engine::tick`] as steps commit.
    pub fn submit_with(
        &mut self,
        request: Request,
        progress: Option<Arc<ProgressSink>>,
    ) -> Result<RequestId> {
        if request.dataset != self.cfg.dataset {
            return Err(Error::Coordinator(format!(
                "engine serves '{}', request wants '{}'",
                self.cfg.dataset, request.dataset
            )));
        }
        if request.lane_count() > self.cfg.max_lanes {
            return Err(Error::Coordinator(format!(
                "request wants {} lanes, engine max is {}",
                request.lane_count(),
                self.cfg.max_lanes
            )));
        }
        let abar = &self.alphas;
        let plan = if request.tau == TauKind::Opt {
            // optimized schedules live in the artifact bundle, keyed by
            // (dataset, S); a missing cell is a typed schedule error
            let sched = self.opt.require(&request.dataset, request.steps)?;
            match &request.body {
                RequestBody::Encode { .. } => {
                    SamplePlan::encode_with_tau(abar, sched.tau.clone())?
                }
                _ => SamplePlan::generate_with_tau(abar, sched.tau.clone(), request.mode)?,
            }
        } else {
            match &request.body {
                RequestBody::Encode { .. } => {
                    SamplePlan::encode(abar, request.tau, request.steps)?
                }
                _ => SamplePlan::generate(abar, request.tau, request.steps, request.mode)?,
            }
        };
        // host-integrated kernels re-derive x from ε and have no σ > 0 form:
        // validated against the materialised plan's mode (encode plans are
        // deterministic whatever `eta` the request carried)
        if !request.sampler.supports(plan.mode) {
            return Err(Error::Request(format!(
                "sampler '{}' requires a deterministic plan: \
                 stochastic plans (eta>0, sigma-hat) are DDIM-only",
                request.sampler.label()
            )));
        }
        // validate provided states' dimensionality up front
        let check_dims = |rows: &[Vec<f32>]| -> Result<()> {
            for r in rows {
                if r.len() != self.dim {
                    return Err(Error::Request(format!(
                        "state has {} elements, model wants {}",
                        r.len(),
                        self.dim
                    )));
                }
            }
            Ok(())
        };
        match &request.body {
            RequestBody::Decode { latents } => check_dims(latents)?,
            RequestBody::Encode { images } => check_dims(images)?,
            RequestBody::Generate { .. } => {}
        }
        // admission-time deadline check: a request that arrives already
        // past its budget is cancelled here, typed, before it costs a
        // queue slot
        let now = Instant::now();
        let submitted = request.qos.arrived.unwrap_or(now);
        let deadline = request.qos.deadline(now);
        if let Some(d) = deadline {
            if now >= d {
                self.deadline_expired += 1;
                return Err(Error::DeadlineExpired {
                    message: format!(
                        "deadline_ms {} expired before admission",
                        request.qos.deadline_ms.unwrap_or(0)
                    ),
                });
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let lanes = request.lane_count();
        let priority = request.qos.priority;
        self.queue.push(
            Pending { id, request, plan, submitted, deadline, progress },
            lanes,
            priority,
        )?;
        Ok(id)
    }

    /// Tick-boundary deadline reaper: cancel queued *and* resident work
    /// whose budget ran out. Expired requests are answered with a typed
    /// deadline rejection — cancelled, not finished — and their lanes are
    /// dropped so the capacity goes to work that can still meet its SLO.
    fn expire_deadlines(&mut self, now: Instant) -> usize {
        // queued work first (cheap: no lanes to unwind)
        let mut expired_count = 0;
        for p in self.queue.reap(|p| p.deadline.is_some_and(|d| now >= d)) {
            self.deadline_expired += 1;
            expired_count += 1;
            self.completed.push(Self::deadline_response(p.id, p.submitted, now));
        }
        // resident work: drop the request's lanes and inflight record
        let expired: Vec<RequestId> = self
            .inflight
            .iter()
            .filter(|(_, inf)| inf.deadline.is_some_and(|d| now >= d))
            .map(|(&id, _)| id)
            .collect();
        if expired.is_empty() {
            return expired_count;
        }
        self.lanes.retain(|l| !expired.contains(&l.req));
        for id in expired {
            let inf = self.inflight.remove(&id).unwrap();
            self.deadline_expired += 1;
            expired_count += 1;
            self.completed.push(Self::deadline_response(id, inf.submitted, now));
        }
        self.rr_cursor = if self.lanes.is_empty() { 0 } else { self.rr_cursor % self.lanes.len() };
        expired_count
    }

    fn deadline_response(id: RequestId, submitted: Instant, now: Instant) -> Response {
        Response {
            id,
            body: ResponseBody::Reject(Reject {
                reason: RejectReason::Deadline,
                queued_lanes: 0,
                message: "deadline expired; work cancelled".into(),
            }),
            latency_s: now.duration_since(submitted).as_secs_f64(),
            steps_executed: 0,
            cached: false,
            degraded: None,
            spans: None,
            coalesced: false,
        }
    }

    /// Number of requests waiting for admission.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Lanes represented by the requests still waiting for admission —
    /// the unit the router's least-loaded dispatch balances in (a queued
    /// count=8 generate is 8 lanes of backlog, not 1). O(1): the queue
    /// keeps a running lane count, since this runs under the router's
    /// load-gauge poll every worker-loop iteration.
    pub fn queued_lanes(&self) -> usize {
        self.queue.lanes()
    }

    /// Number of lanes currently resident.
    pub fn active_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Take all responses completed since the last call.
    pub fn take_completed(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.completed)
    }

    /// Admit queued requests while lane capacity allows (FIFO, whole
    /// requests). Returns how many requests were admitted.
    fn admit(&mut self) -> usize {
        let mut admitted = 0;
        while let Some(p) = self.queue.peek() {
            let want = p.request.lane_count();
            if self.lanes.len() + want > self.cfg.max_lanes {
                break;
            }
            let p = self.queue.pop().unwrap();
            let Pending { id, request, plan, submitted, deadline, progress } = p;
            let steps_total = plan.len() * request.lane_count();
            let n = request.lane_count();
            let kernel = request.sampler;
            // queue span closes at admission; only traced requests read
            // the clock here
            let trace = request.qos.trace.then(|| {
                Box::new(SpanAccum {
                    queue_s: Instant::now().duration_since(submitted).as_secs_f64(),
                    pack_s: 0.0,
                    device_s: 0.0,
                    advance_s: 0.0,
                })
            });
            match request.body {
                RequestBody::Generate { count, seed } => {
                    for i in 0..count {
                        let traj = Trajectory::from_prior_with(
                            plan.clone(),
                            self.dim,
                            seed + i as u64,
                            kernel,
                        );
                        self.lanes.push(Lane {
                            req: id,
                            lane_idx: i,
                            traj,
                            progress: progress.clone(),
                        });
                    }
                }
                // caller-supplied-state lanes seed their noise streams from
                // the *content* (FNV over the f32 bits), never from the
                // engine-assigned id: identical requests must consume
                // identical noise on any engine/shard/process — the
                // determinism contract the sample cache serves under
                // (stochastic decode included)
                RequestBody::Decode { latents } => {
                    let base = crate::rng::state_seed(1, &latents);
                    for (i, x) in latents.into_iter().enumerate() {
                        let traj = Trajectory::from_state_with(
                            plan.clone(),
                            x,
                            base.wrapping_add(i as u64),
                            kernel,
                        );
                        self.lanes.push(Lane {
                            req: id,
                            lane_idx: i,
                            traj,
                            progress: progress.clone(),
                        });
                    }
                }
                RequestBody::Encode { images } => {
                    debug_assert_eq!(plan.direction, Direction::Encode);
                    let base = crate::rng::state_seed(2, &images);
                    for (i, x) in images.into_iter().enumerate() {
                        let traj = Trajectory::from_state_with(
                            plan.clone(),
                            x,
                            base.wrapping_add(i as u64),
                            kernel,
                        );
                        self.lanes.push(Lane {
                            req: id,
                            lane_idx: i,
                            traj,
                            progress: progress.clone(),
                        });
                    }
                }
            }
            self.inflight.insert(
                id,
                Inflight {
                    submitted,
                    deadline,
                    remaining_lanes: n,
                    outputs: (0..n).map(|_| None).collect(),
                    return_images: request.return_images,
                    steps_total,
                    trace,
                },
            );
            admitted += 1;
        }
        admitted
    }

    /// Advance every occupied slot of a completed sub-batch through its
    /// lane's update kernel; lanes that finished their plan are recorded
    /// for the tick's retire pass (indices stay valid until then — lanes
    /// are only removed after the whole tick's plan has drained).
    fn advance_sub(
        lanes: &mut [Lane],
        kernel_steps: &mut [u64; 3],
        ctr: &mut ExecCounters,
        batch: &StepBatch,
        sub: &[usize],
        finished: &mut Vec<usize>,
    ) -> Result<()> {
        for (slot, &li) in sub.iter().enumerate() {
            let lane = &mut lanes[li];
            lane.traj.advance(batch.lane(slot))?;
            // counted only after the commit succeeds, in lock-step, so
            // steps_executed == sum(kernel_steps) holds even when an
            // advance error abandons the rest of the sub-batch
            ctr.steps += 1;
            kernel_steps[lane.traj.kernel_kind().index()] += 1;
            if lane.traj.is_done() {
                finished.push(li);
            } else if let Some(sink) = &lane.progress {
                // stream the predicted x̂₀ (Eq. 12) the kernel just produced;
                // only real executions reach here, so cache hits and
                // coalesced waiters never emit frames
                let step = lane.traj.steps_done();
                if sink.every > 0 && step % sink.every == 0 {
                    let total = lane.traj.plan().len();
                    (sink.on_step)(lane.lane_idx, step, total, batch.lane(slot).x0);
                }
            }
        }
        Ok(())
    }

    /// Add one sub-batch's stage wall-clock to every unique traced
    /// request with a lane in `sub`. No-op (and never called) when no
    /// traced request is resident.
    fn attribute_spans(
        lanes: &[Lane],
        inflight: &mut HashMap<RequestId, Inflight>,
        sub: &[usize],
        pack_s: f64,
        device_s: f64,
        advance_s: f64,
    ) {
        // sub-batches hold at most max_batch lanes: a linear dedup scan
        // beats hashing at that size
        let mut seen: Vec<RequestId> = Vec::new();
        for &li in sub {
            let req = lanes[li].req;
            if seen.contains(&req) {
                continue;
            }
            seen.push(req);
            if let Some(acc) =
                inflight.get_mut(&req).and_then(|inf| inf.trace.as_deref_mut())
            {
                acc.pack_s += pack_s;
                acc.device_s += device_s;
                acc.advance_s += advance_s;
            }
        }
    }

    /// Receive one completion from the executor, record and advance it,
    /// and return its buffers to the pool. Work counters move only on
    /// success, exactly like the inline path.
    fn complete_one(
        pipe: &mut PipelineExecutor,
        lanes: &mut [Lane],
        kernel_steps: &mut [u64; 3],
        finished: &mut Vec<usize>,
        ctr: &mut ExecCounters,
        inflight: &mut HashMap<RequestId, Inflight>,
        tracing: bool,
    ) -> Result<()> {
        let t0 = Instant::now();
        let done = pipe.recv_done()?;
        ctr.wait_s += t0.elapsed().as_secs_f64();
        ctr.busy_s += done.busy_s;
        ctr.ref_compute_s += done.ref_compute_s;
        ctr.ref_bytes += done.ref_bytes;
        let busy_s = done.busy_s;
        let SubBatchDone { job, result, .. } = done;
        let advanced = match &result {
            Ok(()) => {
                ctr.record_call(job.lanes, job.bucket);
                let adv_t0 = if tracing { Some(Instant::now()) } else { None };
                let advanced = Self::advance_sub(
                    lanes,
                    kernel_steps,
                    ctr,
                    &job.batch,
                    &job.sel[..job.lanes],
                    finished,
                );
                if tracing {
                    let adv_s = adv_t0.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
                    // pack time was attributed at the pack site (the tick
                    // loop), before this sub-batch was submitted
                    Self::attribute_spans(
                        lanes,
                        inflight,
                        &job.sel[..job.lanes],
                        0.0,
                        busy_s,
                        adv_s,
                    );
                }
                advanced
            }
            Err(_) => Ok(()),
        };
        pipe.put_free(job);
        result.and(advanced)
    }

    /// One scheduling tick: admit, select up to `max_batch` lanes
    /// round-robin, decompose the selection into planned sub-batches, run
    /// them (serially or through the pipeline), retire finished
    /// lanes/requests. Returns `true` if any work was done.
    pub fn tick(&mut self) -> Result<bool> {
        // reap expired work first so freed capacity is admittable this tick
        let reaped = self.expire_deadlines(Instant::now());
        self.admit();
        if self.lanes.is_empty() {
            return Ok(reaped > 0);
        }
        // span recording is tick-scoped: with no traced request resident
        // the execution paths below take zero extra clock reads
        let tracing = self.inflight.values().any(|i| i.trace.is_some());
        // --- select lanes round-robin (identical at every pipeline depth)
        let n_active = self.lanes.len();
        let n_sel = n_active.min(self.cfg.max_batch);
        self.sel.clear();
        for k in 0..n_sel {
            self.sel.push((self.rr_cursor + k) % n_active);
        }
        self.rr_cursor = (self.rr_cursor + n_sel) % n_active;

        // --- decompose the selection on bucket boundaries; the plan only
        // depends on (n_sel, buckets, threshold), never on pipeline depth,
        // which is what makes pipelined output bitwise-identical to serial
        let mut plan = std::mem::take(&mut self.plan);
        plan_sub_batches(
            n_sel,
            &self.manifest.buckets,
            self.batch_capacity,
            self.cfg.max_padding_waste,
            &mut plan,
        );
        self.ticks += 1;
        let ref_bytes_at_tick_start = self.ctr.ref_bytes;

        let mut finished: Vec<usize> = Vec::new();
        let mut first_err: Option<Error> = None;
        match &mut self.exec {
            ExecBackend::Inline { rt, batch } => {
                'subs: for sb in &plan {
                    let sub = &self.sel[sb.start..sb.start + sb.lanes];
                    let pack_t0 = if tracing { Some(Instant::now()) } else { None };
                    for (slot, &li) in sub.iter().enumerate() {
                        if let Err(e) = batch.pack(slot, &mut self.lanes[li].traj) {
                            first_err = Some(e);
                            break 'subs;
                        }
                    }
                    batch.pad(sb.lanes, sb.bucket);
                    let pack_s = pack_t0.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
                    let t0 = Instant::now();
                    let ran = rt.executable(&self.cfg.dataset, sb.bucket).and_then(|exe| {
                        batch.run(exe, sb.bucket)?;
                        // the reference kernel's counters are complete once
                        // run returns; harvest while the borrow is live
                        Ok(exe.take_ref_stats())
                    });
                    let dt = t0.elapsed().as_secs_f64();
                    // serial execution blocks this thread for the whole
                    // device call: busy == wait, overlap_frac == 0
                    self.ctr.busy_s += dt;
                    self.ctr.wait_s += dt;
                    match ran {
                        Ok((ref_s, ref_b)) => {
                            self.ctr.ref_compute_s += ref_s;
                            self.ctr.ref_bytes += ref_b;
                        }
                        Err(e) => {
                            first_err = Some(e);
                            break 'subs;
                        }
                    }
                    self.ctr.record_call(sb.lanes, sb.bucket);
                    let adv_t0 = if tracing { Some(Instant::now()) } else { None };
                    if let Err(e) = Self::advance_sub(
                        &mut self.lanes,
                        &mut self.kernel_steps,
                        &mut self.ctr,
                        batch,
                        sub,
                        &mut finished,
                    ) {
                        first_err = Some(e);
                        break 'subs;
                    }
                    if tracing {
                        let adv_s = adv_t0.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
                        Self::attribute_spans(
                            &self.lanes,
                            &mut self.inflight,
                            sub,
                            pack_s,
                            dt,
                            adv_s,
                        );
                    }
                }
            }
            ExecBackend::Pipelined(pipe) => {
                'subs: for sb in &plan {
                    // a buffer must be free before packing; completing the
                    // oldest in-flight sub-batch (advancing its lanes while
                    // newer ones run) is the pipeline's steady state
                    let mut job = loop {
                        if let Some(job) = pipe.take_free() {
                            break job;
                        }
                        if let Err(e) = Self::complete_one(
                            pipe,
                            &mut self.lanes,
                            &mut self.kernel_steps,
                            &mut finished,
                            &mut self.ctr,
                            &mut self.inflight,
                            tracing,
                        ) {
                            first_err = Some(e);
                            break 'subs;
                        }
                    };
                    job.sel.clear();
                    job.sel.extend_from_slice(&self.sel[sb.start..sb.start + sb.lanes]);
                    job.lanes = sb.lanes;
                    job.bucket = sb.bucket;
                    let pack_t0 = if tracing { Some(Instant::now()) } else { None };
                    let mut packed = true;
                    for slot in 0..job.lanes {
                        let li = job.sel[slot];
                        if let Err(e) = job.batch.pack(slot, &mut self.lanes[li].traj) {
                            first_err = Some(e);
                            packed = false;
                            break;
                        }
                    }
                    if !packed {
                        pipe.put_free(job);
                        break 'subs;
                    }
                    job.batch.pad(job.lanes, job.bucket);
                    if tracing {
                        // pack is attributed here, at the pack site; device
                        // + advance land in complete_one when this
                        // sub-batch's completion drains
                        let pack_s = pack_t0.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
                        Self::attribute_spans(
                            &self.lanes,
                            &mut self.inflight,
                            &job.sel[..job.lanes],
                            pack_s,
                            0.0,
                            0.0,
                        );
                    }
                    // work is counted at *completion* (complete_one), so a
                    // sub-batch that fails on the executor never inflates
                    // steps_executed
                    if let Err(e) = pipe.submit(job) {
                        first_err = Some(e);
                        break 'subs;
                    }
                }
                // --- drain: a tick ends with nothing in flight, so lane
                // indices stay valid for the retire pass and the next
                // tick's selection (and abort/shutdown) see settled state
                while pipe.in_flight() > 0 {
                    if let Err(e) = Self::complete_one(
                        pipe,
                        &mut self.lanes,
                        &mut self.kernel_steps,
                        &mut finished,
                        &mut self.ctr,
                        &mut self.inflight,
                        tracing,
                    ) {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                }
            }
        }
        self.plan = plan;
        self.ref_bytes_last_tick = self.ctr.ref_bytes - ref_bytes_at_tick_start;

        // --- retire finished lanes/requests, even on a partial tick —
        // a finished lane left resident would fail to pack next tick
        // (highest index first so swap_remove is safe)
        finished.sort_unstable_by(|a, b| b.cmp(a));
        for li in finished {
            let lane = self.lanes.swap_remove(li);
            self.lanes_done += 1;
            let inf = self
                .inflight
                .get_mut(&lane.req)
                .ok_or_else(|| Error::Coordinator("lane without inflight record".into()))?;
            inf.outputs[lane.lane_idx] = Some(lane.traj.into_state());
            inf.remaining_lanes -= 1;
            if inf.remaining_lanes == 0 {
                let inf = self.inflight.remove(&lane.req).unwrap();
                // pre-publish deadline check: work that finished after its
                // budget is cancelled, not delivered (and never reaches the
                // cache — the publish path only stores Ok responses)
                let now = Instant::now();
                if inf.deadline.is_some_and(|d| now >= d) {
                    self.deadline_expired += 1;
                    self.completed.push(Self::deadline_response(lane.req, inf.submitted, now));
                    continue;
                }
                let latency = now.duration_since(inf.submitted).as_secs_f64();
                self.latency.record(latency);
                self.requests_done += 1;
                let outputs = if inf.return_images {
                    inf.outputs.into_iter().map(Option::unwrap).collect()
                } else {
                    Vec::new()
                };
                // publish_s/total_s are the transport's to fill: the engine
                // cannot see serialization or socket time from here
                let spans = inf.trace.map(|b| crate::obs::Spans {
                    queue_s: b.queue_s,
                    pack_s: b.pack_s,
                    device_s: b.device_s,
                    advance_s: b.advance_s,
                    publish_s: 0.0,
                    total_s: latency,
                });
                self.completed.push(Response {
                    id: lane.req,
                    body: ResponseBody::Ok { outputs },
                    latency_s: latency,
                    steps_executed: inf.steps_total,
                    cached: false,
                    degraded: None,
                    spans,
                    coalesced: false,
                });
            }
        }
        if self.lanes.is_empty() {
            self.rr_cursor = 0;
        } else {
            self.rr_cursor %= self.lanes.len();
        }
        // a dead executor took its in-flight buffers with it and can never
        // execute again: answer everything resident/queued with an explicit
        // error now, instead of error-looping while waiters hang
        let executor_dead = matches!(&self.exec, ExecBackend::Pipelined(p) if p.is_dead());
        if executor_dead {
            self.abort_pending("step executor died");
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(true),
        }
    }

    /// Tick until queue and lanes drain; returns everything completed.
    pub fn run_until_idle(&mut self) -> Result<Vec<Response>> {
        while self.tick()? {}
        Ok(self.take_completed())
    }

    /// Whether any request is queued or resident.
    pub fn is_busy(&self) -> bool {
        !self.lanes.is_empty() || !self.queue.is_empty()
    }

    /// Graceful-shutdown path: tick until idle or `deadline`, whichever
    /// comes first, and return everything that completed. Work still
    /// resident after the deadline is left in place for [`Engine::abort_pending`].
    pub fn drain(&mut self, deadline: Instant) -> Result<Vec<Response>> {
        while self.is_busy() && Instant::now() < deadline {
            self.tick()?;
        }
        Ok(self.take_completed())
    }

    /// Answer every queued and in-flight request with an error response
    /// (pushed onto the completed list) and drop their lanes. Returns how
    /// many requests were aborted. Used when a drain deadline expires —
    /// nothing may be left blocked on a response channel. (Safe at any
    /// tick boundary: the pipeline never holds sub-batches across ticks.)
    pub fn abort_pending(&mut self, message: &str) -> usize {
        let mut aborted = 0;
        while let Some(p) = self.queue.pop() {
            self.completed.push(Response {
                id: p.id,
                body: ResponseBody::Error { message: message.to_string() },
                latency_s: p.submitted.elapsed().as_secs_f64(),
                steps_executed: 0,
                cached: false,
                degraded: None,
                spans: None,
                coalesced: false,
            });
            aborted += 1;
        }
        self.lanes.clear();
        self.rr_cursor = 0;
        for (id, inf) in std::mem::take(&mut self.inflight) {
            self.completed.push(Response {
                id,
                body: ResponseBody::Error { message: message.to_string() },
                latency_s: inf.submitted.elapsed().as_secs_f64(),
                steps_executed: 0,
                cached: false,
                degraded: None,
                spans: None,
                coalesced: false,
            });
            aborted += 1;
        }
        aborted
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests_completed: self.requests_done,
            requests_rejected: self.queue.rejected(),
            lanes_completed: self.lanes_done,
            executable_calls: self.ctr.calls,
            steps_executed: self.ctr.steps,
            kernel_steps: self.kernel_steps,
            occupancy_sum: self.ctr.occupancy_sum,
            ticks: self.ticks,
            sub_batches: self.ctr.sub_batches,
            padded_lanes: self.ctr.padded_lanes,
            pipeline_wait_s: self.ctr.wait_s,
            device_busy_s: self.ctr.busy_s,
            ref_compute_s: self.ctr.ref_compute_s,
            ref_bytes_allocated: self.ctr.ref_bytes,
            ref_bytes_last_tick: self.ref_bytes_last_tick,
            latency_p50_s: self.latency.quantile(0.5),
            latency_p95_s: self.latency.quantile(0.95),
            latency_p99_s: self.latency.quantile(0.99),
            latency_mean_s: self.latency.mean(),
            wall_s: self.started.elapsed().as_secs_f64(),
            queue_accepted: self.queue.accepted,
            queue_depth: self.queue.len(),
            queued_lanes: self.queue.lanes(),
            active_lanes: self.lanes.len(),
            queue_rejected_items: self.queue.rejected_items,
            queue_rejected_lanes: self.queue.rejected_lanes,
            deadline_expired: self.deadline_expired,
            // degradation is decided at the router (it sees pool-wide
            // pressure); per-engine snapshots report 0
            requests_degraded: 0,
        }
    }

    /// The raw latency histogram, for cross-shard [`Histogram::merge`]
    /// aggregation (quantiles of quantiles are not quantiles).
    pub fn latency_histogram(&self) -> Histogram {
        self.latency.clone()
    }
}
