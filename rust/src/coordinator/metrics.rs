//! Serving metrics: log-bucketed latency histograms (p50/p95/p99 without
//! storing samples), throughput counters, and batch-occupancy tracking —
//! the numbers `serve_e2e` and Fig. 4 report.

/// Log-bucketed histogram over (0, ~17 min] with ~4% resolution.
#[derive(Debug, Clone)]
pub struct Histogram {
    // bucket i covers [MIN * GROWTH^i, MIN * GROWTH^(i+1))
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max: f64,
}

const MIN_S: f64 = 1e-6;
const GROWTH: f64 = 1.04;
const NBUCKETS: usize = 530; // MIN_S * GROWTH^530 ≈ 1080 s

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { counts: vec![0; NBUCKETS], total: 0, sum: 0.0, max: 0.0 }
    }

    fn bucket(v: f64) -> usize {
        if v <= MIN_S {
            return 0;
        }
        let i = (v / MIN_S).ln() / GROWTH.ln();
        (i as usize).min(NBUCKETS - 1)
    }

    pub fn record(&mut self, v: f64) {
        self.counts[Self::bucket(v)] += 1;
        self.total += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Sum of all recorded values — the Prometheus `_sum` series.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Cumulative `(upper_bound, count_at_or_below)` pairs over every
    /// `stride`-th bucket edge — the Prometheus `_bucket{le=...}`
    /// series. Counts are monotone non-decreasing by construction and
    /// the tail is trimmed once the cumulative count reaches the total
    /// (the `+Inf` bucket the encoder appends covers the rest), keeping
    /// a quiet histogram's exposition short.
    pub fn cumulative(&self, stride: usize) -> Vec<(f64, u64)> {
        let stride = stride.max(1);
        let mut out = Vec::with_capacity(NBUCKETS / stride + 1);
        let mut acc = 0u64;
        let mut i = 0;
        while i < NBUCKETS {
            let end = (i + stride).min(NBUCKETS);
            acc += self.counts[i..end].iter().sum::<u64>();
            // upper edge of the last native bucket in this stride group
            out.push((MIN_S * GROWTH.powi(end as i32), acc));
            if acc == self.total {
                break;
            }
            i = end;
        }
        out
    }

    /// Bucket-wise merge: after `a.merge(&b)`, `a`'s quantiles are exactly
    /// those of a histogram that recorded every sample `a` and `b` saw.
    /// This is the correct way to aggregate latency across shards — taking
    /// the max (or mean) of per-shard quantiles is not (a shard with 3
    /// requests would weigh as much as one with 3 million).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Quantile estimate: upper edge of the containing bucket, clamped to
    /// the recorded max. Without the clamp the top bucket's upper edge
    /// leaks out — p99 could exceed every observed value and
    /// `quantile(1.0) > max()`, which reads as an SLO breach that never
    /// happened.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return (MIN_S * GROWTH.powi(i as i32 + 1)).min(self.max);
            }
        }
        self.max
    }
}

/// Point-in-time snapshot of engine counters.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub requests_completed: u64,
    pub requests_rejected: u64,
    pub lanes_completed: u64,
    pub executable_calls: u64,
    pub steps_executed: u64,
    /// Steps broken down by update kernel, indexed by
    /// [`crate::sampler::SamplerKind::index`] (ddim / pf_ode / ab2).
    /// Sums to `steps_executed`.
    pub kernel_steps: [u64; 3],
    /// sum over calls of (occupied lanes / bucket) — occupancy = this / calls
    pub occupancy_sum: f64,
    /// Ticks that executed at least one sub-batch.
    pub ticks: u64,
    /// Sub-batch device calls issued by the tick planner (equals
    /// `executable_calls`; kept explicit so `sub_batches / ticks` reads
    /// directly as the decomposition factor).
    pub sub_batches: u64,
    /// Dead (padding) lane-slots executed — `padding_waste()` is the
    /// fraction of all executed slots these represent.
    pub padded_lanes: u64,
    /// Engine-thread seconds spent blocked on device completions.
    pub pipeline_wait_s: f64,
    /// Seconds the execution path spent running sub-batches (device +
    /// readback). Serial engines block for all of it (`overlap_frac` 0);
    /// pipelined engines hide part of it behind pack/advance work.
    pub device_busy_s: f64,
    /// Seconds inside the reference step kernel proper (a subset of
    /// `device_busy_s`; 0 on the xla backend). `device_busy_s` minus this
    /// is packing/readback/channel overhead around the math.
    pub ref_compute_s: f64,
    /// Cumulative reference-backend bytes freshly allocated by step
    /// execution (output-buffer growth). Grows only while buffers warm up,
    /// then stays flat — the allocation-free-tick contract.
    pub ref_bytes_allocated: u64,
    /// Reference-backend bytes allocated by the most recent working tick.
    /// Exactly 0 in steady state; nonzero means a buffer grew mid-flight.
    pub ref_bytes_last_tick: u64,
    pub latency_p50_s: f64,
    pub latency_p95_s: f64,
    pub latency_p99_s: f64,
    pub latency_mean_s: f64,
    pub wall_s: f64,
    /// Total requests the admission queue ever accepted.
    pub queue_accepted: u64,
    /// Requests sitting in the admission queue right now.
    pub queue_depth: usize,
    /// Lanes queued (not yet admitted) right now.
    pub queued_lanes: usize,
    /// Lanes resident in the engine right now.
    pub active_lanes: usize,
    /// Rejections where the queue's *item* cap was binding.
    pub queue_rejected_items: u64,
    /// Rejections where the queue's *lane budget* was binding — the cap
    /// that actually bounds backlog latency (a count=8 request is 8 lanes
    /// of work, not 1 item).
    pub queue_rejected_lanes: u64,
    /// Requests cancelled because their deadline expired (at admission, a
    /// tick boundary, or the pre-publish check). Counted separately from
    /// `requests_rejected`: the client asked for the cancellation.
    pub deadline_expired: u64,
    /// Best-effort requests whose step budget was rewritten by the
    /// overload degradation ladder (router-level; per-engine snapshots
    /// report 0 and the router fills it during aggregation).
    pub requests_degraded: u64,
}

impl MetricsSnapshot {
    pub fn occupancy(&self) -> f64 {
        if self.executable_calls == 0 {
            0.0
        } else {
            self.occupancy_sum / self.executable_calls as f64
        }
    }

    pub fn steps_per_second(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.steps_executed as f64 / self.wall_s
        }
    }

    /// Fraction of executed lane-slots that were inert padding
    /// (`padded / (padded + occupied)`). The occupancy planner exists to
    /// drive this toward 0 at off-bucket lane counts.
    pub fn padding_waste(&self) -> f64 {
        let total = self.padded_lanes + self.steps_executed;
        if total == 0 {
            0.0
        } else {
            self.padded_lanes as f64 / total as f64
        }
    }

    /// Average sub-batches per working tick (1.0 = the old single-bucket
    /// policy's shape; higher means the planner is decomposing).
    pub fn sub_batches_per_tick(&self) -> f64 {
        if self.ticks == 0 {
            0.0
        } else {
            self.sub_batches as f64 / self.ticks as f64
        }
    }

    /// Fraction of execution time hidden behind engine-thread work
    /// (`1 - blocked/busy`): 0 for a serial engine, climbing toward 1 as
    /// the pipeline keeps the device and the host concurrently busy.
    pub fn overlap_frac(&self) -> f64 {
        if self.device_busy_s <= 0.0 {
            0.0
        } else {
            (1.0 - self.pipeline_wait_s / self.device_busy_s).clamp(0.0, 1.0)
        }
    }

    /// Fraction of execution-path time spent in the reference kernel
    /// itself (vs packing/readback/channel overhead). 0 on xla.
    pub fn ref_compute_frac(&self) -> f64 {
        if self.device_busy_s <= 0.0 {
            0.0
        } else {
            (self.ref_compute_s / self.device_busy_s).clamp(0.0, 1.0)
        }
    }

    /// Every counter-semantic field of this snapshot, keyed by its
    /// exported Prometheus family (plus `kernel` label where present).
    /// This is the documented gauge/counter audit: fields listed here
    /// are monotone over the life of an engine; everything else in the
    /// snapshot (`queue_depth`, `queued_lanes`, `active_lanes`,
    /// `ref_bytes_last_tick`, the derived occupancy/waste fractions) is
    /// a gauge and may decrease. `obs_spec.rs` asserts scrape-over-
    /// scrape monotonicity over exactly this list.
    pub fn counter_values(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("ddim_requests_completed_total", self.requests_completed as f64),
            ("ddim_requests_rejected_total", self.requests_rejected as f64),
            ("ddim_deadline_expired_total", self.deadline_expired as f64),
            ("ddim_requests_degraded_total", self.requests_degraded as f64),
            ("ddim_lanes_completed_total", self.lanes_completed as f64),
            ("ddim_executable_calls_total", self.executable_calls as f64),
            ("ddim_steps_executed_total", self.steps_executed as f64),
            ("ddim_steps_kernel_total{kernel=ddim}", self.kernel_steps[0] as f64),
            ("ddim_steps_kernel_total{kernel=pf_ode}", self.kernel_steps[1] as f64),
            ("ddim_steps_kernel_total{kernel=ab2}", self.kernel_steps[2] as f64),
            ("ddim_ticks_total", self.ticks as f64),
            ("ddim_sub_batches_total", self.sub_batches as f64),
            ("ddim_padded_lanes_total", self.padded_lanes as f64),
            ("ddim_queue_accepted_total", self.queue_accepted as f64),
            ("ddim_queue_rejected_items_total", self.queue_rejected_items as f64),
            ("ddim_queue_rejected_lanes_total", self.queue_rejected_lanes as f64),
            ("ddim_pipeline_wait_seconds_total", self.pipeline_wait_s),
            ("ddim_device_busy_seconds_total", self.device_busy_s),
            ("ddim_ref_compute_seconds_total", self.ref_compute_s),
            ("ddim_ref_bytes_allocated_total", self.ref_bytes_allocated as f64),
        ]
    }

    /// One-line human summary for examples/benches.
    pub fn summary(&self) -> String {
        format!(
            "req={} rej={} dl={} degr={} lanes={} calls={} steps={} (ddim/pf/ab2={}/{}/{}) occ={:.2} waste={:.2} sub/tick={:.2} ovl={:.2} refc={:.2} alloc/tick={} p50={:.1}ms p95={:.1}ms p99={:.1}ms thr={:.1} steps/s",
            self.requests_completed,
            self.requests_rejected,
            self.deadline_expired,
            self.requests_degraded,
            self.lanes_completed,
            self.executable_calls,
            self.steps_executed,
            self.kernel_steps[0],
            self.kernel_steps[1],
            self.kernel_steps[2],
            self.occupancy(),
            self.padding_waste(),
            self.sub_batches_per_tick(),
            self.overlap_frac(),
            self.ref_compute_frac(),
            self.ref_bytes_last_tick,
            self.latency_p50_s * 1e3,
            self.latency_p95_s * 1e3,
            self.latency_p99_s * 1e3,
            self.steps_per_second(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn quantiles_bracket_true_values() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3); // 1ms .. 1s uniform
        }
        let p50 = h.quantile(0.5);
        assert!((0.45..0.60).contains(&p50), "p50 {p50}");
        let p99 = h.quantile(0.99);
        assert!((0.9..1.1).contains(&p99), "p99 {p99}");
        assert!((h.mean() - 0.5005).abs() < 0.01);
        assert!(h.quantile(1.0) >= h.quantile(0.5));
    }

    #[test]
    fn quantile_never_exceeds_recorded_max() {
        // a single sample: every quantile IS that sample, not the upper
        // edge of its ~4%-wide bucket
        let mut h = Histogram::new();
        h.record(1.0);
        for q in [0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 1.0, "q={q}");
        }
        // many samples: p99 and p100 stay within the observed range
        let mut h = Histogram::new();
        let mut max = 0.0f64;
        for i in 1..=257 {
            let v = (i as f64) * 7.3e-3;
            h.record(v);
            max = max.max(v);
        }
        assert!(h.quantile(0.99) <= max, "p99 {} > max {max}", h.quantile(0.99));
        assert_eq!(h.quantile(1.0), max);
        // merged histograms inherit the clamp
        let mut other = Histogram::new();
        other.record(max * 2.0);
        h.merge(&other);
        assert_eq!(h.quantile(1.0), max * 2.0);
    }

    #[test]
    fn extreme_values_clamp() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(1e9);
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.01) > 0.0);
        assert_eq!(h.max(), 1e9);
    }

    #[test]
    fn merge_matches_recording_into_one_histogram() {
        // two shards with very different latency profiles + counts
        let mut fast = Histogram::new();
        let mut slow = Histogram::new();
        let mut all = Histogram::new();
        for i in 1..=900 {
            let v = i as f64 * 1e-4; // 0.1ms .. 90ms
            fast.record(v);
            all.record(v);
        }
        for i in 1..=100 {
            let v = 0.5 + i as f64 * 1e-2; // 510ms .. 1.5s
            slow.record(v);
            all.record(v);
        }
        let mut merged = fast.clone();
        merged.merge(&slow);
        assert_eq!(merged.count(), all.count());
        for q in [0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(merged.quantile(q), all.quantile(q), "q={q}");
        }
        assert!((merged.mean() - all.mean()).abs() < 1e-12);
        assert_eq!(merged.max(), all.max());
        // and the max-of-quantiles the old server used really is wrong:
        // 90% of traffic is fast, so the true p50 is fast, but the per-shard
        // max picks the slow shard's p50.
        let wrong_p50 = fast.quantile(0.5).max(slow.quantile(0.5));
        assert!(wrong_p50 > 2.0 * all.quantile(0.5));
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut h = Histogram::new();
        h.record(0.25);
        h.record(0.75);
        let before = (h.count(), h.quantile(0.5), h.mean(), h.max());
        h.merge(&Histogram::new());
        assert_eq!(before, (h.count(), h.quantile(0.5), h.mean(), h.max()));
        let mut empty = Histogram::new();
        empty.merge(&h);
        assert_eq!(empty.quantile(0.95), h.quantile(0.95));
        assert_eq!(empty.count(), 2);
    }

    #[test]
    fn cumulative_buckets_are_monotone_trimmed_and_complete() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3);
        }
        let cum = h.cumulative(8);
        assert!(!cum.is_empty());
        let mut prev_bound = 0.0;
        let mut prev_count = 0;
        for &(bound, count) in &cum {
            assert!(bound > prev_bound, "le bounds must increase");
            assert!(count >= prev_count, "bucket counts must be cumulative");
            prev_bound = bound;
            prev_count = count;
        }
        // trimmed: the last pair already covers every sample, so the tail
        // of empty high buckets is gone
        assert_eq!(cum.last().unwrap().1, h.count());
        assert!(cum.len() < NBUCKETS / 8 + 1, "tail not trimmed: {} pairs", cum.len());
        // bucket semantics: count at `le` == number of samples <= le
        for &(bound, count) in &cum {
            let expect = (1..=1000).filter(|&i| i as f64 * 1e-3 <= bound).count() as u64;
            // log-bucket edges shift samples by at most one bucket's worth
            assert!(
                count >= expect.saturating_sub(50) && count <= expect + 50,
                "le={bound}: {count} vs {expect}"
            );
        }
        // an empty histogram still exposes a well-formed (single) pair
        let empty = Histogram::new().cumulative(8);
        assert_eq!(empty.len(), 1);
        assert_eq!(empty[0].1, 0);
    }

    #[test]
    fn counter_values_lists_only_monotone_fields() {
        let names: Vec<&str> =
            MetricsSnapshot::default().counter_values().iter().map(|(n, _)| *n).collect();
        // the gauge side of the audit: point-in-time fields must be absent
        for gauge in ["queue_depth", "queued_lanes", "active_lanes", "ref_bytes_last_tick"] {
            assert!(
                !names.iter().any(|n| n.contains(gauge)),
                "gauge {gauge} leaked into the counter list"
            );
        }
        // simulate engine progress: every listed counter is non-decreasing
        let before = MetricsSnapshot { steps_executed: 10, ticks: 2, ..Default::default() };
        let after = MetricsSnapshot { steps_executed: 25, ticks: 5, ..Default::default() };
        for ((name, a), (_, b)) in before.counter_values().iter().zip(after.counter_values()) {
            assert!(b >= *a, "counter {name} decreased: {a} -> {b}");
        }
    }

    #[test]
    fn snapshot_derived_metrics() {
        let s = MetricsSnapshot {
            executable_calls: 10,
            occupancy_sum: 7.5,
            steps_executed: 100,
            wall_s: 2.0,
            ..Default::default()
        };
        assert!((s.occupancy() - 0.75).abs() < 1e-12);
        assert!((s.steps_per_second() - 50.0).abs() < 1e-12);
        assert!(!s.summary().is_empty());
    }

    #[test]
    fn planner_and_pipeline_gauges() {
        let empty = MetricsSnapshot::default();
        assert_eq!(empty.padding_waste(), 0.0);
        assert_eq!(empty.sub_batches_per_tick(), 0.0);
        assert_eq!(empty.overlap_frac(), 0.0);

        // 100 occupied slots + 25 padded: 20% of executed slots wasted
        let s = MetricsSnapshot {
            steps_executed: 100,
            padded_lanes: 25,
            ticks: 10,
            sub_batches: 15,
            pipeline_wait_s: 1.0,
            device_busy_s: 4.0,
            ..Default::default()
        };
        assert!((s.padding_waste() - 0.2).abs() < 1e-12);
        assert!((s.sub_batches_per_tick() - 1.5).abs() < 1e-12);
        assert!((s.overlap_frac() - 0.75).abs() < 1e-12);

        // serial engines block for every device second: zero overlap,
        // and clock jitter must never push the gauge negative
        let serial = MetricsSnapshot {
            pipeline_wait_s: 4.00001,
            device_busy_s: 4.0,
            ..Default::default()
        };
        assert_eq!(serial.overlap_frac(), 0.0);
    }

    #[test]
    fn reference_kernel_gauges() {
        let empty = MetricsSnapshot::default();
        assert_eq!(empty.ref_compute_frac(), 0.0);

        let s = MetricsSnapshot {
            device_busy_s: 4.0,
            ref_compute_s: 3.0,
            ref_bytes_allocated: 1 << 20,
            ref_bytes_last_tick: 0,
            ..Default::default()
        };
        assert!((s.ref_compute_frac() - 0.75).abs() < 1e-12);
        assert!(s.summary().contains("alloc/tick=0"));

        // clock jitter must never push the fraction past 1
        let jitter = MetricsSnapshot {
            device_busy_s: 4.0,
            ref_compute_s: 4.00001,
            ..Default::default()
        };
        assert_eq!(jitter.ref_compute_frac(), 1.0);
    }
}
