//! Event-loop reactors for the v2 transport: N threads, each multiplexing
//! many connections over a [`Poller`] (raw `epoll` on Linux via a
//! libc-free syscall shim; a sleep-poll fallback elsewhere so the crate
//! still builds and tests off-Linux).
//!
//! Division of labour with the rest of the transport:
//! - [`super::conn::ConnState`] owns framing and buffering (pure, no I/O).
//! - A [`Reactor`] owns the sockets: it reads bytes into the state
//!   machine, hands complete lines to the protocol handler installed by
//!   [`super::server::Server`], and drains write buffers when sockets go
//!   writable — it never blocks on any one client.
//! - Completed requests arrive from engine-shard threads as
//!   [`Completion`]s pushed onto the owning reactor's inbox; the producer
//!   wakes the reactor through a loopback socket pair (the zero-dep
//!   stand-in for an eventfd), and the reactor writes the line out when
//!   the client socket accepts it. A slow client therefore delays only
//!   itself: frames get dropped past the write-buffer soft cap and reads
//!   pause while the backlog is over the cap, but final responses are
//!   never dropped.
//!
//! Tokens are per-reactor, monotonically increasing, and never reused, so
//! a completion racing a disconnect can only miss (dropped response for a
//! gone client), never hit a recycled connection.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::conn::{ConnEvent, ConnState, MAX_LINE_BYTES, WRITE_SOFT_CAP};

/// Token reserved for the wake channel's read end.
const WAKE_TOKEN: u64 = 0;

/// One readiness event out of [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
}

/// Protocol hook installed by the server: called once per complete
/// request line, on the reactor thread. Immediate replies go straight
/// into the [`ConnState`] write buffer; deferred ones come back later as
/// [`Completion`]s addressed by token.
pub(crate) type LineHandler = Arc<dyn Fn(u64, &str, &mut ConnState) + Send + Sync>;

/// One outbound line for a connection owned by some reactor.
pub(crate) struct Completion {
    pub token: u64,
    pub line: String,
    /// Best-effort frame (droppable under backpressure) vs final
    /// response (never dropped).
    pub frame: bool,
}

#[derive(Default)]
struct Inbox {
    conns: Vec<TcpStream>,
    completions: Vec<Completion>,
}

/// Per-reactor counters, read by the metrics endpoint.
#[derive(Default)]
pub struct ReactorStats {
    pub wakeups: AtomicU64,
    pub connections: AtomicU64,
    pub frames_streamed: AtomicU64,
    pub frames_dropped: AtomicU64,
    pub lines_overlong: AtomicU64,
    /// Write syscalls saved by batching a completion burst: queued lines
    /// beyond the first per connection per drain ride the same contiguous
    /// flush instead of each issuing their own `write`.
    pub writes_coalesced: AtomicU64,
}

/// The handle other threads use to feed a reactor: push work, then wake.
pub(crate) struct ReactorShared {
    inbox: Mutex<Inbox>,
    wake_tx: TcpStream,
    wake_pending: AtomicBool,
    pub stats: ReactorStats,
}

impl ReactorShared {
    pub fn push_conn(&self, stream: TcpStream) {
        self.inbox.lock().expect("reactor inbox poisoned").conns.push(stream);
        self.wake();
    }

    pub fn push_completion(&self, c: Completion) {
        self.inbox
            .lock()
            .expect("reactor inbox poisoned")
            .completions
            .push(c);
        self.wake();
    }

    /// Wake the reactor's poller. Coalesced: while a wake byte is already
    /// in flight, producers skip the write — the reactor clears the flag
    /// *before* draining its inbox, so nothing pushed after the clear can
    /// be missed.
    pub fn wake(&self) {
        if !self.wake_pending.swap(true, Ordering::AcqRel) {
            let _ = (&self.wake_tx).write(&[1u8]);
        }
    }
}

/// One connection owned by a reactor.
struct Slot {
    stream: TcpStream,
    state: ConnState,
    /// Interests currently registered with the poller.
    reg_read: bool,
    reg_write: bool,
}

/// One event-loop thread plus its poller and wake channel.
pub(crate) struct Reactor {
    id: usize,
    poller: Poller,
    wake_rx: TcpStream,
    shared: Arc<ReactorShared>,
}

impl Reactor {
    /// Build the poller + wake channel and the shared handle producers
    /// will use. The thread itself starts in [`Reactor::start`].
    pub fn new(id: usize) -> io::Result<(Reactor, Arc<ReactorShared>)> {
        let poller = Poller::new()?;
        let (wake_tx, wake_rx) = wake_pair()?;
        wake_rx.set_nonblocking(true)?;
        poller.add(&wake_rx, WAKE_TOKEN, true, false)?;
        let shared = Arc::new(ReactorShared {
            inbox: Mutex::new(Inbox::default()),
            wake_tx,
            wake_pending: AtomicBool::new(false),
            stats: ReactorStats::default(),
        });
        Ok((Reactor { id, poller, wake_rx, shared: shared.clone() }, shared))
    }

    pub fn start(
        self,
        handler: LineHandler,
        stop: Arc<AtomicBool>,
        open_gauge: Arc<AtomicU64>,
    ) -> io::Result<JoinHandle<()>> {
        std::thread::Builder::new()
            .name(format!("ddim-reactor-{}", self.id))
            .spawn(move || self.run(handler, stop, open_gauge))
    }

    fn run(self, handler: LineHandler, stop: Arc<AtomicBool>, open_gauge: Arc<AtomicU64>) {
        let mut conns: HashMap<u64, Slot> = HashMap::new();
        let mut next_token: u64 = WAKE_TOKEN + 1;
        let mut events: Vec<PollEvent> = Vec::with_capacity(128);
        let mut rdbuf = [0u8; 16 * 1024];
        let mut line_events: Vec<ConnEvent> = Vec::new();
        while !stop.load(Ordering::Acquire) {
            if let Err(e) = self.poller.wait(&mut events, 50) {
                // poller failure is unrecoverable for this reactor; don't
                // spin silently
                eprintln!("ddim-reactor-{}: poll failed: {e}", self.id);
                break;
            }
            let mut woken = false;
            for ev in events.drain(..) {
                if ev.token == WAKE_TOKEN {
                    woken = true;
                    continue;
                }
                let Some(slot) = conns.get_mut(&ev.token) else {
                    continue; // closed earlier this iteration
                };
                let mut dead = false;
                if ev.writable && slot.state.wants_write() {
                    dead = !flush(slot);
                }
                if ev.readable && !dead && slot.reg_read {
                    dead = !read_into(slot, &mut rdbuf, &mut line_events);
                    for le in line_events.drain(..) {
                        match le {
                            ConnEvent::Line(l) => {
                                // a one-shot HTTP exchange ends at its
                                // request line; trailing header lines are
                                // not requests
                                if !l.trim().is_empty() && !slot.state.close_after_flush() {
                                    handler(ev.token, &l, &mut slot.state);
                                }
                            }
                            ConnEvent::Overlong => {
                                self.shared
                                    .stats
                                    .lines_overlong
                                    .fetch_add(1, Ordering::Relaxed);
                                slot.state.queue_line(
                                    "{\"ok\":false,\"error\":\"line too long\"}",
                                );
                            }
                        }
                    }
                    if !dead {
                        dead = !flush(slot);
                    }
                }
                let flushed_close = !dead && {
                    let slot = conns.get_mut(&ev.token).expect("live slot");
                    slot.state.close_after_flush() && !slot.state.wants_write()
                };
                if dead || flushed_close {
                    self.close(&mut conns, ev.token, &open_gauge);
                } else {
                    self.update_interest(conns.get_mut(&ev.token).expect("live slot"), ev.token);
                }
            }
            if woken {
                // drain the wake bytes, then clear the pending flag BEFORE
                // taking the inbox (see ReactorShared::wake)
                let mut junk = [0u8; 64];
                while matches!((&self.wake_rx).read(&mut junk), Ok(n) if n > 0) {}
                self.shared.wake_pending.store(false, Ordering::Release);
                self.shared.stats.wakeups.fetch_add(1, Ordering::Relaxed);
            }
            // always drain the inbox — cheap when empty, and it makes the
            // loop robust to a lost wake byte
            let (new_conns, completions) = {
                let mut inbox = self.shared.inbox.lock().expect("reactor inbox poisoned");
                (
                    std::mem::take(&mut inbox.conns),
                    std::mem::take(&mut inbox.completions),
                )
            };
            for stream in new_conns {
                let token = next_token;
                next_token += 1;
                if self.adopt(&mut conns, token, stream).is_err() {
                    // couldn't register: drop the socket (client sees EOF)
                    open_gauge.fetch_sub(1, Ordering::Relaxed);
                    continue;
                }
                self.shared.stats.connections.fetch_add(1, Ordering::Relaxed);
            }
            // two-pass drain: queue every completion first, then flush each
            // touched connection once. A burst of completions for one client
            // (pipelined ids, streamed frames) previously issued one `write`
            // per line; batching lets the backlog leave in one syscall.
            let mut queued = 0usize;
            let mut touched: Vec<u64> = Vec::with_capacity(completions.len());
            for c in completions {
                let Some(slot) = conns.get_mut(&c.token) else {
                    continue; // client disconnected while the request ran
                };
                if c.frame {
                    if slot.state.queue_frame(&c.line) {
                        queued += 1;
                        self.shared
                            .stats
                            .frames_streamed
                            .fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.shared
                            .stats
                            .frames_dropped
                            .fetch_add(1, Ordering::Relaxed);
                    }
                } else {
                    slot.state.queue_line(&c.line);
                    queued += 1;
                }
                touched.push(c.token);
            }
            touched.sort_unstable();
            touched.dedup();
            if queued > touched.len() {
                self.shared
                    .stats
                    .writes_coalesced
                    .fetch_add((queued - touched.len()) as u64, Ordering::Relaxed);
            }
            for token in touched {
                let Some(slot) = conns.get_mut(&token) else {
                    continue; // closed while queueing an earlier completion
                };
                let dead = !flush(slot);
                if dead || (slot.state.close_after_flush() && !slot.state.wants_write()) {
                    self.close(&mut conns, token, &open_gauge);
                } else {
                    self.update_interest(slot, token);
                }
            }
        }
        // drain on stop: the router finishes answering waiters *before*
        // the stop flag is set, so completions may still be sitting in the
        // inbox (pushed between our last drain and the stop check) — take
        // them now or an in-flight client would see EOF instead of its
        // "shutting down" answer
        let (late_conns, late_completions) = {
            let mut inbox = self.shared.inbox.lock().expect("reactor inbox poisoned");
            (std::mem::take(&mut inbox.conns), std::mem::take(&mut inbox.completions))
        };
        for _ in late_conns {
            // accepted but never served: closing the socket is the answer
            open_gauge.fetch_sub(1, Ordering::Relaxed);
        }
        for c in late_completions {
            if let Some(slot) = conns.get_mut(&c.token) {
                if !c.frame {
                    slot.state.queue_line(&c.line);
                }
            }
        }
        // give pending responses one bounded, non-blocking chance to reach
        // their sockets, then close everything
        let deadline = Instant::now() + Duration::from_millis(100);
        while Instant::now() < deadline {
            let mut pending = false;
            for slot in conns.values_mut() {
                if slot.state.wants_write() {
                    flush(slot);
                    pending |= slot.state.wants_write();
                }
            }
            if !pending {
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        let n = conns.len() as u64;
        for (token, slot) in conns.drain() {
            let _ = self.poller.del(&slot.stream, token);
        }
        open_gauge.fetch_sub(n, Ordering::Relaxed);
        self.shared.stats.connections.store(0, Ordering::Relaxed);
    }

    fn adopt(
        &self,
        conns: &mut HashMap<u64, Slot>,
        token: u64,
        stream: TcpStream,
    ) -> io::Result<()> {
        stream.set_nonblocking(true)?;
        let _ = stream.set_nodelay(true);
        self.poller.add(&stream, token, true, false)?;
        conns.insert(
            token,
            Slot {
                stream,
                state: ConnState::new(MAX_LINE_BYTES, WRITE_SOFT_CAP),
                reg_read: true,
                reg_write: false,
            },
        );
        Ok(())
    }

    fn close(&self, conns: &mut HashMap<u64, Slot>, token: u64, open_gauge: &AtomicU64) {
        if let Some(slot) = conns.remove(&token) {
            let _ = self.poller.del(&slot.stream, token);
            self.shared.stats.connections.fetch_sub(1, Ordering::Relaxed);
            open_gauge.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Re-register the poller interests to match the slot's state:
    /// write interest iff bytes are pending, read interest unless the
    /// write backlog is over the soft cap (read-side backpressure — an
    /// un-drained client stops being able to submit more work).
    fn update_interest(&self, slot: &mut Slot, token: u64) {
        let want_write = slot.state.wants_write();
        let want_read = !slot.state.over_cap();
        if want_write != slot.reg_write || want_read != slot.reg_read {
            slot.reg_write = want_write;
            slot.reg_read = want_read;
            let _ = self.poller.modify(&slot.stream, token, want_read, want_write);
        }
    }
}

/// Read until `WouldBlock`/EOF, feeding the state machine. Returns
/// `false` when the connection is dead (EOF or hard error).
fn read_into(slot: &mut Slot, buf: &mut [u8], out: &mut Vec<ConnEvent>) -> bool {
    loop {
        match slot.stream.read(buf) {
            Ok(0) => return false,
            Ok(n) => {
                slot.state.ingest(&buf[..n], out);
                if slot.state.over_cap() {
                    // stop pulling more requests until the client drains
                    return true;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
}

/// Write as much of the pending buffer as the socket accepts. Returns
/// `false` when the connection is dead.
fn flush(slot: &mut Slot) -> bool {
    while slot.state.wants_write() {
        match slot.stream.write(slot.state.pending_write()) {
            Ok(0) => return false,
            Ok(n) => slot.state.consume_written(n),
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    true
}

/// Loopback socket pair standing in for an eventfd: portable, zero-dep,
/// and its read end registers with the poller like any other socket.
fn wake_pair() -> io::Result<(TcpStream, TcpStream)> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let tx = TcpStream::connect(addr)?;
    let (rx, _) = listener.accept()?;
    tx.set_nodelay(true)?;
    Ok((tx, rx))
}

// ---------------------------------------------------------------------------
// Poller: raw epoll on Linux (no libc — direct syscalls), sleep-poll
// readiness hints elsewhere. Correctness never depends on edge accuracy:
// sockets are nonblocking and the reactor tolerates spurious readiness
// (reads return WouldBlock, writes no-op), so the fallback merely burns
// more CPU. Linux is the production path.
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
pub use linux::{raise_nofile_limit, Poller};

#[cfg(not(target_os = "linux"))]
pub use fallback::{raise_nofile_limit, Poller};

#[cfg(target_os = "linux")]
mod linux {
    use super::PollEvent;
    use std::io;
    use std::net::TcpStream;
    use std::os::unix::io::AsRawFd;

    // x86_64 declares epoll_event packed in the kernel ABI; every other
    // arch uses natural alignment.
    #[cfg_attr(target_arch = "x86_64", repr(C, packed))]
    #[cfg_attr(not(target_arch = "x86_64"), repr(C))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLL_CTL_ADD: i64 = 1;
    const EPOLL_CTL_DEL: i64 = 2;
    const EPOLL_CTL_MOD: i64 = 3;
    const EPOLL_CLOEXEC: i64 = 0x80000;
    const EINTR: i64 = 4;
    const RLIMIT_NOFILE: i64 = 7;

    #[cfg(target_arch = "x86_64")]
    mod nr {
        pub const CLOSE: i64 = 3;
        pub const EPOLL_CTL: i64 = 233;
        pub const EPOLL_PWAIT: i64 = 281;
        pub const EPOLL_CREATE1: i64 = 291;
        pub const PRLIMIT64: i64 = 302;
    }
    #[cfg(target_arch = "aarch64")]
    mod nr {
        pub const CLOSE: i64 = 57;
        pub const EPOLL_CTL: i64 = 21;
        pub const EPOLL_PWAIT: i64 = 22;
        pub const EPOLL_CREATE1: i64 = 20;
        pub const PRLIMIT64: i64 = 261;
    }

    #[cfg(target_arch = "x86_64")]
    #[inline]
    unsafe fn syscall6(n: i64, a1: i64, a2: i64, a3: i64, a4: i64, a5: i64, a6: i64) -> i64 {
        let ret: i64;
        core::arch::asm!(
            "syscall",
            inlateout("rax") n => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
        ret
    }

    #[cfg(target_arch = "aarch64")]
    #[inline]
    unsafe fn syscall6(n: i64, a1: i64, a2: i64, a3: i64, a4: i64, a5: i64, a6: i64) -> i64 {
        let ret: i64;
        core::arch::asm!(
            "svc 0",
            in("x8") n,
            inlateout("x0") a1 => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            options(nostack),
        );
        ret
    }

    /// Kernel return convention: [-4095, -1] is -errno.
    fn check(ret: i64) -> io::Result<i64> {
        if (-4095..0).contains(&ret) {
            Err(io::Error::from_raw_os_error(-ret as i32))
        } else {
            Ok(ret)
        }
    }

    /// Level-triggered epoll instance; the fd closes on drop.
    pub struct Poller {
        ep: i64,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let ep = check(unsafe {
                syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0)
            })?;
            Ok(Poller { ep })
        }

        fn ctl(&self, op: i64, fd: i64, events: u32, token: u64) -> io::Result<()> {
            let ev = EpollEvent { events, data: token };
            check(unsafe {
                syscall6(nr::EPOLL_CTL, self.ep, op, fd, &ev as *const EpollEvent as i64, 0, 0)
            })
            .map(|_| ())
        }

        pub fn add(&self, s: &TcpStream, token: u64, read: bool, write: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, s.as_raw_fd() as i64, interest(read, write), token)
        }

        pub fn modify(
            &self,
            s: &TcpStream,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, s.as_raw_fd() as i64, interest(read, write), token)
        }

        pub fn del(&self, s: &TcpStream, _token: u64) -> io::Result<()> {
            // the event ptr must be non-null for pre-2.6.9 kernels; reuse
            // a dummy
            self.ctl(EPOLL_CTL_DEL, s.as_raw_fd() as i64, 0, 0)
        }

        /// Wait up to `timeout_ms` and decode readiness into `out`
        /// (cleared first). EINTR is retried.
        pub fn wait(&self, out: &mut Vec<PollEvent>, timeout_ms: i32) -> io::Result<()> {
            out.clear();
            let mut evs = [EpollEvent { events: 0, data: 0 }; 128];
            let n = loop {
                // epoll_pwait(epfd, events, maxevents, timeout, sigmask=NULL, sigsetsize)
                // (aarch64 has no plain epoll_wait syscall)
                let r = unsafe {
                    syscall6(
                        nr::EPOLL_PWAIT,
                        self.ep,
                        evs.as_mut_ptr() as i64,
                        evs.len() as i64,
                        timeout_ms as i64,
                        0,
                        8,
                    )
                };
                if r == -EINTR {
                    continue;
                }
                break check(r)? as usize;
            };
            for ev in evs.iter().take(n) {
                let e = *ev;
                let bits = e.events;
                out.push(PollEvent {
                    token: e.data,
                    // errors/hangups surface as readable: the next read
                    // returns 0/Err and the reactor closes the slot
                    readable: bits & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                    writable: bits & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                syscall6(nr::CLOSE, self.ep, 0, 0, 0, 0, 0);
            }
        }
    }

    fn interest(read: bool, write: bool) -> u32 {
        let mut e = 0;
        if read {
            e |= EPOLLIN;
        }
        if write {
            e |= EPOLLOUT;
        }
        e
    }

    #[repr(C)]
    struct Rlimit64 {
        cur: u64,
        max: u64,
    }

    /// Raise the soft RLIMIT_NOFILE to the hard limit (the bench opens
    /// thousands of sockets in one process). Returns the resulting soft
    /// limit; never fails harder than "returns the old limit".
    pub fn raise_nofile_limit() -> u64 {
        let mut old = Rlimit64 { cur: 0, max: 0 };
        let got = unsafe {
            syscall6(
                nr::PRLIMIT64,
                0,
                RLIMIT_NOFILE,
                0,
                &mut old as *mut Rlimit64 as i64,
                0,
                0,
            )
        };
        if check(got).is_err() {
            return 1024;
        }
        if old.cur >= old.max {
            return old.cur;
        }
        let want = Rlimit64 { cur: old.max, max: old.max };
        let set = unsafe {
            syscall6(
                nr::PRLIMIT64,
                0,
                RLIMIT_NOFILE,
                &want as *const Rlimit64 as i64,
                0,
                0,
                0,
            )
        };
        if check(set).is_ok() {
            old.max
        } else {
            old.cur
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod fallback {
    use super::PollEvent;
    use std::collections::HashMap;
    use std::io;
    use std::net::TcpStream;
    use std::sync::Mutex;
    use std::time::Duration;

    /// Portability fallback: no readiness signal, so `wait` sleeps
    /// briefly and reports every registered token as ready for its
    /// interests. Nonblocking sockets make spurious readiness harmless;
    /// this just polls harder than epoll would.
    pub struct Poller {
        interests: Mutex<HashMap<u64, (bool, bool)>>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { interests: Mutex::new(HashMap::new()) })
        }

        pub fn add(&self, _s: &TcpStream, token: u64, read: bool, write: bool) -> io::Result<()> {
            self.interests.lock().unwrap().insert(token, (read, write));
            Ok(())
        }

        pub fn modify(
            &self,
            _s: &TcpStream,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.interests.lock().unwrap().insert(token, (read, write));
            Ok(())
        }

        pub fn del(&self, _s: &TcpStream, token: u64) -> io::Result<()> {
            self.interests.lock().unwrap().remove(&token);
            Ok(())
        }

        pub fn wait(&self, out: &mut Vec<PollEvent>, timeout_ms: i32) -> io::Result<()> {
            out.clear();
            std::thread::sleep(Duration::from_millis((timeout_ms.max(1) as u64).min(3)));
            for (&token, &(read, write)) in self.interests.lock().unwrap().iter() {
                if read || write {
                    out.push(PollEvent { token, readable: read, writable: write });
                }
            }
            Ok(())
        }
    }

    pub fn raise_nofile_limit() -> u64 {
        1024
    }
}
