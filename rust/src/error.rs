//! Crate-wide error type. One enum, `thiserror`-derived, so every layer
//! (artifact loading, JSON, PJRT, coordinator) reports through a single
//! `Result` alias.

use thiserror::Error;

/// All the ways the serving stack can fail.
#[derive(Error, Debug)]
pub enum Error {
    /// I/O errors from artifact / image / socket handling.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// JSON syntax or type errors from [`crate::json`].
    #[error("json: {0}")]
    Json(String),

    /// Malformed or missing artifacts (manifest, tensorfiles, HLO).
    #[error("artifact: {0}")]
    Artifact(String),

    /// PJRT / XLA failures surfaced by the `xla` crate.
    #[error("xla: {0}")]
    Xla(String),

    /// Shape or dtype mismatches in tensor plumbing.
    #[error("shape: {0}")]
    Shape(String),

    /// Invalid schedule parameters (τ, η, S out of range).
    #[error("schedule: {0}")]
    Schedule(String),

    /// Coordinator-level rejections (queue full, unknown dataset, ...).
    #[error("coordinator: {0}")]
    Coordinator(String),

    /// Linear-algebra failures (non-convergence, non-SPD input).
    #[error("linalg: {0}")]
    Linalg(String),

    /// Malformed client requests on the wire protocol.
    #[error("request: {0}")]
    Request(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
