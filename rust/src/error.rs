//! Crate-wide error type. One enum with hand-rolled `Display` /
//! `std::error::Error` impls (the hermetic build carries zero external
//! dependencies — `thiserror` included), so every layer (artifact loading,
//! JSON, backend, coordinator) reports through a single `Result` alias.

use std::fmt;

/// All the ways the serving stack can fail.
#[derive(Debug)]
pub enum Error {
    /// I/O errors from artifact / image / socket handling.
    Io(std::io::Error),

    /// JSON syntax or type errors from [`crate::json`].
    Json(String),

    /// Malformed or missing artifacts (manifest, tensorfiles, HLO).
    Artifact(String),

    /// Step-backend failures: PJRT/XLA errors surfaced by the `xla`
    /// feature's wrapper crate, or reference-backend misuse.
    Xla(String),

    /// Shape or dtype mismatches in tensor plumbing.
    Shape(String),

    /// Invalid schedule parameters (τ, η, S out of range).
    Schedule(String),

    /// Coordinator-level rejections (queue full, unknown dataset, ...).
    Coordinator(String),

    /// Typed admission rejection: queue pressure exhausted the item cap
    /// or the lane budget. Carries the queued-lane count observed at the
    /// decision so the wire response can report it structurally.
    Overload { queued_lanes: usize, message: String },

    /// Typed deadline expiry: the request's completion budget ran out (at
    /// admission, a tick boundary, or the pre-publish check).
    DeadlineExpired { message: String },

    /// Linear-algebra failures (non-convergence, non-SPD input).
    Linalg(String),

    /// Malformed client requests on the wire protocol.
    Request(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Json(m) => write!(f, "json: {m}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
            Error::Shape(m) => write!(f, "shape: {m}"),
            Error::Schedule(m) => write!(f, "schedule: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator: {m}"),
            Error::Overload { queued_lanes, message } => {
                write!(f, "overload: {message} (queued_lanes {queued_lanes})")
            }
            Error::DeadlineExpired { message } => write!(f, "deadline: {message}"),
            Error::Linalg(m) => write!(f, "linalg: {m}"),
            Error::Request(m) => write!(f, "request: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_layer() {
        assert_eq!(Error::Json("bad".into()).to_string(), "json: bad");
        assert_eq!(
            Error::Overload { queued_lanes: 12, message: "queue full".into() }.to_string(),
            "overload: queue full (queued_lanes 12)"
        );
        assert_eq!(
            Error::DeadlineExpired { message: "budget spent".into() }.to_string(),
            "deadline: budget spent"
        );
        assert_eq!(Error::Xla("pjrt".into()).to_string(), "xla: pjrt");
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().starts_with("io: "));
        use std::error::Error as _;
        assert!(io.source().is_some());
        assert!(Error::Shape("s".into()).source().is_none());
    }
}
