//! Literal marshalling between host `f32` buffers and `xla::Literal`s.
//! The hot path avoids intermediate `Vec`s: literals are created with the
//! target shape directly and read back with `copy_raw_to`.

use crate::error::{Error, Result};
use crate::Result as CrateResult;

/// Build an f32 literal of the given shape from a host slice.
pub fn vec_to_literal(data: &[f32], dims: &[usize]) -> CrateResult<xla::Literal> {
    let n: usize = dims.iter().product();
    if n != data.len() {
        return Err(Error::Shape(format!(
            "literal shape {dims:?} wants {n} elems, got {}",
            data.len()
        )));
    }
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::F32, dims);
    lit.copy_raw_from(data)?;
    Ok(lit)
}

/// Copy a literal's f32 payload into a host slice (must match in length).
pub fn literal_to_slice(lit: &xla::Literal, out: &mut [f32]) -> Result<()> {
    let n = lit.element_count();
    if n != out.len() {
        return Err(Error::Shape(format!(
            "literal has {n} elements, destination {}",
            out.len()
        )));
    }
    lit.copy_raw_to(out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let data: Vec<f32> = (0..12).map(|x| x as f32 * 0.5).collect();
        let lit = vec_to_literal(&data, &[3, 4]).unwrap();
        assert_eq!(lit.element_count(), 12);
        let mut back = vec![0.0f32; 12];
        literal_to_slice(&lit, &mut back).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(vec_to_literal(&[1.0, 2.0], &[3]).is_err());
        let lit = vec_to_literal(&[1.0, 2.0], &[2]).unwrap();
        let mut out = vec![0.0f32; 3];
        assert!(literal_to_slice(&lit, &mut out).is_err());
    }
}
