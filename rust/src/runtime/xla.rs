//! The PJRT/XLA step backend (cargo feature `xla`, off by default): loads
//! AOT-lowered HLO text from the artifact tree and executes it through the
//! `xla` wrapper crate's PJRT CPU client.
//!
//! Signature (fixed by `python/compile/aot.py`):
//!   inputs : x[B,1,H,W] f32, t[B], alpha_t[B], alpha_prev[B], sigma[B],
//!            noise[B,1,H,W]
//!   outputs: (x_prev, eps, x0_pred) each [B,1,H,W]
//!
//! The default build compiles against `third_party/xla-stub` (an API-shaped
//! stub so `cargo check --features xla` works offline); production deploys
//! patch the `xla` dependency to a real PJRT wrapper. See docs/testing.md.

use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::literal::literal_to_slice;
use crate::runtime::StepOutput;

/// One PJRT-loaded executable (dataset × bucket).
pub struct XlaExec {
    exe: xla::PjRtLoadedExecutable,
    /// input literals, created once and refilled per call (§Perf: saves six
    /// ~`bucket*dim*4`-byte allocations per step on the hot path)
    inputs: std::cell::RefCell<Vec<xla::Literal>>,
}

/// Device buffers of a submitted-but-unread step.
pub struct XlaPending {
    bufs: Vec<Vec<xla::PjRtBuffer>>,
}

impl XlaExec {
    /// Load HLO text from `path` and compile it on `client`.
    pub fn load(
        client: &xla::PjRtClient,
        path: &Path,
        bucket: usize,
        dim: usize,
    ) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let img = (dim as f64).sqrt() as usize;
        if img * img != dim {
            return Err(Error::Shape(format!("sample dim {dim} is not square")));
        }
        let img_shape = [bucket, 1, img, img];
        let vec_shape = [bucket];
        let inputs = vec![
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &img_shape),
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &vec_shape),
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &vec_shape),
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &vec_shape),
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &vec_shape),
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &img_shape),
        ];
        Ok(Self { exe, inputs: std::cell::RefCell::new(inputs) })
    }

    /// Hand one fused denoise step to the device without waiting for it.
    /// The input literals are snapshotted into device buffers during this
    /// call, so they may be refilled for the next submission while the
    /// returned [`XlaPending`] is still in flight.
    pub fn submit(
        &self,
        x: &[f32],
        t: &[f32],
        alpha_t: &[f32],
        alpha_prev: &[f32],
        sigma: &[f32],
        noise: &[f32],
    ) -> Result<XlaPending> {
        let mut lits = self.inputs.borrow_mut();
        lits[0].copy_raw_from(x)?;
        lits[1].copy_raw_from(t)?;
        lits[2].copy_raw_from(alpha_t)?;
        lits[3].copy_raw_from(alpha_prev)?;
        lits[4].copy_raw_from(sigma)?;
        lits[5].copy_raw_from(noise)?;
        let bufs = self.exe.execute::<xla::Literal>(&lits)?;
        Ok(XlaPending { bufs })
    }
}

impl XlaPending {
    /// Block until the device finishes, then copy `(x_prev, eps, x0)` into
    /// the first `n` elements of `out`'s (already-sized) buffers.
    pub fn wait_into(self, out: &mut StepOutput, n: usize) -> Result<()> {
        let first = self
            .bufs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Xla("execute returned no buffers".into()))?;
        let tuple = first.to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 3 {
            return Err(Error::Xla(format!("expected 3 outputs, got {}", parts.len())));
        }
        literal_to_slice(&parts[0], &mut out.x_prev[..n])?;
        literal_to_slice(&parts[1], &mut out.eps[..n])?;
        literal_to_slice(&parts[2], &mut out.x0[..n])?;
        Ok(())
    }
}
