//! The backend-independent `denoise_step` executable: one fixed call
//! signature served by either step backend.
//!
//! Signature (fixed by `python/compile/aot.py`, mirrored by the reference
//! backend):
//!   inputs : x[B,1,H,W] f32, t[B], alpha_t[B], alpha_prev[B], sigma[B],
//!            noise[B,1,H,W]
//!   outputs: (x_prev, eps, x0_pred) each [B,1,H,W]
//! All schedule quantities are *per-sample vectors* — the property that lets
//! the coordinator batch trajectories at heterogeneous timesteps.
//!
//! Everything above this layer (StepBatch, engine, executor, benches) sees
//! only [`StepExecutable`] / [`PendingStep`] / [`StepOutput`]; which backend
//! computes the step is decided once, at [`super::Runtime`] construction.

use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::runtime::pool::WorkerPool;
use crate::runtime::reference::{RefExec, RefModel, RefPrecision};
#[cfg(feature = "xla")]
use crate::runtime::xla::{XlaExec, XlaPending};

/// Host-side output buffers of one step call (lengths = bucket × dim).
#[derive(Debug, Clone, Default)]
pub struct StepOutput {
    pub x_prev: Vec<f32>,
    pub eps: Vec<f32>,
    pub x0: Vec<f32>,
}

impl StepOutput {
    pub fn zeros(n: usize) -> Self {
        Self { x_prev: vec![0.0; n], eps: vec![0.0; n], x0: vec![0.0; n] }
    }

    /// Borrowed view of one lane's slice of every output. This is what the
    /// sampler layer consumes: an update kernel decides whether to commit
    /// the fused `x_prev` or to re-integrate from `eps` host-side.
    pub fn lane(&self, slot: usize, dim: usize) -> LaneStep<'_> {
        let r = slot * dim..(slot + 1) * dim;
        LaneStep { x_prev: &self.x_prev[r.clone()], eps: &self.eps[r.clone()], x0: &self.x0[r] }
    }
}

/// One lane's view of a [`StepOutput`] — all three executable outputs, so
/// update kernels can pick their ingredient instead of being hard-wired to
/// `x_prev`.
#[derive(Debug, Clone, Copy)]
pub struct LaneStep<'a> {
    pub x_prev: &'a [f32],
    pub eps: &'a [f32],
    pub x0: &'a [f32],
}

enum PendingImpl {
    /// Reference backend: the step was computed synchronously at submit
    /// time into a recycled buffer; `wait_into` lands it and sends the
    /// buffer back to its `spare` home pool, so a steady-state
    /// submit/wait pipeline allocates nothing.
    Ref { out: StepOutput, spare: Arc<Mutex<Vec<StepOutput>>> },
    #[cfg(feature = "xla")]
    Xla(XlaPending),
}

/// A step that has been handed to the backend but not read back yet —
/// the result of [`StepExecutable::submit`]. Owns its backend state
/// (device buffers, or the reference backend's computed outputs), so it is
/// independent of the executable that produced it: the caller can submit
/// the next step (same or different executable) before waiting on this
/// one. [`PendingStep::wait_into`] blocks until done and copies the three
/// outputs host-side.
pub struct PendingStep {
    inner: PendingImpl,
    /// expected elements per output (bucket × dim)
    n: usize,
}

impl PendingStep {
    /// Land `(x_prev, eps, x0)` into the first `bucket*dim` elements of
    /// `out`. All three buffers are validated together — a
    /// caller-constructed [`StepOutput`] with mismatched `eps`/`x0`
    /// lengths is fixed up here rather than slipping through — and they
    /// only ever *grow*: a capacity-sized buffer stays put while
    /// sub-batches of different buckets stream through it, keeping the hot
    /// loop allocation-free.
    pub fn wait_into(self, out: &mut StepOutput) -> Result<()> {
        let n = self.n;
        for buf in [&mut out.x_prev, &mut out.eps, &mut out.x0] {
            if buf.len() < n {
                buf.resize(n, 0.0);
            }
        }
        match self.inner {
            PendingImpl::Ref { out: computed, spare } => {
                // the computed buffer may be larger than n after recycling
                // across buckets (grow-only), so slice both sides
                out.x_prev[..n].copy_from_slice(&computed.x_prev[..n]);
                out.eps[..n].copy_from_slice(&computed.eps[..n]);
                out.x0[..n].copy_from_slice(&computed.x0[..n]);
                spare.lock().unwrap().push(computed);
                Ok(())
            }
            #[cfg(feature = "xla")]
            PendingImpl::Xla(pending) => pending.wait_into(out, n),
        }
    }
}

enum ExecImpl {
    Ref(RefExec),
    #[cfg(feature = "xla")]
    Xla(XlaExec),
}

/// One loaded executable (dataset × bucket), backend-dispatched.
pub struct StepExecutable {
    inner: ExecImpl,
    bucket: usize,
    dim: usize,
    /// number of `submit` calls (metrics)
    pub calls: std::cell::Cell<u64>,
}

impl StepExecutable {
    /// Build a reference-backend executable over a synthetic ε-model with
    /// a private single-thread pool at default f32 precision — the
    /// convenience constructor tests and tools use.
    pub fn reference(model: Arc<RefModel>, bucket: usize, dim: usize) -> Result<Self> {
        Self::reference_with(model, bucket, dim, Arc::new(WorkerPool::new(1)), RefPrecision::F32)
    }

    /// Reference executable on a shared worker pool at an explicit weight
    /// precision — what [`super::Runtime`] builds, so every executable of
    /// a runtime threads its sub-batches over one machine-wide pool.
    pub fn reference_with(
        model: Arc<RefModel>,
        bucket: usize,
        dim: usize,
        pool: Arc<WorkerPool>,
        precision: RefPrecision,
    ) -> Result<Self> {
        if model.dim() != dim {
            return Err(Error::Shape(format!(
                "reference model dim {} vs executable dim {dim}",
                model.dim()
            )));
        }
        Ok(Self {
            inner: ExecImpl::Ref(RefExec::new(model, pool, precision)),
            bucket,
            dim,
            calls: std::cell::Cell::new(0),
        })
    }

    /// Compile HLO text from `path` on the PJRT client (`xla` feature).
    #[cfg(feature = "xla")]
    pub fn xla(
        client: &xla::PjRtClient,
        path: &std::path::Path,
        bucket: usize,
        dim: usize,
    ) -> Result<Self> {
        Ok(Self {
            inner: ExecImpl::Xla(XlaExec::load(client, path, bucket, dim)?),
            bucket,
            dim,
            calls: std::cell::Cell::new(0),
        })
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }

    /// Hand one fused denoise step to the backend without waiting for it.
    ///
    /// `x`, `noise`: `bucket*dim` f32; `t`, `alpha_t`, `alpha_prev`,
    /// `sigma`: `bucket` f32. The inputs are snapshotted during this call
    /// (copied into device literals, or consumed by the synchronous
    /// reference computation), so the caller may refill its buffers for
    /// the next submission while the returned [`PendingStep`] is still in
    /// flight — this is what lets the pipelined executor keep the backend
    /// busy while the engine thread packs and retires lanes.
    pub fn submit(
        &self,
        x: &[f32],
        t: &[f32],
        alpha_t: &[f32],
        alpha_prev: &[f32],
        sigma: &[f32],
        noise: &[f32],
    ) -> Result<PendingStep> {
        self.validate(x, t, alpha_t, alpha_prev, sigma, noise)?;
        let b = self.bucket;
        let inner = match &self.inner {
            ExecImpl::Ref(exec) => {
                let (out, spare) =
                    exec.compute_pooled(b, self.dim, x, t, alpha_t, alpha_prev, sigma, noise);
                PendingImpl::Ref { out, spare }
            }
            #[cfg(feature = "xla")]
            ExecImpl::Xla(exec) => {
                PendingImpl::Xla(exec.submit(x, t, alpha_t, alpha_prev, sigma, noise)?)
            }
        };
        self.calls.set(self.calls.get() + 1);
        Ok(PendingStep { inner, n: b * self.dim })
    }

    /// Execute one fused denoise step synchronously into `out` (reused
    /// across calls by the engine, grow-only). On the reference backend
    /// this computes straight into the caller's buffers — no pending copy,
    /// zero steady-state allocation; the compiled path is
    /// [`StepExecutable::submit`] + [`PendingStep::wait_into`].
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        x: &[f32],
        t: &[f32],
        alpha_t: &[f32],
        alpha_prev: &[f32],
        sigma: &[f32],
        noise: &[f32],
        out: &mut StepOutput,
    ) -> Result<()> {
        match &self.inner {
            ExecImpl::Ref(exec) => {
                self.validate(x, t, alpha_t, alpha_prev, sigma, noise)?;
                exec.compute_into(
                    self.bucket,
                    self.dim,
                    x,
                    t,
                    alpha_t,
                    alpha_prev,
                    sigma,
                    noise,
                    out,
                );
                self.calls.set(self.calls.get() + 1);
                Ok(())
            }
            #[cfg(feature = "xla")]
            ExecImpl::Xla(_) => {
                self.submit(x, t, alpha_t, alpha_prev, sigma, noise)?.wait_into(out)
            }
        }
    }

    /// Drain the reference backend's perf counters accumulated since the
    /// last harvest: (kernel seconds, bytes of fresh buffer growth). The
    /// engine folds these into its `ExecCounters` after each sub-batch;
    /// always zeros on the compiled backend.
    pub fn take_ref_stats(&self) -> (f64, u64) {
        match &self.inner {
            ExecImpl::Ref(exec) => (exec.compute_s.take(), exec.bytes_allocated.take()),
            #[cfg(feature = "xla")]
            ExecImpl::Xla(_) => (0.0, 0),
        }
    }

    fn validate(
        &self,
        x: &[f32],
        t: &[f32],
        alpha_t: &[f32],
        alpha_prev: &[f32],
        sigma: &[f32],
        noise: &[f32],
    ) -> Result<()> {
        let b = self.bucket;
        if x.len() != b * self.dim
            || noise.len() != b * self.dim
            || t.len() != b
            || alpha_t.len() != b
            || alpha_prev.len() != b
            || sigma.len() != b
        {
            return Err(Error::Shape(format!(
                "step inputs inconsistent with bucket {b} dim {}",
                self.dim
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifacts::DatasetInfo;

    fn exe(bucket: usize, dim: usize) -> StepExecutable {
        let info = DatasetInfo { hlo: vec![], params: 7, final_loss: 0.1, ref_n: 8 };
        let model = Arc::new(RefModel::from_manifest("t", &info, dim, 400));
        StepExecutable::reference(model, bucket, dim).unwrap()
    }

    #[test]
    fn submit_validates_input_lengths() {
        let e = exe(2, 4);
        let img = vec![0.0f32; 8];
        let vec2 = vec![0.5f32; 2];
        assert!(e.submit(&img, &vec2, &vec2, &vec2, &vec2, &img).is_ok());
        assert!(e.submit(&img[..7], &vec2, &vec2, &vec2, &vec2, &img).is_err());
        assert!(e.submit(&img, &vec2[..1], &vec2, &vec2, &vec2, &img).is_err());
        assert_eq!(e.calls.get(), 1, "failed submits must not count");
        assert_eq!(e.bucket(), 2);
    }

    #[test]
    fn wait_into_grows_undersized_buffers_together() {
        let e = exe(2, 4);
        let img = vec![0.25f32; 8];
        let vec2 = vec![0.5f32; 2];
        let pending = e.submit(&img, &vec2, &vec2, &vec2, &vec2, &img).unwrap();
        let mut out = StepOutput::zeros(3); // deliberately too small
        out.eps = vec![0.0; 1]; // and internally inconsistent
        pending.wait_into(&mut out).unwrap();
        assert_eq!(out.x_prev.len(), 8);
        assert_eq!(out.eps.len(), 8);
        assert_eq!(out.x0.len(), 8);
        // capacity-sized buffers stay put (grow-only contract)
        let pending = e.submit(&img, &vec2, &vec2, &vec2, &vec2, &img).unwrap();
        let mut big = StepOutput::zeros(32);
        pending.wait_into(&mut big).unwrap();
        assert_eq!(big.x_prev.len(), 32);
    }

    #[test]
    fn submit_before_wait_allows_reuse_of_caller_buffers() {
        // the pipelined executor's contract: two pending steps can be in
        // flight from the same executable, inputs re-filled in between,
        // and each lands its own results
        let e = exe(1, 2);
        let v1 = vec![1.0f32; 2];
        let v0 = vec![0.5f32; 1];
        let p1 = e.submit(&v1, &v0, &v0, &v0, &v0, &[0.0, 0.0]).unwrap();
        let v2 = vec![-1.0f32; 2];
        let p2 = e.submit(&v2, &v0, &v0, &v0, &v0, &[0.0, 0.0]).unwrap();
        let (mut o1, mut o2) = (StepOutput::zeros(2), StepOutput::zeros(2));
        p1.wait_into(&mut o1).unwrap();
        p2.wait_into(&mut o2).unwrap();
        assert_ne!(o1.x_prev, o2.x_prev, "each pending step lands its own inputs' result");
        assert!(o1.x_prev.iter().chain(&o2.x_prev).all(|v| v.is_finite()));
        assert_eq!(e.calls.get(), 2);
    }

    #[test]
    fn run_reference_fast_path_is_allocation_free_once_warm() {
        let e = exe(2, 4);
        let img = vec![0.25f32; 8];
        let vec2 = vec![0.5f32; 2];
        let mut out = StepOutput::zeros(8);
        e.run(&img, &vec2, &vec2, &vec2, &vec2, &img, &mut out).unwrap();
        e.take_ref_stats(); // discard cold-start numbers
        e.run(&img, &vec2, &vec2, &vec2, &vec2, &img, &mut out).unwrap();
        let (secs, bytes) = e.take_ref_stats();
        assert!(secs >= 0.0);
        assert_eq!(bytes, 0, "warm run must not allocate");
        assert_eq!(e.calls.get(), 2);
        // the fast path still validates shapes
        assert!(e.run(&img[..7], &vec2, &vec2, &vec2, &vec2, &img, &mut out).is_err());
        assert_eq!(e.calls.get(), 2, "failed runs must not count");
    }

    #[test]
    fn pending_buffers_recycle_across_submit_wait_cycles() {
        let e = exe(1, 2);
        let v = vec![1.0f32; 2];
        let s = vec![0.5f32; 1];
        let mut out = StepOutput::zeros(2);
        e.submit(&v, &s, &s, &s, &s, &v).unwrap().wait_into(&mut out).unwrap();
        let (_, cold) = e.take_ref_stats();
        assert!(cold > 0, "first submit allocates its pending buffer");
        for _ in 0..3 {
            e.submit(&v, &s, &s, &s, &s, &v).unwrap().wait_into(&mut out).unwrap();
        }
        let (_, warm) = e.take_ref_stats();
        assert_eq!(warm, 0, "sequential submit/wait must reuse the spare buffer");
    }

    #[test]
    fn lane_view_slices_every_output() {
        let out = StepOutput {
            x_prev: vec![1.0, 2.0, 3.0, 4.0],
            eps: vec![5.0, 6.0, 7.0, 8.0],
            x0: vec![9.0, 10.0, 11.0, 12.0],
        };
        let lane = out.lane(1, 2);
        assert_eq!(lane.x_prev, &[3.0, 4.0]);
        assert_eq!(lane.eps, &[7.0, 8.0]);
        assert_eq!(lane.x0, &[11.0, 12.0]);
    }
}
