//! A compiled `denoise_step` executable for one batch bucket.
//!
//! Signature (fixed by `python/compile/aot.py`):
//!   inputs : x[B,1,H,W] f32, t[B], alpha_t[B], alpha_prev[B], sigma[B],
//!            noise[B,1,H,W]
//!   outputs: (x_prev, eps, x0_pred) each [B,1,H,W]
//! All schedule quantities are *per-sample vectors* — the property that lets
//! the coordinator batch trajectories at heterogeneous timesteps.

use std::path::Path;

use crate::error::{Error, Result};
use crate::runtime::literal::literal_to_slice;

/// Host-side output buffers of one step call (lengths = bucket × dim).
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub x_prev: Vec<f32>,
    pub eps: Vec<f32>,
    pub x0: Vec<f32>,
}

impl StepOutput {
    pub fn zeros(n: usize) -> Self {
        Self { x_prev: vec![0.0; n], eps: vec![0.0; n], x0: vec![0.0; n] }
    }

    /// Borrowed view of one lane's slice of every output. This is what the
    /// sampler layer consumes: an update kernel decides whether to commit
    /// the fused `x_prev` or to re-integrate from `eps` host-side.
    pub fn lane(&self, slot: usize, dim: usize) -> LaneStep<'_> {
        let r = slot * dim..(slot + 1) * dim;
        LaneStep { x_prev: &self.x_prev[r.clone()], eps: &self.eps[r.clone()], x0: &self.x0[r] }
    }
}

/// One lane's view of a [`StepOutput`] — all three executable outputs, so
/// update kernels can pick their ingredient instead of being hard-wired to
/// `x_prev`.
#[derive(Debug, Clone, Copy)]
pub struct LaneStep<'a> {
    pub x_prev: &'a [f32],
    pub eps: &'a [f32],
    pub x0: &'a [f32],
}

/// A step that has been handed to the device but not read back yet —
/// the result of [`StepExecutable::submit`]. Owns the device buffers, so
/// it is independent of the executable that produced it: the caller can
/// submit the next step (same or different executable) before waiting on
/// this one. [`PendingStep::wait_into`] blocks on the device and copies
/// the three outputs host-side.
pub struct PendingStep {
    bufs: Vec<Vec<xla::PjRtBuffer>>,
    /// expected elements per output (bucket × dim)
    n: usize,
}

impl PendingStep {
    /// Block until the device finishes, then copy `(x_prev, eps, x0)` into
    /// the first `bucket*dim` elements of `out`. All three buffers are
    /// validated together — a caller-constructed [`StepOutput`] with
    /// mismatched `eps`/`x0` lengths is fixed up here rather than slipping
    /// through to `literal_to_slice` — and they only ever *grow*: a
    /// capacity-sized buffer stays put while sub-batches of different
    /// buckets stream through it, keeping the hot loop allocation-free.
    pub fn wait_into(self, out: &mut StepOutput) -> Result<()> {
        let first = self
            .bufs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Xla("execute returned no buffers".into()))?;
        let tuple = first.to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 3 {
            return Err(Error::Xla(format!("expected 3 outputs, got {}", parts.len())));
        }
        let n = self.n;
        for buf in [&mut out.x_prev, &mut out.eps, &mut out.x0] {
            if buf.len() < n {
                buf.resize(n, 0.0);
            }
        }
        literal_to_slice(&parts[0], &mut out.x_prev[..n])?;
        literal_to_slice(&parts[1], &mut out.eps[..n])?;
        literal_to_slice(&parts[2], &mut out.x0[..n])?;
        Ok(())
    }
}

/// One PJRT-loaded executable (dataset × bucket).
pub struct StepExecutable {
    exe: xla::PjRtLoadedExecutable,
    bucket: usize,
    dim: usize,
    /// input literals, created once and refilled per call (§Perf: saves six
    /// ~`bucket*dim*4`-byte allocations per step on the hot path)
    inputs: std::cell::RefCell<Vec<xla::Literal>>,
    /// number of `run` calls (metrics)
    pub calls: std::cell::Cell<u64>,
}

impl StepExecutable {
    /// Load HLO text from `path` and compile it on `client`.
    pub fn load(
        client: &xla::PjRtClient,
        path: &Path,
        bucket: usize,
        dim: usize,
    ) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact(format!("non-utf8 path {path:?}")))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let img = (dim as f64).sqrt() as usize;
        if img * img != dim {
            return Err(Error::Shape(format!("sample dim {dim} is not square")));
        }
        let img_shape = [bucket, 1, img, img];
        let vec_shape = [bucket];
        let inputs = vec![
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &img_shape),
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &vec_shape),
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &vec_shape),
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &vec_shape),
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &vec_shape),
            xla::Literal::create_from_shape(xla::PrimitiveType::F32, &img_shape),
        ];
        Ok(Self {
            exe,
            bucket,
            dim,
            inputs: std::cell::RefCell::new(inputs),
            calls: std::cell::Cell::new(0),
        })
    }

    pub fn bucket(&self) -> usize {
        self.bucket
    }

    /// Hand one fused denoise step to the device without waiting for it.
    ///
    /// `x`, `noise`: `bucket*dim` f32; `t`, `alpha_t`, `alpha_prev`,
    /// `sigma`: `bucket` f32. The input literals are snapshotted into
    /// device buffers during this call, so they may be refilled for the
    /// next submission while the returned [`PendingStep`] is still in
    /// flight — this is what lets the pipelined executor keep the device
    /// busy while the engine thread packs and retires lanes.
    pub fn submit(
        &self,
        x: &[f32],
        t: &[f32],
        alpha_t: &[f32],
        alpha_prev: &[f32],
        sigma: &[f32],
        noise: &[f32],
    ) -> Result<PendingStep> {
        let b = self.bucket;
        if x.len() != b * self.dim
            || noise.len() != b * self.dim
            || t.len() != b
            || alpha_t.len() != b
            || alpha_prev.len() != b
            || sigma.len() != b
        {
            return Err(Error::Shape(format!(
                "step inputs inconsistent with bucket {b} dim {}",
                self.dim
            )));
        }
        let mut lits = self.inputs.borrow_mut();
        lits[0].copy_raw_from(x)?;
        lits[1].copy_raw_from(t)?;
        lits[2].copy_raw_from(alpha_t)?;
        lits[3].copy_raw_from(alpha_prev)?;
        lits[4].copy_raw_from(sigma)?;
        lits[5].copy_raw_from(noise)?;
        let bufs = self.exe.execute::<xla::Literal>(&lits)?;
        self.calls.set(self.calls.get() + 1);
        Ok(PendingStep { bufs, n: b * self.dim })
    }

    /// Execute one fused denoise step synchronously: [`StepExecutable::submit`]
    /// + [`PendingStep::wait_into`]. Outputs are written into `out` (reused
    /// across calls by the engine — zero steady-state allocation).
    pub fn run(
        &self,
        x: &[f32],
        t: &[f32],
        alpha_t: &[f32],
        alpha_prev: &[f32],
        sigma: &[f32],
        noise: &[f32],
        out: &mut StepOutput,
    ) -> Result<()> {
        self.submit(x, t, alpha_t, alpha_prev, sigma, noise)?.wait_into(out)
    }
}
