//! The hermetic reference backend: a pure-Rust `denoise_step` that stands
//! in for the AOT-compiled executable so the entire serving stack —
//! `Runtime`, `Engine`, `Router`, planner, pipelined executor — runs
//! deterministically on CPU with no XLA and no `artifacts/` tree.
//!
//! The trick (same as Watson et al. 2022's sampler-validation setup): the
//! DDIM generative step (Song et al., Eq. 12) is closed-form *given* ε_θ,
//! so any deterministic ε-model exercises every line of the serving path.
//! We use the Bayes-optimal denoiser for synthetic per-pixel Gaussian data
//! x₀ ~ N(0, diag(scale²)):
//!
//!   ε(x, t, ᾱ)ᵢ = √(1−ᾱ) · xᵢ / (ᾱ·scaleᵢ² + (1−ᾱ))
//!                 + biasᵢ · sin(π t / T)
//!
//! with `scale`/`bias` fields derived deterministically from the manifest's
//! per-dataset weights (name, param count, final loss) — two datasets give
//! two genuinely different models. The bias term makes ε depend on the
//! model timestep `t`, like a real time-embedded U-Net.
//!
//! Why this ε and not something fancier: it is elementwise (lane
//! independence is exact, which is what makes padding sound), smooth in t
//! and ᾱ (so PF-ODE/AB2 host integration converges to the DDIM solution as
//! S grows — Sec. 4.3's small-step limit), and analytically well-behaved
//! at both schedule ends (ᾱ = 1 ⇒ the data term vanishes; the denominator
//! is bounded below by min(scale², 1−ᾱ+ᾱ·scale²) > 0).
//!
//! The step composition mirrors `python/compile/kernels/ddim_step.py`
//! exactly (and therefore [`crate::sampler::ddim_update_host_sigma`]):
//!
//!   x0   = (x − √(1−ᾱ_t) ε) / √ᾱ_t
//!   out  = √ᾱ_p x0 + √max(1−ᾱ_p−σ², 0) ε + σ·noise
//!
//! computed in f64 per element and narrowed to f32 on readback, like the
//! compiled graph's f32 pipeline to within ~1e-7.

use std::sync::Arc;

use crate::artifacts::DatasetInfo;
use crate::rng::Pcg64;

/// One dataset's synthetic ε-model: per-pixel data scale and time-bias
/// fields, deterministically derived from its manifest entry.
#[derive(Debug)]
pub struct RefModel {
    scale: Vec<f64>,
    bias: Vec<f64>,
    t_max: f64,
}

/// FNV-1a over a string — the seed-derivation primitive shared by the
/// reference model and the fixture generator's per-dataset streams.
/// Re-exported from the rng substrate, where the FNV constants live in
/// exactly one place.
pub use crate::rng::fnv1a;

impl RefModel {
    /// Derive the model from a dataset's manifest weights. The seed folds
    /// in the dataset name (FNV-1a), the trained parameter count, and the
    /// final-loss bits, so editing any of them yields a different model —
    /// "weights" in the only sense a manifest carries them.
    pub fn from_manifest(name: &str, info: &DatasetInfo, dim: usize, t_max: usize) -> Self {
        let seed = fnv1a(name) ^ info.params ^ info.final_loss.to_bits();
        let mut rng = Pcg64::seeded(seed);
        let scale = (0..dim).map(|_| rng.uniform(0.7, 1.3)).collect();
        let bias = (0..dim).map(|_| rng.uniform(-0.05, 0.05)).collect();
        Self { scale, bias, t_max: t_max as f64 }
    }

    /// ε_θ at pixel `i` for state `x`, model timestep `t`, cumulative ᾱ `a`.
    #[inline]
    pub fn eps(&self, i: usize, x: f64, t: f64, a: f64) -> f64 {
        let om = (1.0 - a).max(0.0);
        om.sqrt() * x / (a * self.scale[i] * self.scale[i] + om)
            + self.bias[i] * (std::f64::consts::PI * t / self.t_max).sin()
    }

    pub fn dim(&self) -> usize {
        self.scale.len()
    }
}

/// Reference-backend executable for one (dataset × bucket): computes the
/// batched denoise step synchronously on the calling thread. Stateless
/// between calls; all per-call state lives in the returned pending buffers,
/// which is what gives it the same submit-before-wait semantics as the
/// compiled executable (the pipelined executor relies on that).
pub struct RefExec {
    model: Arc<RefModel>,
}

impl RefExec {
    pub fn new(model: Arc<RefModel>) -> Self {
        Self { model }
    }

    /// Compute the three outputs for `bucket` lanes of `dim` elements.
    /// Caller (the `StepExecutable` wrapper) has validated input lengths.
    #[allow(clippy::too_many_arguments)]
    pub fn compute(
        &self,
        bucket: usize,
        dim: usize,
        x: &[f32],
        t: &[f32],
        alpha_t: &[f32],
        alpha_prev: &[f32],
        sigma: &[f32],
        noise: &[f32],
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let n = bucket * dim;
        let mut out_prev = vec![0.0f32; n];
        let mut out_eps = vec![0.0f32; n];
        let mut out_x0 = vec![0.0f32; n];
        for slot in 0..bucket {
            let a = alpha_t[slot] as f64;
            let ap = alpha_prev[slot] as f64;
            let sg = sigma[slot] as f64;
            let tm = t[slot] as f64;
            let dir = (1.0 - ap - sg * sg).max(0.0).sqrt();
            let sq_ap = ap.sqrt();
            let sq_om = (1.0 - a).max(0.0).sqrt();
            let inv_sq_a = 1.0 / a.sqrt();
            for i in 0..dim {
                let idx = slot * dim + i;
                let xv = x[idx] as f64;
                let e = self.model.eps(i, xv, tm, a);
                let x0 = (xv - sq_om * e) * inv_sq_a;
                let xp = sq_ap * x0 + dir * e + sg * noise[idx] as f64;
                out_eps[idx] = e as f32;
                out_x0[idx] = x0 as f32;
                out_prev[idx] = xp as f32;
            }
        }
        (out_prev, out_eps, out_x0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::ddim_update_host_sigma;

    fn info(params: u64, loss: f64) -> DatasetInfo {
        DatasetInfo { hlo: vec![], params, final_loss: loss, ref_n: 64 }
    }

    fn model() -> Arc<RefModel> {
        Arc::new(RefModel::from_manifest("sprites", &info(123456, 0.0421), 16, 400))
    }

    #[test]
    fn model_is_deterministic_and_weight_sensitive() {
        let a = RefModel::from_manifest("sprites", &info(1, 0.5), 8, 400);
        let b = RefModel::from_manifest("sprites", &info(1, 0.5), 8, 400);
        let c = RefModel::from_manifest("blobs", &info(1, 0.5), 8, 400);
        let d = RefModel::from_manifest("sprites", &info(2, 0.5), 8, 400);
        assert_eq!(a.eps(3, 0.7, 100.0, 0.5), b.eps(3, 0.7, 100.0, 0.5));
        assert_ne!(a.eps(3, 0.7, 100.0, 0.5), c.eps(3, 0.7, 100.0, 0.5));
        assert_ne!(a.eps(3, 0.7, 100.0, 0.5), d.eps(3, 0.7, 100.0, 0.5));
        assert_eq!(a.dim(), 8);
    }

    #[test]
    fn eps_is_finite_at_schedule_ends() {
        let m = model();
        for a in [1.0, 0.9999, 0.5, 1e-4, 1e-9] {
            for x in [-3.0, 0.0, 3.0] {
                let e = m.eps(0, x, 1.0, a);
                assert!(e.is_finite(), "eps({x}, a={a}) = {e}");
            }
        }
        // at abar = 1 the data term vanishes: eps is the pure bias field
        let e1 = m.eps(2, 5.0, 200.0, 1.0);
        let e2 = m.eps(2, -5.0, 200.0, 1.0);
        assert_eq!(e1, e2);
    }

    #[test]
    fn eps_depends_on_model_timestep() {
        let m = model();
        let a = m.eps(1, 0.5, 100.0, 0.3);
        let b = m.eps(1, 0.5, 300.0, 0.3);
        assert_ne!(a, b, "bias term must make eps t-dependent");
    }

    #[test]
    fn compute_matches_host_eq12_composition() {
        // the executable's (x_prev, eps, x0) must satisfy the host-side
        // Eq.-12 arithmetic on its own eps output, per lane
        let m = model();
        let exec = RefExec::new(m);
        let (bucket, dim) = (3usize, 16usize);
        let mut rng = Pcg64::seeded(9);
        let x: Vec<f32> = (0..bucket * dim).map(|_| rng.uniform(-1.5, 1.5) as f32).collect();
        let noise: Vec<f32> = (0..bucket * dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let t = vec![120.0f32, 240.0, 360.0];
        let a_t = vec![0.4f32, 0.15, 0.05];
        let a_p = vec![0.7f32, 0.4, 0.15];
        let sigma = vec![0.0f32, 0.1, 0.3];
        let (xp, eps, x0) = exec.compute(bucket, dim, &x, &t, &a_t, &a_p, &sigma, &noise);
        for slot in 0..bucket {
            let r = slot * dim..(slot + 1) * dim;
            let want = ddim_update_host_sigma(
                &x[r.clone()],
                &eps[r.clone()],
                &noise[r.clone()],
                a_t[slot] as f64,
                a_p[slot] as f64,
                sigma[slot] as f64,
            );
            for (got, want) in xp[r.clone()].iter().zip(&want) {
                assert!((got - want).abs() < 1e-5, "lane {slot}: {got} vs {want}");
            }
            // x0 consistency: x = sqrt(a) x0 + sqrt(1-a) eps
            for i in r.clone() {
                let back = (a_t[slot] as f64).sqrt() * x0[i] as f64
                    + (1.0 - a_t[slot] as f64).sqrt() * eps[i] as f64;
                assert!((back - x[i] as f64).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn lanes_are_independent() {
        let exec = RefExec::new(model());
        let (bucket, dim) = (4usize, 16usize);
        let lane0_x: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.37).sin()).collect();
        let mk = |fill: f32| {
            let mut v = vec![fill; bucket * dim];
            v[..dim].copy_from_slice(&lane0_x);
            v
        };
        let t = vec![100.0f32; bucket];
        let a_t = vec![0.4f32; bucket];
        let a_p = vec![0.8f32; bucket];
        let sigma = vec![0.0f32; bucket];
        let zeros = vec![0.0f32; bucket * dim];
        let (p1, e1, _) = exec.compute(bucket, dim, &mk(1.3), &t, &a_t, &a_p, &sigma, &zeros);
        let (p2, e2, _) = exec.compute(bucket, dim, &mk(-2.0), &t, &a_t, &a_p, &sigma, &zeros);
        assert_eq!(&p1[..dim], &p2[..dim], "lane 0 depends on other lanes");
        assert_eq!(&e1[..dim], &e2[..dim]);
    }
}
