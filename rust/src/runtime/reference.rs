//! The hermetic reference backend: a pure-Rust `denoise_step` that stands
//! in for the AOT-compiled executable so the entire serving stack —
//! `Runtime`, `Engine`, `Router`, planner, pipelined executor — runs
//! deterministically on CPU with no XLA and no `artifacts/` tree.
//!
//! The trick (same as Watson et al. 2022's sampler-validation setup): the
//! DDIM generative step (Song et al., Eq. 12) is closed-form *given* ε_θ,
//! so any deterministic ε-model exercises every line of the serving path.
//! We use the Bayes-optimal denoiser for synthetic per-pixel Gaussian data
//! x₀ ~ N(0, diag(scale²)):
//!
//!   ε(x, t, ᾱ)ᵢ = √(1−ᾱ) · xᵢ / (ᾱ·scaleᵢ² + (1−ᾱ))
//!                 + biasᵢ · sin(π t / T)
//!
//! with `scale`/`bias` fields derived deterministically from the manifest's
//! per-dataset weights (name, param count, final loss) — two datasets give
//! two genuinely different models. The bias term makes ε depend on the
//! model timestep `t`, like a real time-embedded U-Net.
//!
//! Why this ε and not something fancier: it is elementwise (lane
//! independence is exact, which is what makes padding sound), smooth in t
//! and ᾱ (so PF-ODE/AB2 host integration converges to the DDIM solution as
//! S grows — Sec. 4.3's small-step limit), and analytically well-behaved
//! at both schedule ends (ᾱ = 1 ⇒ the data term vanishes; the denominator
//! is bounded below by min(scale², 1−ᾱ+ᾱ·scale²) > 0).
//!
//! The step composition mirrors `python/compile/kernels/ddim_step.py`
//! exactly (and therefore [`crate::sampler::ddim_update_host_sigma`]):
//!
//!   x0   = (x − √(1−ᾱ_t) ε) / √ᾱ_t
//!   out  = √ᾱ_p x0 + √max(1−ᾱ_p−σ², 0) ε + σ·noise
//!
//! computed in f64 per element and narrowed to f32 on readback, like the
//! compiled graph's f32 pipeline to within ~1e-7.
//!
//! # Kernel layout (structure-of-arrays, see docs/performance.md)
//!
//! [`RefExec::compute_into`] is the hot path. Per slot it hoists every
//! scalar that the naive composition recomputed per element — the schedule
//! coefficients *and* the ε-model's `sin(πt/T)` phase and `scale²`
//! denominator term (precomputed once at model construction) — then walks
//! the lane in fixed-width [`UNROLL`]-element chunks whose constant trip
//! count lets stable `rustc` unroll and auto-vectorize without bounds
//! checks or `std::simd`. Slots are spread across a persistent
//! [`WorkerPool`] (`--ref-threads`); because ε is elementwise, slot-granular
//! splitting is *bitwise*-safe: every path — scalar baseline
//! ([`compute_scalar_into`]), unrolled, 1 thread or N — produces identical
//! bits at the default f32 precision (pinned by
//! `rust/tests/reference_kernel.rs`). The optional `--ref-precision f16`
//! path stores the weight fields as IEEE binary16 and accumulates in f32;
//! it is tolerance-gated, not bitwise.
//!
//! Outputs land in caller-owned [`StepOutput`] buffers (grow-only), so a
//! steady-state engine tick allocates nothing — tracked by the
//! `ref_bytes_allocated` counter surfaced through the metrics op.

use std::cell::Cell;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::artifacts::DatasetInfo;
use crate::error::{Error, Result};
use crate::rng::Pcg64;
use crate::runtime::executable::StepOutput;
use crate::runtime::pool::WorkerPool;

/// Fixed chunk width of the unrolled kernel. Eight f64 lanes span two
/// AVX2 vectors (or four NEON ones) — wide enough to saturate the FMA
/// ports, narrow enough that odd dims pay at most seven scalar-tail
/// elements.
pub const UNROLL: usize = 8;

/// Weight-storage precision of the reference kernel (`--ref-precision`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RefPrecision {
    /// Full-precision weights, f64 element math — bitwise-identical to the
    /// scalar baseline composition. The default.
    #[default]
    F32,
    /// Weights stored as IEEE binary16 bits, decoded and accumulated in
    /// f32. Halves weight-table bandwidth; tolerance-gated, not bitwise.
    F16,
}

impl RefPrecision {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(RefPrecision::F32),
            "f16" => Ok(RefPrecision::F16),
            other => Err(Error::Request(format!(
                "unknown ref precision '{other}' (want f32 | f16)"
            ))),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RefPrecision::F32 => "f32",
            RefPrecision::F16 => "f16",
        }
    }
}

/// Reference-backend tuning knobs (`--ref-threads` / `--ref-precision`,
/// env `DDIM_REF_THREADS` / `DDIM_REF_PRECISION`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RefOptions {
    /// Total compute threads inside one sub-batch (the caller counts as
    /// one); `0` means available parallelism.
    pub threads: usize,
    pub precision: RefPrecision,
}

impl Default for RefOptions {
    fn default() -> Self {
        Self { threads: 0, precision: RefPrecision::F32 }
    }
}

impl RefOptions {
    /// Env overrides, mirroring `DDIM_BACKEND`: `DDIM_REF_THREADS` and
    /// `DDIM_REF_PRECISION`, else the defaults (auto threads, f32).
    pub fn from_env() -> Result<Self> {
        let mut opts = Self::default();
        if let Ok(v) = std::env::var("DDIM_REF_THREADS") {
            opts.threads = v.parse().map_err(|_| {
                Error::Request(format!("DDIM_REF_THREADS must be an integer, got '{v}'"))
            })?;
        }
        if let Ok(v) = std::env::var("DDIM_REF_PRECISION") {
            opts.precision = RefPrecision::parse(&v)?;
        }
        Ok(opts)
    }

    /// Resolve `threads == 0` to the machine's available parallelism.
    pub fn resolved_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

/// Encode an f32 as IEEE-754 binary16 bits, round-to-nearest-even.
/// Hand-rolled because the hermetic build carries no `half` crate.
pub fn f16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / nan (keep a payload bit so nan stays nan)
        return sign | 0x7c00 | (u16::from(mant != 0) << 9);
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow → ±inf
    }
    if unbiased >= -14 {
        // normal half: keep 10 mantissa bits, round to nearest even
        let mut m = mant >> 13;
        let rem = mant & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && m & 1 == 1) {
            m += 1;
        }
        let mut e = (unbiased + 15) as u32;
        if m == 0x400 {
            // mantissa carry (1.111… rounded up): bump the exponent
            m = 0;
            e += 1;
            if e >= 31 {
                return sign | 0x7c00;
            }
        }
        return sign | ((e as u16) << 10) | m as u16;
    }
    if unbiased < -25 {
        return sign; // below half the smallest subnormal → ±0
    }
    // subnormal half: shift the explicit-leading-1 mantissa into place
    let m = mant | 0x0080_0000;
    let shift = (-1 - unbiased) as u32; // 13 + (-14 - unbiased), in 14..=24
    let kept = m >> shift;
    let rem = m & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    let mut s = kept;
    if rem > half || (rem == half && kept & 1 == 1) {
        s += 1; // a carry here lands on 0x0400, the smallest normal — fine
    }
    sign | s as u16
}

/// Decode IEEE-754 binary16 bits to f32 (exact: every half is an f32).
pub fn f32_from_f16(h: u16) -> f32 {
    let sign = u32::from(h & 0x8000) << 16;
    let exp = u32::from(h >> 10) & 0x1f;
    let mant = u32::from(h & 0x03ff);
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // inf / nan
    } else if exp == 0 {
        if mant == 0 {
            sign // ±0
        } else {
            // subnormal half → normal f32: renormalize the mantissa
            let mut e: i32 = -14;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (((e + 127) as u32) << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// One dataset's synthetic ε-model: per-pixel data scale and time-bias
/// fields, deterministically derived from its manifest entry. The scale
/// field enters ε only through its square, so `scale²` is precomputed here
/// once (both in f64 and as f16 bits for the reduced-precision path)
/// instead of being re-squared per element per step.
#[derive(Debug)]
pub struct RefModel {
    scale_sq: Vec<f64>,
    bias: Vec<f64>,
    /// IEEE binary16 bits of `scale_sq` / `bias` for [`RefPrecision::F16`].
    scale_sq_f16: Vec<u16>,
    bias_f16: Vec<u16>,
    t_max: f64,
}

/// FNV-1a over a string — the seed-derivation primitive shared by the
/// reference model and the fixture generator's per-dataset streams.
/// Re-exported from the rng substrate, where the FNV constants live in
/// exactly one place.
pub use crate::rng::fnv1a;

impl RefModel {
    /// Derive the model from a dataset's manifest weights. The seed folds
    /// in the dataset name (FNV-1a), the trained parameter count, and the
    /// final-loss bits, so editing any of them yields a different model —
    /// "weights" in the only sense a manifest carries them.
    pub fn from_manifest(name: &str, info: &DatasetInfo, dim: usize, t_max: usize) -> Self {
        let seed = fnv1a(name) ^ info.params ^ info.final_loss.to_bits();
        let mut rng = Pcg64::seeded(seed);
        let scale: Vec<f64> = (0..dim).map(|_| rng.uniform(0.7, 1.3)).collect();
        let bias: Vec<f64> = (0..dim).map(|_| rng.uniform(-0.05, 0.05)).collect();
        let scale_sq: Vec<f64> = scale.iter().map(|s| s * s).collect();
        let scale_sq_f16 = scale_sq.iter().map(|&v| f16_from_f32(v as f32)).collect();
        let bias_f16 = bias.iter().map(|&v| f16_from_f32(v as f32)).collect();
        Self { scale_sq, bias, scale_sq_f16, bias_f16, t_max: t_max as f64 }
    }

    /// ε_θ at pixel `i` for state `x`, model timestep `t`, cumulative ᾱ `a`.
    /// Scalar form of the kernel's element math — the unrolled paths must
    /// stay bitwise-identical to compositions of this function.
    #[inline]
    pub fn eps(&self, i: usize, x: f64, t: f64, a: f64) -> f64 {
        let om = (1.0 - a).max(0.0);
        om.sqrt() * x / (a * self.scale_sq[i] + om)
            + self.bias[i] * (std::f64::consts::PI * t / self.t_max).sin()
    }

    pub fn dim(&self) -> usize {
        self.scale_sq.len()
    }
}

/// Per-slot scalars of Eq. 12, hoisted once per lane. The element loop
/// sees only loads, multiplies, one divide and one narrowing store —
/// everything t-, ᾱ- and σ-dependent (including the ε-model's sine phase,
/// which the naive composition re-evaluated per pixel) lives here.
#[derive(Clone, Copy)]
struct SlotScalars {
    a: f64,
    om: f64,
    sq_om: f64,
    inv_sq_a: f64,
    sq_ap: f64,
    dir: f64,
    sg: f64,
    sin_t: f64,
}

impl SlotScalars {
    fn hoist(t_max: f64, t: f32, a_t: f32, a_p: f32, sigma: f32) -> Self {
        let a = a_t as f64;
        let ap = a_p as f64;
        let sg = sigma as f64;
        let om = (1.0 - a).max(0.0);
        Self {
            a,
            om,
            sq_om: om.sqrt(),
            inv_sq_a: 1.0 / a.sqrt(),
            sq_ap: ap.sqrt(),
            dir: (1.0 - ap - sg * sg).max(0.0).sqrt(),
            sg,
            sin_t: (std::f64::consts::PI * t as f64 / t_max).sin(),
        }
    }

    fn narrow(&self) -> SlotScalars32 {
        SlotScalars32 {
            a: self.a as f32,
            om: self.om as f32,
            sq_om: self.sq_om as f32,
            inv_sq_a: self.inv_sq_a as f32,
            sq_ap: self.sq_ap as f32,
            dir: self.dir as f32,
            sg: self.sg as f32,
            sin_t: self.sin_t as f32,
        }
    }
}

/// f32 twin of [`SlotScalars`] for the f16-weight path (scalars are still
/// hoisted in f64, then narrowed once per slot).
#[derive(Clone, Copy)]
struct SlotScalars32 {
    a: f32,
    om: f32,
    sq_om: f32,
    inv_sq_a: f32,
    sq_ap: f32,
    dir: f32,
    sg: f32,
    sin_t: f32,
}

/// One slot's disjoint window of the three output buffers.
struct SlotOut<'a> {
    x_prev: &'a mut [f32],
    eps: &'a mut [f32],
    x0: &'a mut [f32],
}

/// Element math of the f64 path. Expression shapes are copied verbatim
/// from [`RefModel::eps`] and the scalar Eq.-12 composition — bitwise
/// identity across scalar/unrolled/threaded paths depends on it.
#[inline(always)]
fn lane_f64(s: &SlotScalars, scale_sq: f64, bias: f64, x: f32, noise: f32) -> (f32, f32, f32) {
    let xv = x as f64;
    let e = s.sq_om * xv / (s.a * scale_sq + s.om) + bias * s.sin_t;
    let x0 = (xv - s.sq_om * e) * s.inv_sq_a;
    let xp = s.sq_ap * x0 + s.dir * e + s.sg * noise as f64;
    (e as f32, x0 as f32, xp as f32)
}

/// Element math of the f16-stored / f32-accumulated path.
#[inline(always)]
fn lane_f16(s: &SlotScalars32, scale_sq: u16, bias: u16, x: f32, noise: f32) -> (f32, f32, f32) {
    let e = s.sq_om * x / (s.a * f32_from_f16(scale_sq) + s.om) + f32_from_f16(bias) * s.sin_t;
    let x0 = (x - s.sq_om * e) * s.inv_sq_a;
    let xp = s.sq_ap * x0 + s.dir * e + s.sg * noise;
    (e, x0, xp)
}

fn slot_kernel_f64(
    scale_sq: &[f64],
    bias: &[f64],
    s: SlotScalars,
    x: &[f32],
    noise: &[f32],
    o: SlotOut<'_>,
) {
    let dim = x.len();
    let main = dim - dim % UNROLL;
    let mut i = 0;
    while i < main {
        // fixed-width chunks: the constant trip count lets the compiler
        // unroll and vectorize with a single bounds check per array
        let xs: &[f32; UNROLL] = x[i..i + UNROLL].try_into().unwrap();
        let ns: &[f32; UNROLL] = noise[i..i + UNROLL].try_into().unwrap();
        let ss: &[f64; UNROLL] = scale_sq[i..i + UNROLL].try_into().unwrap();
        let bs: &[f64; UNROLL] = bias[i..i + UNROLL].try_into().unwrap();
        let oe: &mut [f32; UNROLL] = (&mut o.eps[i..i + UNROLL]).try_into().unwrap();
        let ox: &mut [f32; UNROLL] = (&mut o.x0[i..i + UNROLL]).try_into().unwrap();
        let op: &mut [f32; UNROLL] = (&mut o.x_prev[i..i + UNROLL]).try_into().unwrap();
        for k in 0..UNROLL {
            let (e, x0, xp) = lane_f64(&s, ss[k], bs[k], xs[k], ns[k]);
            oe[k] = e;
            ox[k] = x0;
            op[k] = xp;
        }
        i += UNROLL;
    }
    for k in main..dim {
        let (e, x0, xp) = lane_f64(&s, scale_sq[k], bias[k], x[k], noise[k]);
        o.eps[k] = e;
        o.x0[k] = x0;
        o.x_prev[k] = xp;
    }
}

fn slot_kernel_f16(
    scale_sq: &[u16],
    bias: &[u16],
    s: SlotScalars32,
    x: &[f32],
    noise: &[f32],
    o: SlotOut<'_>,
) {
    let dim = x.len();
    let main = dim - dim % UNROLL;
    let mut i = 0;
    while i < main {
        let xs: &[f32; UNROLL] = x[i..i + UNROLL].try_into().unwrap();
        let ns: &[f32; UNROLL] = noise[i..i + UNROLL].try_into().unwrap();
        let ss: &[u16; UNROLL] = scale_sq[i..i + UNROLL].try_into().unwrap();
        let bs: &[u16; UNROLL] = bias[i..i + UNROLL].try_into().unwrap();
        let oe: &mut [f32; UNROLL] = (&mut o.eps[i..i + UNROLL]).try_into().unwrap();
        let ox: &mut [f32; UNROLL] = (&mut o.x0[i..i + UNROLL]).try_into().unwrap();
        let op: &mut [f32; UNROLL] = (&mut o.x_prev[i..i + UNROLL]).try_into().unwrap();
        for k in 0..UNROLL {
            let (e, x0, xp) = lane_f16(&s, ss[k], bs[k], xs[k], ns[k]);
            oe[k] = e;
            ox[k] = x0;
            op[k] = xp;
        }
        i += UNROLL;
    }
    for k in main..dim {
        let (e, x0, xp) = lane_f16(&s, scale_sq[k], bias[k], x[k], noise[k]);
        o.eps[k] = e;
        o.x0[k] = x0;
        o.x_prev[k] = xp;
    }
}

/// Grow the three output buffers to hold `n` elements (grow-only, zeros),
/// returning the number of freshly allocated bytes (0 in steady state).
fn ensure_len(out: &mut StepOutput, n: usize) -> u64 {
    let mut grown = 0u64;
    for buf in [&mut out.x_prev, &mut out.eps, &mut out.x0] {
        if buf.len() < n {
            grown += ((n - buf.len()) * std::mem::size_of::<f32>()) as u64;
            buf.resize(n, 0.0);
        }
    }
    grown
}

/// The pre-optimization scalar composition: per-slot coefficient hoisting
/// only, [`RefModel::eps`] called per element. Kept as the baseline that
/// `benches/reference_step.rs` measures against and that the property
/// tests pin the unrolled/threaded kernel to, bitwise.
#[allow(clippy::too_many_arguments)]
pub fn compute_scalar_into(
    model: &RefModel,
    bucket: usize,
    dim: usize,
    x: &[f32],
    t: &[f32],
    alpha_t: &[f32],
    alpha_prev: &[f32],
    sigma: &[f32],
    noise: &[f32],
    out: &mut StepOutput,
) {
    ensure_len(out, bucket * dim);
    for slot in 0..bucket {
        let a = alpha_t[slot] as f64;
        let ap = alpha_prev[slot] as f64;
        let sg = sigma[slot] as f64;
        let tm = t[slot] as f64;
        let dir = (1.0 - ap - sg * sg).max(0.0).sqrt();
        let sq_ap = ap.sqrt();
        let sq_om = (1.0 - a).max(0.0).sqrt();
        let inv_sq_a = 1.0 / a.sqrt();
        for i in 0..dim {
            let idx = slot * dim + i;
            let xv = x[idx] as f64;
            let e = model.eps(i, xv, tm, a);
            let x0 = (xv - sq_om * e) * inv_sq_a;
            let xp = sq_ap * x0 + dir * e + sg * noise[idx] as f64;
            out.eps[idx] = e as f32;
            out.x0[idx] = x0 as f32;
            out.x_prev[idx] = xp as f32;
        }
    }
}

/// Raw output base pointer, smuggled into the slot task. Slots write
/// disjoint `dim`-wide windows, and the pool joins every worker before the
/// publishing call returns, so shared access is sound.
#[derive(Clone, Copy)]
struct RawF32(*mut f32);

// SAFETY: see `RawF32` — disjoint writes, pool-join synchronization.
unsafe impl Send for RawF32 {}
unsafe impl Sync for RawF32 {}

/// Reference-backend executable for one (dataset × bucket): computes the
/// batched denoise step synchronously on the calling thread (plus the
/// shared worker pool). All per-call output state lives in caller-owned or
/// pool-recycled buffers, which is what gives it the same submit-before-wait
/// semantics as the compiled executable (the pipelined executor relies on
/// that) without per-call allocation.
pub struct RefExec {
    model: Arc<RefModel>,
    pool: Arc<WorkerPool>,
    precision: RefPrecision,
    /// Recycled pending-output buffers for the submit/wait path; the
    /// population is bounded by the executor's pipeline depth. `Arc`
    /// because each `PendingStep` carries a handle home — it must outlive
    /// (and stay independent of) the executable that produced it.
    spare: Arc<Mutex<Vec<StepOutput>>>,
    /// Seconds spent inside the kernel since the last harvest.
    pub(crate) compute_s: Cell<f64>,
    /// Bytes of fresh buffer growth since the last harvest (0 once warm).
    pub(crate) bytes_allocated: Cell<u64>,
}

impl RefExec {
    pub fn new(model: Arc<RefModel>, pool: Arc<WorkerPool>, precision: RefPrecision) -> Self {
        Self {
            model,
            pool,
            precision,
            spare: Arc::new(Mutex::new(Vec::new())),
            compute_s: Cell::new(0.0),
            bytes_allocated: Cell::new(0),
        }
    }

    /// Compute the three outputs for `bucket` lanes of `dim` elements
    /// straight into `out` (grown if undersized, never shrunk — zero
    /// allocation in steady state). Caller (the `StepExecutable` wrapper)
    /// has validated input lengths.
    #[allow(clippy::too_many_arguments)]
    pub fn compute_into(
        &self,
        bucket: usize,
        dim: usize,
        x: &[f32],
        t: &[f32],
        alpha_t: &[f32],
        alpha_prev: &[f32],
        sigma: &[f32],
        noise: &[f32],
        out: &mut StepOutput,
    ) {
        let grown = ensure_len(out, bucket * dim);
        self.bytes_allocated.set(self.bytes_allocated.get() + grown);
        let t0 = Instant::now();
        let model = &*self.model;
        let precision = self.precision;
        let t_max = model.t_max;
        let xp = RawF32(out.x_prev.as_mut_ptr());
        let oe = RawF32(out.eps.as_mut_ptr());
        let ox = RawF32(out.x0.as_mut_ptr());
        let task = |slot: usize| {
            let base = slot * dim;
            // SAFETY: slot windows are disjoint, `ensure_len` guaranteed
            // `bucket * dim` elements, and `pool.run` joins every worker
            // before returning (RawF32's contract).
            let o = unsafe {
                SlotOut {
                    x_prev: std::slice::from_raw_parts_mut(xp.0.add(base), dim),
                    eps: std::slice::from_raw_parts_mut(oe.0.add(base), dim),
                    x0: std::slice::from_raw_parts_mut(ox.0.add(base), dim),
                }
            };
            let xs = &x[base..base + dim];
            let ns = &noise[base..base + dim];
            let s =
                SlotScalars::hoist(t_max, t[slot], alpha_t[slot], alpha_prev[slot], sigma[slot]);
            match precision {
                RefPrecision::F32 => {
                    slot_kernel_f64(&model.scale_sq, &model.bias, s, xs, ns, o);
                }
                RefPrecision::F16 => {
                    slot_kernel_f16(&model.scale_sq_f16, &model.bias_f16, s.narrow(), xs, ns, o);
                }
            }
        };
        self.pool.run(bucket, &task);
        self.compute_s.set(self.compute_s.get() + t0.elapsed().as_secs_f64());
    }

    /// Submit-path variant: compute into a recycled spare buffer and hand
    /// it out together with the home pool the pending step returns it to
    /// on `wait_into`. Steady state pops a warm buffer; only a cold start
    /// (or a bucket larger than anything seen) allocates.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn compute_pooled(
        &self,
        bucket: usize,
        dim: usize,
        x: &[f32],
        t: &[f32],
        alpha_t: &[f32],
        alpha_prev: &[f32],
        sigma: &[f32],
        noise: &[f32],
    ) -> (StepOutput, Arc<Mutex<Vec<StepOutput>>>) {
        let mut out = self.spare.lock().unwrap().pop().unwrap_or_default();
        self.compute_into(bucket, dim, x, t, alpha_t, alpha_prev, sigma, noise, &mut out);
        (out, Arc::clone(&self.spare))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampler::ddim_update_host_sigma;

    fn info(params: u64, loss: f64) -> DatasetInfo {
        DatasetInfo { hlo: vec![], params, final_loss: loss, ref_n: 64 }
    }

    fn model() -> Arc<RefModel> {
        Arc::new(RefModel::from_manifest("sprites", &info(123456, 0.0421), 16, 400))
    }

    fn exec(threads: usize, precision: RefPrecision) -> RefExec {
        RefExec::new(model(), Arc::new(WorkerPool::new(threads)), precision)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_compute(
        e: &RefExec,
        bucket: usize,
        dim: usize,
        x: &[f32],
        t: &[f32],
        a_t: &[f32],
        a_p: &[f32],
        sigma: &[f32],
        noise: &[f32],
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut out = StepOutput::zeros(bucket * dim);
        e.compute_into(bucket, dim, x, t, a_t, a_p, sigma, noise, &mut out);
        (out.x_prev, out.eps, out.x0)
    }

    #[test]
    fn model_is_deterministic_and_weight_sensitive() {
        let a = RefModel::from_manifest("sprites", &info(1, 0.5), 8, 400);
        let b = RefModel::from_manifest("sprites", &info(1, 0.5), 8, 400);
        let c = RefModel::from_manifest("blobs", &info(1, 0.5), 8, 400);
        let d = RefModel::from_manifest("sprites", &info(2, 0.5), 8, 400);
        assert_eq!(a.eps(3, 0.7, 100.0, 0.5), b.eps(3, 0.7, 100.0, 0.5));
        assert_ne!(a.eps(3, 0.7, 100.0, 0.5), c.eps(3, 0.7, 100.0, 0.5));
        assert_ne!(a.eps(3, 0.7, 100.0, 0.5), d.eps(3, 0.7, 100.0, 0.5));
        assert_eq!(a.dim(), 8);
    }

    #[test]
    fn eps_is_finite_at_schedule_ends() {
        let m = model();
        for a in [1.0, 0.9999, 0.5, 1e-4, 1e-9] {
            for x in [-3.0, 0.0, 3.0] {
                let e = m.eps(0, x, 1.0, a);
                assert!(e.is_finite(), "eps({x}, a={a}) = {e}");
            }
        }
        // at abar = 1 the data term vanishes: eps is the pure bias field
        let e1 = m.eps(2, 5.0, 200.0, 1.0);
        let e2 = m.eps(2, -5.0, 200.0, 1.0);
        assert_eq!(e1, e2);
    }

    #[test]
    fn eps_depends_on_model_timestep() {
        let m = model();
        let a = m.eps(1, 0.5, 100.0, 0.3);
        let b = m.eps(1, 0.5, 300.0, 0.3);
        assert_ne!(a, b, "bias term must make eps t-dependent");
    }

    #[test]
    fn compute_matches_host_eq12_composition() {
        // the executable's (x_prev, eps, x0) must satisfy the host-side
        // Eq.-12 arithmetic on its own eps output, per lane
        let exec = exec(1, RefPrecision::F32);
        let (bucket, dim) = (3usize, 16usize);
        let mut rng = Pcg64::seeded(9);
        let x: Vec<f32> = (0..bucket * dim).map(|_| rng.uniform(-1.5, 1.5) as f32).collect();
        let noise: Vec<f32> = (0..bucket * dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let t = vec![120.0f32, 240.0, 360.0];
        let a_t = vec![0.4f32, 0.15, 0.05];
        let a_p = vec![0.7f32, 0.4, 0.15];
        let sigma = vec![0.0f32, 0.1, 0.3];
        let (xp, eps, x0) = run_compute(&exec, bucket, dim, &x, &t, &a_t, &a_p, &sigma, &noise);
        for slot in 0..bucket {
            let r = slot * dim..(slot + 1) * dim;
            let want = ddim_update_host_sigma(
                &x[r.clone()],
                &eps[r.clone()],
                &noise[r.clone()],
                a_t[slot] as f64,
                a_p[slot] as f64,
                sigma[slot] as f64,
            );
            for (got, want) in xp[r.clone()].iter().zip(&want) {
                assert!((got - want).abs() < 1e-5, "lane {slot}: {got} vs {want}");
            }
            // x0 consistency: x = sqrt(a) x0 + sqrt(1-a) eps
            for i in r.clone() {
                let back = (a_t[slot] as f64).sqrt() * x0[i] as f64
                    + (1.0 - a_t[slot] as f64).sqrt() * eps[i] as f64;
                assert!((back - x[i] as f64).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn lanes_are_independent() {
        let exec = exec(1, RefPrecision::F32);
        let (bucket, dim) = (4usize, 16usize);
        let lane0_x: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.37).sin()).collect();
        let mk = |fill: f32| {
            let mut v = vec![fill; bucket * dim];
            v[..dim].copy_from_slice(&lane0_x);
            v
        };
        let t = vec![100.0f32; bucket];
        let a_t = vec![0.4f32; bucket];
        let a_p = vec![0.8f32; bucket];
        let sigma = vec![0.0f32; bucket];
        let zeros = vec![0.0f32; bucket * dim];
        let (p1, e1, _) =
            run_compute(&exec, bucket, dim, &mk(1.3), &t, &a_t, &a_p, &sigma, &zeros);
        let (p2, e2, _) =
            run_compute(&exec, bucket, dim, &mk(-2.0), &t, &a_t, &a_p, &sigma, &zeros);
        assert_eq!(&p1[..dim], &p2[..dim], "lane 0 depends on other lanes");
        assert_eq!(&e1[..dim], &e2[..dim]);
    }

    #[test]
    fn unrolled_and_threaded_match_scalar_bitwise() {
        // quick in-module smoke; the exhaustive odd-shape sweep lives in
        // rust/tests/reference_kernel.rs
        let m = model();
        let (bucket, dim) = (5usize, 16usize);
        let mut rng = Pcg64::seeded(41);
        let x: Vec<f32> = (0..bucket * dim).map(|_| rng.uniform(-2.0, 2.0) as f32).collect();
        let noise: Vec<f32> = (0..bucket * dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let t: Vec<f32> = (0..bucket).map(|s| 40.0 * (s as f32 + 1.0)).collect();
        let a_t: Vec<f32> = (0..bucket).map(|s| 0.9 - 0.15 * s as f32).collect();
        let a_p: Vec<f32> = (0..bucket).map(|s| 0.95 - 0.1 * s as f32).collect();
        let sigma: Vec<f32> = (0..bucket).map(|s| 0.05 * s as f32).collect();
        let mut want = StepOutput::zeros(bucket * dim);
        compute_scalar_into(&m, bucket, dim, &x, &t, &a_t, &a_p, &sigma, &noise, &mut want);
        for threads in [1usize, 4] {
            let e = RefExec::new(m.clone(), Arc::new(WorkerPool::new(threads)), RefPrecision::F32);
            let (xp, eps, x0) = run_compute(&e, bucket, dim, &x, &t, &a_t, &a_p, &sigma, &noise);
            assert_eq!(xp, want.x_prev, "x_prev at {threads} threads");
            assert_eq!(eps, want.eps, "eps at {threads} threads");
            assert_eq!(x0, want.x0, "x0 at {threads} threads");
        }
    }

    #[test]
    fn f16_conversion_round_trips() {
        // exactly representable halves survive the round trip bit-for-bit
        for v in [0.0f32, 1.0, -1.0, 0.5, 0.25, 1.5, -0.75, 2048.0, 65504.0] {
            assert_eq!(f32_from_f16(f16_from_f32(v)), v, "{v}");
        }
        // general values land within half-epsilon relative error
        for v in [0.49f32, 1.69, 0.0421, -0.05, 0.7, 1.3, 3.14159] {
            let back = f32_from_f16(f16_from_f32(v));
            assert!((back - v).abs() / v.abs() < 1e-3, "{v} → {back}");
        }
        // overflow and specials
        assert_eq!(f32_from_f16(f16_from_f32(1e6)), f32::INFINITY);
        assert_eq!(f32_from_f16(f16_from_f32(-1e6)), f32::NEG_INFINITY);
        assert_eq!(f32_from_f16(f16_from_f32(f32::INFINITY)), f32::INFINITY);
        assert!(f32_from_f16(f16_from_f32(f32::NAN)).is_nan());
        assert_eq!(f16_from_f32(65520.0), 0x7c00, "ties-to-even rounds max half up to inf");
        // subnormal halves
        let tiny = 1e-5f32;
        let back = f32_from_f16(f16_from_f32(tiny));
        assert!((back - tiny).abs() / tiny < 5e-3, "{tiny} → {back}");
        assert_eq!(f32_from_f16(f16_from_f32(1e-12)), 0.0, "below half range → 0");
    }

    #[test]
    fn f16_path_tracks_f32_path() {
        let (bucket, dim) = (2usize, 32usize);
        let mut rng = Pcg64::seeded(17);
        let x: Vec<f32> = (0..bucket * dim).map(|_| rng.uniform(-1.5, 1.5) as f32).collect();
        let noise = vec![0.0f32; bucket * dim];
        let t = vec![100.0f32, 300.0];
        let a_t = vec![0.5f32, 0.2];
        let a_p = vec![0.8f32, 0.5];
        let sigma = vec![0.0f32; 2];
        let full = exec(1, RefPrecision::F32);
        let half = exec(1, RefPrecision::F16);
        let (xp32, ..) = run_compute(&full, bucket, dim, &x, &t, &a_t, &a_p, &sigma, &noise);
        let (xp16, ..) = run_compute(&half, bucket, dim, &x, &t, &a_t, &a_p, &sigma, &noise);
        let max = xp32.iter().zip(&xp16).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max < 5e-2, "f16 drift {max}");
        assert!(max > 0.0, "f16 path must actually quantize (else it is untested)");
    }

    #[test]
    fn compute_into_is_allocation_free_once_warm() {
        let e = exec(2, RefPrecision::F32);
        let (bucket, dim) = (4usize, 16usize);
        let x = vec![0.3f32; bucket * dim];
        let noise = vec![0.1f32; bucket * dim];
        let sc = vec![0.5f32; bucket];
        let t = vec![100.0f32; bucket];
        let mut out = StepOutput::default();
        e.compute_into(bucket, dim, &x, &t, &sc, &sc, &sc, &noise, &mut out);
        let (s1, b1) = (e.compute_s.take(), e.bytes_allocated.take());
        assert!(s1 >= 0.0);
        assert_eq!(b1, (3 * bucket * dim * 4) as u64, "cold start grows all three buffers");
        for _ in 0..5 {
            e.compute_into(bucket, dim, &x, &t, &sc, &sc, &sc, &noise, &mut out);
        }
        let (_, b2) = (e.compute_s.take(), e.bytes_allocated.take());
        assert_eq!(b2, 0, "warm ticks must not allocate");
    }

    #[test]
    fn pooled_buffers_recycle() {
        let e = exec(1, RefPrecision::F32);
        let (bucket, dim) = (2usize, 8usize);
        let x = vec![0.2f32; bucket * dim];
        let noise = vec![0.0f32; bucket * dim];
        let sc = vec![0.6f32; bucket];
        let t = vec![50.0f32; bucket];
        let (out, home) = e.compute_pooled(bucket, dim, &x, &t, &sc, &sc, &sc, &noise);
        assert!(e.bytes_allocated.take() > 0, "cold submit allocates its buffer");
        home.lock().unwrap().push(out);
        let (out, home) = e.compute_pooled(bucket, dim, &x, &t, &sc, &sc, &sc, &noise);
        assert_eq!(e.bytes_allocated.take(), 0, "recycled submit must not allocate");
        home.lock().unwrap().push(out);
    }
}
