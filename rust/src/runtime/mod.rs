//! Runtime layer: loads the artifact bundle (manifest + ᾱ table) and
//! serves `denoise_step` executables to the coordinator's hot loop through
//! one of two step backends:
//!
//! - [`BackendKind::Reference`] (default, always compiled): a pure-Rust
//!   synthetic ε-model ([`reference`]) — deterministic, hermetic, runs the
//!   whole serving stack on CPU with no XLA and no compiled artifacts.
//! - [`BackendKind::Xla`] (cargo feature `xla`, off by default): the
//!   PJRT/XLA path ([`xla`]) over AOT-lowered HLO text.
//!
//! One [`StepExecutable`] per (dataset × batch bucket); the [`Runtime`]
//! builds them lazily and caches them. Everything above this module is
//! backend-agnostic.

mod executable;
#[cfg(feature = "xla")]
mod literal;
mod pool;
pub mod reference;
#[cfg(feature = "xla")]
mod xla;

pub use executable::{LaneStep, PendingStep, StepExecutable, StepOutput};
#[cfg(feature = "xla")]
pub use literal::{literal_to_slice, vec_to_literal};
pub use pool::WorkerPool;
pub use reference::{RefModel, RefOptions, RefPrecision};

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::artifacts::Manifest;
use crate::error::{Error, Result};
use crate::schedule::AlphaTable;

/// Which step backend a [`Runtime`] executes on (`--backend ref|xla`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust reference backend (synthetic ε-model) — the hermetic
    /// default: tier-1 CI runs the full stack on it.
    #[default]
    Reference,
    /// PJRT/XLA over compiled HLO artifacts. Requires the `xla` cargo
    /// feature; selecting it on a default build fails loudly at load.
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "ref" | "reference" => Ok(BackendKind::Reference),
            "xla" => Ok(BackendKind::Xla),
            other => Err(Error::Request(format!("unknown backend '{other}' (want ref | xla)"))),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Reference => "ref",
            BackendKind::Xla => "xla",
        }
    }

    /// `DDIM_BACKEND=ref|xla` override, else the hermetic default. This is
    /// what parameterless [`Runtime::load`] uses, so benches and examples
    /// switch backends without re-plumbing flags.
    pub fn from_env() -> Result<Self> {
        match std::env::var("DDIM_BACKEND") {
            Ok(v) => Self::parse(&v),
            Err(_) => Ok(Self::default()),
        }
    }
}

/// Backend-specific load-time state.
enum Backend {
    /// Synthetic per-dataset ε-models, derived lazily from the manifest,
    /// plus the worker pool and weight precision every reference
    /// executable of this runtime shares.
    Reference {
        models: HashMap<String, Arc<RefModel>>,
        pool: Arc<WorkerPool>,
        precision: RefPrecision,
    },
    #[cfg(feature = "xla")]
    Xla { client: ::xla::PjRtClient },
}

/// Loaded artifact bundle + step backend + executable cache.
pub struct Runtime {
    backend: Backend,
    kind: BackendKind,
    manifest: Manifest,
    alphas: AlphaTable,
    // (dataset, bucket) -> built executable
    cache: HashMap<(String, usize), StepExecutable>,
    /// cumulative time spent building executables (startup cost accounting;
    /// PJRT compilation on the xla backend, ~free on the reference backend)
    pub compile_seconds: f64,
}

impl Runtime {
    /// Create a runtime over an artifact directory (`artifacts/` by
    /// default) on the `DDIM_BACKEND` env backend, defaulting to the
    /// hermetic reference backend.
    pub fn load(artifact_root: impl AsRef<Path>) -> Result<Self> {
        Self::load_with(artifact_root, BackendKind::from_env()?)
    }

    /// Create a runtime on an explicit step backend (`cfg.backend` /
    /// `--backend`), with reference tuning taken from the environment
    /// (`DDIM_REF_THREADS` / `DDIM_REF_PRECISION`).
    pub fn load_with(artifact_root: impl AsRef<Path>, kind: BackendKind) -> Result<Self> {
        Self::load_full(artifact_root, kind, RefOptions::from_env()?)
    }

    /// Fully explicit constructor: backend kind plus reference-backend
    /// tuning (`--ref-threads` / `--ref-precision`). The worker pool is
    /// created here, once per runtime, and shared by every executable.
    pub fn load_full(
        artifact_root: impl AsRef<Path>,
        kind: BackendKind,
        opts: RefOptions,
    ) -> Result<Self> {
        let manifest = Manifest::load(&artifact_root)?;
        let alphas = AlphaTable::from_artifact(artifact_root.as_ref().join("alphas.json"))?;
        alphas.validate()?;
        let backend = match kind {
            BackendKind::Reference => Backend::Reference {
                models: HashMap::new(),
                pool: Arc::new(WorkerPool::new(opts.resolved_threads())),
                precision: opts.precision,
            },
            #[cfg(feature = "xla")]
            BackendKind::Xla => Backend::Xla { client: ::xla::PjRtClient::cpu()? },
            #[cfg(not(feature = "xla"))]
            BackendKind::Xla => {
                return Err(Error::Xla(
                    "this binary was built without the 'xla' cargo feature; \
                     rebuild with `--features xla` (and a real PJRT wrapper \
                     in place of third_party/xla-stub) or use --backend ref"
                        .into(),
                ))
            }
        };
        Ok(Self {
            backend,
            kind,
            manifest,
            alphas,
            cache: HashMap::new(),
            compile_seconds: 0.0,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn alphas(&self) -> &AlphaTable {
        &self.alphas
    }

    /// Which backend this runtime executes steps on.
    pub fn backend_kind(&self) -> BackendKind {
        self.kind
    }

    /// Get (building if needed) the executable for `dataset` at `bucket`.
    /// Single-probe via the entry API — this runs once per engine tick, so
    /// the old `contains_key` → `insert` → `get` triple probe (plus a
    /// second key clone on the miss path) was hot-loop waste. The one
    /// remaining `to_string` is the entry API's owned-key cost; trading it
    /// for a two-level map would mean four probes per hit instead of one.
    pub fn executable(&mut self, dataset: &str, bucket: usize) -> Result<&StepExecutable> {
        match self.cache.entry((dataset.to_string(), bucket)) {
            Entry::Occupied(e) => Ok(e.into_mut()),
            Entry::Vacant(e) => {
                let ds = self.manifest.dataset(dataset)?;
                let idx = self.manifest.bucket_index(bucket)?;
                let dim = self.manifest.sample_dim();
                let t0 = Instant::now();
                let exe = match &mut self.backend {
                    Backend::Reference { models, pool, precision } => {
                        let model = match models.entry(dataset.to_string()) {
                            Entry::Occupied(m) => m.get().clone(),
                            Entry::Vacant(m) => m
                                .insert(Arc::new(RefModel::from_manifest(
                                    dataset,
                                    ds,
                                    dim,
                                    self.manifest.t_max,
                                )))
                                .clone(),
                        };
                        StepExecutable::reference_with(
                            model,
                            bucket,
                            dim,
                            Arc::clone(pool),
                            *precision,
                        )?
                    }
                    #[cfg(feature = "xla")]
                    Backend::Xla { client } => {
                        let path = self.manifest.hlo_path(ds, idx);
                        StepExecutable::xla(client, &path, bucket, dim)?
                    }
                };
                let _ = idx; // used by the xla arm only
                self.compile_seconds += t0.elapsed().as_secs_f64();
                Ok(e.insert(exe))
            }
        }
    }

    /// Eagerly build every bucket for `dataset` (benches / server startup).
    pub fn warmup(&mut self, dataset: &str) -> Result<()> {
        for b in self.manifest.buckets.clone() {
            self.executable(dataset, b)?;
        }
        Ok(())
    }

    /// Number of executables built so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses_and_labels() {
        assert_eq!(BackendKind::parse("ref").unwrap(), BackendKind::Reference);
        assert_eq!(BackendKind::parse("reference").unwrap(), BackendKind::Reference);
        assert_eq!(BackendKind::parse("xla").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Reference);
        for k in [BackendKind::Reference, BackendKind::Xla] {
            assert_eq!(BackendKind::parse(k.label()).unwrap(), k);
        }
    }

    #[test]
    fn runtime_loads_fixtures_and_caches_executables() {
        let root = crate::testing::fixtures::root();
        let mut rt = Runtime::load_with(&root, BackendKind::Reference).unwrap();
        assert_eq!(rt.backend_kind(), BackendKind::Reference);
        assert_eq!(rt.compiled_count(), 0);
        let b = rt.manifest().buckets[0];
        rt.executable("sprites", b).unwrap();
        rt.executable("sprites", b).unwrap();
        assert_eq!(rt.compiled_count(), 1, "second probe must hit the cache");
        assert!(rt.executable("no_such_dataset", b).is_err());
        let bad_bucket = rt.manifest().buckets.iter().max().unwrap() + 1;
        assert!(rt.executable("sprites", bad_bucket).is_err());
        rt.warmup("sprites").unwrap();
        assert_eq!(rt.compiled_count(), rt.manifest().buckets.len());
    }

    #[test]
    fn load_full_honours_ref_options() {
        let root = crate::testing::fixtures::root();
        let opts = RefOptions { threads: 2, precision: RefPrecision::F16 };
        let mut rt = Runtime::load_full(&root, BackendKind::Reference, opts).unwrap();
        let b = rt.manifest().buckets[0];
        rt.executable("sprites", b).unwrap();
        for p in [RefPrecision::F32, RefPrecision::F16] {
            assert_eq!(RefPrecision::parse(p.label()).unwrap(), p);
        }
        assert!(RefPrecision::parse("bf16").is_err());
        assert!(RefOptions::default().resolved_threads() >= 1, "0 resolves to the machine");
        assert_eq!(RefOptions { threads: 3, ..Default::default() }.resolved_threads(), 3);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn xla_backend_fails_loudly_without_the_feature() {
        let root = crate::testing::fixtures::root();
        let err = Runtime::load_with(&root, BackendKind::Xla).unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
