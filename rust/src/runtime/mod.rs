//! Runtime layer: wraps the `xla` crate's PJRT CPU client to load the
//! AOT-compiled `denoise_step` HLO-text modules and execute them from the
//! coordinator's hot loop.
//!
//! One [`StepExecutable`] per (dataset × batch bucket); the [`Runtime`]
//! compiles them lazily and caches them. Interchange is HLO *text* (see
//! `python/compile/aot.py` for why not serialized protos).

mod executable;
mod literal;

pub use executable::{LaneStep, PendingStep, StepExecutable, StepOutput};
pub use literal::{literal_to_slice, vec_to_literal};

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::artifacts::Manifest;
use crate::error::Result;
use crate::schedule::AlphaTable;

/// Loaded artifact bundle + PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    alphas: AlphaTable,
    // (dataset, bucket) -> compiled executable
    cache: HashMap<(String, usize), StepExecutable>,
    /// cumulative time spent in `client.compile` (startup cost accounting)
    pub compile_seconds: f64,
}

impl Runtime {
    /// Create a runtime over an artifact directory (`artifacts/` by default).
    pub fn load(artifact_root: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifact_root)?;
        let alphas = AlphaTable::from_artifact(artifact_root.as_ref().join("alphas.json"))?;
        alphas.validate()?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, manifest, alphas, cache: HashMap::new(), compile_seconds: 0.0 })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn alphas(&self) -> &AlphaTable {
        &self.alphas
    }

    /// Get (compiling if needed) the executable for `dataset` at `bucket`.
    /// Single-probe via the entry API — this runs once per engine tick, so
    /// the old `contains_key` → `insert` → `get` triple probe (plus a
    /// second key clone on the miss path) was hot-loop waste. The one
    /// remaining `to_string` is the entry API's owned-key cost; trading it
    /// for a two-level map would mean four probes per hit instead of one.
    pub fn executable(&mut self, dataset: &str, bucket: usize) -> Result<&StepExecutable> {
        match self.cache.entry((dataset.to_string(), bucket)) {
            Entry::Occupied(e) => Ok(e.into_mut()),
            Entry::Vacant(e) => {
                let ds = self.manifest.dataset(dataset)?;
                let idx = self.manifest.bucket_index(bucket)?;
                let path = self.manifest.hlo_path(ds, idx);
                let t0 = Instant::now();
                let exe = StepExecutable::load(
                    &self.client,
                    &path,
                    bucket,
                    self.manifest.sample_dim(),
                )?;
                self.compile_seconds += t0.elapsed().as_secs_f64();
                Ok(e.insert(exe))
            }
        }
    }

    /// Eagerly compile every bucket for `dataset` (benches / server startup).
    pub fn warmup(&mut self, dataset: &str) -> Result<()> {
        for b in self.manifest.buckets.clone() {
            self.executable(dataset, b)?;
        }
        Ok(())
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}
