//! Persistent chunked worker pool for the reference kernel.
//!
//! `std::thread::scope` would be the safe way to fan a borrowed closure out
//! across threads, but it spawns OS threads per call — tens of microseconds
//! against a kernel that finishes a sub-batch in a similar amount of time.
//! This pool spawns its workers once and hands them borrowed work through a
//! lifetime-erased pointer, amortizing thread creation to zero on the hot
//! path (the whole point of ROADMAP item 4's "hardware-fast" goal).
//!
//! Protocol: [`WorkerPool::run`] publishes the task under the state mutex
//! (bumping an epoch counter), every worker plus the caller claims chunk
//! indices from a shared atomic cursor until the range is exhausted, and
//! `run` blocks until the per-epoch `running` count drains back to zero.
//! That final wait is the safety argument for the erased borrow: no worker
//! can touch the task pointer after `run` returns.
//!
//! Chunk-claim order is nondeterministic. Callers must therefore hand in
//! tasks whose chunks write disjoint data and depend only on their own
//! index — which the reference kernel's slot-granular split satisfies
//! exactly (lanes are elementwise-independent, see `reference.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Lifetime-erased pointer to the closure of the live epoch.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointer is only dereferenced by workers between observing a
// fresh epoch and decrementing `running`; `WorkerPool::run`, which owns the
// underlying borrow, does not return until `running` is zero.
unsafe impl Send for TaskPtr {}

struct State {
    /// Task of the live epoch (present from publish until `run` returns).
    task: Option<TaskPtr>,
    /// Number of chunks in the live epoch.
    chunks: usize,
    /// Bumped once per `run`; workers detect fresh work by comparing it
    /// against the last epoch they served.
    epoch: u64,
    /// Workers still inside the live epoch.
    running: usize,
    /// Set once, by `Drop`.
    stop: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Next unclaimed chunk index of the live epoch.
    cursor: AtomicUsize,
    work: Condvar,
    done: Condvar,
}

/// A fixed set of worker threads that repeatedly execute borrowed
/// `Fn(usize)` tasks over chunk ranges. One pool is shared by every
/// reference executable of a `Runtime`, so a sub-batch uses the machine
/// once, not once per (dataset × bucket).
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Pool computing with `threads` total threads: `threads - 1` spawned
    /// workers plus the calling thread, which participates in every
    /// [`WorkerPool::run`]. `threads <= 1` spawns nothing and `run`
    /// degenerates to an inline loop.
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                task: None,
                chunks: 0,
                epoch: 0,
                running: 0,
                stop: false,
            }),
            cursor: AtomicUsize::new(0),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = (1..threads.max(1))
            .map(|_| {
                let sh = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&sh))
            })
            .collect();
        Self { shared, workers }
    }

    /// Total compute threads (spawned workers + the caller).
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Run `task(chunk)` for every chunk in `0..chunks`, spread across the
    /// pool, blocking until all chunks complete. Chunks must write disjoint
    /// data and depend only on their own index: claim order across threads
    /// is nondeterministic, and that is only sound (and bitwise-reproducible)
    /// when no chunk reads another's output.
    pub fn run(&self, chunks: usize, task: &(dyn Fn(usize) + Sync)) {
        if self.workers.is_empty() || chunks <= 1 {
            for c in 0..chunks {
                task(c);
            }
            return;
        }
        // Erase the borrow's lifetime. Workers stop dereferencing the
        // pointer strictly before the `running == 0` wait below completes,
        // so the borrow outlives every use.
        let ptr = TaskPtr(task as *const (dyn Fn(usize) + Sync));
        {
            let mut st = self.shared.state.lock().unwrap();
            self.shared.cursor.store(0, Ordering::Relaxed);
            st.task = Some(ptr);
            st.chunks = chunks;
            st.epoch += 1;
            st.running = self.workers.len();
            self.shared.work.notify_all();
        }
        // the caller is a worker too — never idle while others compute
        claim_chunks(&self.shared.cursor, chunks, task);
        let mut st = self.shared.state.lock().unwrap();
        st = self.shared.done.wait_while(st, |s| s.running > 0).unwrap();
        st.task = None;
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.stop = true;
            self.shared.work.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Claim-and-execute loop shared by workers and the publishing caller.
fn claim_chunks(cursor: &AtomicUsize, chunks: usize, task: &(dyn Fn(usize) + Sync)) {
    loop {
        let c = cursor.fetch_add(1, Ordering::Relaxed);
        if c >= chunks {
            return;
        }
        task(c);
    }
}

fn worker_loop(sh: &Shared) {
    let mut seen = 0u64;
    loop {
        let (ptr, chunks) = {
            let st = sh.state.lock().unwrap();
            let st = sh
                .work
                .wait_while(st, |s| !s.stop && s.epoch == seen)
                .unwrap();
            if st.stop {
                return;
            }
            seen = st.epoch;
            (st.task.expect("live epoch carries a task"), st.chunks)
        };
        // SAFETY: `run` published the pointer under the lock and blocks
        // until `running` reaches zero, which happens strictly after this
        // dereference; the closure is `Sync`, so concurrent calls are fine.
        let task = unsafe { &*ptr.0 };
        claim_chunks(&sh.cursor, chunks, task);
        let mut st = sh.state.lock().unwrap();
        st.running -= 1;
        if st.running == 0 {
            sh.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn counters(n: usize) -> Vec<AtomicU64> {
        (0..n).map(|_| AtomicU64::new(0)).collect()
    }

    #[test]
    fn every_chunk_runs_exactly_once() {
        for threads in [1usize, 2, 4, 7] {
            let pool = WorkerPool::new(threads);
            assert_eq!(pool.threads(), threads.max(1));
            let hits = counters(97);
            pool.run(hits.len(), &|c| {
                hits[c].fetch_add(1, Ordering::Relaxed);
            });
            for (c, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {c} at {threads} threads");
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_many_epochs() {
        let pool = WorkerPool::new(3);
        let total = AtomicU64::new(0);
        for _ in 0..500 {
            pool.run(5, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 2500);
    }

    #[test]
    fn zero_threads_clamps_to_inline() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.threads(), 1);
        let hits = counters(4);
        pool.run(hits.len(), &|c| {
            hits[c].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn single_chunk_runs_on_the_caller() {
        // chunks <= 1 takes the inline path even on a threaded pool
        let pool = WorkerPool::new(4);
        let caller = std::thread::current().id();
        let saw = Mutex::new(None);
        pool.run(1, &|_| {
            *saw.lock().unwrap() = Some(std::thread::current().id());
        });
        assert_eq!(*saw.lock().unwrap(), Some(caller));
    }

    #[test]
    fn empty_range_is_a_noop() {
        let pool = WorkerPool::new(2);
        pool.run(0, &|_| panic!("no chunk to run"));
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        let pool = WorkerPool::new(4);
        pool.run(8, &|_| {});
        drop(pool); // must not hang or leak panicking threads
    }
}
