//! Production observability: Prometheus text-format exposition
//! ([`prom`]), rotating structured access logs ([`access_log`] over
//! [`rotation`]), and per-request trace spans ([`Spans`]).
//!
//! The module sits beside the coordinator, not above it: the transport
//! ([`crate::coordinator::server`]) samples traces, emits access-log
//! lines from its completion path, and serves the Prometheus scrape
//! both as `{"op":"metrics","format":"prometheus"}` on the JSON-line
//! wire and as a minimal `GET /metrics` HTTP/1.0 responder on the same
//! port. The engine ([`crate::coordinator::engine`]) fills span
//! accumulators only for traced requests, so an untraced workload pays
//! no extra clock reads (bench section (k) gates the overhead).

pub mod access_log;
pub mod prom;
pub mod rotation;

pub use access_log::{AccessLogger, AccessRecord};
pub use prom::{BuildInfo, ObsSelf, PromText, TransportCounters};
pub use rotation::{RotatingFile, RotationPolicy};

use crate::jobj;
use crate::json::Value;

/// Wall-clock stage timings for one traced request, following the
/// request through queue → plan/pack → device → advance → publish.
///
/// `queue_s` is admission wait (transport arrival → engine admit).
/// `pack_s`/`device_s`/`advance_s` are the summed wall-clock of every
/// sub-batch the request's lanes participated in — a shared sub-batch
/// is attributed in full to each participating traced request (the
/// span answers "where did my request spend its time", not "how much
/// device time did it consume exclusively"). `publish_s` is
/// completion → response-bytes-queued at the transport, and `total_s`
/// is arrival → publish on the same clock as the latency histograms.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Spans {
    pub queue_s: f64,
    pub pack_s: f64,
    pub device_s: f64,
    pub advance_s: f64,
    pub publish_s: f64,
    pub total_s: f64,
}

impl Spans {
    /// Wire/log form: `{"queue_s":...,"pack_s":...,...}`.
    pub fn to_json(&self) -> Value {
        jobj![
            ("queue_s", self.queue_s),
            ("pack_s", self.pack_s),
            ("device_s", self.device_s),
            ("advance_s", self.advance_s),
            ("publish_s", self.publish_s),
            ("total_s", self.total_s),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_json_has_every_stage() {
        let s = Spans { queue_s: 0.5, total_s: 1.0, ..Default::default() };
        let v = s.to_json();
        for k in ["queue_s", "pack_s", "device_s", "advance_s", "publish_s", "total_s"] {
            assert!(v.get(k).is_ok(), "missing span stage {k}");
        }
        assert_eq!(v.get("queue_s").unwrap().as_f64().unwrap(), 0.5);
        assert_eq!(v.get("total_s").unwrap().as_f64().unwrap(), 1.0);
    }
}
