//! Size- and interval-based log rotation with a bounded retention
//! window, logrotate-style: on rotation `PATH` is renamed to `PATH.1`,
//! `PATH.1` to `PATH.2`, …, and `PATH.keep` is deleted — so at most
//! `keep` rotated files (plus the live one) ever exist.
//!
//! Rotation is checked at write time, before the line lands, so a file
//! never exceeds `max_bytes` by more than one line and an idle log is
//! never rotated (age only applies once something was written).

use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::time::Instant;

/// When and how much to rotate. Either trigger may be disabled with 0;
/// with both disabled the file grows forever (keep is then unused).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RotationPolicy {
    /// Rotate when the live file would exceed this many bytes (0 = no
    /// size-based rotation).
    pub max_bytes: u64,
    /// Rotate when the live file has been open at least this many
    /// seconds and holds at least one line (0 = no interval rotation).
    pub max_secs: u64,
    /// Rotated files retained (`PATH.1` … `PATH.keep`); older ones are
    /// deleted. Clamped to at least 1 when rotation can trigger.
    pub keep: usize,
}

impl RotationPolicy {
    /// No rotation at all: a plain append-forever file.
    pub fn none() -> Self {
        Self { max_bytes: 0, max_secs: 0, keep: 1 }
    }

    fn enabled(&self) -> bool {
        self.max_bytes > 0 || self.max_secs > 0
    }
}

/// An append-mode line file that rotates itself per [`RotationPolicy`].
/// Not thread-safe by design — the access logger owns exactly one on
/// its dedicated writer thread.
pub struct RotatingFile {
    path: PathBuf,
    policy: RotationPolicy,
    file: BufWriter<File>,
    /// bytes written to the live file (including pre-existing content
    /// when opened in append mode)
    written: u64,
    opened_at: Instant,
    rotations: u64,
}

impl RotatingFile {
    /// Open (append, create) the live file; parent directories are
    /// created as needed. Pre-existing bytes count toward the size
    /// trigger, so restarting over a full file rotates on first write.
    pub fn open(path: impl Into<PathBuf>, policy: RotationPolicy) -> std::io::Result<Self> {
        let path = path.into();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)?;
            }
        }
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        let written = file.metadata()?.len();
        Ok(Self {
            path,
            policy,
            file: BufWriter::new(file),
            written,
            opened_at: Instant::now(),
            rotations: 0,
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Bytes in the live file.
    pub fn current_bytes(&self) -> u64 {
        self.written
    }

    /// How many times this handle has rotated.
    pub fn rotations(&self) -> u64 {
        self.rotations
    }

    fn should_rotate(&self, next_line_bytes: u64) -> bool {
        if !self.policy.enabled() || self.written == 0 {
            // never rotate an empty file: an oversized single line must
            // still land somewhere, and an idle log must not churn names
            return false;
        }
        if self.policy.max_bytes > 0 && self.written + next_line_bytes > self.policy.max_bytes {
            return true;
        }
        self.policy.max_secs > 0 && self.opened_at.elapsed().as_secs() >= self.policy.max_secs
    }

    /// Append one line (a trailing `\n` is added), rotating first if
    /// the policy says so.
    pub fn write_line(&mut self, line: &str) -> std::io::Result<()> {
        let n = line.len() as u64 + 1;
        if self.should_rotate(n) {
            self.rotate()?;
        }
        self.file.write_all(line.as_bytes())?;
        self.file.write_all(b"\n")?;
        self.written += n;
        Ok(())
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.file.flush()
    }

    fn numbered(&self, i: usize) -> PathBuf {
        PathBuf::from(format!("{}.{i}", self.path.display()))
    }

    /// The logrotate shift: drop `.keep`, slide `.i` → `.i+1`, move the
    /// live file to `.1`, reopen a fresh live file.
    fn rotate(&mut self) -> std::io::Result<()> {
        self.file.flush()?;
        let keep = self.policy.keep.max(1);
        let _ = fs::remove_file(self.numbered(keep));
        for i in (1..keep).rev() {
            let from = self.numbered(i);
            if from.exists() {
                fs::rename(&from, self.numbered(i + 1))?;
            }
        }
        fs::rename(&self.path, self.numbered(1))?;
        self.file =
            BufWriter::new(OpenOptions::new().create(true).append(true).open(&self.path)?);
        self.written = 0;
        self.opened_at = Instant::now();
        self.rotations += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_path(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("ddim_rotation_test_{}_{name}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir.join("access.log")
    }

    fn read_lines(p: &Path) -> Vec<String> {
        fs::read_to_string(p)
            .unwrap_or_default()
            .lines()
            .map(str::to_string)
            .collect()
    }

    #[test]
    fn no_policy_never_rotates() {
        let path = temp_path("none");
        let mut f = RotatingFile::open(&path, RotationPolicy::none()).unwrap();
        for i in 0..100 {
            f.write_line(&format!("line {i}")).unwrap();
        }
        f.flush().unwrap();
        assert_eq!(f.rotations(), 0);
        assert_eq!(read_lines(&path).len(), 100);
        assert!(!path.with_extension("log.1").exists());
    }

    #[test]
    fn size_rotation_keeps_exactly_k_files() {
        let path = temp_path("keep_k");
        let policy = RotationPolicy { max_bytes: 32, max_secs: 0, keep: 3 };
        let mut f = RotatingFile::open(&path, policy).unwrap();
        // each line is 24 bytes + newline; two fit in 32 bytes never, so
        // every second write rotates — plenty of shifts to overflow keep
        for i in 0..20 {
            f.write_line(&format!("payload-{i:04}-xxxxxxxxxx")).unwrap();
        }
        f.flush().unwrap();
        assert!(f.rotations() >= 4, "expected many rotations, got {}", f.rotations());
        assert!(path.exists());
        for i in 1..=3usize {
            assert!(
                PathBuf::from(format!("{}.{i}", path.display())).exists(),
                "missing rotated file .{i}"
            );
        }
        assert!(
            !PathBuf::from(format!("{}.4", path.display())).exists(),
            "keep=3 must delete .4"
        );
        // newest rotated file holds newer lines than the older one
        let n1 = read_lines(&PathBuf::from(format!("{}.1", path.display())));
        let n2 = read_lines(&PathBuf::from(format!("{}.2", path.display())));
        assert!(n1.last().unwrap() > n2.last().unwrap(), "{n1:?} vs {n2:?}");
    }

    #[test]
    fn oversized_single_line_still_lands() {
        let path = temp_path("oversize");
        let policy = RotationPolicy { max_bytes: 8, max_secs: 0, keep: 2 };
        let mut f = RotatingFile::open(&path, policy).unwrap();
        f.write_line("a line far larger than the whole budget").unwrap();
        f.flush().unwrap();
        assert_eq!(f.rotations(), 0, "an empty live file must never rotate");
        assert_eq!(read_lines(&path).len(), 1);
        // the next write rotates the oversized file out
        f.write_line("next").unwrap();
        f.flush().unwrap();
        assert_eq!(f.rotations(), 1);
        assert_eq!(read_lines(&path), vec!["next".to_string()]);
    }

    #[test]
    fn append_reopen_counts_existing_bytes() {
        let path = temp_path("reopen");
        let policy = RotationPolicy { max_bytes: 16, max_secs: 0, keep: 2 };
        {
            let mut f = RotatingFile::open(&path, policy).unwrap();
            f.write_line("0123456789abcd").unwrap(); // fills the budget
            f.flush().unwrap();
        }
        let mut f = RotatingFile::open(&path, policy).unwrap();
        assert_eq!(f.current_bytes(), 15);
        f.write_line("after restart").unwrap(); // must rotate first
        f.flush().unwrap();
        assert_eq!(f.rotations(), 1);
        assert_eq!(read_lines(&path), vec!["after restart".to_string()]);
    }
}
