//! Prometheus text-exposition (format 0.0.4) encoder over the serving
//! stack's existing counters, gauges, and histograms.
//!
//! Rendering rules, pinned by `rust/tests/obs_spec.rs`:
//! - every family is declared exactly once (`# HELP` + `# TYPE`) and all
//!   of its samples follow contiguously;
//! - counters are monotonic and named `*_total` (the gauge/counter split
//!   is audited in [`render`]: `connections_open` is a gauge because
//!   disconnects decrement it, `connections_total` is a counter because
//!   nothing ever does; cache `bytes`/`entries` are gauges — eviction
//!   shrinks them — while `hits`/`evictions` only grow);
//! - [`Histogram`](crate::coordinator::metrics::Histogram) exports as
//!   cumulative `_bucket{le="..."}` lines over its log buckets plus
//!   `_sum`/`_count`, with `le="+Inf"` equal to `_count`;
//! - label values escape `\`, `"`, and newline; HELP text escapes `\`
//!   and newline.
//!
//! [`validate_exposition`] is a strict parser for the same dialect —
//! the proxy for "a stock Prometheus scraper accepts this" used by the
//! spec tests and the gated bench.

use std::collections::BTreeMap;

use crate::cache::CacheMetrics;
use crate::coordinator::metrics::{Histogram, MetricsSnapshot};
use crate::coordinator::shard::ShardStats;

/// Every metric this stack exports carries this prefix.
pub const PREFIX: &str = "ddim";

/// Stride over the histogram's ~530 log buckets when exporting: one
/// `le` bound per 8 native buckets ≈ 67 bounds at ~37% spacing — dense
/// enough for quantile math, small enough to scrape every second.
pub const BUCKET_STRIDE: usize = 8;

/// Identity of this server process, exported as the classic
/// `ddim_build_info{...} 1` gauge so dashboards can correlate restarts
/// and artifact rollouts with metric discontinuities.
#[derive(Debug, Clone)]
pub struct BuildInfo {
    /// Crate version (`CARGO_PKG_VERSION`).
    pub version: &'static str,
    /// Cache key schema version ([`crate::cache::key`]).
    pub key_version: u8,
    /// Digest of the artifact manifest requests are being keyed against
    /// (0 when the cache front is inert).
    pub manifest_digest: u64,
    /// Seconds since the server started.
    pub uptime_s: f64,
}

/// Transport-layer counters/gauges as the server publishes them.
/// Defined here (rather than borrowing the server's internal stats
/// struct) so the encoder states which of these are monotonic: all of
/// them except `reactors` and `connections_open`.
#[derive(Debug, Clone, Copy, Default)]
pub struct TransportCounters {
    /// Gauge: configured reactor threads.
    pub reactors: u64,
    /// Counter: connections ever accepted.
    pub connections_total: u64,
    /// Gauge: connections open right now.
    pub connections_open: u64,
    /// Counter: accept() failures.
    pub accept_errors: u64,
    /// Counter: reactor wakeup pipe signals.
    pub wakeups: u64,
    /// Counter: streamed preview frames queued.
    pub frames_streamed: u64,
    /// Counter: preview frames dropped at the write buffer cap.
    pub frames_dropped: u64,
    /// Counter: request lines rejected for exceeding the length bound.
    pub lines_overlong: u64,
    /// Counter: socket writes that flushed more than one queued line.
    pub writes_coalesced: u64,
}

/// The observability layer's own health counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct ObsSelf {
    pub access_log_enabled: bool,
    /// Counter: access-log lines durably written.
    pub lines_written: u64,
    /// Counter: access-log lines dropped at the bounded channel.
    pub lines_dropped: u64,
    /// Counter: requests picked by `--trace-sample` (explicit
    /// `"trace":true` requests are not counted here).
    pub traces_sampled: u64,
}

/// Incremental exposition builder: declare a family, then emit its
/// samples. Keeps families contiguous by construction; a debug assert
/// catches double declaration.
pub struct PromText {
    out: String,
    declared: Vec<String>,
}

impl Default for PromText {
    fn default() -> Self {
        Self::new()
    }
}

impl PromText {
    pub fn new() -> Self {
        PromText { out: String::with_capacity(8 << 10), declared: Vec::new() }
    }

    /// Declare a family: `# HELP` + `# TYPE`. `kind` is `counter`,
    /// `gauge`, or `histogram`. All of the family's samples must be
    /// emitted before the next `family` call.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        debug_assert!(
            !self.declared.iter().any(|d| d == name),
            "family {name} declared twice"
        );
        self.declared.push(name.to_string());
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(&escape_help(help));
        self.out.push('\n');
        self.out.push_str("# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    /// One sample line: `name{labels} value`.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(&escape_label_value(v));
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&fmt_value(value));
        self.out.push('\n');
    }

    /// Declare + emit a full histogram family from cumulative
    /// `(upper_bound, cumulative_count)` pairs: `_bucket` lines (with a
    /// final `le="+Inf"` equal to `count`), `_sum`, `_count`. `labels`
    /// are attached to every line (the `le` label is appended last).
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        cumulative: &[(f64, u64)],
        sum: f64,
        count: u64,
    ) {
        self.family(name, "histogram", help);
        let bucket = format!("{name}_bucket");
        let mut with_le: Vec<(&str, &str)> = labels.to_vec();
        for &(ub, cum) in cumulative {
            let le = fmt_value(ub);
            with_le.push(("le", &le));
            self.sample(&bucket, &with_le, cum as f64);
            with_le.pop();
        }
        with_le.push(("le", "+Inf"));
        self.sample(&bucket, &with_le, count as f64);
        self.sample(&format!("{name}_sum"), labels, sum);
        self.sample(&format!("{name}_count"), labels, count as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Escape a label value per the exposition format: `\` → `\\`,
/// `"` → `\"`, newline → `\n`.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Exposition-format float: integral values (counters) print without a
/// decimal point; everything else uses Rust's shortest round-trip form.
fn fmt_value(v: f64) -> String {
    if v.is_infinite() {
        return if v > 0.0 { "+Inf".into() } else { "-Inf".into() };
    }
    if v.is_nan() {
        return "NaN".into();
    }
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Update-kernel label values, indexed like
/// [`MetricsSnapshot::kernel_steps`] (i.e. `SamplerKind::index`).
pub const KERNEL_NAMES: [&str; 3] = ["ddim", "pf_ode", "ab2"];

/// Render the complete scrape: build info, merged engine families,
/// the latency histogram, per-shard families (`shard`/`dataset`
/// labels), cache, transport, and the observability layer's own
/// counters.
pub fn render(
    build: &BuildInfo,
    agg: &MetricsSnapshot,
    latency: &Histogram,
    shards: &[ShardStats],
    cache: &CacheMetrics,
    transport: &TransportCounters,
    obs: &ObsSelf,
) -> String {
    let mut p = PromText::new();

    // --- identity -------------------------------------------------------
    let digest = format!("{:016x}", build.manifest_digest);
    p.family(
        "ddim_build_info",
        "gauge",
        "Constant 1, labeled with crate version, cache key schema version, and manifest digest.",
    );
    let kv = format!("{}", build.key_version);
    p.sample(
        "ddim_build_info",
        &[("version", build.version), ("key_version", &kv), ("manifest_digest", &digest)],
        1.0,
    );
    p.family("ddim_uptime_seconds", "gauge", "Seconds since the server started.");
    p.sample("ddim_uptime_seconds", &[], build.uptime_s);

    // --- merged engine counters ----------------------------------------
    let c: &[(&str, &str, f64)] = &[
        (
            "ddim_requests_completed_total",
            "Requests answered with a successful sample.",
            agg.requests_completed as f64,
        ),
        (
            "ddim_requests_rejected_total",
            "Requests answered with an error or typed rejection.",
            agg.requests_rejected as f64,
        ),
        (
            "ddim_deadline_expired_total",
            "Requests cancelled because their deadline expired.",
            agg.deadline_expired as f64,
        ),
        (
            "ddim_requests_degraded_total",
            "Best-effort requests whose step budget was shed by the degradation ladder.",
            agg.requests_degraded as f64,
        ),
        ("ddim_lanes_completed_total", "Sample lanes completed.", agg.lanes_completed as f64),
        (
            "ddim_executable_calls_total",
            "Device executable invocations.",
            agg.executable_calls as f64,
        ),
        ("ddim_steps_executed_total", "Denoising steps executed.", agg.steps_executed as f64),
        ("ddim_ticks_total", "Engine ticks that executed at least one sub-batch.", agg.ticks as f64),
        (
            "ddim_sub_batches_total",
            "Sub-batch device calls issued by the tick planner.",
            agg.sub_batches as f64,
        ),
        (
            "ddim_padded_lanes_total",
            "Dead padding lane-slots executed.",
            agg.padded_lanes as f64,
        ),
        (
            "ddim_queue_accepted_total",
            "Requests the admission queue accepted.",
            agg.queue_accepted as f64,
        ),
        (
            "ddim_queue_rejected_items_total",
            "Admissions rejected at the queue item cap.",
            agg.queue_rejected_items as f64,
        ),
        (
            "ddim_queue_rejected_lanes_total",
            "Admissions rejected at the queue lane budget.",
            agg.queue_rejected_lanes as f64,
        ),
        (
            "ddim_pipeline_wait_seconds_total",
            "Engine-thread seconds blocked on device completions.",
            agg.pipeline_wait_s,
        ),
        (
            "ddim_device_busy_seconds_total",
            "Seconds the execution path spent running sub-batches.",
            agg.device_busy_s,
        ),
        (
            "ddim_ref_compute_seconds_total",
            "Seconds inside the reference step kernel proper.",
            agg.ref_compute_s,
        ),
        (
            "ddim_ref_bytes_allocated_total",
            "Reference-backend bytes freshly allocated by step execution.",
            agg.ref_bytes_allocated as f64,
        ),
    ];
    for &(name, help, v) in c {
        p.family(name, "counter", help);
        p.sample(name, &[], v);
    }

    p.family(
        "ddim_steps_kernel_total",
        "counter",
        "Denoising steps executed, by update kernel.",
    );
    for (i, &k) in KERNEL_NAMES.iter().enumerate() {
        p.sample("ddim_steps_kernel_total", &[("kernel", k)], agg.kernel_steps[i] as f64);
    }

    // --- merged engine gauges ------------------------------------------
    let g: &[(&str, &str, f64)] = &[
        (
            "ddim_queue_depth",
            "Requests sitting in the admission queue right now.",
            agg.queue_depth as f64,
        ),
        ("ddim_queued_lanes", "Lanes queued but not yet admitted.", agg.queued_lanes as f64),
        ("ddim_active_lanes", "Lanes resident in the engines.", agg.active_lanes as f64),
        ("ddim_occupancy", "Mean occupied-lane fraction per executable call.", agg.occupancy()),
        (
            "ddim_padding_waste",
            "Fraction of executed lane-slots that were inert padding.",
            agg.padding_waste(),
        ),
        (
            "ddim_ref_bytes_last_tick",
            "Reference-backend bytes allocated by the most recent working tick.",
            agg.ref_bytes_last_tick as f64,
        ),
    ];
    for &(name, help, v) in g {
        p.family(name, "gauge", help);
        p.sample(name, &[], v);
    }

    // --- merged latency histogram --------------------------------------
    p.histogram(
        "ddim_request_latency_seconds",
        "Request latency, transport arrival to completion (log-bucketed).",
        &[],
        &latency.cumulative(BUCKET_STRIDE),
        latency.sum(),
        latency.count(),
    );

    // --- per-shard families --------------------------------------------
    let shard_counters: &[(&str, &str, fn(&MetricsSnapshot) -> f64)] = &[
        ("ddim_shard_requests_completed_total", "Per-shard requests completed.", |s| {
            s.requests_completed as f64
        }),
        ("ddim_shard_requests_rejected_total", "Per-shard requests rejected.", |s| {
            s.requests_rejected as f64
        }),
        ("ddim_shard_deadline_expired_total", "Per-shard deadline cancellations.", |s| {
            s.deadline_expired as f64
        }),
        ("ddim_shard_steps_executed_total", "Per-shard denoising steps executed.", |s| {
            s.steps_executed as f64
        }),
        ("ddim_shard_executable_calls_total", "Per-shard executable invocations.", |s| {
            s.executable_calls as f64
        }),
    ];
    let shard_gauges: &[(&str, &str, fn(&MetricsSnapshot) -> f64)] = &[
        ("ddim_shard_active_lanes", "Per-shard lanes resident in the engine.", |s| {
            s.active_lanes as f64
        }),
        ("ddim_shard_queued_lanes", "Per-shard lanes queued for admission.", |s| {
            s.queued_lanes as f64
        }),
        ("ddim_shard_queue_depth", "Per-shard admission queue depth.", |s| {
            s.queue_depth as f64
        }),
        ("ddim_shard_occupancy", "Per-shard mean occupied-lane fraction.", |s| s.occupancy()),
    ];
    for &(name, help, get) in shard_counters {
        p.family(name, "counter", help);
        for sh in shards {
            let id = format!("{}", sh.shard_id);
            p.sample(name, &[("shard", &id), ("dataset", &sh.dataset)], get(&sh.snapshot));
        }
    }
    for &(name, help, get) in shard_gauges {
        p.family(name, "gauge", help);
        for sh in shards {
            let id = format!("{}", sh.shard_id);
            p.sample(name, &[("shard", &id), ("dataset", &sh.dataset)], get(&sh.snapshot));
        }
    }

    // --- cache ----------------------------------------------------------
    // counter/gauge audit: hits/misses/coalesced/bypassed/evictions only
    // ever grow; bytes/entries/inflight shrink on eviction and flight
    // completion, so they are gauges.
    let cc: &[(&str, &str, u64)] = &[
        ("ddim_cache_hits_total", "Completed-sample cache hits.", cache.hits),
        ("ddim_cache_misses_total", "Cache misses that dispatched an execution.", cache.misses),
        (
            "ddim_cache_coalesced_waiters_total",
            "Requests parked behind an identical in-flight execution.",
            cache.coalesced_waiters,
        ),
        ("ddim_cache_bypassed_total", "Requests that bypassed the cache.", cache.bypassed),
        ("ddim_cache_evictions_total", "Entries evicted by the byte budget.", cache.evictions),
    ];
    for &(name, help, v) in cc {
        p.family(name, "counter", help);
        p.sample(name, &[], v as f64);
    }
    let cg: &[(&str, &str, f64)] = &[
        ("ddim_cache_enabled", "1 when the completed-sample store is on.", cache.enabled as u64 as f64),
        (
            "ddim_cache_coalesce_enabled",
            "1 when single-flight coalescing is on.",
            cache.coalesce_enabled as u64 as f64,
        ),
        ("ddim_cache_bytes", "Bytes held by the completed-sample store.", cache.bytes as f64),
        ("ddim_cache_capacity_bytes", "Store byte budget.", cache.capacity_bytes as f64),
        ("ddim_cache_entries", "Completed samples resident.", cache.entries as f64),
        ("ddim_cache_inflight", "In-flight placeholders pinned.", cache.inflight as f64),
    ];
    for &(name, help, v) in cg {
        p.family(name, "gauge", help);
        p.sample(name, &[], v);
    }

    // --- transport ------------------------------------------------------
    let tc: &[(&str, &str, u64)] = &[
        ("ddim_connections_total", "Connections ever accepted.", transport.connections_total),
        ("ddim_accept_errors_total", "accept() failures.", transport.accept_errors),
        ("ddim_wakeups_total", "Reactor wakeup signals.", transport.wakeups),
        ("ddim_frames_streamed_total", "Preview frames queued.", transport.frames_streamed),
        (
            "ddim_frames_dropped_total",
            "Preview frames dropped at the write buffer cap.",
            transport.frames_dropped,
        ),
        (
            "ddim_lines_overlong_total",
            "Request lines rejected for exceeding the length bound.",
            transport.lines_overlong,
        ),
        (
            "ddim_writes_coalesced_total",
            "Socket writes that flushed more than one queued line.",
            transport.writes_coalesced,
        ),
    ];
    for &(name, help, v) in tc {
        p.family(name, "counter", help);
        p.sample(name, &[], v as f64);
    }
    p.family("ddim_reactors", "gauge", "Configured reactor event-loop threads.");
    p.sample("ddim_reactors", &[], transport.reactors as f64);
    p.family("ddim_connections_open", "gauge", "Connections open right now.");
    p.sample("ddim_connections_open", &[], transport.connections_open as f64);

    // --- observability self-counters -----------------------------------
    p.family("ddim_access_log_enabled", "gauge", "1 when the access log is writing.");
    p.sample("ddim_access_log_enabled", &[], obs.access_log_enabled as u64 as f64);
    p.family(
        "ddim_access_log_lines_total",
        "counter",
        "Access-log lines durably written.",
    );
    p.sample("ddim_access_log_lines_total", &[], obs.lines_written as f64);
    p.family(
        "ddim_access_log_dropped_total",
        "counter",
        "Access-log lines dropped at the bounded writer channel.",
    );
    p.sample("ddim_access_log_dropped_total", &[], obs.lines_dropped as f64);
    p.family(
        "ddim_traces_sampled_total",
        "counter",
        "Requests picked for span tracing by --trace-sample.",
    );
    p.sample("ddim_traces_sampled_total", &[], obs.traces_sampled as f64);

    p.finish()
}

// ---------------------------------------------------------------------------
// strict exposition parser — the spec tests' stand-in for a stock scraper
// ---------------------------------------------------------------------------

#[derive(Default)]
struct FamilyState {
    kind: String,
    help_seen: bool,
    type_seen: bool,
    /// histogram accumulation keyed by the non-`le` label set
    buckets: BTreeMap<String, Vec<(String, f64)>>,
    sums: BTreeMap<String, f64>,
    counts: BTreeMap<String, f64>,
}

/// Validate a complete scrape body against the text exposition format:
/// metric/label name syntax, label escaping, HELP/TYPE exactly once per
/// family with all samples contiguous, histogram buckets cumulative
/// with `le="+Inf"` == `_count`. Returns the first violation.
pub fn validate_exposition(text: &str) -> Result<(), String> {
    let mut current: Option<(String, FamilyState)> = None;
    let mut sealed: Vec<String> = Vec::new();

    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        let err = |msg: String| Err(format!("line {}: {msg} [{line}]", ln + 1));
        if let Some(rest) = line.strip_prefix("# ") {
            let (what, rest) = match rest.split_once(' ') {
                Some(p) => p,
                None => return err("malformed comment".into()),
            };
            if what != "HELP" && what != "TYPE" {
                continue; // arbitrary comments are legal
            }
            let (name, payload) = match rest.split_once(' ') {
                Some(p) => p,
                None => return err(format!("{what} without payload")),
            };
            if !valid_metric_name(name) {
                return err(format!("bad family name {name:?}"));
            }
            let switching = current.as_ref().map(|(n, _)| n != name).unwrap_or(true);
            if switching {
                if let Some((prev, st)) = current.take() {
                    finish_family(&prev, &st)?;
                    sealed.push(prev);
                }
                if sealed.iter().any(|s| s == name) {
                    return err(format!("family {name} re-opened (samples not contiguous)"));
                }
                current = Some((name.to_string(), FamilyState::default()));
            }
            let (_, st) = current.as_mut().unwrap();
            match what {
                "HELP" => {
                    if st.help_seen {
                        return err(format!("duplicate HELP for {name}"));
                    }
                    st.help_seen = true;
                }
                _ => {
                    if st.type_seen {
                        return err(format!("duplicate TYPE for {name}"));
                    }
                    st.type_seen = true;
                    if !["counter", "gauge", "histogram", "summary", "untyped"]
                        .contains(&payload)
                    {
                        return err(format!("unknown TYPE {payload:?}"));
                    }
                    st.kind = payload.to_string();
                }
            }
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        // sample line: name[{labels}] value
        let (name, labels, value) = parse_sample(line).map_err(|e| {
            format!("line {}: {e} [{line}]", ln + 1)
        })?;
        let Some((fam, st)) = current.as_mut() else {
            return err(format!("sample {name} before any family declaration"));
        };
        let base = if st.kind == "histogram" {
            name.strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_sum"))
                .or_else(|| name.strip_suffix("_count"))
                .unwrap_or(&name)
        } else {
            &name
        };
        if base != fam {
            return err(format!("sample {name} inside family {fam}"));
        }
        if st.kind == "histogram" {
            let mut le = None;
            let mut rest = Vec::new();
            for (k, v) in &labels {
                if k == "le" {
                    le = Some(v.clone());
                } else {
                    rest.push(format!("{k}={v}"));
                }
            }
            let group = rest.join(",");
            if name.ends_with("_bucket") {
                let le = le.ok_or_else(|| {
                    format!("line {}: bucket without le label [{line}]", ln + 1)
                })?;
                st.buckets.entry(group).or_default().push((le, value));
            } else if name.ends_with("_sum") {
                st.sums.insert(group, value);
            } else if name.ends_with("_count") {
                st.counts.insert(group, value);
            } else {
                return err(format!("bare sample {name} in histogram family"));
            }
        }
    }
    if let Some((prev, st)) = current.take() {
        finish_family(&prev, &st)?;
    }
    Ok(())
}

fn finish_family(name: &str, st: &FamilyState) -> Result<(), String> {
    if !st.help_seen || !st.type_seen {
        return Err(format!("family {name}: missing HELP or TYPE"));
    }
    if st.kind != "histogram" {
        return Ok(());
    }
    for (group, buckets) in &st.buckets {
        let mut prev = f64::NEG_INFINITY;
        let mut prev_cum = 0.0;
        let mut inf = None;
        for (le, cum) in buckets {
            let bound: f64 = le
                .parse()
                .map_err(|_| format!("{name}{{{group}}}: unparseable le {le:?}"))?;
            if bound <= prev {
                return Err(format!("{name}{{{group}}}: le bounds not increasing at {le}"));
            }
            if *cum < prev_cum {
                return Err(format!(
                    "{name}{{{group}}}: buckets not cumulative at le={le} ({cum} < {prev_cum})"
                ));
            }
            prev = bound;
            prev_cum = *cum;
            if bound.is_infinite() {
                inf = Some(*cum);
            }
        }
        let inf = inf.ok_or_else(|| format!("{name}{{{group}}}: no le=\"+Inf\" bucket"))?;
        let count = st
            .counts
            .get(group)
            .ok_or_else(|| format!("{name}{{{group}}}: missing _count"))?;
        if (inf - count).abs() > 0.0 {
            return Err(format!("{name}{{{group}}}: +Inf bucket {inf} != _count {count}"));
        }
        if !st.sums.contains_key(group) {
            return Err(format!("{name}{{{group}}}: missing _sum"));
        }
    }
    Ok(())
}

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

type Sample = (String, Vec<(String, String)>, f64);

fn parse_sample(line: &str) -> Result<Sample, String> {
    let bytes = line.as_bytes();
    let name_end = bytes
        .iter()
        .position(|&b| b == b'{' || b == b' ')
        .ok_or("sample with no value")?;
    let name = &line[..name_end];
    if !valid_metric_name(name) {
        return Err(format!("bad metric name {name:?}"));
    }
    let mut labels = Vec::new();
    let mut i = name_end;
    if bytes[i] == b'{' {
        i += 1;
        loop {
            if i >= bytes.len() {
                return Err("unterminated label set".into());
            }
            if bytes[i] == b'}' {
                i += 1;
                break;
            }
            let eq = line[i..]
                .find('=')
                .ok_or("label without =")?
                + i;
            let lname = &line[i..eq];
            if !valid_label_name(lname) {
                return Err(format!("bad label name {lname:?}"));
            }
            if bytes.get(eq + 1) != Some(&b'"') {
                return Err("label value not quoted".into());
            }
            let mut j = eq + 2;
            let mut val = String::new();
            loop {
                match bytes.get(j) {
                    None => return Err("unterminated label value".into()),
                    Some(b'\\') => {
                        match bytes.get(j + 1) {
                            Some(b'\\') => val.push('\\'),
                            Some(b'"') => val.push('"'),
                            Some(b'n') => val.push('\n'),
                            other => return Err(format!("bad escape {other:?}")),
                        }
                        j += 2;
                    }
                    Some(b'"') => {
                        j += 1;
                        break;
                    }
                    Some(&b) => {
                        val.push(b as char);
                        j += 1;
                    }
                }
            }
            labels.push((lname.to_string(), val));
            i = j;
            if bytes.get(i) == Some(&b',') {
                i += 1;
            }
        }
    }
    if bytes.get(i) != Some(&b' ') {
        return Err("no space before value".into());
    }
    let value_str = line[i + 1..].trim();
    let value: f64 = match value_str {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        "NaN" => f64::NAN,
        s => s.parse().map_err(|_| format!("unparseable value {s:?}"))?,
    };
    Ok((name.to_string(), labels, value))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label_value(r#"a\b"c"#), r#"a\\b\"c"#);
        assert_eq!(escape_label_value("two\nlines"), "two\\nlines");
        assert_eq!(escape_label_value("plain"), "plain");
    }

    #[test]
    fn value_formatting() {
        assert_eq!(fmt_value(42.0), "42");
        assert_eq!(fmt_value(0.0), "0");
        assert_eq!(fmt_value(0.125), "0.125");
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(f64::NEG_INFINITY), "-Inf");
        assert_eq!(fmt_value(f64::NAN), "NaN");
        // a parser must round-trip what we print
        assert_eq!("0.30000000000000004".parse::<f64>().unwrap(), 0.1 + 0.2);
        assert_eq!(fmt_value(0.1 + 0.2), "0.30000000000000004");
    }

    #[test]
    fn families_and_samples_render_contiguously() {
        let mut p = PromText::new();
        p.family("ddim_x_total", "counter", "An x.");
        p.sample("ddim_x_total", &[], 3.0);
        p.family("ddim_y", "gauge", "A y with\nnewline help.");
        p.sample("ddim_y", &[("shard", "0"), ("dataset", "spri\"tes")], 0.5);
        let text = p.finish();
        assert!(text.contains("# HELP ddim_x_total An x.\n"));
        assert!(text.contains("# TYPE ddim_x_total counter\n"));
        assert!(text.contains("ddim_x_total 3\n"));
        assert!(text.contains("A y with\\nnewline help."));
        assert!(text.contains(r#"ddim_y{shard="0",dataset="spri\"tes"} 0.5"#));
        validate_exposition(&text).unwrap();
    }

    #[test]
    fn histogram_renders_cumulative_with_inf() {
        let mut p = PromText::new();
        p.histogram(
            "ddim_lat_seconds",
            "latency",
            &[],
            &[(0.001, 2), (0.01, 5), (0.1, 9)],
            1.234,
            9,
        );
        let text = p.finish();
        assert!(text.contains(r#"ddim_lat_seconds_bucket{le="0.001"} 2"#));
        assert!(text.contains(r#"ddim_lat_seconds_bucket{le="+Inf"} 9"#));
        assert!(text.contains("ddim_lat_seconds_sum 1.234\n"));
        assert!(text.contains("ddim_lat_seconds_count 9\n"));
        validate_exposition(&text).unwrap();
    }

    #[test]
    fn validator_rejects_malformed_expositions() {
        // duplicate TYPE
        let dup = "# HELP a_total h\n# TYPE a_total counter\n# TYPE a_total counter\na_total 1\n";
        assert!(validate_exposition(dup).unwrap_err().contains("duplicate TYPE"));
        // family re-opened after another began
        let split = "# HELP a h\n# TYPE a gauge\na 1\n# HELP b h\n# TYPE b gauge\nb 1\n# HELP a h\n# TYPE a gauge\na 2\n";
        assert!(validate_exposition(split).unwrap_err().contains("re-opened"));
        // non-cumulative histogram buckets
        let noncum = "# HELP h_s x\n# TYPE h_s histogram\nh_s_bucket{le=\"0.1\"} 5\nh_s_bucket{le=\"1\"} 3\nh_s_bucket{le=\"+Inf\"} 5\nh_s_sum 1\nh_s_count 5\n";
        assert!(validate_exposition(noncum).unwrap_err().contains("not cumulative"));
        // +Inf != count
        let inf = "# HELP h_s x\n# TYPE h_s histogram\nh_s_bucket{le=\"+Inf\"} 4\nh_s_sum 1\nh_s_count 5\n";
        assert!(validate_exposition(inf).unwrap_err().contains("+Inf"));
        // sample from a foreign family
        let foreign = "# HELP a h\n# TYPE a gauge\nother 1\n";
        assert!(validate_exposition(foreign).unwrap_err().contains("inside family"));
        // bad metric name
        assert!(validate_exposition("# HELP 9bad h\n# TYPE 9bad gauge\n").is_err());
        // missing TYPE
        let nohelp = "# HELP a h\na 1\n";
        assert!(validate_exposition(nohelp).unwrap_err().contains("missing HELP or TYPE"));
    }

    #[test]
    fn full_render_validates_and_covers_every_family_kind() {
        let mut latency = Histogram::new();
        for i in 1..=100 {
            latency.record(i as f64 * 1e-3);
        }
        let mut snap = MetricsSnapshot::default();
        snap.requests_completed = 100;
        snap.steps_executed = 2000;
        snap.kernel_steps = [1500, 400, 100];
        snap.executable_calls = 40;
        snap.occupancy_sum = 30.0;
        let shards = vec![
            ShardStats {
                shard_id: 0,
                dataset: "sprites".into(),
                snapshot: snap.clone(),
                latency: latency.clone(),
            },
            ShardStats {
                shard_id: 1,
                dataset: "checkerboard".into(),
                snapshot: snap.clone(),
                latency: latency.clone(),
            },
        ];
        let build = BuildInfo {
            version: "0.4.0",
            key_version: 3,
            manifest_digest: 0xdead_beef,
            uptime_s: 12.5,
        };
        let cache = CacheMetrics { hits: 5, misses: 7, bytes: 1024, ..Default::default() };
        let transport =
            TransportCounters { reactors: 2, connections_total: 9, ..Default::default() };
        let obs = ObsSelf {
            access_log_enabled: true,
            lines_written: 99,
            lines_dropped: 1,
            traces_sampled: 6,
        };
        let text = render(&build, &snap, &latency, &shards, &cache, &transport, &obs);
        validate_exposition(&text).unwrap();
        for needle in [
            "ddim_build_info{version=\"0.4.0\",key_version=\"3\",manifest_digest=\"00000000deadbeef\"} 1",
            "ddim_requests_completed_total 100",
            "ddim_steps_kernel_total{kernel=\"pf_ode\"} 400",
            "ddim_request_latency_seconds_count 100",
            "ddim_shard_requests_completed_total{shard=\"1\",dataset=\"checkerboard\"} 100",
            "ddim_cache_hits_total 5",
            "ddim_cache_bytes 1024",
            "ddim_connections_total 9",
            "ddim_access_log_dropped_total 1",
            "ddim_traces_sampled_total 6",
        ] {
            assert!(text.contains(needle), "scrape missing: {needle}\n---\n{text}");
        }
        // counters all end in _total (the monotonicity audit's naming half)
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let (name, kind) = rest.split_once(' ').unwrap();
                if kind == "counter" {
                    assert!(name.ends_with("_total"), "counter {name} not *_total");
                }
            }
        }
    }
}
