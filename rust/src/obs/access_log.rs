//! Structured access logs: one JSON line per completed request, written
//! through a dedicated writer thread behind a bounded channel.
//!
//! The transport's completion path calls [`AccessLogger::log`] — a
//! `try_send` that **never blocks a reactor**: when the writer falls
//! behind (slow disk, rotation storm) lines are dropped and counted
//! instead of back-pressuring the event loop. Durability is best-effort
//! by design; the drop counter is exported so the gap is observable.
//!
//! Rotation policy and file shifting live in [`super::rotation`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::jobj;
use crate::json::{self, Value};

use super::rotation::{RotatingFile, RotationPolicy};
use super::Spans;

/// Bounded writer-channel depth: at ~300 bytes a line this is ~1.2 MiB
/// of backlog before drops start — enough to ride out a rotation shift
/// without ever blocking the transport.
pub const CHANNEL_CAPACITY: usize = 4096;

enum Msg {
    Line(String),
    Shutdown,
}

/// Everything one access-log line records about a completed request.
/// The transport fills it from the wire request (pre-submit clones) and
/// the [`crate::coordinator::request::Response`] that answered it.
#[derive(Debug, Clone)]
pub struct AccessRecord {
    /// Wire id (client-supplied, any JSON value) or the engine id.
    pub id: Value,
    pub op: &'static str,
    pub dataset: String,
    pub lanes: usize,
    /// Step budget the client asked for (pre-degradation).
    pub steps_requested: usize,
    /// Steps the answering execution actually ran (0 on reject/error).
    pub steps_executed: usize,
    pub sampler: &'static str,
    pub tau: &'static str,
    pub priority: &'static str,
    pub deadline_ms: Option<u64>,
    /// `"ok"`, `"reject"`, or `"error"`.
    pub outcome: &'static str,
    /// `"overload"` / `"deadline"` when outcome is `"reject"`.
    pub reject_reason: Option<&'static str>,
    /// Cache disposition: `"hit"`, `"miss"`, `"coalesced"`, `"bypass"`.
    pub cache: &'static str,
    /// Degradation record `(from, to)` when the step budget was shed.
    pub degraded: Option<(usize, usize)>,
    /// Engine-observed latency (arrival → completion), seconds.
    pub latency_s: f64,
    /// Arrival → response-bytes-queued at the transport, seconds.
    pub total_s: f64,
    /// Serialized response-line bytes queued to the socket.
    pub bytes_out: usize,
    /// Stage spans, present for traced (sampled or explicit) requests.
    pub spans: Option<Spans>,
}

impl AccessRecord {
    pub fn to_json(&self) -> Value {
        let mut v = jobj![
            ("id", self.id.clone()),
            ("op", self.op),
            ("dataset", self.dataset.as_str()),
            ("lanes", self.lanes),
            ("steps_requested", self.steps_requested),
            ("steps_executed", self.steps_executed),
            ("sampler", self.sampler),
            ("tau", self.tau),
            ("priority", self.priority),
            ("outcome", self.outcome),
            ("cache", self.cache),
            ("latency_s", self.latency_s),
            ("total_s", self.total_s),
            ("bytes_out", self.bytes_out),
        ];
        if let Some(ms) = self.deadline_ms {
            let _ = v.set("deadline_ms", Value::from(ms));
        }
        if let Some(r) = self.reject_reason {
            let _ = v.set("reject_reason", Value::from(r));
        }
        if let Some((from, to)) = self.degraded {
            let _ = v.set("degraded", jobj![("from", from), ("to", to)]);
        }
        if let Some(s) = &self.spans {
            let _ = v.set("spans", s.to_json());
        }
        v
    }

    /// The line that lands in the log (no trailing newline).
    pub fn to_json_line(&self) -> String {
        json::to_string(&self.to_json())
    }
}

/// Handle to the writer thread. Cheap to share (`Arc`); `log` is
/// lock-free on the hot path (`SyncSender::try_send` + relaxed
/// counters).
pub struct AccessLogger {
    tx: SyncSender<Msg>,
    written: Arc<AtomicU64>,
    dropped: AtomicU64,
    handle: Mutex<Option<JoinHandle<()>>>,
}

impl AccessLogger {
    /// Open the log file (erroring loudly at startup, not on the first
    /// request) and spawn the writer thread.
    pub fn start(path: &str, policy: RotationPolicy) -> std::io::Result<Self> {
        let sink = RotatingFile::open(path, policy)?;
        let (tx, rx) = sync_channel(CHANNEL_CAPACITY);
        let written = Arc::new(AtomicU64::new(0));
        let w = Arc::clone(&written);
        let handle = std::thread::Builder::new()
            .name("access-log".into())
            .spawn(move || writer_loop(rx, sink, w))
            .map_err(|e| std::io::Error::other(format!("spawn access-log writer: {e}")))?;
        Ok(Self {
            tx,
            written,
            dropped: AtomicU64::new(0),
            handle: Mutex::new(Some(handle)),
        })
    }

    /// Enqueue one record. Never blocks: a full channel (or a logger
    /// already shut down) drops the line and bumps the drop counter.
    pub fn log(&self, record: &AccessRecord) {
        self.log_line(record.to_json_line());
    }

    /// Enqueue one pre-serialized line (no trailing newline).
    pub fn log_line(&self, line: String) {
        match self.tx.try_send(Msg::Line(line)) {
            Ok(()) => {}
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Lines durably written by the writer thread.
    pub fn lines_written(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }

    /// Lines dropped because the channel was full.
    pub fn lines_dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Drain everything queued so far, flush, and join the writer.
    /// Idempotent; called by `Server::shutdown` after the reactors have
    /// joined (so nothing can race new lines in).
    pub fn shutdown(&self) {
        // a full channel here means the writer is alive and draining —
        // block until the sentinel fits so queued lines are not lost
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for AccessLogger {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn writer_loop(rx: Receiver<Msg>, mut sink: RotatingFile, written: Arc<AtomicU64>) {
    loop {
        match rx.recv() {
            Ok(Msg::Line(line)) => {
                if sink.write_line(&line).is_ok() {
                    written.fetch_add(1, Ordering::Relaxed);
                }
            }
            Ok(Msg::Shutdown) | Err(_) => break,
        }
    }
    let _ = sink.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record() -> AccessRecord {
        AccessRecord {
            id: Value::from(7u64),
            op: "generate",
            dataset: "sprites".into(),
            lanes: 2,
            steps_requested: 100,
            steps_executed: 20,
            sampler: "ddim",
            tau: "opt",
            priority: "best_effort",
            deadline_ms: Some(250),
            outcome: "ok",
            reject_reason: None,
            cache: "miss",
            degraded: Some((100, 20)),
            latency_s: 0.125,
            total_s: 0.126,
            bytes_out: 64,
            spans: Some(Spans { queue_s: 0.01, total_s: 0.126, ..Default::default() }),
        }
    }

    #[test]
    fn record_round_trips_through_the_json_parser() {
        let line = record().to_json_line();
        let v = json::parse(&line).expect("access-log line must be valid JSON");
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "generate");
        assert_eq!(v.get("dataset").unwrap().as_str().unwrap(), "sprites");
        assert_eq!(v.get("steps_requested").unwrap().as_usize().unwrap(), 100);
        assert_eq!(v.get("steps_executed").unwrap().as_usize().unwrap(), 20);
        assert_eq!(v.get("cache").unwrap().as_str().unwrap(), "miss");
        assert_eq!(v.get("deadline_ms").unwrap().as_u64().unwrap(), 250);
        let d = v.get("degraded").unwrap();
        assert_eq!(d.get("from").unwrap().as_usize().unwrap(), 100);
        assert_eq!(d.get("to").unwrap().as_usize().unwrap(), 20);
        assert!(v.get("spans").unwrap().get("queue_s").is_ok());
        assert!(v.get_opt("reject_reason").is_none());
    }

    #[test]
    fn reject_record_omits_success_only_fields() {
        let mut r = record();
        r.outcome = "reject";
        r.reject_reason = Some("deadline");
        r.degraded = None;
        r.spans = None;
        let v = json::parse(&r.to_json_line()).unwrap();
        assert_eq!(v.get("outcome").unwrap().as_str().unwrap(), "reject");
        assert_eq!(v.get("reject_reason").unwrap().as_str().unwrap(), "deadline");
        assert!(v.get_opt("degraded").is_none());
        assert!(v.get_opt("spans").is_none());
    }

    #[test]
    fn logger_writes_drains_and_counts() {
        let dir = std::env::temp_dir()
            .join(format!("ddim_access_log_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("access.log");
        let logger =
            AccessLogger::start(path.to_str().unwrap(), RotationPolicy::none()).unwrap();
        for _ in 0..50 {
            logger.log(&record());
        }
        logger.shutdown();
        assert_eq!(logger.lines_written(), 50);
        assert_eq!(logger.lines_dropped(), 0);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 50);
        for l in lines {
            json::parse(l).expect("every line parses");
        }
        // post-shutdown logs are counted as drops, never lost silently
        logger.log_line("late".into());
        assert_eq!(logger.lines_dropped(), 1);
    }
}
