//! JSON serializer half of the [`crate::json`] substrate. Deterministic
//! (objects are `BTreeMap`s), round-trip safe for every finite f64, and
//! integral numbers print without a fractional part (so usize counters in
//! manifests and wire messages stay readable).

use super::Value;

/// Serialize a [`Value`] to a compact JSON string.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out);
    out
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_str(s, out),
        Value::Arr(items) => {
            out.push('[');
            for (i, it) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(it, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; null is the conventional degradation.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // {:?} on f64 is the shortest representation that round-trips.
        out.push_str(&format!("{n:?}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;
    use crate::jobj;

    #[test]
    fn integral_numbers_have_no_fraction() {
        assert_eq!(to_string(&Value::Num(42.0)), "42");
        assert_eq!(to_string(&Value::Num(-3.0)), "-3");
    }

    #[test]
    fn floats_round_trip() {
        for x in [0.1, 1.0 / 3.0, 1e-300, std::f64::consts::PI, -2.5e17] {
            let s = to_string(&Value::Num(x));
            assert_eq!(parse(&s).unwrap().as_f64().unwrap(), x, "{s}");
        }
    }

    #[test]
    fn nan_degrades_to_null() {
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
    }

    #[test]
    fn escapes() {
        let v = Value::Str("a\"b\\c\nd\u{1}".into());
        let s = to_string(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn object_round_trip_is_deterministic() {
        let v = jobj![("b", 1.0), ("a", "x"), ("c", vec![1.0, 2.0])];
        let s1 = to_string(&v);
        let s2 = to_string(&parse(&s1).unwrap());
        assert_eq!(s1, s2);
        assert!(s1.starts_with(r#"{"a":"#)); // BTreeMap ordering
    }
}
