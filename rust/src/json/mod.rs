//! Minimal JSON substrate (the offline build has no `serde_json`): a
//! recursive-descent parser and a serializer over a single [`Value`] enum.
//!
//! Used for the artifact manifest, tensorfile sidecars, the wire protocol of
//! the coordinator server, and bench result dumps. Supports the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP (not needed by any of
//! our producers, which are ASCII).

mod parse;
mod ser;

pub use parse::parse;
pub use ser::to_string;

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value. Objects use `BTreeMap` so serialization is
/// deterministic (stable golden files, diffable bench dumps).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Object field access; errors mention the key for debuggability.
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m
                .get(key)
                .ok_or_else(|| Error::Json(format!("missing key '{key}'"))),
            _ => Err(Error::Json(format!("expected object looking up '{key}'"))),
        }
    }

    /// Optional object field access.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(Error::Json(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    /// Strict u64 accessor: rejects negatives, fractions, and magnitudes
    /// at or above 2^53. The parser stores numbers as f64, so anything
    /// larger has already lost bits — the old `as_f64() as u64` path
    /// silently accepted it (and saturated negatives to 0). 2^53 itself is
    /// rejected too: it is exactly representable, but it is also what
    /// 2^53 + 1 rounds to, so accepting it would silently serve a
    /// possibly-different seed than the client sent.
    pub fn as_u64(&self) -> Result<u64> {
        // 2^53: below this every integer round-trips uniquely through f64
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0;
        let n = self.as_f64()?;
        if n < 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {n}")));
        }
        if n.fract() != 0.0 {
            return Err(Error::Json(format!("expected integer, got fractional {n}")));
        }
        if n >= MAX_EXACT {
            return Err(Error::Json(format!(
                "integer {n} is not exactly representable (>= 2^53)"
            )));
        }
        Ok(n as u64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(Error::Json(format!("expected bool, got {self:?}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => Err(Error::Json(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => Err(Error::Json(format!("expected object, got {self:?}"))),
        }
    }

    /// Convenience: an array of numbers -> `Vec<f64>`.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Convenience: an array of numbers -> `Vec<usize>`.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Insert (or replace) an object field in place. Errors on non-objects
    /// — used by the transport to inject `"id"` / `"transport"` into
    /// responses built by lower layers that know nothing about wire v2.
    pub fn set(&mut self, key: &str, val: Value) -> Result<()> {
        match self {
            Value::Obj(m) => {
                m.insert(key.to_string(), val);
                Ok(())
            }
            _ => Err(Error::Json(format!("expected object setting '{key}'"))),
        }
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::Num(v as f64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Num(v as f64)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object literal: `obj![("k", 1.0), ("s", "x")]`-style helper.
#[macro_export]
macro_rules! jobj {
    ($(($k:expr, $v:expr)),* $(,)?) => {{
        let mut m = std::collections::BTreeMap::new();
        $( m.insert($k.to_string(), $crate::json::Value::from($v)); )*
        $crate::json::Value::Obj(m)
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&to_string(&v)).unwrap();
            assert_eq!(v, back, "round trip of {src}");
        }
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"a": [1, 2, 3], "b": {"c": "x"}, "n": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64_vec().unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x");
        assert!(matches!(v.get("n").unwrap(), Value::Null));
        assert!(v.get("zz").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
    }

    #[test]
    fn set_inserts_replaces_and_rejects_non_objects() {
        let mut v = parse(r#"{"a": 1}"#).unwrap();
        v.set("b", Value::from("x")).unwrap();
        v.set("a", Value::from(2.0)).unwrap();
        assert_eq!(to_string(&v), r#"{"a":2,"b":"x"}"#);
        assert!(Value::Null.set("k", Value::Bool(true)).is_err());
    }

    #[test]
    fn as_usize_rejects_fractional_and_negative() {
        assert!(parse("1.5").unwrap().as_usize().is_err());
        assert!(parse("-2").unwrap().as_usize().is_err());
        assert_eq!(parse("42").unwrap().as_usize().unwrap(), 42);
    }

    #[test]
    fn as_u64_is_exact_or_errors() {
        assert_eq!(parse("0").unwrap().as_u64().unwrap(), 0);
        assert_eq!(parse("42").unwrap().as_u64().unwrap(), 42);
        // 2^53 - 1: the largest uniquely-representable integer
        assert_eq!(parse("9007199254740991").unwrap().as_u64().unwrap(), (1u64 << 53) - 1);
        // 2^53 itself is ambiguous (2^53 + 1 rounds onto it) — rejected
        for bad in [
            "-1", "-0.5", "1.5", "9007199254740992", "9007199254740994", "1e300", "\"7\"",
            "true",
        ] {
            assert!(parse(bad).unwrap().as_u64().is_err(), "{bad}");
        }
    }

    #[test]
    fn jobj_macro() {
        let v = jobj![("x", 1.0), ("name", "ddim")];
        assert_eq!(v.get("x").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "ddim");
    }
}
