//! Recursive-descent JSON parser. Hand-rolled because the offline build has
//! no serde_json; kept strict (trailing garbage, bad escapes, and unterminated
//! literals are errors) so malformed client requests fail loudly.

use std::collections::BTreeMap;

use super::Value;
use crate::error::{Error, Result};

/// Parse a complete JSON document from `src`.
pub fn parse(src: &str) -> Result<Value> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.i))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected {word})")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let code = self.hex4()?;
                            // BMP only; surrogate halves rejected (our
                            // producers are ASCII — see module docs).
                            match char::from_u32(code) {
                                Some(c) => s.push(c),
                                None => return Err(self.err("invalid \\u escape")),
                            }
                            continue;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(_) => {
                    // copy a full UTF-8 scalar
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    if rest.len() < ch_len {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let ch = std::str::from_utf8(&rest[..ch_len])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    s.push_str(ch);
                    self.i += ch_len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut code = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            code = code * 16 + d;
            self.i += 1;
        }
        Ok(code)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nested() {
        let v = parse(r#" { "a" : [ 1 , { "b" : [ ] } ] , "c" : { } } "#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].as_f64().unwrap(), 1.0);
    }

    #[test]
    fn numbers() {
        for (s, want) in [
            ("0", 0.0),
            ("-0.5", -0.5),
            ("3.25e2", 325.0),
            ("1E-3", 0.001),
            ("1000000", 1e6),
        ] {
            assert_eq!(parse(s).unwrap().as_f64().unwrap(), want, "{s}");
        }
    }

    #[test]
    fn strings_and_escapes() {
        assert_eq!(
            parse(r#""a\nb\t\"q\" \\ A""#).unwrap().as_str().unwrap(),
            "a\nb\t\"q\" \\ A"
        );
        // real UTF-8 multibyte passes through
        assert_eq!(parse("\"π≈3\"").unwrap().as_str().unwrap(), "π≈3");
    }

    #[test]
    fn rejects_malformed() {
        for s in [
            "", "{", "[1,", "{\"a\"}", "tru", "01x", "\"", "\"\\q\"", "[1] 2",
            "{\"a\":1,}", "nul",
        ] {
            assert!(parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn control_char_rejected() {
        assert!(parse("\"a\u{1}b\"").is_err());
    }
}
