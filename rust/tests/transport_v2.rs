//! Transport v2 end-to-end: pipelined ids, streamed x̂₀ previews, framing
//! robustness, payload equivalence with the v1 serial shape, and clean
//! teardown. Real TCP against the epoll reactors, fixture artifacts on
//! the hermetic reference backend — no `make artifacts`, no XLA, zero
//! skips.

use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::server::Client;
use ddim_serve::coordinator::Server;
use ddim_serve::jobj;
use ddim_serve::json::{self, Value};
use ddim_serve::testing::fixtures;

fn cfg() -> ServeConfig {
    ServeConfig {
        artifact_root: fixtures::root_string(),
        dataset: "sprites".into(),
        listen: "127.0.0.1:0".into(),
        max_batch: 8,
        ..Default::default()
    }
}

fn gen(steps: f64, seed: f64) -> Value {
    jobj![
        ("op", "generate"),
        ("dataset", "sprites"),
        ("steps", steps),
        ("eta", 0.0),
        ("count", 1.0),
        ("seed", seed),
        ("cache", "bypass"),
        ("return_images", true),
    ]
}

/// Many in-flight ids on ONE connection, mixed short/long step counts:
/// completions arrive out of order, every id is answered exactly once,
/// and each response carries the payload its id's request asked for.
#[test]
fn pipelined_ids_complete_out_of_order() {
    let server = Server::start(cfg()).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    // id 1 is long (S=40); ids 2..=6 are short (S=4) submitted after it.
    // All lanes run concurrently in one engine, so the shorts must finish
    // (and be delivered) before the long one — out-of-order by design.
    c.submit(1, &gen(40.0, 100.0)).unwrap();
    for id in 2..=6u64 {
        c.submit(id, &gen(4.0, 100.0 + id as f64)).unwrap();
    }
    let mut seen = Vec::new();
    for _ in 0..6 {
        let r = c.recv_frame().unwrap();
        assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
        let id = r.get("id").unwrap().as_u64().unwrap();
        let steps = r.get("steps_executed").unwrap().as_usize().unwrap();
        assert_eq!(steps, if id == 1 { 40 } else { 4 }, "id {id} got the wrong payload");
        assert_eq!(r.get("outputs").unwrap().as_arr().unwrap().len(), 1);
        seen.push(id);
    }
    let mut sorted = seen.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, vec![1, 2, 3, 4, 5, 6], "every id answered exactly once");
    assert_ne!(seen[0], 1, "the 40-step request must not complete first: {seen:?}");
    assert_eq!(*seen.last().unwrap(), 1, "the 40-step request completes last: {seen:?}");

    // the connection is still healthy for ordinary serial traffic
    let pong = c.roundtrip(&jobj![("op", "ping")]).unwrap();
    assert!(pong.get("ok").unwrap().as_bool().unwrap());
    server.shutdown();
}

/// `"stream":{"every":K}`: preview frames are well formed, cover exactly
/// the non-final steps divisible by K for every lane, interleave ahead of
/// the final response on the same connection, and echo the request id.
/// A cache hit streams nothing (no execution, no x̂₀ to preview).
#[test]
fn streamed_x0_previews_are_well_formed() {
    let server = Server::start(cfg()).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    let mut req = jobj![
        ("op", "generate"),
        ("dataset", "sprites"),
        ("steps", 12.0),
        ("eta", 0.0),
        ("count", 2.0),
        ("seed", 7.0),
        ("cache", "bypass"),
    ];
    req.set("stream", jobj![("every", 3.0)]).unwrap();
    c.submit(9, &req).unwrap();

    let mut frames = Vec::new();
    let fin = loop {
        let v = c.recv_frame().unwrap();
        if v.get_opt("frame").is_some() {
            frames.push(v);
        } else {
            break v;
        }
    };
    assert!(fin.get("ok").unwrap().as_bool().unwrap(), "{fin:?}");
    assert_eq!(fin.get("id").unwrap().as_u64().unwrap(), 9);

    // steps 3, 6, 9 for each of the 2 lanes (12 is the final step — its
    // x₀ ships in the response, not as a frame)
    let mut step_by_lane = vec![Vec::new(), Vec::new()];
    for f in &frames {
        assert_eq!(f.get("frame").unwrap().as_str().unwrap(), "x0_preview");
        assert_eq!(f.get("id").unwrap().as_u64().unwrap(), 9);
        assert_eq!(f.get("total_steps").unwrap().as_usize().unwrap(), 12);
        let lane = f.get("lane").unwrap().as_usize().unwrap();
        let step = f.get("step").unwrap().as_usize().unwrap();
        assert!(lane < 2, "{f:?}");
        assert_eq!(f.get("x0").unwrap().as_arr().unwrap().len(), 256);
        step_by_lane[lane].push(step);
    }
    for lane in &mut step_by_lane {
        lane.sort_unstable();
        assert_eq!(*lane, vec![3, 6, 9], "every-3 previews of a 12-step plan");
    }

    // a cacheable repeat: first populate, then stream a hit — zero frames
    let mut cached = jobj![
        ("op", "generate"),
        ("dataset", "sprites"),
        ("steps", 6.0),
        ("eta", 0.0),
        ("count", 1.0),
        ("seed", 31.0),
    ];
    let warm = c.roundtrip(&cached).unwrap();
    assert!(!warm.get("cached").unwrap().as_bool().unwrap());
    cached.set("stream", jobj![("every", 1.0)]).unwrap();
    c.submit(10, &cached).unwrap();
    let v = c.recv_frame().unwrap();
    assert!(v.get_opt("frame").is_none(), "cache hits stream no frames: {v:?}");
    assert!(v.get("cached").unwrap().as_bool().unwrap());
    assert_eq!(v.get("id").unwrap().as_u64().unwrap(), 10);

    // malformed stream directives are typed errors, not disconnects
    let mut bad = gen(4.0, 1.0);
    bad.set("stream", jobj![("every", 0.0)]).unwrap();
    let e = c.roundtrip(&bad).unwrap();
    assert!(!e.get("ok").unwrap().as_bool().unwrap());
    assert!(e.get("error").unwrap().as_str().unwrap().contains("stream.every"));
    server.shutdown();
}

/// The multiplexed path changes *delivery only*: the same request sent
/// v1-serial (no id), pipelined (id), and streamed (id + frames) yields
/// bitwise-identical sample payloads — `"id"`/`"stream"` never reach the
/// cache key or the engine.
#[test]
fn pipelined_and_streamed_payloads_match_v1_serial() {
    let server = Server::start(cfg()).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();
    let req = gen(6.0, 55.0);

    let v1 = c.roundtrip(&req).unwrap();
    assert!(v1.get("ok").unwrap().as_bool().unwrap(), "{v1:?}");

    c.submit(2, &req).unwrap();
    let piped = c.recv_frame().unwrap();
    assert_eq!(piped.get("id").unwrap().as_u64().unwrap(), 2);

    let mut streamed_req = req.clone();
    streamed_req.set("stream", jobj![("every", 2.0)]).unwrap();
    c.submit(3, &streamed_req).unwrap();
    let streamed = loop {
        let v = c.recv_frame().unwrap();
        if v.get_opt("frame").is_none() {
            break v;
        }
    };

    // bitwise payload equality (serialized f64s are exact): the sample,
    // its cost, and its cache disposition
    for key in ["outputs", "steps_executed", "cached", "ok"] {
        assert_eq!(
            json::to_string(v1.get(key).unwrap()),
            json::to_string(piped.get(key).unwrap()),
            "pipelined '{key}' diverged from v1"
        );
        assert_eq!(
            json::to_string(v1.get(key).unwrap()),
            json::to_string(streamed.get(key).unwrap()),
            "streamed '{key}' diverged from v1"
        );
    }
    server.shutdown();
}

/// `"id"` and `"stream"` are transport fields: two wire forms differing
/// only in them parse to requests with identical cache keys.
#[test]
fn cache_key_excludes_id_and_stream() {
    use ddim_serve::cache::key::CacheKey;
    use ddim_serve::coordinator::Request;
    use ddim_serve::runtime::BackendKind;

    let plain = json::parse(
        r#"{"op":"generate","dataset":"d","steps":8,"eta":0.0,"count":1,"seed":3}"#,
    )
    .unwrap();
    let tagged = json::parse(
        r#"{"op":"generate","dataset":"d","steps":8,"eta":0.0,"count":1,"seed":3,
            "id":"abc","stream":{"every":2}}"#,
    )
    .unwrap();
    let a = Request::from_json(&plain).unwrap();
    let b = Request::from_json(&tagged).unwrap();
    assert_eq!(
        CacheKey::of(&a, 0xD1D5, BackendKind::Reference, 0),
        CacheKey::of(&b, 0xD1D5, BackendKind::Reference, 0),
        "id/stream must not shape the cache key"
    );
}

/// Framing robustness on a live socket: an overlong line gets the typed
/// error and the connection survives (discard-to-newline resync); a
/// slow-loris request dribbled byte-ranges apart still parses.
#[test]
fn overlong_lines_and_partial_frames() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let server = Server::start(cfg()).unwrap();
    let stream = TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut stream = stream;

    // 2 MiB of garbage on one line: typed error, no disconnect
    let big = vec![b'x'; 2 << 20];
    stream.write_all(&big).unwrap();
    stream.write_all(b"\n").unwrap();
    stream.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert!(!v.get("ok").unwrap().as_bool().unwrap());
    assert_eq!(v.get("error").unwrap().as_str().unwrap(), "line too long");

    // slow loris: the next request arrives in three fragments with pauses
    let req = b"{\"op\":\"ping\"}\n";
    for chunk in req.chunks(5) {
        stream.write_all(chunk).unwrap();
        stream.flush().unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(line.trim()).unwrap();
    assert!(v.get("ok").unwrap().as_bool().unwrap(), "conn survived the overlong line");
    assert!(v.get("pong").unwrap().as_bool().unwrap());
    server.shutdown();
}

/// Shutdown leaks nothing: after serving real traffic over several
/// connections, `shutdown` joins every thread (acceptor, reactors,
/// shards) and closes every fd — process-wide counts return to their
/// pre-start baseline. The v1 server leaked one thread per connection.
#[cfg(target_os = "linux")]
#[test]
fn shutdown_releases_all_threads_and_fds() {
    fn count_fds() -> usize {
        std::fs::read_dir("/proc/self/fd").unwrap().count()
    }
    fn count_threads() -> usize {
        let status = std::fs::read_to_string("/proc/self/status").unwrap();
        status
            .lines()
            .find_map(|l| l.strip_prefix("Threads:"))
            .and_then(|v| v.trim().parse().ok())
            .unwrap()
    }

    // fixtures are materialised once, before the baseline
    let config = cfg();
    let fd_base = count_fds();
    let thread_base = count_threads();

    {
        let server = Server::start(config).unwrap();
        let addr = server.addr();
        let mut clients: Vec<Client> =
            (0..8).map(|_| Client::connect(addr).unwrap()).collect();
        for (i, c) in clients.iter_mut().enumerate() {
            let r = c.roundtrip(&gen(4.0, i as f64)).unwrap();
            assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
        }
        drop(clients);
        server.shutdown();
    }

    // joins have happened; give the kernel a beat to retire fd table
    // entries for the client sockets dropped just above
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        let (fds, threads) = (count_fds(), count_threads());
        if fds <= fd_base && threads <= thread_base {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "leak: fds {fd_base} -> {fds}, threads {thread_base} -> {threads}"
        );
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
}
