//! Property tests pinning the reference-kernel optimisation invariants:
//!
//! - the unrolled structure-of-arrays path is **bitwise** identical to the
//!   plain scalar baseline, at every dim (including the odd tails 1, 7,
//!   63, 65 that exercise the remainder loop) and every bucket;
//! - N worker threads are **bitwise** identical to 1 thread — slot
//!   granularity means threading can never change a result;
//! - padding slots cannot leak into live lanes, bitwise;
//! - the f16-stored / f32-accumulated path stays within tolerance of f32,
//!   per step and over a short feedback trajectory;
//! - through the whole engine, `--ref-threads 1` and `--ref-threads 4`
//!   produce identical samples on a mixed η=0 / η=1 workload, and a warm
//!   engine allocates **zero** reference-backend bytes per tick.
//!
//! Hermetic: the kernel tests build a synthetic ε-model directly; the
//! engine tests run on `testing::fixtures` artifacts. No XLA anywhere.

use std::sync::Arc;

use ddim_serve::artifacts::DatasetInfo;
use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::request::{CacheMode, Request, RequestBody};
use ddim_serve::coordinator::{Engine, ResponseBody};
use ddim_serve::runtime::reference::compute_scalar_into;
use ddim_serve::runtime::{RefModel, RefPrecision, StepExecutable, StepOutput, WorkerPool};
use ddim_serve::sampler::SamplerKind;
use ddim_serve::schedule::{NoiseMode, TauKind};
use ddim_serve::testing::{check, fixtures, Gen};

/// Dims that stress every kernel layout case: below one unrolled chunk,
/// odd remainders either side of a chunk boundary, and a clean multiple.
const DIMS: [usize; 5] = [1, 7, 63, 65, 256];

fn model(dim: usize) -> Arc<RefModel> {
    let info = DatasetInfo { hlo: vec![], params: 9_999, final_loss: 0.031, ref_n: 32 };
    Arc::new(RefModel::from_manifest("sprites", &info, dim, 1000))
}

/// One random packed sub-batch at (bucket × dim).
struct Case {
    bucket: usize,
    dim: usize,
    x: Vec<f32>,
    t: Vec<f32>,
    a_t: Vec<f32>,
    a_p: Vec<f32>,
    sigma: Vec<f32>,
    noise: Vec<f32>,
}

impl Case {
    fn random(g: &mut Gen, bucket: usize, dim: usize) -> Self {
        let n = bucket * dim;
        Self {
            bucket,
            dim,
            x: g.vec_f32(n, -3.0, 3.0),
            noise: g.vec_f32(n, -2.0, 2.0),
            t: (0..bucket).map(|_| g.f64_in(1.0, 999.0) as f32).collect(),
            a_t: (0..bucket).map(|_| g.f64_in(0.05, 0.99) as f32).collect(),
            a_p: (0..bucket).map(|_| g.f64_in(0.1, 0.999) as f32).collect(),
            // mix deterministic and stochastic lanes like a real tick
            sigma: (0..bucket)
                .map(|_| if g.bool() { g.f64_in(0.0, 0.3) as f32 } else { 0.0 })
                .collect(),
        }
    }

    fn scalar(&self, m: &RefModel) -> StepOutput {
        let mut out = StepOutput::zeros(self.bucket * self.dim);
        compute_scalar_into(
            m, self.bucket, self.dim, &self.x, &self.t, &self.a_t, &self.a_p, &self.sigma,
            &self.noise, &mut out,
        );
        out
    }

    fn run(&self, exe: &StepExecutable) -> StepOutput {
        let mut out = StepOutput::zeros(self.bucket * self.dim);
        exe.run(&self.x, &self.t, &self.a_t, &self.a_p, &self.sigma, &self.noise, &mut out)
            .expect("reference step");
        out
    }
}

fn exe(
    m: &Arc<RefModel>,
    bucket: usize,
    dim: usize,
    threads: usize,
    p: RefPrecision,
) -> StepExecutable {
    let pool = Arc::new(WorkerPool::new(threads));
    StepExecutable::reference_with(Arc::clone(m), bucket, dim, pool, p)
        .expect("reference executable")
}

fn bitwise_eq(a: &StepOutput, b: &StepOutput, what: &str) -> Result<(), String> {
    if a.x_prev != b.x_prev {
        return Err(format!("{what}: x_prev differs bitwise"));
    }
    if a.eps != b.eps {
        return Err(format!("{what}: eps differs bitwise"));
    }
    if a.x0 != b.x0 {
        return Err(format!("{what}: x0 differs bitwise"));
    }
    Ok(())
}

/// Unrolled SoA kernel == scalar baseline, bit for bit, across every odd
/// dim and bucket shape.
#[test]
fn unrolled_matches_scalar_bitwise() {
    check("unrolled_matches_scalar_bitwise", 60, |g| {
        let dim = *g.choose(&DIMS);
        let bucket = g.int_in(1, 9);
        let m = model(dim);
        let case = Case::random(g, bucket, dim);
        bitwise_eq(
            &case.run(&exe(&m, bucket, dim, 1, RefPrecision::F32)),
            &case.scalar(&m),
            &format!("bucket {bucket} dim {dim}"),
        )
    });
}

/// N threads == 1 thread, bit for bit: work is split at slot granularity,
/// every slot runs the identical lane kernel, so the thread count (even
/// exceeding the slot count) must be unobservable in the output.
#[test]
fn threaded_matches_single_thread_bitwise() {
    check("threaded_matches_single_thread_bitwise", 40, |g| {
        let dim = *g.choose(&DIMS);
        let bucket = g.int_in(1, 11);
        let threads = *g.choose(&[2usize, 3, 4, 8]);
        let m = model(dim);
        let case = Case::random(g, bucket, dim);
        bitwise_eq(
            &case.run(&exe(&m, bucket, dim, threads, RefPrecision::F32)),
            &case.run(&exe(&m, bucket, dim, 1, RefPrecision::F32)),
            &format!("bucket {bucket} dim {dim} threads {threads}"),
        )
    });
}

/// Padding soundness, bitwise: live lanes must not depend on what the
/// padding slots carry — states, scalars, or noise.
#[test]
fn padded_slots_do_not_leak_into_live_lanes() {
    check("padded_slots_do_not_leak", 40, |g| {
        let dim = *g.choose(&DIMS);
        let lanes = g.int_in(1, 6);
        let bucket = lanes + g.int_in(1, 5); // at least one padded slot
        let threads = *g.choose(&[1usize, 3]);
        let m = model(dim);
        let live = Case::random(g, bucket, dim);
        // same live region, totally different garbage in [lanes..bucket)
        let mut junk = Case::random(g, bucket, dim);
        let keep = lanes * dim;
        junk.x[..keep].copy_from_slice(&live.x[..keep]);
        junk.noise[..keep].copy_from_slice(&live.noise[..keep]);
        junk.t[..lanes].copy_from_slice(&live.t[..lanes]);
        junk.a_t[..lanes].copy_from_slice(&live.a_t[..lanes]);
        junk.a_p[..lanes].copy_from_slice(&live.a_p[..lanes]);
        junk.sigma[..lanes].copy_from_slice(&live.sigma[..lanes]);
        let e = exe(&m, bucket, dim, threads, RefPrecision::F32);
        let a = live.run(&e);
        let b = junk.run(&e);
        if a.x_prev[..keep] != b.x_prev[..keep] || a.eps[..keep] != b.eps[..keep] {
            return Err(format!(
                "padding contents changed live lanes (lanes {lanes}, bucket {bucket}, dim {dim})"
            ));
        }
        Ok(())
    });
}

/// The f16-stored weight path stays close to f32: per step, every element
/// within a loose half-precision tolerance; over a short feedback loop
/// (x_prev fed back as x), the drift stays bounded instead of compounding.
#[test]
fn f16_path_tracks_f32_within_tolerance() {
    check("f16_tracks_f32", 30, |g| {
        let dim = *g.choose(&DIMS);
        let bucket = g.int_in(1, 6);
        let m = model(dim);
        let mut case = Case::random(g, bucket, dim);
        let e32 = exe(&m, bucket, dim, 1, RefPrecision::F32);
        let e16 = exe(&m, bucket, dim, 2, RefPrecision::F16);
        let mut f32_x = case.x.clone();
        for step in 0..4 {
            case.x = f32_x.clone();
            let want = case.run(&e32);
            let got = case.run(&e16);
            let drift = got
                .x_prev
                .iter()
                .zip(&want.x_prev)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            let tol = if step == 0 { 0.05 } else { 0.1 };
            if drift > tol {
                return Err(format!(
                    "f16 drift {drift} > {tol} at step {step} (bucket {bucket} dim {dim})"
                ));
            }
            f32_x = want.x_prev;
        }
        Ok(())
    });
}

// ---- engine-level invariants over the fixtures artifacts ----------------

fn engine_with(threads: usize, depth: usize) -> Engine {
    let cfg = ServeConfig {
        artifact_root: fixtures::root_string(),
        dataset: "sprites".into(),
        max_batch: 8,
        queue_capacity: 32,
        max_lanes: 16,
        ref_threads: threads,
        pipeline_depth: depth,
        ..Default::default()
    };
    Engine::new(cfg).unwrap()
}

fn gen_request(steps: usize, mode: NoiseMode, count: usize, seed: u64) -> Request {
    Request {
        dataset: "sprites".into(),
        steps,
        mode,
        tau: TauKind::Linear,
        sampler: SamplerKind::Ddim,
        body: RequestBody::Generate { count, seed },
        return_images: true,
        cache: CacheMode::Bypass,
        qos: Default::default(),
    }
}

fn outputs(resp: &ddim_serve::coordinator::Response) -> Vec<Vec<f32>> {
    match &resp.body {
        ResponseBody::Ok { outputs } => outputs.clone(),
        other => panic!("request failed: {other:?}"),
    }
}

/// THE end-to-end threading invariant: an engine configured with
/// `ref_threads: 4` must produce **bitwise** the same samples as
/// `ref_threads: 1` on a mixed workload — odd lane counts (so sub-batches
/// carry padded slots), η=1 stochastic plans, and heterogeneous lengths.
#[test]
fn engine_is_bitwise_identical_across_ref_threads() {
    let run = |threads: usize| -> Vec<(u64, Vec<Vec<f32>>)> {
        let mut e = engine_with(threads, 1);
        let mut ids = Vec::new();
        ids.push(e.submit(gen_request(6, NoiseMode::Eta(0.0), 3, 21)).unwrap());
        ids.push(e.submit(gen_request(9, NoiseMode::Eta(1.0), 2, 22)).unwrap());
        ids.push(e.submit(gen_request(4, NoiseMode::SigmaHat, 1, 23)).unwrap());
        ids.push(e.submit(gen_request(7, NoiseMode::Eta(0.5), 3, 24)).unwrap());
        let resp = e.run_until_idle().unwrap();
        ids.iter()
            .map(|&id| (id, outputs(resp.iter().find(|r| r.id == id).unwrap())))
            .collect()
    };
    let serial = run(1);
    let threaded = run(4);
    assert_eq!(serial, threaded, "ref_threads changed sample bits");
}

/// Steady-state allocation-freedom, observed through the metrics the wire
/// exposes: after a warm-up request has grown every buffer, an
/// identical-shape request (different seed) must allocate **zero** fresh
/// reference-backend bytes — and the last working tick reports 0 too.
/// Runs pipelined (depth 2): the submit path computes into pooled output
/// buffers, so the cold request demonstrably grows them and the warm one
/// demonstrably recycles them. (A depth-1 engine writes into the tick
/// loop's pre-sized buffers and never allocates at all.)
#[test]
fn warm_engine_allocates_zero_reference_bytes() {
    let mut e = engine_with(2, 2);
    // cold: first request grows scratch + pooled output buffers
    e.submit(gen_request(5, NoiseMode::Eta(1.0), 2, 1)).unwrap();
    e.run_until_idle().unwrap();
    let cold = e.metrics().ref_bytes_allocated;
    assert!(cold > 0, "cold run should have grown reference buffers");

    // warm: same shape, different seed → every buffer is recycled
    e.submit(gen_request(5, NoiseMode::Eta(1.0), 2, 2)).unwrap();
    e.run_until_idle().unwrap();
    let m = e.metrics();
    assert_eq!(
        m.ref_bytes_allocated, cold,
        "warm identical-shape request allocated fresh reference bytes"
    );
    assert_eq!(m.ref_bytes_last_tick, 0, "warm ticks must report 0 bytes/tick");
    assert!(m.ref_compute_s > 0.0, "reference compute seconds should accumulate");
    assert!(m.ref_compute_frac() > 0.0 && m.ref_compute_frac() <= 1.0);
}
