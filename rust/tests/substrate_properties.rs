//! Property tests over the pure substrates (no artifacts / PJRT needed):
//! JSON round-trips under fuzzing, histogram quantile laws, slerp geometry,
//! feature-map structure, linalg identities, workload statistics.

use ddim_serve::coordinator::Histogram;
use ddim_serve::json::{self, Value};
use ddim_serve::linalg::{cholesky, eigh, sqrtm_spd, Mat};
use ddim_serve::rng::{slerp, GaussianSource, Pcg64};
use ddim_serve::stats::extract_features;
use ddim_serve::testing::{check, Gen};

fn random_value(g: &mut Gen, depth: usize) -> Value {
    let pick = if depth == 0 { g.rng.next_below(4) } else { g.rng.next_below(6) };
    match pick {
        0 => Value::Null,
        1 => Value::Bool(g.bool()),
        2 => {
            // mix of integral, fractional, large, tiny
            let v = match g.rng.next_below(4) {
                0 => g.rng.uniform(-1e6, 1e6).round(),
                1 => g.rng.uniform(-1.0, 1.0),
                2 => g.rng.uniform(-1e18, 1e18),
                _ => g.rng.uniform(-1e-9, 1e-9),
            };
            Value::Num(v)
        }
        3 => {
            let n = g.int_in(0, 12);
            let s: String = (0..n)
                .map(|_| {
                    let c = g.rng.next_below(96) as u8 + 32;
                    c as char
                })
                .collect();
            Value::Str(format!("{s}\"\\\n\tπ"))
        }
        4 => {
            let n = g.int_in(0, 4);
            Value::Arr((0..n).map(|_| random_value(g, depth - 1)).collect())
        }
        _ => {
            let n = g.int_in(0, 4);
            let mut m = std::collections::BTreeMap::new();
            for i in 0..n {
                m.insert(format!("k{i}"), random_value(g, depth - 1));
            }
            Value::Obj(m)
        }
    }
}

#[test]
fn prop_json_round_trip() {
    check("json_round_trip", 300, |g| {
        let v = random_value(g, 3);
        let s = json::to_string(&v);
        let back = json::parse(&s).map_err(|e| format!("{e} on {s}"))?;
        // floats round-trip exactly ({:?} shortest representation); so the
        // whole tree must compare equal
        if back != v {
            return Err(format!("round trip changed value: {s}"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    check("json_no_panic", 500, |g| {
        let n = g.int_in(0, 64);
        let bytes: Vec<u8> = (0..n).map(|_| (g.rng.next_below(94) + 32) as u8).collect();
        let s = String::from_utf8_lossy(&bytes);
        let _ = json::parse(&s); // must not panic; result irrelevant
        Ok(())
    });
}

#[test]
fn prop_histogram_quantiles_monotone_and_bracketing() {
    check("hist_quantiles", 100, |g| {
        let mut h = Histogram::new();
        let n = g.int_in(2, 500);
        let mut max = 0.0f64;
        let mut min = f64::INFINITY;
        for _ in 0..n {
            let v = g.f64_in(1e-6, 10.0);
            h.record(v);
            max = max.max(v);
            min = min.min(v);
        }
        let mut last = 0.0;
        for q in [0.1, 0.5, 0.9, 0.99, 1.0] {
            let qv = h.quantile(q);
            if qv + 1e-12 < last {
                return Err(format!("quantile not monotone at q={q}"));
            }
            last = qv;
        }
        // p100 must bracket the true max within one bucket width (4%)
        let p100 = h.quantile(1.0);
        if p100 < max * 0.9 || h.quantile(0.0) > min * 1.1 + 1e-6 {
            return Err(format!("bracketing broken: p100 {p100} vs max {max}"));
        }
        Ok(())
    });
}

#[test]
fn prop_slerp_sweeps_angle_monotonically() {
    // the defining slerp property: the angle from `a` to slerp(a,b;α)
    // grows monotonically in α, reaching angle(a,b) at α=1
    check("slerp_angle", 200, |g| {
        let n = g.int_in(2, 256).max(2);
        let mut gs = GaussianSource::new(Pcg64::seeded(g.rng.next_u64()));
        let a = gs.vec(n);
        let b = gs.vec(n);
        let angle = |u: &[f32], v: &[f32]| {
            let dot: f64 = u.iter().zip(v).map(|(x, y)| *x as f64 * *y as f64).sum();
            let nu: f64 = u.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
            let nv: f64 = v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
            (dot / (nu * nv)).clamp(-1.0, 1.0).acos()
        };
        let total = angle(&a, &b);
        if total < 1e-3 || total > std::f64::consts::PI - 1e-3 {
            return Ok(()); // degenerate: lerp fallback regime
        }
        let mut last = -1e-9;
        for k in 0..=10 {
            let s = slerp(&a, &b, k as f64 / 10.0);
            let th = angle(&a, &s);
            if th + 1e-7 < last {
                return Err(format!("angle not monotone at k={k}: {th} < {last}"));
            }
            last = th;
        }
        if (last - total).abs() > 1e-5 {
            return Err(format!("endpoint angle {last} != {total}"));
        }
        Ok(())
    });
}

#[test]
fn prop_feature_map_is_shift_equivariant_in_mean() {
    // adding a constant c shifts pooled/mean dims by exactly c and leaves
    // all contrast dims untouched — a structural property of the map
    check("feature_shift", 100, |g| {
        let base = g.vec_f32(256, -0.5, 0.5);
        let c = g.f64_in(-0.4, 0.4) as f32;
        let shifted: Vec<f32> = base.iter().map(|v| v + c).collect();
        let fa = extract_features(&base);
        let fb = extract_features(&shifted);
        for d in 0..17 {
            if (fb[d] - fa[d] - c as f64).abs() > 1e-5 {
                return Err(format!("dim {d} not shifted by c"));
            }
        }
        for d in 17..24 {
            if (fb[d] - fa[d]).abs() > 1e-6 {
                return Err(format!("contrast dim {d} changed under shift"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sqrtm_and_cholesky_agree_on_trace() {
    // for SPD A: Tr(A) == Tr(L Lᵀ) == Tr(sqrtm(A)²)
    check("spd_traces", 60, |g| {
        let n = g.int_in(2, 10);
        let mut b = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = g.f64_in(-1.0, 1.0);
            }
        }
        let a = b
            .matmul(&b.transpose())
            .unwrap()
            .add(&Mat::identity(n).scale(0.2))
            .unwrap()
            .symmetrize();
        let l = cholesky(&a).map_err(|e| e.to_string())?;
        let r = sqrtm_spd(&a).map_err(|e| e.to_string())?;
        let t1 = a.trace();
        let t2 = l.matmul(&l.transpose()).unwrap().trace();
        let t3 = r.matmul(&r).unwrap().trace();
        if (t1 - t2).abs() > 1e-8 * t1.abs() || (t1 - t3).abs() > 1e-7 * t1.abs().max(1.0) {
            return Err(format!("traces disagree: {t1} {t2} {t3}"));
        }
        // eigenvalues of sqrtm are sqrt of eigenvalues of A
        let (wa, _) = eigh(&a, 1e-12, 64).unwrap();
        let (wr, _) = eigh(&r, 1e-12, 64).unwrap();
        for (x, y) in wa.iter().zip(&wr) {
            if (x.sqrt() - y).abs() > 1e-6 {
                return Err(format!("eig mismatch {} vs {}", x.sqrt(), y));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gaussian_source_tail_fraction() {
    // |z| > 2 should happen ~4.55% of the time; catch badly-scaled output
    let mut g = GaussianSource::seeded(0xAA);
    let n = 40_000;
    let tails = (0..n).filter(|_| g.next().abs() > 2.0).count() as f64 / n as f64;
    assert!((tails - 0.0455).abs() < 0.006, "2-sigma tail fraction {tails}");
}

#[test]
fn prop_workload_arrivals_exponential() {
    // inter-arrival CV ≈ 1 for a Poisson process
    use ddim_serve::workload::Workload;
    let w = Workload::standard("sprites", 50.0);
    let plan = w.generate(5000, 9);
    let gaps: Vec<f64> = plan.windows(2).map(|p| p[1].0 - p[0].0).collect();
    let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
    let var = gaps.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / gaps.len() as f64;
    let cv = var.sqrt() / mean;
    assert!((cv - 1.0).abs() < 0.08, "CV {cv} (exponential gaps have CV 1)");
}
