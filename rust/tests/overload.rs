//! Overload-control integration on fixture artifacts (hermetic reference
//! backend): typed rejection, deadlines, priority classes, and adaptive
//! quality degradation through the full router → cache → shard → engine
//! path.
//!
//! - queue overflow surfaces as `Error::Overload` with the queued-lane
//!   pressure attached, never a bare string;
//! - a request whose deadline expired is cancelled with a typed
//!   `"reject":{"reason":"deadline"}` — and the cancelled execution is
//!   never published to the sample cache;
//! - priority classes schedule strictly: interactive drains ahead of
//!   batch ahead of best_effort regardless of submission order;
//! - under queued-lane pressure a best-effort request is transparently
//!   degraded (S=100 → S=20), the response says so in `"degraded"`, and a
//!   coalesced waiter parked behind the degraded leader learns the same.

use std::time::{Duration, Instant};

use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::request::{CacheMode, Priority, Request, RequestBody};
use ddim_serve::coordinator::{Engine, ResponseBody, Router};
use ddim_serve::sampler::SamplerKind;
use ddim_serve::schedule::{NoiseMode, TauKind};
use ddim_serve::testing::fixtures;
use ddim_serve::Error;

fn cfg() -> ServeConfig {
    ServeConfig {
        artifact_root: fixtures::root_string(),
        dataset: "sprites".into(),
        max_batch: 8,
        max_lanes: 8,
        queue_capacity: 64,
        ..Default::default()
    }
}

fn gen(steps: usize, count: usize, seed: u64) -> Request {
    Request {
        dataset: "sprites".into(),
        steps,
        mode: NoiseMode::Eta(0.0),
        tau: TauKind::Linear,
        sampler: SamplerKind::Ddim,
        body: RequestBody::Generate { count, seed },
        return_images: true,
        cache: CacheMode::Use,
        qos: Default::default(),
    }
}

#[test]
fn queue_overflow_is_typed_overload() {
    let mut c = cfg();
    c.queue_capacity = 2;
    let mut e = Engine::new(c).unwrap();
    e.submit(gen(3, 1, 1)).unwrap();
    e.submit(gen(3, 1, 2)).unwrap();
    match e.submit(gen(3, 1, 3)) {
        Err(Error::Overload { queued_lanes, message }) => {
            assert_eq!(queued_lanes, 2);
            assert!(message.contains("queue full"), "{message}");
        }
        other => panic!("want typed overload, got {other:?}"),
    }
    // the lane budget rejects independently of the item cap: 2 queued
    // items hold 2 lanes; an 8-lane request would need 10 > budget
    let mut c = cfg();
    c.queue_capacity = 64;
    c.queue_lane_cap = 8;
    let mut e2 = Engine::new(c).unwrap();
    e2.submit(gen(3, 1, 1)).unwrap();
    e2.submit(gen(3, 1, 2)).unwrap();
    match e2.submit(gen(3, 8, 3)) {
        Err(Error::Overload { queued_lanes, message }) => {
            assert_eq!(queued_lanes, 2);
            assert!(message.contains("lane budget"), "{message}");
        }
        other => panic!("want typed lane-budget overload, got {other:?}"),
    }
    let m = e2.metrics();
    assert_eq!((m.queue_rejected_items, m.queue_rejected_lanes), (0, 1));
    assert!(e2.run_until_idle().is_ok());
}

#[test]
fn priority_classes_schedule_strictly() {
    // one lane: completion order IS scheduling order. Submission order is
    // deliberately worst-case (best_effort first, interactive last).
    let mut c = cfg();
    c.max_lanes = 1;
    c.max_batch = 1;
    let mut e = Engine::new(c).unwrap();
    let mut be = gen(3, 1, 1);
    be.qos.priority = Priority::BestEffort;
    let mut ba = gen(3, 1, 2);
    ba.qos.priority = Priority::Batch;
    let mut it = gen(3, 1, 3);
    it.qos.priority = Priority::Interactive;
    let id_be = e.submit(be).unwrap();
    let id_ba = e.submit(ba).unwrap();
    let id_it = e.submit(it).unwrap();
    let order: Vec<_> = e.run_until_idle().unwrap().iter().map(|r| r.id).collect();
    assert_eq!(order, vec![id_it, id_ba, id_be], "strict band order, not FIFO");
}

#[test]
fn queued_work_past_its_deadline_is_cancelled_not_finished() {
    // one busy lane; the queued request's deadline expires while it waits
    // and the tick-boundary reaper must cancel it with a typed timeout
    let mut c = cfg();
    c.max_lanes = 1;
    c.max_batch = 1;
    let mut e = Engine::new(c).unwrap();
    let long = e.submit(gen(40, 1, 1)).unwrap();
    let mut doomed = gen(5, 1, 2);
    doomed.qos.arrived = Some(Instant::now());
    doomed.qos.deadline_ms = Some(1);
    let doomed_id = e.submit(doomed).unwrap();
    std::thread::sleep(Duration::from_millis(5));
    let resps = e.run_until_idle().unwrap();
    let d = resps.iter().find(|r| r.id == doomed_id).unwrap();
    match &d.body {
        ResponseBody::Reject(r) => {
            assert_eq!(r.reason.label(), "deadline");
            assert_eq!(d.steps_executed, 0, "cancelled work must not have run");
        }
        other => panic!("want deadline reject, got {other:?}"),
    }
    let l = resps.iter().find(|r| r.id == long).unwrap();
    assert!(matches!(l.body, ResponseBody::Ok { .. }), "unrelated work completes");
    assert_eq!(e.metrics().deadline_expired, 1);
}

#[test]
fn deadline_expired_is_a_typed_timeout_and_never_cached() {
    let router = Router::start(cfg()).unwrap();
    // arrival anchored in the past: expired before admission
    let mut req = gen(5, 1, 77);
    req.qos.arrived = Some(Instant::now() - Duration::from_millis(50));
    req.qos.deadline_ms = Some(10);
    let resp = router.call(req).unwrap();
    let wire = resp.to_json_line();
    match &resp.body {
        ResponseBody::Reject(r) => {
            assert_eq!(r.reason.label(), "deadline");
            assert!(
                wire.contains("\"reject\"") && wire.contains("\"reason\":\"deadline\""),
                "typed on the wire: {wire}"
            );
        }
        other => panic!("want typed deadline reject, got {other:?} ({wire})"),
    }
    // the cancelled identity was never published: the same request
    // (without the deadline) executes fresh, and only THEN becomes a hit
    let r1 = router.call(gen(5, 1, 77)).unwrap();
    assert!(matches!(r1.body, ResponseBody::Ok { .. }));
    assert!(!r1.cached, "a cancelled request must not seed the cache");
    let r2 = router.call(gen(5, 1, 77)).unwrap();
    assert!(r2.cached, "the completed execution is cacheable as usual");
    router.shutdown();
}

#[test]
fn coalesced_waiters_behind_a_degraded_leader_get_degraded_responses() {
    // mid watermark at ~0 lanes of pressure: any in-flight work triggers
    // the first rung (S -> 20) for best-effort arrivals
    let mut c = cfg();
    c.degrade_mid = 0.001;
    c.degrade_high = 100.0;
    let router = Router::start(c).unwrap();
    // pressure source: a 4-lane batch-priority request that outlives the
    // degraded pair's admission (batch traffic is never degraded itself)
    let blocker = {
        let mut r = gen(400, 4, 9);
        r.qos.priority = Priority::Batch;
        router.submit(r)
    };
    // leader + identical waiter, both best_effort S=100: the router
    // rewrites both to the degraded budget *before* cache admission, so
    // they coalesce on the executed schedule
    let mk = || {
        let mut r = gen(100, 1, 5);
        r.qos.priority = Priority::BestEffort;
        r
    };
    let rx_leader = router.submit(mk());
    let rx_waiter = router.submit(mk());
    let leader = rx_leader.recv().unwrap();
    let waiter = rx_waiter.recv().unwrap();
    for (who, resp) in [("leader", &leader), ("waiter", &waiter)] {
        assert!(
            matches!(resp.body, ResponseBody::Ok { .. }),
            "{who} should succeed: {:?}",
            resp.body
        );
        assert_eq!(
            resp.degraded,
            Some((100, 20)),
            "{who} must carry the from->to degradation record"
        );
        let wire = resp.to_json_line();
        assert!(
            wire.contains("\"degraded\":{\"from\":100,\"to\":20}"),
            "degradation is visible on the wire: {wire}"
        );
    }
    // same executed schedule => bitwise-identical bodies
    match (&leader.body, &waiter.body) {
        (ResponseBody::Ok { outputs: a }, ResponseBody::Ok { outputs: b }) => {
            assert_eq!(a, b, "waiter shares the degraded leader's bits")
        }
        _ => unreachable!(),
    }
    let cm = router.cache().metrics();
    assert!(
        cm.coalesced_waiters + cm.hits >= 1,
        "the second request must not have executed independently: {cm:?}"
    );
    let (agg, _) = router.aggregate();
    assert_eq!(agg.requests_degraded, 2, "both callers counted at the router");
    blocker.recv().unwrap();
    router.shutdown();
}
