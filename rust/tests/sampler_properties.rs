//! Property tests over the schedule/sampler layers that don't need PJRT
//! (fast, run with the mini-proptest harness) plus BatchRunner-vs-Engine
//! agreement on the real artifacts.

use ddim_serve::schedule::{
    sigma_eta, sigma_hat, tau_subsequence, AlphaTable, NoiseMode, SamplePlan, TauKind,
};
use ddim_serve::testing::check;

#[test]
fn prop_tau_valid_for_all_s() {
    let t_max = 1000;
    check("tau_valid", 300, |g| {
        let s = g.int_in(1, t_max);
        let kind = *g.choose(&[TauKind::Linear, TauKind::Quadratic]);
        let tau = tau_subsequence(kind, s, t_max).map_err(|e| e.to_string())?;
        if tau.len() != s {
            return Err(format!("len {} != {s}", tau.len()));
        }
        if !tau.windows(2).all(|w| w[1] > w[0]) {
            return Err("not strictly increasing".into());
        }
        if *tau.first().unwrap() < 1 || *tau.last().unwrap() > t_max {
            return Err("out of range".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sigma_ordering_and_interpolation() {
    let abar = AlphaTable::linear(1000);
    check("sigma_ordering", 200, |g| {
        let prev = g.int_in(0, 998);
        let cur = prev + g.int_in(1, 999 - prev.min(998)).min(1000 - prev - 1) + 0;
        let cur = cur.min(1000).max(prev + 1);
        let e1 = g.f64_in(0.0, 1.0);
        let e2 = e1 + g.f64_in(0.0, 1.0 - e1);
        let s1 = sigma_eta(&abar, cur, prev, e1);
        let s2 = sigma_eta(&abar, cur, prev, e2);
        if s1 > s2 + 1e-15 {
            return Err(format!("sigma not monotone in eta: {s1} > {s2}"));
        }
        let sh = sigma_hat(&abar, cur, prev);
        let s_ddpm = sigma_eta(&abar, cur, prev, 1.0);
        if sh + 1e-12 < s_ddpm {
            return Err(format!("sigma_hat {sh} < sigma(1) {s_ddpm}"));
        }
        Ok(())
    });
}

#[test]
fn prop_generate_plan_invariants() {
    let abar = AlphaTable::linear(1000);
    check("plan_invariants", 200, |g| {
        let s = g.int_in(1, 400);
        let eta = g.f64_in(0.0, 1.0);
        let kind = *g.choose(&[TauKind::Linear, TauKind::Quadratic]);
        let mode = if g.bool() { NoiseMode::Eta(eta) } else { NoiseMode::SigmaHat };
        let plan =
            SamplePlan::generate(&abar, kind, s, mode).map_err(|e| e.to_string())?;
        if plan.len() != s {
            return Err("plan length".into());
        }
        let steps = plan.steps();
        // alpha_out of step i == alpha_in of step i+1 (chained trajectory)
        for w in steps.windows(2) {
            if (w[0].alpha_out - w[1].alpha_in).abs() > 1e-15 {
                return Err("alpha chain broken".into());
            }
        }
        if steps.last().unwrap().alpha_out != 1.0 {
            return Err("final step must land on alpha_bar=1".into());
        }
        for st in steps {
            if st.alpha_out <= st.alpha_in {
                return Err("alpha_out <= alpha_in".into());
            }
            // direction coefficient stays real — except the final sigma-hat
            // step (alpha_out = 1), where the kernel's max(.., 0) clamp IS
            // the defined behaviour (App. D.3 / plan.rs docs).
            if st.alpha_out < 1.0
                && 1.0 - st.alpha_out - st.sigma_dir * st.sigma_dir < -1e-9
            {
                return Err(format!(
                    "dir coef imaginary: a_out={} sigma={}",
                    st.alpha_out, st.sigma_dir
                ));
            }
            if st.sigma_noise < st.sigma_dir - 1e-15 {
                return Err("noise sigma below dir sigma".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_encode_plan_mirrors_generate() {
    let abar = AlphaTable::linear(1000);
    check("encode_mirror", 100, |g| {
        let s = g.int_in(1, 300);
        let kind = *g.choose(&[TauKind::Linear, TauKind::Quadratic]);
        let gen =
            SamplePlan::generate(&abar, kind, s, NoiseMode::Eta(0.0)).map_err(|e| e.to_string())?;
        let enc = SamplePlan::encode(&abar, kind, s).map_err(|e| e.to_string())?;
        if gen.tau != enc.tau {
            return Err("tau mismatch".into());
        }
        for (gstep, estep) in gen.steps().iter().rev().zip(enc.steps()) {
            if (gstep.alpha_in - estep.alpha_out).abs() > 1e-15
                || (gstep.alpha_out - estep.alpha_in).abs() > 1e-15
            {
                return Err("encode endpoints don't mirror generate".into());
            }
            if estep.sigma_noise != 0.0 {
                return Err("encode must be deterministic".into());
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Fixture-backed agreement test: BatchRunner (homogeneous harness) and the
// Engine (continuous batcher) must produce identical eta=0 samples.

#[test]
fn runner_and_engine_agree() {
    let root = ddim_serve::testing::fixtures::root_string();
    use ddim_serve::config::ServeConfig;
    use ddim_serve::coordinator::request::{Request, RequestBody};
    use ddim_serve::coordinator::{Engine, ResponseBody};
    use ddim_serve::runtime::Runtime;
    use ddim_serve::sampler::{BatchRunner, SamplerKind};

    let mut rt = Runtime::load(&root).unwrap();
    let plan =
        SamplePlan::generate(rt.alphas(), TauKind::Quadratic, 7, NoiseMode::Eta(0.0)).unwrap();
    let mut runner = BatchRunner::new(&rt, "sprites", 4).unwrap();
    let direct = runner.generate(&mut rt, &plan, 3, 555).unwrap();

    let cfg = ServeConfig {
        artifact_root: root,
        dataset: "sprites".into(),
        max_batch: 4,
        ..Default::default()
    };
    let mut engine = Engine::new(cfg).unwrap();
    let id = engine
        .submit(Request {
            dataset: "sprites".into(),
            steps: 7,
            mode: NoiseMode::Eta(0.0),
            tau: TauKind::Quadratic,
            sampler: SamplerKind::Ddim,
            body: RequestBody::Generate { count: 3, seed: 555 },
            return_images: true,
            cache: ddim_serve::coordinator::CacheMode::Use,
            qos: Default::default(),
        })
        .unwrap();
    let resp = engine.run_until_idle().unwrap();
    let via_engine = match &resp.iter().find(|r| r.id == id).unwrap().body {
        ResponseBody::Ok { outputs } => outputs.clone(),
        other => panic!("{other:?}"),
    };
    assert_eq!(direct, via_engine, "two independent drivers disagree");
}
