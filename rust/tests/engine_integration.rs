//! Engine integration tests: correctness of continuous batching
//! (batched == solo at η=0, bitwise), request lifecycle, encode/decode
//! fidelity, and backpressure.
//!
//! Hermetic: every test runs on `testing::fixtures` synthetic artifacts
//! over the reference backend — no `make artifacts`, no XLA, zero skips.

use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::request::{CacheMode, Request, RequestBody};
use ddim_serve::coordinator::{Engine, ResponseBody};
use ddim_serve::sampler::SamplerKind;
use ddim_serve::schedule::{NoiseMode, TauKind};
use ddim_serve::testing::fixtures;

fn artifacts_root() -> String {
    fixtures::root_string()
}

fn engine(max_batch: usize, queue_cap: usize, max_lanes: usize) -> Engine {
    let cfg = ServeConfig {
        artifact_root: artifacts_root(),
        dataset: "sprites".into(),
        max_batch,
        queue_capacity: queue_cap,
        max_lanes,
        ..Default::default()
    };
    Engine::new(cfg).unwrap()
}

fn gen_request(steps: usize, mode: NoiseMode, count: usize, seed: u64) -> Request {
    gen_request_with(steps, mode, count, seed, SamplerKind::Ddim)
}

fn gen_request_with(
    steps: usize,
    mode: NoiseMode,
    count: usize,
    seed: u64,
    sampler: SamplerKind,
) -> Request {
    Request {
        dataset: "sprites".into(),
        steps,
        mode,
        tau: TauKind::Linear,
        sampler,
        body: RequestBody::Generate { count, seed },
        return_images: true,
        cache: CacheMode::Use,
        qos: Default::default(),
    }
}

fn outputs(resp: &ddim_serve::coordinator::Response) -> Vec<Vec<f32>> {
    match &resp.body {
        ResponseBody::Ok { outputs } => outputs.clone(),
        other => panic!("request failed: {other:?}"),
    }
}

/// THE batching-correctness property: a deterministic (η=0) request packed
/// with unrelated heterogeneous requests (different S, η, σ̂, at different
/// timesteps, across shrinking buckets as the pool drains) must produce the
/// same images as running alone. Cross-bucket XLA executables differ in
/// fusion order, so equality is to fp tolerance; *within* one executable,
/// lane independence is exact (see `lanes_are_independent_bitwise`).
#[test]
fn batched_equals_solo_at_eta0() {
    // solo: one request, max_batch 1 (forces bucket-1 executables)
    let mut solo = engine(1, 16, 16);
    let id = solo.submit(gen_request(6, NoiseMode::Eta(0.0), 1, 4242)).unwrap();
    let solo_resp = solo.run_until_idle().unwrap();
    let solo_img = outputs(solo_resp.iter().find(|r| r.id == id).unwrap());

    // batched: same request packed with different-length/different-mode
    // requests so lanes sit at heterogeneous timesteps
    let mut busy = engine(8, 16, 32);
    let id2 = busy.submit(gen_request(6, NoiseMode::Eta(0.0), 1, 4242)).unwrap();
    busy.submit(gen_request(13, NoiseMode::Eta(1.0), 3, 7)).unwrap();
    busy.submit(gen_request(4, NoiseMode::Eta(0.5), 2, 8)).unwrap();
    busy.submit(gen_request(9, NoiseMode::SigmaHat, 2, 9)).unwrap();
    let busy_resp = busy.run_until_idle().unwrap();
    let busy_img = outputs(busy_resp.iter().find(|r| r.id == id2).unwrap());

    assert_eq!(solo_img.len(), 1);
    let max_diff = solo_img[0]
        .iter()
        .zip(&busy_img[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_diff < 1e-3,
        "continuous batching changed a deterministic trajectory: max diff {max_diff}"
    );
}

/// Within one executable, a lane's output must be bitwise independent of
/// what the *other* lanes carry — this is what makes padding and
/// heterogeneous packing sound at all.
#[test]
fn lanes_are_independent_bitwise() {
    use ddim_serve::runtime::{Runtime, StepOutput};
    let mut rt = Runtime::load(artifacts_root()).unwrap();
    let dim = rt.manifest().sample_dim();
    let b = 4usize;
    let mk = |fill: f32, lane0: &[f32]| {
        let mut v = vec![fill; b * dim];
        v[..dim].copy_from_slice(lane0);
        v
    };
    let lane0_x: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.37).sin()).collect();
    let lane0_n: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.11).cos()).collect();
    let mut scal_a = vec![0.4f32; b];
    let mut scal_b = vec![0.8f32; b];
    let mut t = vec![300.0f32; b];
    let sigma = vec![0.05f32; b];
    // run 1: companions filled with 1.3
    let exe = rt.executable("sprites", b).unwrap();
    let mut out1 = StepOutput::zeros(b * dim);
    exe.run(&mk(1.3, &lane0_x), &t, &scal_a, &scal_b, &sigma, &mk(0.7, &lane0_n), &mut out1)
        .unwrap();
    // run 2: companions totally different, including their scalars
    scal_a[1] = 0.1;
    scal_b[2] = 0.99;
    t[3] = 900.0;
    let mut out2 = StepOutput::zeros(b * dim);
    exe.run(&mk(-2.0, &lane0_x), &t, &scal_a, &scal_b, &sigma, &mk(5.0, &lane0_n), &mut out2)
        .unwrap();
    assert_eq!(
        &out1.x_prev[..dim],
        &out2.x_prev[..dim],
        "lane 0 output depends on other lanes"
    );
    assert_eq!(&out1.eps[..dim], &out2.eps[..dim]);
}

#[test]
fn eta0_is_reproducible_across_runs_and_seeds_differ() {
    let mut e = engine(8, 16, 32);
    let a = e.submit(gen_request(5, NoiseMode::Eta(0.0), 2, 1)).unwrap();
    let b = e.submit(gen_request(5, NoiseMode::Eta(0.0), 2, 1)).unwrap();
    let c = e.submit(gen_request(5, NoiseMode::Eta(0.0), 2, 2)).unwrap();
    let resp = e.run_until_idle().unwrap();
    let get = |id| outputs(resp.iter().find(|r| r.id == id).unwrap());
    assert_eq!(get(a), get(b), "same seed must reproduce");
    assert_ne!(get(a), get(c), "different seed must differ");
}

#[test]
fn all_requests_complete_under_saturation() {
    let mut e = engine(16, 64, 24);
    let mut ids = Vec::new();
    for i in 0..12 {
        let steps = 3 + (i % 5);
        let mode = if i % 3 == 0 { NoiseMode::Eta(1.0) } else { NoiseMode::Eta(0.0) };
        ids.push(e.submit(gen_request(steps, mode, 1 + i % 3, i as u64)).unwrap());
    }
    let resp = e.run_until_idle().unwrap();
    assert_eq!(resp.len(), ids.len());
    for id in ids {
        let r = resp.iter().find(|r| r.id == id).unwrap();
        assert!(matches!(r.body, ResponseBody::Ok { .. }));
        assert!(r.latency_s >= 0.0);
    }
    let m = e.metrics();
    assert_eq!(m.requests_completed, 12);
    assert!(m.occupancy() > 0.3, "occupancy {}", m.occupancy());
    assert_eq!(e.active_lanes(), 0);
    assert_eq!(e.queued(), 0);
}

#[test]
fn encode_decode_round_trip_has_low_error() {
    let mut e = engine(8, 16, 16);
    // generate a clean sample deterministically
    let gid = e.submit(gen_request(20, NoiseMode::Eta(0.0), 1, 77)).unwrap();
    let resp = e.run_until_idle().unwrap();
    let img = outputs(resp.iter().find(|r| r.id == gid).unwrap()).remove(0);

    // encode it, then decode the latent
    let eid = e
        .submit(Request {
            dataset: "sprites".into(),
            steps: 50,
            mode: NoiseMode::Eta(0.0),
            tau: TauKind::Linear,
            sampler: SamplerKind::Ddim,
            body: RequestBody::Encode { images: vec![img.clone()] },
            return_images: true,
            cache: CacheMode::Use,
            qos: Default::default(),
        })
        .unwrap();
    let resp = e.run_until_idle().unwrap();
    let latent = outputs(resp.iter().find(|r| r.id == eid).unwrap()).remove(0);
    // a latent of a 16x16 image should look ~N(0,1): check scale
    let var: f64 =
        latent.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / latent.len() as f64;
    assert!((0.3..3.0).contains(&var), "latent variance {var}");

    let did = e
        .submit(Request {
            dataset: "sprites".into(),
            steps: 50,
            mode: NoiseMode::Eta(0.0),
            tau: TauKind::Linear,
            sampler: SamplerKind::Ddim,
            body: RequestBody::Decode { latents: vec![latent] },
            return_images: true,
            cache: CacheMode::Use,
            qos: Default::default(),
        })
        .unwrap();
    let resp = e.run_until_idle().unwrap();
    let recon = outputs(resp.iter().find(|r| r.id == did).unwrap()).remove(0);
    let mse = ddim_serve::eval::per_dim_mse(&[img], &[recon]).unwrap();
    assert!(mse < 0.01, "S=50 reconstruction error {mse} (paper: ~0.0023)");
}

#[test]
fn backpressure_rejects_when_queue_full() {
    // queue capacity 2: admission happens at tick time, so the third
    // *submit* (queue already holding two) must be rejected immediately.
    let mut e = engine(4, 2, 4);
    e.submit(gen_request(3, NoiseMode::Eta(0.0), 4, 1)).unwrap();
    e.submit(gen_request(3, NoiseMode::Eta(0.0), 4, 2)).unwrap();
    let err = e.submit(gen_request(3, NoiseMode::Eta(0.0), 4, 3));
    assert!(err.is_err(), "queue should be full");
    let resp = e.run_until_idle().unwrap();
    assert_eq!(resp.len(), 2, "admitted requests still complete");
    assert_eq!(e.metrics().requests_rejected, 1);
    // after draining, capacity is available again
    e.submit(gen_request(3, NoiseMode::Eta(0.0), 4, 4)).unwrap();
    assert_eq!(e.run_until_idle().unwrap().len(), 1);
}

#[test]
fn submit_validates_requests() {
    let mut e = engine(4, 8, 8);
    // wrong dataset
    let mut r = gen_request(3, NoiseMode::Eta(0.0), 1, 0);
    r.dataset = "blobs".into();
    assert!(e.submit(r).is_err());
    // too many lanes
    assert!(e.submit(gen_request(3, NoiseMode::Eta(0.0), 9, 0)).is_err());
    // zero steps
    assert!(e.submit(gen_request(0, NoiseMode::Eta(0.0), 1, 0)).is_err());
    // wrong state dims
    let bad = Request {
        dataset: "sprites".into(),
        steps: 3,
        mode: NoiseMode::Eta(0.0),
        tau: TauKind::Linear,
        sampler: SamplerKind::Ddim,
        body: RequestBody::Decode { latents: vec![vec![0.0; 7]] },
        return_images: false,
        cache: CacheMode::Use,
        qos: Default::default(),
    };
    assert!(e.submit(bad).is_err());
    // host kernels on a stochastic plan are rejected at admission
    let err = e.submit(gen_request_with(3, NoiseMode::Eta(1.0), 1, 0, SamplerKind::Ab2));
    assert!(err.unwrap_err().to_string().contains("DDIM-only"));
    let err = e.submit(gen_request_with(3, NoiseMode::SigmaHat, 1, 0, SamplerKind::PfOde));
    assert!(err.is_err());
}

/// No starvation: a long request admitted alongside a constant churn of
/// short ones must finish within a bounded number of ticks — round-robin
/// guarantees every resident lane advances at least once per
/// ceil(active/max_batch) ticks.
#[test]
fn long_request_is_not_starved_by_short_churn() {
    let mut e = engine(4, 64, 16);
    let long_steps = 12usize;
    let long_id = e.submit(gen_request(long_steps, NoiseMode::Eta(0.0), 1, 1)).unwrap();
    let mut next_seed = 100u64;
    let mut ticks = 0usize;
    let mut long_done = false;
    // keep the engine saturated with fresh 2-step requests while ticking
    while !long_done {
        while e.active_lanes() + e.queued() < 12 {
            e.submit(gen_request(2, NoiseMode::Eta(0.0), 1, next_seed)).unwrap();
            next_seed += 1;
        }
        e.tick().unwrap();
        ticks += 1;
        long_done = e.take_completed().iter().any(|r| r.id == long_id);
        // bound: 16 lanes / max_batch 4 = 4 ticks per full rotation;
        // 12 steps * 4 = 48 ticks plus slack
        assert!(ticks < 120, "long request starved: {ticks} ticks and counting");
    }
    assert!(ticks >= long_steps, "finished impossibly fast");
}

#[test]
fn ddpm_same_seed_same_result_different_seed_differs() {
    // stochastic path must also be reproducible (noise is seeded per lane)
    let mut e = engine(4, 8, 8);
    let a = e.submit(gen_request(5, NoiseMode::Eta(1.0), 1, 10)).unwrap();
    let resp_a = e.run_until_idle().unwrap();
    let img_a = outputs(resp_a.iter().find(|r| r.id == a).unwrap());

    let mut e2 = engine(4, 8, 8);
    let b = e2.submit(gen_request(5, NoiseMode::Eta(1.0), 1, 10)).unwrap();
    let resp_b = e2.run_until_idle().unwrap();
    let img_b = outputs(resp_b.iter().find(|r| r.id == b).unwrap());
    assert_eq!(img_a, img_b);
}

/// §4.3's point, end to end through the engine: at S=10 the three update
/// kernels genuinely disagree; at S=100 (small-step limit) the Eq.-13 and
/// Eq.-15 discretisations converge onto the same ODE solution.
#[test]
fn kernels_differ_at_s10_and_agree_at_s100() {

    let run = |steps: usize, sampler: SamplerKind| -> Vec<f32> {
        let mut e = engine(4, 8, 8);
        let id = e
            .submit(gen_request_with(steps, NoiseMode::Eta(0.0), 1, 2024, sampler))
            .unwrap();
        let resp = e.run_until_idle().unwrap();
        outputs(resp.iter().find(|r| r.id == id).unwrap()).remove(0)
    };
    let rms = |a: &[f32], b: &[f32]| -> f64 {
        let s: f64 = a
            .iter()
            .zip(b)
            .map(|(x, y)| ((x - y) as f64).powi(2))
            .sum::<f64>();
        (s / a.len() as f64).sqrt()
    };

    let d10 = run(10, SamplerKind::Ddim);
    let p10 = run(10, SamplerKind::PfOde);
    let a10 = run(10, SamplerKind::Ab2);
    let rms_pf_10 = rms(&d10, &p10);
    let rms_ab_10 = rms(&d10, &a10);
    assert!(rms_pf_10 > 1e-4, "S=10: PF-ODE should differ from DDIM, rms {rms_pf_10}");
    assert!(rms_ab_10 > 1e-4, "S=10: AB2 should differ from DDIM, rms {rms_ab_10}");

    let d100 = run(100, SamplerKind::Ddim);
    let p100 = run(100, SamplerKind::PfOde);
    let rms_pf_100 = rms(&d100, &p100);
    assert!(
        rms_pf_100 < 0.5 * rms_pf_10,
        "S=100: Eq.13 vs Eq.15 should shrink toward the shared ODE \
         (rms {rms_pf_100} vs S=10 rms {rms_pf_10})"
    );
    assert!(rms_pf_100 < 0.1, "S=100 disagreement still large: {rms_pf_100}");
}

/// Lanes running *different* update kernels must batch correctly in one
/// tick: each request's result matches its solo run, and the AB2 lane's ε
/// history survives the engine's swap_remove/round-robin shuffling.
#[test]
fn heterogeneous_kernels_batch_in_one_tick() {
    let steps = 6usize;
    let solo = |sampler: SamplerKind| -> Vec<f32> {
        let mut e = engine(8, 8, 8);
        let id = e
            .submit(gen_request_with(steps, NoiseMode::Eta(0.0), 1, 77, sampler))
            .unwrap();
        let resp = e.run_until_idle().unwrap();
        outputs(resp.iter().find(|r| r.id == id).unwrap()).remove(0)
    };
    let solo_imgs: Vec<Vec<f32>> = SamplerKind::ALL.iter().map(|&k| solo(k)).collect();

    let mut e = engine(8, 8, 8);
    let ids: Vec<_> = SamplerKind::ALL
        .iter()
        .map(|&k| e.submit(gen_request_with(steps, NoiseMode::Eta(0.0), 1, 77, k)).unwrap())
        .collect();
    // one tick admits and advances all three lanes together
    assert!(e.tick().unwrap());
    assert_eq!(e.active_lanes(), 3, "all kernels resident in one batch");
    let resp = e.run_until_idle().unwrap();

    for ((&id, want), kind) in ids.iter().zip(&solo_imgs).zip(SamplerKind::ALL) {
        let got = outputs(resp.iter().find(|r| r.id == id).unwrap()).remove(0);
        let max_diff = got
            .iter()
            .zip(want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_diff < 1e-3,
            "{kind:?}: batched with other kernels changed the result, diff {max_diff}"
        );
    }
    // same prior, same model, different committed updates: results differ
    let d = outputs(resp.iter().find(|r| r.id == ids[0]).unwrap()).remove(0);
    let p = outputs(resp.iter().find(|r| r.id == ids[1]).unwrap()).remove(0);
    let a = outputs(resp.iter().find(|r| r.id == ids[2]).unwrap()).remove(0);
    assert_ne!(d, p);
    assert_ne!(d, a);

    // per-kernel accounting: each kernel stepped `steps` times
    let m = e.metrics();
    assert_eq!(m.kernel_steps, [steps as u64, steps as u64, steps as u64]);
    assert_eq!(m.kernel_steps.iter().sum::<u64>(), m.steps_executed);
}

/// THE pipelining-correctness property: with the tick plan fixed, depth
/// only changes *when* sub-batches execute, never what they compute — so
/// a pipelined engine (depth ≥ 2, executor thread, ping-pong buffers)
/// must be **bitwise** identical to the serial engine (depth 1) on a
/// heterogeneous-kernel, mixed-length, partly stochastic workload whose
/// off-bucket lane counts force multi-sub-batch ticks.
#[test]
fn pipelined_depth_matches_serial_bitwise() {
    let run = |depth: usize| -> Vec<(u64, Vec<Vec<f32>>)> {
        let cfg = ServeConfig {
            artifact_root: artifacts_root(),
            dataset: "sprites".into(),
            max_batch: 16,
            queue_capacity: 32,
            max_lanes: 32,
            pipeline_depth: depth,
            ..Default::default()
        };
        let mut e = Engine::new(cfg).unwrap();
        // 3+2+2+2 = 9 lanes resident at once: the planner splits 9 → 8+1,
        // so every tick exercises multiple sub-batches through the pipe;
        // mixed kernels + an η=1 request cover host integration and the
        // per-lane noise streams
        let mut ids = Vec::new();
        ids.push(e.submit(gen_request_with(6, NoiseMode::Eta(0.0), 3, 5, SamplerKind::Ddim)).unwrap());
        ids.push(e.submit(gen_request_with(9, NoiseMode::Eta(0.0), 2, 6, SamplerKind::PfOde)).unwrap());
        ids.push(e.submit(gen_request_with(4, NoiseMode::Eta(0.0), 2, 7, SamplerKind::Ab2)).unwrap());
        ids.push(e.submit(gen_request_with(7, NoiseMode::Eta(1.0), 2, 8, SamplerKind::Ddim)).unwrap());
        let resp = e.run_until_idle().unwrap();
        let m = e.metrics();
        assert_eq!(m.sub_batches, m.executable_calls);
        assert!(
            m.sub_batches_per_tick() > 1.0,
            "workload was meant to force decomposed ticks, got {}",
            m.sub_batches_per_tick()
        );
        if depth == 1 {
            assert_eq!(m.overlap_frac(), 0.0, "serial engines cannot overlap");
        }
        ids.iter()
            .map(|&id| (id, outputs(resp.iter().find(|r| r.id == id).unwrap())))
            .collect()
    };
    let serial = run(1);
    for depth in [2usize, 3] {
        let pipelined = run(depth);
        assert_eq!(
            serial, pipelined,
            "pipeline depth {depth} changed sample bits vs serial"
        );
    }
}

/// The planner's occupancy win, observed end-to-end: 9 equal-length lanes
/// at max_batch 16 run 8+1 (occupancy 1.0, zero padding) instead of one
/// padded bucket-16 call — while `max_padding_waste: 1.0` restores the
/// old single-bucket policy exactly.
#[test]
fn planner_raises_occupancy_at_off_bucket_counts() {
    let run = |max_waste: f64| {
        let cfg = ServeConfig {
            artifact_root: artifacts_root(),
            dataset: "sprites".into(),
            max_batch: 16,
            queue_capacity: 16,
            max_lanes: 32,
            max_padding_waste: max_waste,
            ..Default::default()
        };
        let mut e = Engine::new(cfg).unwrap();
        e.submit(gen_request(5, NoiseMode::Eta(0.0), 9, 42)).unwrap();
        e.run_until_idle().unwrap();
        e.metrics()
    };
    let old = run(1.0);
    assert_eq!(old.sub_batches, 5, "single-bucket policy: one call per tick");
    assert_eq!(old.padded_lanes, 5 * (16 - 9));
    assert!((old.occupancy() - 9.0 / 16.0).abs() < 1e-9, "occ {}", old.occupancy());

    let planned = run(0.25);
    assert_eq!(planned.sub_batches, 10, "9 lanes split 8+1 each tick");
    assert_eq!(planned.padded_lanes, 0);
    assert!((planned.occupancy() - 1.0).abs() < 1e-9, "occ {}", planned.occupancy());
    assert!(planned.padding_waste() < old.padding_waste());
    assert_eq!(planned.steps_executed, old.steps_executed);
}

/// The acceptance-criteria wire shape, minus TCP: a JSON `"sampler":"ab2"`
/// request parses, admits, and completes through `run_until_idle`.
#[test]
fn ab2_json_request_runs_to_completion() {
    let v = ddim_serve::json::parse(
        r#"{"op":"generate","dataset":"sprites","steps":8,"eta":0.0,
            "count":2,"seed":11,"sampler":"ab2","return_images":true}"#,
    )
    .unwrap();
    let req = Request::from_json(&v).unwrap();
    assert_eq!(req.sampler, SamplerKind::Ab2);
    let mut e = engine(8, 8, 8);
    let id = e.submit(req).unwrap();
    let resp = e.run_until_idle().unwrap();
    let imgs = outputs(resp.iter().find(|r| r.id == id).unwrap());
    assert_eq!(imgs.len(), 2);
    assert!(imgs[0].iter().all(|v| v.is_finite()));
    let m = e.metrics();
    assert_eq!(m.kernel_steps[SamplerKind::Ab2.index()], 16, "2 lanes x 8 steps");
}
