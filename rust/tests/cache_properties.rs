//! Property tests for the sample-cache subsystem: key sensitivity (every
//! sampling-relevant field perturbs the digest; delivery-shaping fields
//! never do) and LRU store invariants (byte budget never exceeded, strict
//! recency eviction, pinned in-flight entries survive pressure) — checked
//! against an executable model.

use std::sync::Arc;

use ddim_serve::cache::{CacheKey, CacheStore, CachedSample, Probe};
use ddim_serve::coordinator::request::{CacheMode, Request, RequestBody};
use ddim_serve::runtime::BackendKind;
use ddim_serve::sampler::SamplerKind;
use ddim_serve::schedule::{NoiseMode, TauKind};
use ddim_serve::testing::{check, Gen};

// ---------------------------------------------------------------- keys

fn rand_rows(g: &mut Gen) -> Vec<Vec<f32>> {
    let rows = g.int_in(1, 4).max(1);
    let dim = g.int_in(1, 16).max(1);
    (0..rows).map(|_| g.vec_f32(dim, -2.0, 2.0)).collect()
}

fn rand_request(g: &mut Gen) -> Request {
    let dataset = (*g.choose(&["sprites", "blobs", "digits"])).to_string();
    let mode = match g.int_in(0, 3) {
        0 => NoiseMode::Eta(0.0),
        1 => NoiseMode::Eta(g.f64_in(0.0, 2.0)),
        2 => NoiseMode::SigmaHat,
        _ => NoiseMode::Eta(1.0),
    };
    let body = match g.int_in(0, 2) {
        0 => RequestBody::Generate {
            count: g.int_in(1, 8).max(1),
            seed: g.rng.next_u64() >> 12,
        },
        1 => RequestBody::Decode { latents: rand_rows(g) },
        _ => RequestBody::Encode { images: rand_rows(g) },
    };
    Request {
        dataset,
        steps: g.int_in(1, 100).max(1),
        mode,
        tau: *g.choose(&[TauKind::Linear, TauKind::Quadratic]),
        sampler: *g.choose(&SamplerKind::ALL),
        body,
        return_images: g.bool(),
        cache: CacheMode::Use,
        qos: Default::default(),
    }
}

/// Apply one sampling-relevant perturbation; returns what changed.
fn perturb(g: &mut Gen, req: &mut Request) -> &'static str {
    loop {
        match g.int_in(0, 7) {
            0 => {
                req.dataset.push('x');
                return "dataset";
            }
            1 => {
                req.steps += 1;
                return "steps";
            }
            2 => {
                req.mode = match req.mode {
                    NoiseMode::Eta(e) => {
                        if e < 1.5 {
                            NoiseMode::Eta(e + 0.125)
                        } else {
                            NoiseMode::SigmaHat
                        }
                    }
                    NoiseMode::SigmaHat => NoiseMode::Eta(0.5),
                };
                return "mode";
            }
            3 => {
                req.tau = match req.tau {
                    TauKind::Linear => TauKind::Quadratic,
                    TauKind::Quadratic | TauKind::Opt => TauKind::Linear,
                };
                return "tau";
            }
            4 => {
                let cur = req.sampler;
                req.sampler = *SamplerKind::ALL
                    .iter()
                    .find(|&&k| k != cur)
                    .expect("three kernels exist");
                return "sampler";
            }
            5 => match &mut req.body {
                RequestBody::Generate { seed, .. } => {
                    *seed ^= 1;
                    return "seed";
                }
                RequestBody::Decode { latents } | RequestBody::Encode { images: latents } => {
                    let r = g.int_in(0, latents.len() - 1);
                    let c = g.int_in(0, latents[r].len() - 1);
                    latents[r][c] = f32::from_bits(latents[r][c].to_bits() ^ 1);
                    return "state bit";
                }
            },
            6 => match &mut req.body {
                RequestBody::Generate { count, .. } => {
                    *count += 1;
                    return "count";
                }
                RequestBody::Decode { latents } | RequestBody::Encode { images: latents } => {
                    latents.push(vec![0.25; latents[0].len()]);
                    return "row count";
                }
            },
            7 => {
                // flip the body *kind* while keeping the payload bits
                req.body = match std::mem::replace(
                    &mut req.body,
                    RequestBody::Generate { count: 1, seed: 0 },
                ) {
                    RequestBody::Decode { latents } => RequestBody::Encode { images: latents },
                    RequestBody::Encode { images } => RequestBody::Decode { latents: images },
                    original @ RequestBody::Generate { .. } => {
                        req.body = original;
                        continue; // not applicable; redraw
                    }
                };
                return "body kind";
            }
            _ => unreachable!(),
        }
    }
}

#[test]
fn property_key_changes_with_every_sampling_relevant_field() {
    check("cache_key_sensitivity", 200, |g| {
        let base = rand_request(g);
        let digest = g.rng.next_u64();
        let backend = *g.choose(&[BackendKind::Reference, BackendKind::Xla]);
        let base_key = CacheKey::of(&base, digest, backend, 0);

        // delivery-shaping fields are excluded from the digest
        let mut delivery = base.clone();
        delivery.return_images = !delivery.return_images;
        delivery.cache = CacheMode::Bypass;
        if CacheKey::of(&delivery, digest, backend, 0) != base_key {
            return Err("return_images / cache directive leaked into the key".into());
        }

        // any sampling-relevant perturbation must move the digest
        let mut p = base.clone();
        let what = perturb(g, &mut p);
        if CacheKey::of(&p, digest, backend, 0) == base_key {
            return Err(format!("perturbing {what} did not change the key: {p:?}"));
        }

        // environment axes count too
        if CacheKey::of(&base, digest ^ 1, backend, 0) == base_key {
            return Err("manifest digest did not change the key".into());
        }
        let other_backend = match backend {
            BackendKind::Reference => BackendKind::Xla,
            BackendKind::Xla => BackendKind::Reference,
        };
        if CacheKey::of(&base, digest, other_backend, 0) == base_key {
            return Err("backend kind did not change the key".into());
        }
        Ok(())
    });
}

// --------------------------------------------------------------- store

/// Executable model of one LRU shard: ready entries carry (bytes, stamp),
/// in-flight entries are pinned. Mirrors the store's documented policy
/// exactly; the property asserts the real store never diverges.
#[derive(Default)]
struct Model {
    entries: Vec<(u128, ModelSlot)>,
    bytes: usize,
    stamp: u64,
}

enum ModelSlot {
    InFlight,
    Ready { bytes: usize, stamp: u64 },
}

impl Model {
    fn find(&self, key: u128) -> Option<usize> {
        self.entries.iter().position(|(k, _)| *k == key)
    }

    fn reserve(&mut self, key: u128) {
        if self.find(key).is_none() {
            self.entries.push((key, ModelSlot::InFlight));
        }
    }

    fn publish(&mut self, key: u128, cost: usize, budget: usize) {
        if cost > budget {
            if let Some(i) = self.find(key) {
                if matches!(self.entries[i].1, ModelSlot::InFlight) {
                    self.entries.remove(i);
                }
            }
            return;
        }
        let stamp = self.stamp;
        self.stamp += 1;
        if let Some(i) = self.find(key) {
            if let ModelSlot::Ready { bytes, .. } = self.entries[i].1 {
                self.bytes -= bytes;
            }
            self.entries.remove(i);
        }
        self.entries.push((key, ModelSlot::Ready { bytes: cost, stamp }));
        self.bytes += cost;
        while self.bytes > budget {
            // strict recency: evict the ready entry with the oldest stamp
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter_map(|(i, (_, s))| match s {
                    ModelSlot::Ready { stamp, .. } => Some((*stamp, i)),
                    ModelSlot::InFlight => None,
                })
                .min()
                .map(|(_, i)| i)
                .expect("bytes > 0 implies a ready entry");
            if let ModelSlot::Ready { bytes, .. } = self.entries[victim].1 {
                self.bytes -= bytes;
            }
            self.entries.remove(victim);
        }
    }

    fn get(&mut self, key: u128) -> bool {
        let stamp = self.stamp;
        match self.find(key) {
            Some(i) => match &mut self.entries[i].1 {
                ModelSlot::Ready { stamp: s, .. } => {
                    *s = stamp;
                    self.stamp += 1;
                    true
                }
                ModelSlot::InFlight => false,
            },
            None => false,
        }
    }

    fn cancel(&mut self, key: u128) {
        if let Some(i) = self.find(key) {
            if matches!(self.entries[i].1, ModelSlot::InFlight) {
                self.entries.remove(i);
            }
        }
    }

    fn probe(&self, key: u128) -> Probe {
        match self.find(key) {
            None => Probe::Absent,
            Some(i) => match self.entries[i].1 {
                ModelSlot::InFlight => Probe::InFlight,
                ModelSlot::Ready { .. } => Probe::Ready,
            },
        }
    }
}

fn sample_of_rows(rows: usize, dim: usize) -> Arc<CachedSample> {
    Arc::new(CachedSample {
        outputs: (0..rows).map(|r| vec![r as f32 * 0.5; dim]).collect(),
        steps_executed: rows * dim,
    })
}

#[test]
fn property_single_shard_store_matches_lru_model_exactly() {
    check("cache_store_lru_model", 150, |g| {
        // budget sized so a handful of samples fit — eviction is frequent
        let unit = sample_of_rows(1, 8).cost_bytes();
        let budget = unit * g.int_in(1, 6).max(1);
        let store = CacheStore::with_shards(budget, 1);
        let mut model = Model::default();
        let universe: Vec<u128> = (0..8).collect();
        let ops = g.int_in(10, 200);
        for step in 0..ops {
            let key = *g.choose(&universe);
            match g.int_in(0, 3) {
                0 => {
                    store.reserve(CacheKey(key));
                    model.reserve(key);
                }
                1 => {
                    let rows = g.int_in(1, 4).max(1);
                    let sample = sample_of_rows(rows, 8);
                    model.publish(key, sample.cost_bytes(), budget);
                    store.publish(CacheKey(key), sample);
                }
                2 => {
                    let got = store.get(CacheKey(key)).is_some();
                    let want = model.get(key);
                    if got != want {
                        return Err(format!("op {step}: get({key}) = {got}, model {want}"));
                    }
                }
                _ => {
                    store.cancel(CacheKey(key));
                    model.cancel(key);
                }
            }
            if store.bytes() > budget {
                return Err(format!(
                    "op {step}: bytes {} exceeded budget {budget}",
                    store.bytes()
                ));
            }
            if store.bytes() != model.bytes {
                return Err(format!(
                    "op {step}: bytes {} diverged from model {}",
                    store.bytes(),
                    model.bytes
                ));
            }
            for &k in &universe {
                let got = store.probe(CacheKey(k));
                let want = model.probe(k);
                if got != want {
                    return Err(format!("op {step}: probe({k}) = {got:?}, model {want:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn property_sharded_store_keeps_global_budget_and_pins() {
    check("cache_store_sharded_budget", 100, |g| {
        let unit = sample_of_rows(1, 16).cost_bytes();
        let shards = g.int_in(2, 8).max(2);
        let budget = unit * shards * g.int_in(1, 4).max(1);
        let store = CacheStore::with_shards(budget, shards);
        // pin a few in-flight keys up front
        let pinned: Vec<u128> = (1000..1000 + g.int_in(1, 5).max(1) as u128).collect();
        for &k in &pinned {
            store.reserve(CacheKey(k));
        }
        let ops = g.int_in(20, 300);
        for _ in 0..ops {
            let key = g.rng.next_below(64) as u128;
            let rows = g.int_in(1, 3).max(1);
            store.publish(CacheKey(key), sample_of_rows(rows, 16));
            if g.bool() {
                let _ = store.get(CacheKey(g.rng.next_below(64) as u128));
            }
            if store.bytes() > budget {
                return Err(format!("bytes {} > budget {budget}", store.bytes()));
            }
        }
        for &k in &pinned {
            if store.probe(CacheKey(k)) != Probe::InFlight {
                return Err(format!("pinned in-flight key {k} was evicted under pressure"));
            }
        }
        if store.inflight() != pinned.len() {
            return Err(format!(
                "inflight() {} != pinned {}",
                store.inflight(),
                pinned.len()
            ));
        }
        Ok(())
    });
}
