//! End-to-end server test: real TCP, real engine, fixture artifacts on the
//! hermetic reference backend. One process, ephemeral port, concurrent
//! clients — no `make artifacts`, no XLA, zero skips.

use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::server::Client;
use ddim_serve::coordinator::Server;
use ddim_serve::jobj;
use ddim_serve::json::Value;
use ddim_serve::testing::fixtures;

#[test]
fn server_serves_generate_metrics_and_rejects_garbage() {
    let root = fixtures::root_string();
    let cfg = ServeConfig {
        artifact_root: root,
        dataset: "sprites".into(),
        listen: "127.0.0.1:0".into(),
        max_batch: 8,
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let addr = server.addr();

    // ping
    let mut c = Client::connect(addr).unwrap();
    let pong = c.roundtrip(&jobj![("op", "ping")]).unwrap();
    assert!(pong.get("ok").unwrap().as_bool().unwrap());

    // two concurrent generate clients with different configs
    let h1 = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.roundtrip(&jobj![
            ("op", "generate"),
            ("dataset", "sprites"),
            ("steps", 5.0),
            ("eta", 0.0),
            ("count", 2.0),
            ("seed", 1.0),
            ("return_images", true),
        ])
        .unwrap()
    });
    let h2 = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.roundtrip(&jobj![
            ("op", "generate"),
            ("dataset", "sprites"),
            ("steps", 9.0),
            ("eta", "hat"),
            ("count", 1.0),
            ("seed", 2.0),
        ])
        .unwrap()
    });
    let r1 = h1.join().unwrap();
    let r2 = h2.join().unwrap();
    assert!(r1.get("ok").unwrap().as_bool().unwrap(), "{r1:?}");
    assert!(r2.get("ok").unwrap().as_bool().unwrap(), "{r2:?}");
    let imgs = r1.get("outputs").unwrap().as_arr().unwrap();
    assert_eq!(imgs.len(), 2);
    assert_eq!(imgs[0].as_arr().unwrap().len(), 256);
    // stats-only response has empty outputs
    assert_eq!(r2.get("outputs").unwrap().as_arr().unwrap().len(), 0);

    // same request repeated must be byte-identical (eta=0 determinism over
    // the full wire path) — and with the sample cache on by default, the
    // repeat is served from the store without touching an engine
    let mut c3 = Client::connect(addr).unwrap();
    let req = jobj![
        ("op", "generate"),
        ("dataset", "sprites"),
        ("steps", 5.0),
        ("eta", 0.0),
        ("count", 1.0),
        ("seed", 42.0),
        ("return_images", true),
    ];
    let a = c3.roundtrip(&req).unwrap();
    let b = c3.roundtrip(&req).unwrap();
    assert_eq!(
        a.get("outputs").unwrap(),
        b.get("outputs").unwrap(),
        "wire-level determinism"
    );
    assert!(!a.get("cached").unwrap().as_bool().unwrap(), "first execution is fresh");
    assert!(b.get("cached").unwrap().as_bool().unwrap(), "repeat is a cache hit");
    // "cache":"bypass" opts out: same bits, but freshly executed
    let mut bypass_req = req.clone();
    if let Value::Obj(m) = &mut bypass_req {
        m.insert("cache".into(), Value::Str("bypass".into()));
    }
    let by = c3.roundtrip(&bypass_req).unwrap();
    assert!(!by.get("cached").unwrap().as_bool().unwrap(), "bypass re-executes");
    assert_eq!(a.get("outputs").unwrap(), by.get("outputs").unwrap());

    // malformed lines produce JSON errors, not disconnects
    let mut c4 = Client::connect(addr).unwrap();
    let e = c4.roundtrip(&jobj![("op", "generate"), ("dataset", "nope")]).unwrap();
    assert!(!e.get("ok").unwrap().as_bool().unwrap());
    let e = c4.roundtrip(&Value::Str("not even an object".into())).unwrap();
    assert!(!e.get("ok").unwrap().as_bool().unwrap());
    // connection still alive after errors
    let pong = c4.roundtrip(&jobj![("op", "ping")]).unwrap();
    assert!(pong.get("ok").unwrap().as_bool().unwrap());

    // metrics reflect the work, with histogram-merged quantiles and the
    // queue counters the engine always had but never exposed. Engine-side
    // counters only see the *executed* requests — the cache hit above
    // never reached one — while the "cache" object accounts for it.
    let m = c4.roundtrip(&jobj![("op", "metrics")]).unwrap();
    assert!(m.get("ok").unwrap().as_bool().unwrap());
    assert!(m.get("requests_completed").unwrap().as_usize().unwrap() >= 3);
    assert!(m.get("steps_executed").unwrap().as_usize().unwrap() >= 5 * 2 + 9);
    assert!(m.get("queue_accepted").unwrap().as_usize().unwrap() >= 3);
    let cache = m.get("cache").unwrap();
    assert!(cache.get("enabled").unwrap().as_bool().unwrap());
    assert!(cache.get("hits").unwrap().as_usize().unwrap() >= 1);
    assert!(cache.get("misses").unwrap().as_usize().unwrap() >= 3);
    assert!(cache.get("bypassed").unwrap().as_usize().unwrap() >= 1);
    assert!(cache.get("entries").unwrap().as_usize().unwrap() >= 1);
    assert!(cache.get("bytes").unwrap().as_usize().unwrap() > 0);
    assert!(m.get("latency_p50_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(
        m.get("latency_p95_s").unwrap().as_f64().unwrap()
            >= m.get("latency_p50_s").unwrap().as_f64().unwrap()
    );
    let shards = m.get("shards").unwrap().as_arr().unwrap();
    assert!(!shards.is_empty());
    assert_eq!(shards[0].get("dataset").unwrap().as_str().unwrap(), "sprites");

    // multi-model routing: a request for a *different* dataset spins up a
    // second engine lazily and serves it
    let mut c5 = Client::connect(addr).unwrap();
    let r = c5
        .roundtrip(&jobj![
            ("op", "generate"),
            ("dataset", "blobs"),
            ("steps", 4.0),
            ("eta", 0.0),
            ("count", 1.0),
            ("seed", 5.0),
            ("return_images", true),
        ])
        .unwrap();
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
    let m = c5.roundtrip(&jobj![("op", "metrics")]).unwrap();
    assert_eq!(m.get("engines").unwrap().as_usize().unwrap(), 2);
    // a dataset that doesn't exist is rejected with an error, not a hang
    let r = c5
        .roundtrip(&jobj![
            ("op", "generate"),
            ("dataset", "not_a_dataset"),
            ("steps", 4.0),
            ("count", 1.0),
            ("seed", 0.0),
        ])
        .unwrap();
    assert!(!r.get("ok").unwrap().as_bool().unwrap());

    server.shutdown();
}

/// Lazy multi-dataset bring-up at shard granularity: a request for a
/// second dataset spins up that dataset's whole pool (placement says 2
/// shards each), both datasets answer, and the metrics breakdown lists
/// every shard.
#[test]
fn lazy_bring_up_spawns_sharded_pools() {
    let root = fixtures::root_string();
    let cfg = ServeConfig {
        artifact_root: root,
        dataset: "sprites".into(),
        listen: "127.0.0.1:0".into(),
        max_batch: 8,
        placement: vec![("sprites".into(), 2), ("blobs".into(), 2)],
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    // only the default dataset's pool exists at startup
    let m = c.roundtrip(&jobj![("op", "metrics")]).unwrap();
    assert_eq!(m.get("engines").unwrap().as_usize().unwrap(), 2);
    assert_eq!(m.get("datasets").unwrap().as_usize().unwrap(), 1);

    // several requests across both datasets; blobs' pool comes up lazily
    let mut replies = Vec::new();
    for (i, ds) in ["sprites", "blobs", "sprites", "blobs"].iter().enumerate() {
        replies.push(
            c.roundtrip(&jobj![
                ("op", "generate"),
                ("dataset", *ds),
                ("steps", 4.0),
                ("eta", 0.0),
                ("count", 2.0),
                ("seed", i as f64),
            ])
            .unwrap(),
        );
    }
    for r in &replies {
        assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
    }

    let m = c.roundtrip(&jobj![("op", "metrics")]).unwrap();
    assert_eq!(m.get("engines").unwrap().as_usize().unwrap(), 4);
    assert_eq!(m.get("datasets").unwrap().as_usize().unwrap(), 2);
    assert!(m.get("queue_accepted").unwrap().as_usize().unwrap() >= 4);
    let shards = m.get("shards").unwrap().as_arr().unwrap();
    assert_eq!(shards.len(), 4);
    let blob_shards = shards
        .iter()
        .filter(|s| s.get("dataset").unwrap().as_str().unwrap() == "blobs")
        .count();
    assert_eq!(blob_shards, 2);
    // merged totals equal the sum of the per-shard breakdown
    let total: usize = shards
        .iter()
        .map(|s| s.get("requests_completed").unwrap().as_usize().unwrap())
        .sum();
    assert_eq!(m.get("requests_completed").unwrap().as_usize().unwrap(), total);
    assert!(total >= 4);

    server.shutdown();
}

/// Graceful shutdown: a request in flight when `shutdown` is called is
/// either drained to completion (inside drain_timeout) or answered with
/// an explicit "shutting down" error — the waiter is never abandoned.
#[test]
fn shutdown_answers_inflight_waiters() {
    let root = fixtures::root_string();
    let cfg = ServeConfig {
        artifact_root: root,
        dataset: "sprites".into(),
        listen: "127.0.0.1:0".into(),
        max_batch: 4,
        drain_timeout_ms: 10_000,
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let addr = server.addr();
    let worker = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.roundtrip(&jobj![
            ("op", "generate"),
            ("dataset", "sprites"),
            ("steps", 50.0),
            ("eta", 0.0),
            ("count", 4.0),
            ("seed", 1.0),
        ])
        .unwrap()
    });
    // let the request reach the engine, then pull the plug
    std::thread::sleep(std::time::Duration::from_millis(150));
    server.shutdown();
    let r = worker.join().unwrap();
    let ok = r.get("ok").unwrap().as_bool().unwrap();
    if ok {
        // drained to completion before the deadline
        assert!(r.get("steps_executed").unwrap().as_usize().unwrap() >= 1);
    } else {
        let msg = r.get("error").unwrap().as_str().unwrap().to_string();
        assert!(msg.contains("shutting down"), "unexpected error: {msg}");
    }
}

/// Acceptance: `{"op":"generate",...,"sampler":"ab2"}` round-trips through
/// the *sharded* server; per-kernel step counters surface in the merged
/// metrics and the per-shard breakdown; stochastic+host-kernel requests are
/// rejected on the wire.
#[test]
fn sampler_field_round_trips_through_sharded_server() {
    let root = fixtures::root_string();
    let cfg = ServeConfig {
        artifact_root: root,
        dataset: "sprites".into(),
        listen: "127.0.0.1:0".into(),
        max_batch: 8,
        shards: 2,
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let mut c = Client::connect(server.addr()).unwrap();

    // one request per kernel, eta=0, same seed
    let gen = |sampler: &str| {
        jobj![
            ("op", "generate"),
            ("dataset", "sprites"),
            ("steps", 6.0),
            ("eta", 0.0),
            ("count", 1.0),
            ("seed", 9.0),
            ("sampler", sampler),
            ("return_images", true),
        ]
    };
    let rd = c.roundtrip(&gen("ddim")).unwrap();
    let rp = c.roundtrip(&gen("pf_ode")).unwrap();
    let ra = c.roundtrip(&gen("ab2")).unwrap();
    for r in [&rd, &rp, &ra] {
        assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
        assert_eq!(r.get("steps_executed").unwrap().as_usize().unwrap(), 6);
    }
    // distinct kernels commit distinct trajectories from the same prior
    assert_ne!(rd.get("outputs").unwrap(), rp.get("outputs").unwrap());
    assert_ne!(rd.get("outputs").unwrap(), ra.get("outputs").unwrap());

    // unknown sampler and stochastic+host-kernel combinations are rejected
    let mut bad = gen("euler");
    let e = c.roundtrip(&bad).unwrap();
    assert!(!e.get("ok").unwrap().as_bool().unwrap());
    bad = jobj![
        ("op", "generate"),
        ("dataset", "sprites"),
        ("steps", 6.0),
        ("eta", 1.0),
        ("count", 1.0),
        ("seed", 9.0),
        ("sampler", "ab2"),
    ];
    let e = c.roundtrip(&bad).unwrap();
    assert!(!e.get("ok").unwrap().as_bool().unwrap());
    assert!(e.get("error").unwrap().as_str().unwrap().contains("DDIM-only"));
    // a >2^53 seed is rejected loudly instead of silently truncated
    let e = c
        .roundtrip(&jobj![
            ("op", "generate"),
            ("dataset", "sprites"),
            ("steps", 6.0),
            ("count", 1.0),
            ("seed", 9007199254740994.0),
        ])
        .unwrap();
    assert!(!e.get("ok").unwrap().as_bool().unwrap());
    assert!(e.get("error").unwrap().as_str().unwrap().contains("seed"));

    // merged metrics expose per-kernel steps; shard breakdown carries them too
    let m = c.roundtrip(&jobj![("op", "metrics")]).unwrap();
    assert!(m.get("ok").unwrap().as_bool().unwrap());
    assert_eq!(m.get("steps_pf_ode").unwrap().as_usize().unwrap(), 6);
    assert_eq!(m.get("steps_ab2").unwrap().as_usize().unwrap(), 6);
    assert!(m.get("steps_ddim").unwrap().as_usize().unwrap() >= 6);
    let shards = m.get("shards").unwrap().as_arr().unwrap();
    assert_eq!(shards.len(), 2);
    let shard_ab2: usize = shards
        .iter()
        .map(|s| s.get("steps_ab2").unwrap().as_usize().unwrap())
        .sum();
    assert_eq!(shard_ab2, 6, "per-shard kernel counters sum to the merged total");

    server.shutdown();
}
