//! End-to-end server test: real TCP, real engine, real artifacts. One
//! process, ephemeral port, concurrent clients.

use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::server::Client;
use ddim_serve::coordinator::Server;
use ddim_serve::jobj;
use ddim_serve::json::Value;

const ROOT: &str = env!("CARGO_MANIFEST_DIR");

#[test]
fn server_serves_generate_metrics_and_rejects_garbage() {
    let root = format!("{ROOT}/artifacts");
    if !std::path::Path::new(&root).join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing");
        return;
    }
    let cfg = ServeConfig {
        artifact_root: root,
        dataset: "sprites".into(),
        listen: "127.0.0.1:0".into(),
        max_batch: 8,
        ..Default::default()
    };
    let server = Server::start(cfg).unwrap();
    let addr = server.addr();

    // ping
    let mut c = Client::connect(addr).unwrap();
    let pong = c.roundtrip(&jobj![("op", "ping")]).unwrap();
    assert!(pong.get("ok").unwrap().as_bool().unwrap());

    // two concurrent generate clients with different configs
    let h1 = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.roundtrip(&jobj![
            ("op", "generate"),
            ("dataset", "sprites"),
            ("steps", 5.0),
            ("eta", 0.0),
            ("count", 2.0),
            ("seed", 1.0),
            ("return_images", true),
        ])
        .unwrap()
    });
    let h2 = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.roundtrip(&jobj![
            ("op", "generate"),
            ("dataset", "sprites"),
            ("steps", 9.0),
            ("eta", "hat"),
            ("count", 1.0),
            ("seed", 2.0),
        ])
        .unwrap()
    });
    let r1 = h1.join().unwrap();
    let r2 = h2.join().unwrap();
    assert!(r1.get("ok").unwrap().as_bool().unwrap(), "{r1:?}");
    assert!(r2.get("ok").unwrap().as_bool().unwrap(), "{r2:?}");
    let imgs = r1.get("outputs").unwrap().as_arr().unwrap();
    assert_eq!(imgs.len(), 2);
    assert_eq!(imgs[0].as_arr().unwrap().len(), 256);
    // stats-only response has empty outputs
    assert_eq!(r2.get("outputs").unwrap().as_arr().unwrap().len(), 0);

    // same request repeated must be byte-identical (eta=0 determinism over
    // the full wire path)
    let mut c3 = Client::connect(addr).unwrap();
    let req = jobj![
        ("op", "generate"),
        ("dataset", "sprites"),
        ("steps", 5.0),
        ("eta", 0.0),
        ("count", 1.0),
        ("seed", 42.0),
        ("return_images", true),
    ];
    let a = c3.roundtrip(&req).unwrap();
    let b = c3.roundtrip(&req).unwrap();
    assert_eq!(
        a.get("outputs").unwrap(),
        b.get("outputs").unwrap(),
        "wire-level determinism"
    );

    // malformed lines produce JSON errors, not disconnects
    let mut c4 = Client::connect(addr).unwrap();
    let e = c4.roundtrip(&jobj![("op", "generate"), ("dataset", "nope")]).unwrap();
    assert!(!e.get("ok").unwrap().as_bool().unwrap());
    let e = c4.roundtrip(&Value::Str("not even an object".into())).unwrap();
    assert!(!e.get("ok").unwrap().as_bool().unwrap());
    // connection still alive after errors
    let pong = c4.roundtrip(&jobj![("op", "ping")]).unwrap();
    assert!(pong.get("ok").unwrap().as_bool().unwrap());

    // metrics reflect the work
    let m = c4.roundtrip(&jobj![("op", "metrics")]).unwrap();
    assert!(m.get("ok").unwrap().as_bool().unwrap());
    assert!(m.get("requests_completed").unwrap().as_usize().unwrap() >= 4);
    assert!(m.get("steps_executed").unwrap().as_usize().unwrap() >= 5 * 2 + 9);

    // multi-model routing: a request for a *different* dataset spins up a
    // second engine lazily and serves it
    let mut c5 = Client::connect(addr).unwrap();
    let r = c5
        .roundtrip(&jobj![
            ("op", "generate"),
            ("dataset", "blobs"),
            ("steps", 4.0),
            ("eta", 0.0),
            ("count", 1.0),
            ("seed", 5.0),
            ("return_images", true),
        ])
        .unwrap();
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r:?}");
    let m = c5.roundtrip(&jobj![("op", "metrics")]).unwrap();
    assert_eq!(m.get("engines").unwrap().as_usize().unwrap(), 2);
    // a dataset that doesn't exist is rejected with an error, not a hang
    let r = c5
        .roundtrip(&jobj![
            ("op", "generate"),
            ("dataset", "not_a_dataset"),
            ("steps", 4.0),
            ("count", 1.0),
            ("seed", 0.0),
        ])
        .unwrap();
    assert!(!r.get("ok").unwrap().as_bool().unwrap());

    server.shutdown();
}
