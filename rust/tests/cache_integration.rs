//! End-to-end cache + coalescing behavior through the router on fixture
//! artifacts (hermetic reference backend):
//!
//! - N identical concurrent requests execute **once** (engine step
//!   counters prove it), every response is bitwise-identical, and the
//!   `hits`/`coalesced_waiters` metrics account for all N−1 followers;
//! - a repeated identical request is a pure store hit: no engine is
//!   touched, the wire says `cached:true`, and the bytes equal the
//!   uncached path's;
//! - `"cache":"bypass"` re-executes;
//! - stochastic (η > 0) requests are request-deterministic (seeded PCG64,
//!   content-derived decode noise seeds) and therefore cacheable;
//! - a manifest rewrite (artifact reload) invalidates the store.

use std::sync::{Arc, Barrier};

use ddim_serve::config::ServeConfig;
use ddim_serve::coordinator::request::{CacheMode, Request, RequestBody};
use ddim_serve::coordinator::{ResponseBody, Router};
use ddim_serve::sampler::SamplerKind;
use ddim_serve::schedule::{NoiseMode, TauKind};
use ddim_serve::testing::fixtures;

fn cfg(cache: bool, coalesce: bool, shards: usize) -> ServeConfig {
    ServeConfig {
        artifact_root: fixtures::root_string(),
        dataset: "sprites".into(),
        max_batch: 8,
        max_lanes: 64,
        queue_capacity: 256,
        shards,
        cache_enabled: cache,
        coalesce_enabled: coalesce,
        ..Default::default()
    }
}

fn gen_request(
    steps: usize,
    mode: NoiseMode,
    count: usize,
    seed: u64,
    cache: CacheMode,
) -> Request {
    Request {
        dataset: "sprites".into(),
        steps,
        mode,
        tau: TauKind::Linear,
        sampler: SamplerKind::Ddim,
        body: RequestBody::Generate { count, seed },
        return_images: true,
        cache,
        qos: Default::default(),
    }
}

fn outputs_of(resp: &ddim_serve::coordinator::Response) -> &Vec<Vec<f32>> {
    match &resp.body {
        ResponseBody::Ok { outputs } => outputs,
        other => panic!("request failed: {other:?}"),
    }
}

#[test]
fn concurrent_identical_requests_execute_once_and_match_uncached_bitwise() {
    const N: usize = 6;
    const STEPS: usize = 40;
    const COUNT: usize = 4;

    // ground truth: the same request through a cache-less router
    let plain = Router::start(cfg(false, false, 1)).unwrap();
    let truth = plain
        .call(gen_request(STEPS, NoiseMode::Eta(0.0), COUNT, 77, CacheMode::Use))
        .unwrap();
    assert!(!truth.cached);
    let truth_outputs = outputs_of(&truth).clone();
    assert_eq!(truth_outputs.len(), COUNT);
    plain.shutdown();

    // cached router, 2 shards: coalescing must hold across the pool
    let router = Arc::new(Router::start(cfg(true, true, 2)).unwrap());
    let barrier = Arc::new(Barrier::new(N));
    let mut handles = Vec::new();
    for _ in 0..N {
        let router = router.clone();
        let barrier = barrier.clone();
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            router
                .call(gen_request(STEPS, NoiseMode::Eta(0.0), COUNT, 77, CacheMode::Use))
                .unwrap()
        }));
    }
    let responses: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    for r in &responses {
        assert_eq!(outputs_of(r), &truth_outputs, "every waiter gets the uncached bits");
        assert_eq!(r.steps_executed, STEPS * COUNT);
    }

    // exactly one engine execution, proven by the step counters
    let (agg, _) = router.aggregate();
    assert_eq!(
        agg.steps_executed,
        (STEPS * COUNT) as u64,
        "N identical concurrent requests must execute once"
    );
    assert_eq!(agg.queue_accepted, 1, "only the leader reached an admission queue");
    let m = router.cache().metrics();
    assert_eq!(m.misses, 1);
    assert_eq!(
        m.hits + m.coalesced_waiters,
        (N - 1) as u64,
        "every follower was a hit or a coalesced waiter: {m:?}"
    );

    // a repeated identical request is a pure store hit: cached:true and
    // still bitwise-equal, with no new engine work
    let hit = router
        .call(gen_request(STEPS, NoiseMode::Eta(0.0), COUNT, 77, CacheMode::Use))
        .unwrap();
    assert!(hit.cached, "repeat must be served from the store");
    assert_eq!(outputs_of(&hit), &truth_outputs);
    assert_eq!(hit.steps_executed, STEPS * COUNT, "reports the producing run's cost");
    let (agg2, _) = router.aggregate();
    assert_eq!(agg2.steps_executed, agg.steps_executed, "no engine touched on a hit");
    assert_eq!(agg2.queue_accepted, agg.queue_accepted);
    assert_eq!(router.cache().metrics().hits, m.hits + 1);

    // a return_images:false variant is the same key — still a hit, with
    // the pixels filtered out of the response
    let mut quiet = gen_request(STEPS, NoiseMode::Eta(0.0), COUNT, 77, CacheMode::Use);
    quiet.return_images = false;
    let r = router.call(quiet).unwrap();
    assert!(r.cached);
    assert!(outputs_of(&r).is_empty());

    // "cache":"bypass" re-executes on a live engine
    let bypass = router
        .call(gen_request(STEPS, NoiseMode::Eta(0.0), COUNT, 77, CacheMode::Bypass))
        .unwrap();
    assert!(!bypass.cached);
    assert_eq!(
        outputs_of(&bypass),
        &truth_outputs,
        "determinism: bypass recomputes the same bits"
    );
    let (agg3, _) = router.aggregate();
    assert_eq!(
        agg3.steps_executed,
        agg.steps_executed + (STEPS * COUNT) as u64,
        "bypass must re-execute"
    );
    assert_eq!(router.cache().metrics().bypassed, 1);

    router.shutdown();
}

#[test]
fn stochastic_requests_are_request_deterministic_and_cacheable() {
    // η=1 generate: the noise stream is seeded by the request seed, so
    // two *separate* cache-less routers produce identical bits
    let a = Router::start(cfg(false, false, 1)).unwrap();
    let b = Router::start(cfg(false, false, 1)).unwrap();
    let req = || gen_request(12, NoiseMode::Eta(1.0), 2, 31, CacheMode::Use);
    let ra = a.call(req()).unwrap();
    let rb = b.call(req()).unwrap();
    assert_eq!(outputs_of(&ra), outputs_of(&rb), "η=1 generate is request-deterministic");

    // stochastic decode: noise seeds derive from the latent *content*
    // (not the engine-assigned request id), so identical requests match
    // even when their engine ids differ
    let latents = vec![vec![0.25f32; 256], vec![-0.5f32; 256]];
    let dec = |cache: CacheMode| Request {
        dataset: "sprites".into(),
        steps: 9,
        mode: NoiseMode::Eta(1.0),
        tau: TauKind::Linear,
        sampler: SamplerKind::Ddim,
        body: RequestBody::Decode { latents: latents.clone() },
        return_images: true,
        cache,
        qos: Default::default(),
    };
    let d1 = a.call(dec(CacheMode::Use)).unwrap();
    let d2 = a.call(dec(CacheMode::Use)).unwrap();
    assert_ne!(d1.id, d2.id, "distinct engine ids...");
    assert_eq!(outputs_of(&d1), outputs_of(&d2), "...same stochastic decode bits");
    a.shutdown();
    b.shutdown();

    // and therefore the cache may serve it: second identical decode hits
    let cached = Router::start(cfg(true, true, 1)).unwrap();
    let c1 = cached.call(dec(CacheMode::Use)).unwrap();
    let c2 = cached.call(dec(CacheMode::Use)).unwrap();
    assert!(!c1.cached && c2.cached);
    assert_eq!(outputs_of(&c1), outputs_of(&d1), "cached path == uncached path bitwise");
    assert_eq!(outputs_of(&c2), outputs_of(&d1));
    let (agg, _) = cached.aggregate();
    assert_eq!(agg.steps_executed, 18, "2 lanes × 9 steps, executed once");
    cached.shutdown();
}

#[test]
fn manifest_rewrite_invalidates_the_store() {
    // private artifact tree this test may mutate
    let dir = std::env::temp_dir()
        .join(format!("ddim-cache-invalidate-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    fixtures::write_into(&dir).unwrap();

    let mut config = cfg(true, true, 1);
    config.artifact_root = dir.to_string_lossy().into_owned();
    let router = Router::start(config).unwrap();

    // prime the store
    let r1 = router
        .call(gen_request(6, NoiseMode::Eta(0.0), 1, 5, CacheMode::Use))
        .unwrap();
    assert!(!r1.cached);
    assert_eq!(router.cache().metrics().entries, 1);
    // same tree on disk: refresh is a no-op
    assert!(!router.refresh_cache_manifest().unwrap());
    assert_eq!(router.cache().metrics().entries, 1);

    // rewrite the manifest with a changed model fingerprint (params) —
    // the digest moves, so the refresh must flush everything
    let manifest_path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&manifest_path).unwrap();
    let v = ddim_serve::json::parse(&text).unwrap();
    let ddim_serve::json::Value::Obj(mut top) = v else { panic!("manifest is an object") };
    let Some(ddim_serve::json::Value::Obj(datasets)) = top.get_mut("datasets") else {
        panic!("manifest has datasets")
    };
    let Some(ddim_serve::json::Value::Obj(ds)) = datasets.get_mut("sprites") else {
        panic!("sprites dataset present")
    };
    ds.insert("params".into(), ddim_serve::json::Value::Num(999_999.0));
    std::fs::write(
        &manifest_path,
        ddim_serve::json::to_string(&ddim_serve::json::Value::Obj(top)),
    )
    .unwrap();

    assert!(router.refresh_cache_manifest().unwrap(), "digest change detected");
    let m = router.cache().metrics();
    assert_eq!(m.entries, 0, "stale entries flushed");
    assert_eq!(m.bytes, 0);

    // the old result can no longer be served: the request re-executes
    let (before, _) = router.aggregate();
    let r2 = router
        .call(gen_request(6, NoiseMode::Eta(0.0), 1, 5, CacheMode::Use))
        .unwrap();
    assert!(!r2.cached);
    let (after, _) = router.aggregate();
    assert!(after.steps_executed > before.steps_executed);

    router.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
